#!/usr/bin/env bash
# Tier-1+ gate: build, tests, lints, decode perf smoke.
#
#   scripts/check.sh            full gate
#   SKIP_CLIPPY=1 scripts/check.sh   when clippy is unavailable
#
# The decode smoke writes BENCH_decode.json at the repo root
# (tokens/sec, mean step ms, batch occupancy) so the serving perf
# trajectory is tracked across PRs — see rust/README.md §Serving
# performance.
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
cd "$ROOT/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if [ "${SKIP_CLIPPY:-0}" != "1" ]; then
    echo "== cargo clippy -- -D warnings =="
    cargo clippy -- -D warnings
fi

echo "== decode perf smoke (BENCH_decode.json) =="
rm -f "$ROOT/BENCH_decode.json"
SPDF_BENCH_SMOKE=1 SPDF_BENCH_OUT="$ROOT/BENCH_decode.json" \
    cargo bench --bench perf_decode
# perf_decode exits 0 with a notice when artifacts are missing; a
# green gate must mean the smoke actually ran and left a datapoint
if [ ! -f "$ROOT/BENCH_decode.json" ]; then
    echo "check.sh: perf_decode smoke produced no BENCH_decode.json" \
         "(AOT artifacts missing? run \`make artifacts\`)" >&2
    exit 1
fi

echo "check.sh: all gates passed"
