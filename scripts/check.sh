#!/usr/bin/env bash
# Tier-1+ gate: build, tests, lints, perf smokes, perf-regression gate.
#
#   scripts/check.sh                 full gate
#   SKIP_CLIPPY=1 scripts/check.sh   when clippy is unavailable
#   SKIP_FMT=1 scripts/check.sh      when rustfmt is unavailable
#   SKIP_DOC=1 scripts/check.sh      when rustdoc is unavailable
#   SKIP_LINT=1 scripts/check.sh     skip the spdf lint pass (only
#                                    while bisecting — CI runs it)
#   BENCH_GATE_REFRESH=1 ...         refresh bench_baselines/ after an
#                                    intentional perf change (commit
#                                    the result)
#
# The smokes write BENCH_decode.json (tokens/sec, occupancy) and
# BENCH_serve_load.json (latency-under-load percentiles) at the repo
# root so the serving perf trajectory is tracked across PRs — see
# rust/README.md §Serving performance and §Load testing. The gate
# (scripts/bench_gate.py) then compares them against the committed
# bench_baselines/.
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
cd "$ROOT/rust"

# every datapoint the perf gate expects: stale copies are removed up
# front and each is re-verified after its smoke, so a green gate can
# never ride on a previous run's file
BENCH_FILES=(BENCH_decode.json BENCH_serve_load.json)

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if [ "${SKIP_FMT:-0}" != "1" ]; then
    if cargo fmt --version >/dev/null 2>&1; then
        echo "== cargo fmt --check =="
        cargo fmt --check
    else
        echo "check.sh: rustfmt unavailable, skipping format check" \
             "(set SKIP_FMT=1 to silence)"
    fi
fi

if [ "${SKIP_CLIPPY:-0}" != "1" ]; then
    echo "== cargo clippy --all-targets -- -D warnings =="
    cargo clippy --all-targets -- -D warnings
fi

if [ "${SKIP_DOC:-0}" != "1" ]; then
    # rustdoc warnings (broken intra-doc links, bad code fences) are
    # hard failures: docs/ARCHITECTURE.md routes readers into the
    # rendered API docs, so they must build clean
    echo '== RUSTDOCFLAGS="-D warnings" cargo doc --no-deps =='
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
fi

if [ "${SKIP_LINT:-0}" != "1" ]; then
    echo "== spdf lint (determinism & panic-safety & doc coverage) =="
    cargo run --release --quiet -- lint
fi

for f in "${BENCH_FILES[@]}"; do
    rm -f "$ROOT/$f"
done

echo "== decode perf smoke (BENCH_decode.json) =="
SPDF_BENCH_SMOKE=1 SPDF_BENCH_OUT="$ROOT/BENCH_decode.json" \
    cargo bench --bench perf_decode

echo "== serve-load perf smoke (BENCH_serve_load.json) =="
SPDF_BENCH_SMOKE=1 SPDF_BENCH_OUT="$ROOT/BENCH_serve_load.json" \
    cargo bench --bench perf_serve_load

# the benches exit 0 with a notice when artifacts are missing; a green
# gate must mean every smoke actually ran and left its datapoint
for f in "${BENCH_FILES[@]}"; do
    if [ ! -f "$ROOT/$f" ]; then
        echo "check.sh: perf smoke produced no $f" \
             "(AOT artifacts missing? run \`make artifacts\`)" >&2
        exit 1
    fi
done

# the serve-load smoke must carry the scheduling/shedding datapoints
# (goodput + shed rate per point, plus the past-the-knee shed leg,
# the multi-model registry leg, the fault-injection leg, the
# CSR-resident sparse leg, the draft-then-verify speculative leg and
# the paged-KV leg) — bench_gate.py gates on them, so their absence
# should fail loudly here with a better message than a
# missing-metric skip
python3 - "$ROOT/BENCH_serve_load.json" <<'EOF'
import json, sys

j = json.load(open(sys.argv[1]))
pts = j.get("points") or []
assert pts, "serve-load smoke wrote no sweep points"
missing = [i for i, p in enumerate(pts)
           if "shed_rate" not in p
           or "goodput_tokens_per_sec" not in p
           or "admission" not in p]
assert not missing, f"points {missing} lack shed/goodput datapoints"
shed = j.get("shed") or {}
for key in ("shed_rate", "p95_vs_unbounded",
            "goodput_tokens_per_sec"):
    assert key in shed, f"shed leg lacks {key}"
multi = j.get("multi_model") or {}
assert "aggregate" in multi, "multi-model leg lacks its aggregate"
per_model = multi.get("per_model") or []
assert len(per_model) >= 2, \
    "multi-model leg must cover >= 2 models"
for p in per_model:
    for key in ("model", "requests", "completed", "shed_rate",
                "goodput_tokens_per_sec", "latency_ms"):
        assert key in p, f"multi-model point lacks {key}"
fault = j.get("fault") or {}
rates = fault.get("rates") or []
assert rates, "fault leg missing or swept no rates"
assert any((r.get("fault_rate") or 0) > 0 for r in rates), \
    "fault leg never injected a nonzero fault rate"
for i, r in enumerate(rates):
    for variant in ("no_failover", "failover"):
        p = r.get(variant) or {}
        for key in ("requests", "completed", "failed", "retries",
                    "degraded", "goodput_tokens_per_sec"):
            assert key in p, \
                f"fault rate row {i} {variant} lacks {key}"
sparse = j.get("sparse") or {}
for key in ("sparsity", "sparse_slots", "step_scale",
            "csr_host_bytes", "dense_equiv_bytes", "flops_speedup",
            "required_speedup", "measured_speedup"):
    assert key in sparse, f"sparse leg lacks {key}"
for variant in ("dense", "s75"):
    p = sparse.get(variant) or {}
    for key in ("requests", "completed", "generated_tokens",
                "tokens_per_vsec"):
        assert key in p, f"sparse leg {variant} run lacks {key}"
spec = j.get("speculative") or {}
for key in ("draft", "verifier", "k", "acceptance_floor",
            "mean_acceptance", "tokens_per_verify", "bitwise_equal",
            "measured_speedup"):
    assert key in spec, f"speculative leg lacks {key}"
assert spec["bitwise_equal"] is True, \
    "speculative leg output diverged from plain dense"
for variant in ("dense", "spec"):
    p = spec.get(variant) or {}
    for key in ("requests", "completed", "generated_tokens",
                "tokens_per_vsec"):
        assert key in p, f"speculative leg {variant} run lacks {key}"
paged = j.get("paged") or {}
for key in ("page_size", "kv_pages", "full_peak_seated",
            "paged_peak_seated", "leaked_pages", "preemptions",
            "lost_tokens", "bitwise_equal"):
    assert key in paged, f"paged leg lacks {key}"
assert paged["leaked_pages"] == 0, \
    f"paged leg leaked {paged['leaked_pages']} pages"
assert paged["bitwise_equal"] is True, \
    "unconstrained paged run diverged from the monolithic loop"
for variant in ("full", "paged"):
    p = paged.get(variant) or {}
    for key in ("requests", "completed", "lost_tokens",
                "tokens_per_vsec", "goodput_tokens_per_sec"):
        assert key in p, f"paged leg {variant} run lacks {key}"
print(f"check.sh: serve-load smoke carries goodput/shed/multi-model/"
      f"fault/sparse/speculative/paged datapoints ({len(pts)} points "
      f"+ shed leg, shed rate {shed['shed_rate']:.0%}, "
      f"{len(per_model)} registry models, {len(rates)} fault rates, "
      f"sparse speedup {sparse['measured_speedup']:.2f}x, spec "
      f"acceptance {spec['mean_acceptance']:.2f}/verify vs floor "
      f"{spec['acceptance_floor']:.2f}, bitwise dense, paged seats "
      f"{paged['paged_peak_seated']} vs full "
      f"{paged['full_peak_seated']} at {paged['kv_pages']} pages)")
EOF

echo "== perf-regression gate (scripts/bench_gate.py) =="
python3 "$ROOT/scripts/bench_gate.py" "$ROOT"

echo "check.sh: all gates passed"
