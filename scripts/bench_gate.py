#!/usr/bin/env python3
"""Perf-regression gate over the BENCH_*.json serving datapoints.

scripts/check.sh runs the decode + serve-load smokes, then calls this
gate to compare the fresh datapoints against the committed baselines
in bench_baselines/. The gate fails (exit 1) when a gated metric
regresses by more than the tolerance:

  BENCH_decode.json      tokens/sec legs (higher is better) and the
                         serve latency p95 (lower is better)
  BENCH_serve_load.json  per-point latency/TTFT p95 (lower is better)
                         and goodput_tokens_per_sec (higher is
                         better), plus the absolute invariants that
                         the KV path's p95 is no worse than the
                         literal path's at budgets >= 32
                         (kv_p95_vs_literal), that shedding past the
                         knee keeps p95 at or below the unbounded run
                         (shed.p95_vs_unbounded), and that points
                         under unbounded admission report a zero
                         shed_rate. Every fresh point must carry the
                         shed_rate/goodput datapoints — the smoke is
                         required to produce them. The multi-model
                         leg (multi_model.*) is required too: its
                         per-model goodput is gated per model name,
                         its aggregate goodput relatively, and the
                         per-model requests/completed counts must sum
                         to the aggregate (a mismatch means the
                         registry loop lost or double-counted a
                         request). The fault leg (fault.rates) is
                         required as well: every rate row must carry
                         the no-failover/failover datapoint pair,
                         each pair must conserve outcomes (completed
                         + shed + expired + failed == requests), and
                         at every nonzero fault rate the failover
                         goodput must be at least the no-failover
                         goodput — failover that does not help is a
                         recovery regression, not noise. The sparse
                         leg (sparse.*) is required too: the
                         CSR-resident s75 run and its dense twin must
                         both complete every request, the CSR
                         residency must actually cost fewer host
                         bytes than the dense equivalent, and the
                         measured virtual-time speedup must be at
                         least the required floor (sqrt of the
                         theoretical FLOPs ratio) — all enforced
                         fresh-side, so a BENCH_GATE_REFRESH can
                         never bake a truncated or violating sparse
                         leg into the baseline. The speculative leg
                         (speculative.*) is required too: the spec
                         run's output must be bitwise equal to the
                         plain dense run, every verify must advance
                         its request (verifies never exceed emitted
                         tokens + completions), the acceptance
                         bookkeeping must conserve the emitted
                         tokens, and whenever the mean acceptance
                         clears the k·(1−s) break-even floor the
                         speculative virtual-time throughput must be
                         at least the dense run's — again all
                         fresh-side, so REFRESH can never bake a
                         violating speculative leg into the baseline.
                         The paged leg (paged.*) is required too: the
                         unconstrained paged run must be bitwise
                         equal to the monolithic loop, prompt-sized
                         reservation must seat strictly more
                         concurrent requests than full-context
                         reservation at the same page budget, no page
                         may leak from any arm, and on every paged
                         datapoint the completed-only goodput must
                         not exceed the raw throughput that counts
                         dropped work — all fresh-side, so REFRESH
                         can never bake a truncated or violating
                         paged leg into the baseline.

Usage:
    python3 scripts/bench_gate.py [ROOT]

Env knobs:
    BENCH_GATE_TOL      relative tolerance, default 0.25 (25%)
    BENCH_GATE_REFRESH  =1: overwrite bench_baselines/ with the fresh
                        datapoints and exit green — use after an
                        intentional perf change, then commit the new
                        baselines

A missing baseline passes with a bootstrap notice (the first machine
with a toolchain runs BENCH_GATE_REFRESH=1 and commits the result);
a missing *fresh* datapoint is a hard failure — the smoke must have
produced it.
"""

import json
import os
import sys
from pathlib import Path

TOL_DEFAULT = 0.25
BASELINE_DIR = "bench_baselines"

# file -> [(dotted metric path, direction)]
RELATIVE_SPECS = {
    "BENCH_decode.json": [
        ("engine.tokens_per_sec", "higher"),
        ("kv.tokens_per_sec", "higher"),
        ("serve.tokens_per_sec", "higher"),
        ("serve.latency_ms.p95", "lower"),
    ],
    "BENCH_serve_load.json": [
        ("kv_p95_vs_literal", "lower"),
        ("shed.p95_vs_unbounded", "lower"),
        ("shed.goodput_tokens_per_sec", "higher"),
        ("multi_model.aggregate.goodput_tokens_per_sec", "higher"),
        ("multi_model.aggregate.latency_ms.p95", "lower"),
        ("sparse.measured_speedup", "higher"),
        ("speculative.measured_speedup", "higher"),
        ("speculative.tokens_per_verify", "higher"),
    ],
}

# file -> [(dotted metric path, cap)]: current <= cap * (1 + tol),
# independent of any baseline
ABSOLUTE_SPECS = {
    "BENCH_serve_load.json": [
        ("kv_p95_vs_literal", 1.0),
        ("shed.p95_vs_unbounded", 1.0),
    ],
}

# serve-load points: per-point gated metrics
POINT_METRICS = [
    ("latency_ms.p95", "lower"),
    ("ttft_ms.p95", "lower"),
    ("goodput_tokens_per_sec", "higher"),
]

# keys every fresh serve-load point must carry (the smoke must
# produce the scheduling/shedding datapoints; old baselines may lack
# them and are skipped by the relative gates)
POINT_REQUIRED_KEYS = ["admission", "shed_rate",
                       "goodput_tokens_per_sec"]


def get_path(obj, dotted):
    """Resolve a dotted key path to a number, or None."""
    cur = obj
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur if isinstance(cur, (int, float)) else None


def compare_metric(label, current, baseline, direction, tol):
    """One relative comparison. Returns a failure string or None;
    metrics absent on either side are skipped (legs are optional —
    e.g. no KV artifacts in a pre-KV manifest)."""
    if current is None or baseline is None:
        return None
    if baseline <= 0:
        return None
    if direction == "higher":
        if current < baseline * (1.0 - tol):
            return (f"{label}: {current:.3f} < baseline "
                    f"{baseline:.3f} - {tol:.0%}")
    else:
        if current > baseline * (1.0 + tol):
            return (f"{label}: {current:.3f} > baseline "
                    f"{baseline:.3f} + {tol:.0%}")
    return None


def check_absolute(name, current, tol):
    """Baseline-independent invariants (e.g. KV p95 <= literal p95,
    zero shed rate under unbounded admission, required shed/goodput
    datapoints on every fresh point)."""
    failures = []
    for dotted, cap in ABSOLUTE_SPECS.get(name, []):
        value = get_path(current, dotted)
        if value is None:
            continue
        if value > cap * (1.0 + tol):
            failures.append(f"{name}:{dotted}: {value:.3f} exceeds "
                            f"{cap} + {tol:.0%}")
    if name == "BENCH_serve_load.json":
        failures.extend(check_shed_datapoints(name, current))
        failures.extend(check_multi_model_datapoints(name, current))
        failures.extend(check_fault_datapoints(name, current))
        failures.extend(check_sparse_datapoints(name, current))
        failures.extend(check_speculative_datapoints(name, current))
        failures.extend(check_paged_datapoints(name, current))
    return failures


SHED_REQUIRED_KEYS = ["shed_rate", "p95_vs_unbounded",
                      "goodput_tokens_per_sec"]


def check_shed_datapoints(name, current):
    """Structural + invariant checks on the fresh serve-load file:
    the past-the-knee shed leg must be present (otherwise a stale
    bench could silently drop it — and a refresh would bake the gap
    into the baseline, disabling the shed gates forever), every point
    must carry the scheduling/shedding datapoints, and a point
    measured under unbounded admission must report a zero shed rate
    (shedding with nothing to shed means the loop miscounted)."""
    failures = []
    shed = current.get("shed")
    if not isinstance(shed, dict):
        failures.append(f"{name}:shed: block missing — the smoke did "
                        "not run the past-the-knee shed leg")
    else:
        missing = [k for k in SHED_REQUIRED_KEYS if k not in shed]
        if missing:
            failures.append(f"{name}:shed: missing "
                            f"{','.join(missing)}")
    for i, p in enumerate(current.get("points") or []):
        missing = [k for k in POINT_REQUIRED_KEYS if k not in p]
        if missing:
            failures.append(
                f"{name}:points[{i}]: missing {','.join(missing)} — "
                "the smoke did not carry the shed/goodput datapoints")
            continue
        if p["admission"] == "unbounded" and p["shed_rate"] != 0:
            failures.append(
                f"{name}:points[{i}]: shed_rate {p['shed_rate']} "
                "under unbounded admission (must be 0)")
    return failures


# latency_ms is included because latency_ms.p95 is relative-gated per
# model — a fresh leg missing it would silently disable that gate
MULTI_MODEL_POINT_KEYS = ["model", "requests", "completed",
                          "shed_rate", "goodput_tokens_per_sec",
                          "latency_ms"]


def check_multi_model_datapoints(name, current):
    """Structural + invariant checks on the fresh multi-model leg:
    the block must be present and untruncated (otherwise a stale
    bench could silently drop it — and a refresh would bake the gap
    into the baseline, disabling the multi-model gates forever),
    every per-model point must carry the gated datapoints, and the
    per-model requests/completed counts must sum to the aggregate —
    a mismatch means the registry loop lost or double-counted a
    request."""
    failures = []
    multi = current.get("multi_model")
    if not isinstance(multi, dict):
        failures.append(f"{name}:multi_model: block missing — the "
                        "smoke did not run the multi-model leg")
        return failures
    agg = multi.get("aggregate")
    per_model = multi.get("per_model")
    if not isinstance(agg, dict):
        failures.append(f"{name}:multi_model.aggregate: missing")
    else:
        # the aggregate block feeds two RELATIVE_SPECS gates; a
        # keyless aggregate would silently skip them (and REFRESH
        # would bake the gap into the baseline)
        missing = [k for k in ("requests", "completed",
                               "goodput_tokens_per_sec", "latency_ms")
                   if k not in agg]
        if missing:
            failures.append(f"{name}:multi_model.aggregate: missing "
                            f"{','.join(missing)}")
    if not isinstance(per_model, list) or len(per_model) < 2:
        failures.append(
            f"{name}:multi_model.per_model: want >= 2 per-model "
            "points (the leg must actually multiplex models)")
        return failures
    for i, p in enumerate(per_model):
        missing = [k for k in MULTI_MODEL_POINT_KEYS if k not in p]
        if missing:
            failures.append(
                f"{name}:multi_model.per_model[{i}]: missing "
                f"{','.join(missing)}")
    if failures or not isinstance(agg, dict):
        return failures
    for key in ("requests", "completed"):
        total = sum(p[key] for p in per_model)
        if total != agg.get(key):
            failures.append(
                f"{name}:multi_model: per-model {key} sum {total} != "
                f"aggregate {agg.get(key)} (registry loop lost or "
                "double-counted requests)")
    return failures


# every fault-leg variant must carry the outcome counters and the
# gated goodput datapoint; a missing counter would silently disable
# the conservation/failover checks
FAULT_VARIANT_KEYS = ["requests", "completed", "shed", "expired",
                      "failed", "retries", "degraded",
                      "goodput_tokens_per_sec", "tokens_per_vsec"]


def check_fault_datapoints(name, current):
    """Structural + invariant checks on the fresh fault leg: the
    block must be present and untruncated (a stale bench could
    silently drop it — and a refresh would bake the gap into the
    baseline, disabling the fault gates forever), every rate row must
    carry the no-failover/failover pair with the outcome counters,
    each variant must conserve outcomes, and at every nonzero fault
    rate the failover run's goodput must be at least the no-failover
    run's — recovery that loses throughput is a regression."""
    failures = []
    fault = current.get("fault")
    if not isinstance(fault, dict):
        failures.append(f"{name}:fault: block missing — the smoke "
                        "did not run the fault-injection leg")
        return failures
    rates = fault.get("rates")
    if not isinstance(rates, list) or not rates:
        failures.append(f"{name}:fault.rates: missing or empty — the "
                        "leg must sweep at least one fault rate")
        return failures
    nonzero = 0
    for i, row in enumerate(rates):
        rate = row.get("fault_rate")
        if not isinstance(rate, (int, float)):
            failures.append(f"{name}:fault.rates[{i}]: missing "
                            "fault_rate")
            continue
        variants = {}
        for variant in ("no_failover", "failover"):
            point = row.get(variant)
            if not isinstance(point, dict):
                failures.append(f"{name}:fault.rates[{i}]: missing "
                                f"{variant} datapoint")
                continue
            missing = [k for k in FAULT_VARIANT_KEYS
                       if k not in point]
            if missing:
                failures.append(
                    f"{name}:fault.rates[{i}].{variant}: missing "
                    f"{','.join(missing)}")
                continue
            lost = (point["completed"] + point["shed"]
                    + point["expired"] + point["failed"])
            if lost != point["requests"]:
                failures.append(
                    f"{name}:fault.rates[{i}].{variant}: outcomes "
                    f"sum to {lost} != requests {point['requests']} "
                    "(the fault loop lost or double-counted a "
                    "request)")
                continue
            goodput = point["goodput_tokens_per_sec"]
            raw = point["tokens_per_vsec"]
            if goodput > raw * (1.0 + 1e-9):
                failures.append(
                    f"{name}:fault.rates[{i}].{variant}: goodput "
                    f"{goodput:.3f} exceeds raw throughput "
                    f"{raw:.3f} — completed-only tokens per second "
                    "cannot beat the count that includes dropped "
                    "work")
                continue
            variants[variant] = point
        if rate <= 0 or len(variants) != 2:
            continue
        nonzero += 1
        no_gp = variants["no_failover"]["goodput_tokens_per_sec"]
        fo_gp = variants["failover"]["goodput_tokens_per_sec"]
        if fo_gp < no_gp:
            failures.append(
                f"{name}:fault.rates[{i}]: failover goodput "
                f"{fo_gp:.3f} below no-failover {no_gp:.3f} at fault "
                f"rate {rate} (cross-model failover must not lose "
                "throughput)")
    if nonzero == 0 and not failures:
        failures.append(f"{name}:fault.rates: no nonzero fault rate "
                        "— the leg never actually injected faults")
    return failures


# the sparse block's scalar datapoints; a missing one would silently
# disable the speedup/residency checks below
SPARSE_REQUIRED_KEYS = ["sparsity", "sparse_slots", "step_scale",
                        "csr_host_bytes", "dense_equiv_bytes",
                        "flops_speedup", "required_speedup",
                        "measured_speedup"]

# each routed run (all-dense / all-s75) must carry the counters the
# completion check reads plus the virtual-time throughput the speedup
# is computed from
SPARSE_VARIANT_KEYS = ["requests", "completed", "generated_tokens",
                       "tokens_per_vsec"]


def check_sparse_datapoints(name, current):
    """Structural + invariant checks on the fresh sparse leg: the
    block must be present and untruncated (a stale bench could
    silently drop it — and a refresh would bake the gap into the
    baseline, disabling the sparsity gates forever), both routed runs
    must complete every request (the leg serves an unbounded queue),
    the CSR residency must actually cost fewer host bytes than the
    dense equivalent, and the measured virtual-time speedup of the
    s75 lane over the dense lane must be at least the required floor
    (sqrt of the theoretical FLOPs ratio) — the heterogeneous step
    costs must show up on the clock, not just in the config."""
    failures = []
    sparse = current.get("sparse")
    if not isinstance(sparse, dict):
        failures.append(f"{name}:sparse: block missing — the smoke "
                        "did not run the CSR-resident sparse leg")
        return failures
    missing = [k for k in SPARSE_REQUIRED_KEYS if k not in sparse]
    if missing:
        failures.append(f"{name}:sparse: missing "
                        f"{','.join(missing)}")
    for variant in ("dense", "s75"):
        point = sparse.get(variant)
        if not isinstance(point, dict):
            failures.append(f"{name}:sparse: missing {variant} "
                            "datapoint")
            continue
        absent = [k for k in SPARSE_VARIANT_KEYS if k not in point]
        if absent:
            failures.append(f"{name}:sparse.{variant}: missing "
                            f"{','.join(absent)}")
            continue
        if point["completed"] != point["requests"]:
            failures.append(
                f"{name}:sparse.{variant}: {point['completed']} of "
                f"{point['requests']} requests completed (the leg "
                "serves an unbounded queue — every request must "
                "finish)")
    if missing:
        return failures
    csr = get_path(sparse, "csr_host_bytes")
    dense = get_path(sparse, "dense_equiv_bytes")
    if csr is not None and dense is not None and csr >= dense:
        failures.append(
            f"{name}:sparse: CSR residency costs {csr} host bytes, "
            f"no better than the {dense}-byte dense equivalent — "
            "sparse storage that saves nothing is a residency "
            "regression")
    measured = get_path(sparse, "measured_speedup")
    required = get_path(sparse, "required_speedup")
    if measured is not None and required is not None \
            and measured < required:
        failures.append(
            f"{name}:sparse: measured speedup {measured:.3f} below "
            f"required {required:.3f} (the s75 lane's virtual-time "
            "throughput must beat dense by at least the sqrt of the "
            "FLOPs ratio)")
    return failures


# the speculative block's scalar datapoints; a missing one would
# silently disable the bitwise/break-even checks below
SPECULATIVE_REQUIRED_KEYS = ["draft", "verifier", "k",
                             "draft_step_scale", "acceptance_floor",
                             "mean_acceptance", "acceptance_rate",
                             "tokens_per_verify", "drafted",
                             "accepted", "corrections", "verifies",
                             "wasted_drafts", "bitwise_equal",
                             "measured_speedup"]

# each routed run (plain dense / speculative) must carry the counters
# the completion/conservation checks read plus the virtual-time
# throughput the speedup is computed from
SPECULATIVE_VARIANT_KEYS = ["requests", "completed",
                            "generated_tokens", "tokens_per_vsec"]


def check_speculative_datapoints(name, current):
    """Structural + invariant checks on the fresh speculative leg:
    the block must be present and untruncated (a stale bench could
    silently drop it — and a refresh would bake the gap into the
    baseline, disabling the speculation gates forever), the spec
    run's output must be bitwise equal to the plain dense run, the
    draft lane must actually have proposed tokens, every verify must
    advance its request (the only verify that emits nothing is the
    terminal EOS one, so verifies can exceed the emitted tokens by at
    most one per completed request), the acceptance bookkeeping
    must conserve the emitted tokens, both runs must complete every
    request, and whenever the mean acceptance clears the k·(1−s)
    break-even floor the speculative virtual-time throughput must be
    at least the dense run's — speculation is free to lose only when
    the draft is too wrong to pay for itself."""
    failures = []
    spec = current.get("speculative")
    if not isinstance(spec, dict):
        failures.append(f"{name}:speculative: block missing — the "
                        "smoke did not run the speculative leg")
        return failures
    missing = [k for k in SPECULATIVE_REQUIRED_KEYS if k not in spec]
    if missing:
        failures.append(f"{name}:speculative: missing "
                        f"{','.join(missing)}")
    points = {}
    for variant in ("dense", "spec"):
        point = spec.get(variant)
        if not isinstance(point, dict):
            failures.append(f"{name}:speculative: missing {variant} "
                            "datapoint")
            continue
        absent = [k for k in SPECULATIVE_VARIANT_KEYS
                  if k not in point]
        if absent:
            failures.append(f"{name}:speculative.{variant}: missing "
                            f"{','.join(absent)}")
            continue
        if point["completed"] != point["requests"]:
            failures.append(
                f"{name}:speculative.{variant}: {point['completed']} "
                f"of {point['requests']} requests completed (the leg "
                "serves an unbounded queue — every request must "
                "finish, speculating or not)")
            continue
        points[variant] = point
    if missing:
        return failures
    if spec.get("bitwise_equal") is not True:
        failures.append(
            f"{name}:speculative: bitwise_equal is "
            f"{spec.get('bitwise_equal')!r} — speculative greedy "
            "output MUST be bit-identical to the plain dense stream")
    drafted = get_path(spec, "drafted")
    verifies = get_path(spec, "verifies")
    if drafted is not None and verifies is not None \
            and (drafted <= 0 or verifies <= 0):
        failures.append(
            f"{name}:speculative: leg never engaged (drafted "
            f"{drafted}, verifies {verifies})")
    accepted = get_path(spec, "accepted")
    corrections = get_path(spec, "corrections")
    completed = get_path(points.get("spec", {}), "completed")
    if None not in (verifies, accepted, corrections, completed) \
            and verifies > accepted + corrections + completed:
        failures.append(
            f"{name}:speculative: verifies {verifies} > accepted "
            f"{accepted} + corrections {corrections} + completed "
            f"{completed} — a verify committed no progress (every "
            "verify commits the longest agreeing prefix plus a "
            "correction; only the terminal EOS verify emits nothing)")
    emitted = get_path(points.get("spec", {}), "generated_tokens")
    if None not in (accepted, corrections, emitted) \
            and accepted + corrections != emitted:
        failures.append(
            f"{name}:speculative: accepted {accepted} + corrections "
            f"{corrections} != generated_tokens {emitted} (the "
            "acceptance bookkeeping lost or invented a token)")
    mean = get_path(spec, "mean_acceptance")
    floor = get_path(spec, "acceptance_floor")
    speedup = get_path(spec, "measured_speedup")
    if None not in (mean, floor, speedup) and mean > floor \
            and speedup < 1.0:
        failures.append(
            f"{name}:speculative: mean acceptance {mean:.3f} clears "
            f"the k(1-s) break-even floor {floor:.3f} but the "
            f"speculative run is only {speedup:.3f}x dense on the "
            "virtual clock — winning drafts must show up as "
            "throughput")
    return failures


# the paged block's scalar datapoints; a missing one would silently
# disable the concurrency/leak/bitwise checks below
PAGED_REQUIRED_KEYS = ["page_size", "kv_pages", "requests",
                       "full_peak_seated", "paged_peak_seated",
                       "leaked_pages", "preemptions", "lost_tokens",
                       "bitwise_equal"]

# each reservation arm (full-context / prompt-reserve) must carry the
# counters the completion check reads plus both throughput datapoints
# the goodput invariant compares
PAGED_VARIANT_KEYS = ["requests", "completed", "generated_tokens",
                      "lost_tokens", "tokens_per_vsec",
                      "goodput_tokens_per_sec"]


def check_paged_datapoints(name, current):
    """Structural + invariant checks on the fresh paged-KV leg: the
    block must be present and untruncated (a stale bench could
    silently drop it — and a refresh would bake the gap into the
    baseline, disabling the paging gates forever), the unconstrained
    paged run must be bitwise equal to the monolithic loop, no page
    may leak from any arm, prompt-sized reservation must seat
    strictly more concurrent requests than full-context reservation
    at the same page budget, both arms must complete every request
    (the leg serves an unbounded queue — preempted requests requeue),
    and each arm's completed-only goodput must not exceed the raw
    throughput that counts dropped work."""
    failures = []
    paged = current.get("paged")
    if not isinstance(paged, dict):
        failures.append(f"{name}:paged: block missing — the smoke "
                        "did not run the paged-KV leg")
        return failures
    missing = [k for k in PAGED_REQUIRED_KEYS if k not in paged]
    if missing:
        failures.append(f"{name}:paged: missing "
                        f"{','.join(missing)}")
    for variant in ("full", "paged"):
        point = paged.get(variant)
        if not isinstance(point, dict):
            failures.append(f"{name}:paged: missing {variant} "
                            "datapoint")
            continue
        absent = [k for k in PAGED_VARIANT_KEYS if k not in point]
        if absent:
            failures.append(f"{name}:paged.{variant}: missing "
                            f"{','.join(absent)}")
            continue
        if point["completed"] != point["requests"]:
            failures.append(
                f"{name}:paged.{variant}: {point['completed']} of "
                f"{point['requests']} requests completed (the leg "
                "serves an unbounded queue — preempted requests "
                "requeue, so every request must finish)")
        goodput = point["goodput_tokens_per_sec"]
        raw = point["tokens_per_vsec"]
        if goodput > raw * (1.0 + 1e-9):
            failures.append(
                f"{name}:paged.{variant}: goodput {goodput:.3f} "
                f"exceeds raw throughput {raw:.3f} — completed-only "
                "tokens per second cannot beat the count that "
                "includes dropped work")
    if missing:
        return failures
    if paged.get("bitwise_equal") is not True:
        failures.append(
            f"{name}:paged: bitwise_equal is "
            f"{paged.get('bitwise_equal')!r} — the unconstrained "
            "paged run MUST decode bit-identically to the monolithic "
            "loop")
    leaked = get_path(paged, "leaked_pages")
    if leaked is not None and leaked != 0:
        failures.append(
            f"{name}:paged: {leaked} pages leaked — every page must "
            "return to the free list when its slot drains")
    full_seats = get_path(paged, "full_peak_seated")
    page_seats = get_path(paged, "paged_peak_seated")
    if None not in (full_seats, page_seats) \
            and page_seats <= full_seats:
        failures.append(
            f"{name}:paged: prompt reservation peaked at "
            f"{page_seats} concurrent seats, not strictly more than "
            f"full-context's {full_seats} at the same page budget — "
            "paging that buys no concurrency is a memory-accounting "
            "regression")
    return failures


def check_multi_model_relative(name, current, baseline, tol):
    """Relative per-model gates: goodput (higher is better) and e2e
    p95 (lower is better), paired by model name. Baselines predating
    the multi-model leg skip with a notice."""
    failures, notes = [], []
    cur = (current.get("multi_model") or {}).get("per_model") or []
    base = (baseline.get("multi_model") or {}).get("per_model") or []
    if not base:
        if cur:
            notes.append(f"{name}: baseline predates the multi-model "
                         "leg — refresh baselines to gate it")
        return failures, notes
    base_by_model = {p.get("model"): p for p in base}
    # a model present in the baseline but absent from the fresh leg
    # would silently stop being gated — fail instead (an intentional
    # registry change goes through BENCH_GATE_REFRESH)
    cur_models = {p.get("model") for p in cur}
    for dropped in sorted(m for m in base_by_model
                          if m not in cur_models):
        failures.append(
            f"{name}:multi_model: model {dropped} in baseline but "
            "missing from the fresh leg — its gates would be "
            "silently disabled (intentional? refresh baselines)")
    for p in cur:
        b = base_by_model.get(p.get("model"))
        if b is None:
            notes.append(f"{name}: model {p.get('model')} not in "
                         "baseline multi-model leg, skipping — "
                         "refresh baselines")
            continue
        for dotted, direction in [
            ("goodput_tokens_per_sec", "higher"),
            ("latency_ms.p95", "lower"),
        ]:
            label = (f"{name}:multi_model.per_model"
                     f"({p.get('model')}).{dotted}")
            fail = compare_metric(label, get_path(p, dotted),
                                  get_path(b, dotted), direction,
                                  tol)
            if fail:
                failures.append(fail)
    return failures, notes


def check_points(name, current, baseline, tol):
    """Pair serve-load sweep points by position (the sweep layout —
    rates x engines — is fixed by the bench) and gate the latency
    percentiles. Layout changes skip with a notice instead of
    misparing points."""
    failures, notes = [], []
    cur_pts = current.get("points") or []
    base_pts = baseline.get("points") or []
    if len(cur_pts) != len(base_pts):
        notes.append(f"{name}: point layout changed "
                     f"({len(base_pts)} -> {len(cur_pts)}), "
                     "skipping per-point gates — refresh baselines")
        return failures, notes
    for i, (c, b) in enumerate(zip(cur_pts, base_pts)):
        if c.get("engine") != b.get("engine") \
                or c.get("pattern") != b.get("pattern"):
            notes.append(f"{name}: point {i} identity changed, "
                         "skipping — refresh baselines")
            continue
        for dotted, direction in POINT_METRICS:
            label = (f"{name}:points[{i}]"
                     f"({c.get('engine')}).{dotted}")
            fail = compare_metric(label,
                                  get_path(c, dotted),
                                  get_path(b, dotted),
                                  direction, tol)
            if fail:
                failures.append(fail)
    return failures, notes


def check_file(name, current, baseline, tol):
    """All gates for one datapoint file. `baseline` may be None
    (bootstrap)."""
    failures = list(check_absolute(name, current, tol))
    notes = []
    if baseline is None:
        notes.append(f"{name}: no committed baseline — bootstrap "
                     "pass (run with BENCH_GATE_REFRESH=1 and commit "
                     f"{BASELINE_DIR}/{name})")
        return failures, notes
    for dotted, direction in RELATIVE_SPECS.get(name, []):
        fail = compare_metric(f"{name}:{dotted}",
                              get_path(current, dotted),
                              get_path(baseline, dotted),
                              direction, tol)
        if fail:
            failures.append(fail)
    if name == "BENCH_serve_load.json":
        pf, pn = check_points(name, current, baseline, tol)
        failures.extend(pf)
        notes.extend(pn)
        mf, mn = check_multi_model_relative(name, current, baseline,
                                            tol)
        failures.extend(mf)
        notes.extend(mn)
    return failures, notes


def load_json(path):
    with open(path) as f:
        return json.load(f)


def main(argv):
    root = Path(argv[1]) if len(argv) > 1 else Path(__file__) \
        .resolve().parent.parent
    tol = float(os.environ.get("BENCH_GATE_TOL", TOL_DEFAULT))
    refresh = os.environ.get("BENCH_GATE_REFRESH", "") == "1"
    baseline_dir = root / BASELINE_DIR

    all_failures, all_notes = [], []
    for name in sorted(RELATIVE_SPECS):
        fresh_path = root / name
        if not fresh_path.exists():
            all_failures.append(
                f"{name}: fresh datapoint missing — the bench smoke "
                "did not produce it")
            continue
        current = load_json(fresh_path)
        base_path = baseline_dir / name
        if refresh:
            # absolute invariants hold even when rebaselining — a
            # violating datapoint must never become the norm
            abs_failures = check_absolute(name, current, tol)
            if abs_failures:
                all_failures.extend(
                    f"{f} (refusing to refresh baseline)"
                    for f in abs_failures)
                continue
            baseline_dir.mkdir(parents=True, exist_ok=True)
            base_path.write_text(fresh_path.read_text())
            all_notes.append(f"{name}: baseline refreshed")
            continue
        baseline = load_json(base_path) if base_path.exists() else None
        failures, notes = check_file(name, current, baseline, tol)
        all_failures.extend(failures)
        all_notes.extend(notes)

    for note in all_notes:
        print(f"bench_gate: note: {note}")
    if all_failures:
        for fail in all_failures:
            print(f"bench_gate: FAIL: {fail}", file=sys.stderr)
        print(f"bench_gate: {len(all_failures)} regression(s) beyond "
              f"{tol:.0%} tolerance (intentional? rerun with "
              "BENCH_GATE_REFRESH=1 and commit the new baselines)",
              file=sys.stderr)
        return 1
    print(f"bench_gate: green (tolerance {tol:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
