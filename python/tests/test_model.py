"""L2 model semantics: shapes, SPDF mask invariants, optimization."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.GPTConfig("test", n_layers=2, d_model=32, n_heads=2,
                  vocab_size=64, ctx_len=32)


def _setup(sparsity=0.75, seed=0, use_pallas=False):
    key = jax.random.PRNGKey(seed)
    params = M.init_params(CFG, key)
    masks = {}
    for i, n in enumerate(M.masked_param_names(CFG)):
        u = jax.random.uniform(jax.random.PRNGKey(100 + i),
                               params[n].shape)
        masks[n] = (u >= sparsity).astype(jnp.float32)
        params[n] = params[n] * masks[n]
    zeros = {n: jnp.zeros_like(p) for n, p in params.items()}
    return params, dict(zeros), {n: jnp.zeros_like(p) for n, p
                                 in params.items()}, masks


def _batch(b=4, t=32, seed=0):
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (b, t), 0, CFG.vocab_size)
    targets = jnp.roll(tokens, -1, axis=1)
    loss_mask = jnp.ones((b, t), jnp.float32)
    return tokens, targets, loss_mask


class TestForward:
    def test_logit_shape(self):
        params, _, _, _ = _setup()
        tokens, _, _ = _batch()
        logits = M.gpt_forward(CFG, params, tokens, use_pallas=False)
        assert logits.shape == (4, 32, CFG.vocab_size)

    def test_causality(self):
        """Future tokens must not influence earlier logits."""
        params, _, _, _ = _setup()
        tokens, _, _ = _batch()
        l1 = M.gpt_forward(CFG, params, tokens, use_pallas=False)
        tokens2 = tokens.at[:, -1].set((tokens[:, -1] + 1)
                                       % CFG.vocab_size)
        l2 = M.gpt_forward(CFG, params, tokens2, use_pallas=False)
        np.testing.assert_allclose(l1[:, :-1], l2[:, :-1],
                                   rtol=1e-5, atol=1e-5)

    def test_pallas_and_jnp_paths_agree(self):
        params, _, _, masks = _setup()
        tokens, _, _ = _batch()
        lp = M.gpt_forward(CFG, params, tokens, masks=masks,
                           use_pallas=True)
        lj = M.gpt_forward(CFG, params, tokens, masks=masks,
                           use_pallas=False)
        np.testing.assert_allclose(lp, lj, rtol=1e-4, atol=1e-4)

    def test_fused_attention_path_agrees(self):
        params, _, _, _ = _setup()
        tokens, _, _ = _batch()
        lf = M.gpt_forward(CFG, params, tokens, use_pallas=False,
                           fused_attn=True)
        lj = M.gpt_forward(CFG, params, tokens, use_pallas=False,
                           fused_attn=False)
        np.testing.assert_allclose(lf, lj, rtol=2e-4, atol=2e-4)

    def test_masked_forward_equals_masked_params_dense_forward(self):
        """x @ (m*w) with raw params == dense forward with pre-masked
        params — the invariant the eval/logits artifacts rely on."""
        params, _, _, masks = _setup()
        tokens, _, _ = _batch()
        lm = M.gpt_forward(CFG, params, tokens, masks=masks,
                           use_pallas=False)
        ld = M.gpt_forward(CFG, params, tokens, masks=None,
                           use_pallas=False)
        np.testing.assert_allclose(lm, ld, rtol=1e-5, atol=1e-5)


class TestTrainStep:
    def test_masked_weights_stay_zero(self):
        params, m, v, masks = _setup(sparsity=0.75)
        step_fn = M.make_train_step(CFG, use_pallas=False)
        tokens, targets, lmask = _batch()
        for t in range(3):
            params, m, v, loss = step_fn(params, m, v, masks, tokens,
                                         targets, lmask,
                                         jnp.float32(t + 1),
                                         jnp.float32(1e-3))
        for n in M.masked_param_names(CFG):
            hole = (1 - masks[n])
            assert float(jnp.abs(params[n] * hole).max()) == 0.0
            assert float(jnp.abs(m[n] * hole).max()) == 0.0
            assert float(jnp.abs(v[n] * hole).max()) == 0.0

    def test_loss_decreases_overfit(self):
        """A few steps on one batch must reduce the loss (dense)."""
        params, m, v, masks = _setup(sparsity=0.0)
        ones = {n: jnp.ones_like(mask) for n, mask in masks.items()}
        step_fn = jax.jit(M.make_train_step(CFG, use_pallas=False))
        tokens, targets, lmask = _batch()
        losses = []
        for t in range(30):
            params, m, v, loss = step_fn(params, m, v, ones, tokens,
                                         targets, lmask,
                                         jnp.float32(t + 1),
                                         jnp.float32(3e-3))
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.5, losses

    def test_sparse_loss_decreases(self):
        params, m, v, masks = _setup(sparsity=0.75)
        step_fn = jax.jit(M.make_train_step(CFG, use_pallas=False))
        tokens, targets, lmask = _batch()
        losses = []
        for t in range(30):
            params, m, v, loss = step_fn(params, m, v, masks, tokens,
                                         targets, lmask,
                                         jnp.float32(t + 1),
                                         jnp.float32(3e-3))
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.5, losses

    def test_pallas_step_matches_jnp_step(self):
        """One train step, pallas vs jnp linears: same new params."""
        params, m, v, masks = _setup(sparsity=0.5)
        tokens, targets, lmask = _batch()
        a = M.make_train_step(CFG, use_pallas=True)(
            params, m, v, masks, tokens, targets, lmask,
            jnp.float32(1), jnp.float32(1e-3))
        b = M.make_train_step(CFG, use_pallas=False)(
            params, m, v, masks, tokens, targets, lmask,
            jnp.float32(1), jnp.float32(1e-3))
        np.testing.assert_allclose(float(a[3]), float(b[3]),
                                   rtol=1e-4, atol=1e-5)
        for n in params:
            np.testing.assert_allclose(a[0][n], b[0][n],
                                       rtol=2e-3, atol=2e-5,
                                       err_msg=n)

    def test_loss_mask_excludes_positions(self):
        """Zeroing the loss mask on a position removes its gradient."""
        params, m, v, masks = _setup(sparsity=0.0)
        ones = {n: jnp.ones_like(x) for n, x in masks.items()}
        tokens, targets, lmask = _batch()
        lmask0 = lmask.at[:, :16].set(0.0)
        l_full = M.lm_loss(CFG, params, tokens, targets, lmask,
                           use_pallas=False)
        l_half = M.lm_loss(CFG, params, tokens, targets, lmask0,
                           use_pallas=False)
        assert not np.isclose(float(l_full), float(l_half))


class TestEvalAndDecode:
    def test_eval_loss_matches_lm_loss(self):
        params, _, _, _ = _setup()
        tokens, targets, lmask = _batch()
        fn = M.make_eval_loss(CFG, use_pallas=False)
        s, c = fn(params, tokens, targets, lmask)
        mean = float(s) / float(c)
        ref = float(M.lm_loss(CFG, params, tokens, targets, lmask,
                              use_pallas=False))
        assert np.isclose(mean, ref, rtol=1e-5)

    def test_logits_last_gathers_correct_position(self):
        params, _, _, _ = _setup()
        tokens, _, _ = _batch()
        pos = jnp.array([3, 7, 11, 31], jnp.int32)
        fn = M.make_logits_last(CFG, use_pallas=False, fused_attn=False)
        out = fn(params, tokens, pos)
        full = M.gpt_forward(CFG, params, tokens, use_pallas=False)
        for i in range(4):
            np.testing.assert_allclose(out[i], full[i, int(pos[i])],
                                       rtol=1e-5, atol=1e-5)

    def test_logits_last_ignores_right_padding(self):
        """Causality: junk tokens after pos don't change logits at pos."""
        params, _, _, _ = _setup()
        tokens, _, _ = _batch()
        pos = jnp.array([5, 5, 5, 5], jnp.int32)
        fn = M.make_logits_last(CFG, use_pallas=False, fused_attn=False)
        a = fn(params, tokens, pos)
        tokens2 = tokens.at[:, 6:].set(0)
        b = fn(params, tokens2, pos)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


class TestKvDecode:
    """The KV-cache incremental pair must be *bit-identical* to the
    full-recompute ``logits_last`` path — the rust serve loop's
    equivalence guarantee sits on exactly this property."""

    def _decode_setup(self, seed=0, b=4):
        key = jax.random.PRNGKey(seed)
        params = M.init_params(CFG, key)
        t, v = CFG.ctx_len, CFG.vocab_size
        rng = np.random.default_rng(seed)
        plens = [3 + 2 * i for i in range(b)]
        tokens = np.zeros((b, t), np.int32)
        for i, plen in enumerate(plens):
            tokens[i, :plen] = rng.integers(4, v, size=plen)
        pos = np.array([plen - 1 for plen in plens], np.int32)
        return params, tokens, pos

    def test_prefill_matches_logits_last_bitwise(self):
        params, tokens, pos = self._decode_setup()
        b = tokens.shape[0]
        logits_last = jax.jit(M.make_logits_last(CFG, use_pallas=False))
        prefill = jax.jit(M.make_prefill(CFG, use_pallas=False))
        kv = M.init_kv_cache(CFG, b)
        got, _ = prefill(params, kv, jnp.array(tokens), jnp.array(pos),
                         jnp.ones((b,), jnp.float32))
        want = logits_last(params, jnp.array(tokens), jnp.array(pos))
        assert bool(jnp.all(got == want)), \
            float(jnp.abs(got - want).max())

    def test_decode_step_bit_identical_to_full_recompute(self):
        """Greedy-extend every row to the context edge: each
        incremental step's logits must equal the full forward's, bit
        for bit, so argmax trajectories can never diverge."""
        params, tokens, pos = self._decode_setup()
        b, t = tokens.shape
        logits_last = jax.jit(M.make_logits_last(CFG, use_pallas=False))
        decode_step = jax.jit(M.make_decode_step(CFG))
        prefill = jax.jit(M.make_prefill(CFG, use_pallas=False))
        kv = M.init_kv_cache(CFG, b)
        _, kv = prefill(params, kv, jnp.array(tokens), jnp.array(pos),
                        jnp.ones((b,), jnp.float32))
        while int(pos.max()) < t - 2:
            full = np.asarray(logits_last(params, jnp.array(tokens),
                                          jnp.array(pos)))
            ntok = np.array([tokens[i, pos[i]] for i in range(b)],
                            np.int32)
            inc, kv = decode_step(params, kv, jnp.array(ntok),
                                  jnp.array(pos))
            np.testing.assert_array_equal(np.asarray(inc), full)
            nxt = full.argmax(axis=1)
            for i in range(b):
                if pos[i] < t - 2:
                    pos[i] += 1
                    tokens[i, pos[i]] = nxt[i]

    def test_prefill_passthrough_keeps_other_rows(self):
        """refill=0 rows keep their cache exactly — a refilled slot
        must not disturb its batch neighbours."""
        params, tokens, pos = self._decode_setup()
        b = tokens.shape[0]
        prefill = jax.jit(M.make_prefill(CFG, use_pallas=False))
        kv = M.init_kv_cache(CFG, b)
        _, kv = prefill(params, kv, jnp.array(tokens), jnp.array(pos),
                        jnp.ones((b,), jnp.float32))
        # re-prompt row 0 only; rows 1.. must be untouched
        tokens2 = tokens.copy()
        tokens2[0] = 0
        tokens2[0, :4] = [9, 8, 7, 6]
        refill = np.zeros((b,), np.float32)
        refill[0] = 1.0
        _, kv2 = prefill(params, kv, jnp.array(tokens2),
                         jnp.array(pos), jnp.array(refill))
        for name in kv:
            a, c = np.asarray(kv[name]), np.asarray(kv2[name])
            np.testing.assert_array_equal(a[1:], c[1:], err_msg=name)
            assert not np.array_equal(a[0], c[0]), \
                f"{name} row 0 should have been recomputed"

    def test_cache_rows_above_pos_are_invisible(self):
        """Garbage in cache positions > pos must not change logits
        (the serve loop relies on stale cache tails being masked)."""
        params, tokens, pos = self._decode_setup()
        b, t = tokens.shape
        decode_step = jax.jit(M.make_decode_step(CFG))
        prefill = jax.jit(M.make_prefill(CFG, use_pallas=False))
        kv = M.init_kv_cache(CFG, b)
        _, kv = prefill(params, kv, jnp.array(tokens), jnp.array(pos),
                        jnp.ones((b,), jnp.float32))
        ntok = jnp.array([tokens[i, pos[i]] for i in range(b)],
                         jnp.int32)
        la, _ = decode_step(params, kv, ntok, jnp.array(pos))
        junk = {n: np.asarray(c).copy() for n, c in kv.items()}
        for i in range(b):
            for n in junk:
                junk[n][i, pos[i] + 1:] = 1e3
        lb, _ = decode_step(params,
                            {n: jnp.array(c) for n, c in junk.items()},
                            ntok, jnp.array(pos))
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    def test_kv_specs_sorted_matches_flatten_order(self):
        specs = M.kv_cache_specs(CFG, 4)
        names = [n for n, _ in specs]
        assert names == sorted(names)
        cache = M.init_kv_cache(CFG, 4)
        leaves, _ = jax.tree_util.tree_flatten_with_path(cache)
        assert [p[0].key for p, _ in leaves] == names
        assert all(s == (4, CFG.ctx_len, CFG.d_model)
                   for _, s in specs)


class TestParamSpecs:
    def test_spec_names_unique_and_sorted_matches_dict_flatten(self):
        specs = M.param_specs(CFG)
        names = [n for n, _, _ in specs]
        assert len(names) == len(set(names))
        params = {n: jnp.zeros(s) for n, s, _ in specs}
        leaves, _ = jax.tree_util.tree_flatten_with_path(params)
        flat_names = [p[0].key for p, _ in leaves]
        assert flat_names == sorted(names)

    def test_masked_names_are_2d_weights(self):
        shapes = {n: s for n, s, _ in M.param_specs(CFG)}
        for n in M.masked_param_names(CFG):
            assert len(shapes[n]) == 2

    def test_param_count_formula(self):
        """non-embedding params ~= 12 * d^2 * L (+ small LN/bias terms)."""
        total = sum(int(np.prod(s)) for n, s, _ in M.param_specs(CFG)
                    if n not in ("wte", "wpe"))
        d, L = CFG.d_model, CFG.n_layers
        assert abs(total - 12 * d * d * L) / (12 * d * d * L) < 0.05
