"""L1 kernel correctness: Pallas vs pure-jnp oracle (ref.py).

hypothesis sweeps shapes, block sizes, sparsity levels and value scales;
assert_allclose against the reference is the core correctness signal.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # Minimal environments (no hypothesis) still run every deterministic
    # test; the property sweeps skip. CI installs requirements.txt, so
    # the sweeps always run there.
    def settings(**_kw):
        return lambda f: f

    def given(**_kw):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    class _SampledStrategies:
        @staticmethod
        def sampled_from(xs):
            return xs

        @staticmethod
        def integers(lo, hi):
            return (lo, hi)

    st = _SampledStrategies()

from compile.kernels import (masked_matmul, pallas_matmul, causal_attention,
                             pick_blocks, kernel_stats, csr_from_dense,
                             csr_to_dense, sparse_pallas_matmul,
                             sparse_kernel_stats, block_nonzero_map)
from compile.kernels import ref
from compile.kernels.masked_matmul import _masked_matmul_impl, _tile_bytes
from compile.kernels.sparse_matmul import spmm_ref, dense_matmul_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape,
                                     dtype=jnp.float32)


def _mask(key, shape, sparsity):
    u = jax.random.uniform(jax.random.PRNGKey(key), shape)
    return (u >= sparsity).astype(jnp.float32)


# ---------------------------------------------------------------------------
# masked_matmul
# ---------------------------------------------------------------------------

class TestMaskedMatmul:
    def test_matches_ref_basic(self):
        x, w = _rand(0, (64, 32)), _rand(1, (32, 48))
        m = _mask(2, (32, 48), 0.75)
        np.testing.assert_allclose(masked_matmul(x, w, m),
                                   ref.masked_matmul_ref(x, w, m),
                                   rtol=2e-5, atol=2e-5)

    def test_zero_mask_gives_zero(self):
        x, w = _rand(0, (16, 8)), _rand(1, (8, 8))
        m = jnp.zeros((8, 8), jnp.float32)
        assert float(jnp.abs(masked_matmul(x, w, m)).max()) == 0.0

    def test_ones_mask_is_dense(self):
        x, w = _rand(0, (16, 8)), _rand(1, (8, 8))
        m = jnp.ones((8, 8), jnp.float32)
        np.testing.assert_allclose(masked_matmul(x, w, m), x @ w,
                                   rtol=2e-5, atol=2e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.sampled_from([8, 16, 33, 64, 128]),
        k=st.sampled_from([8, 16, 24, 64]),
        n=st.sampled_from([8, 16, 40, 96]),
        sparsity=st.sampled_from([0.0, 0.5, 0.75, 0.9]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes_sparsity(self, m, k, n, sparsity, seed):
        x = _rand(seed, (m, k))
        w = _rand(seed + 1, (k, n))
        msk = _mask(seed + 2, (k, n), sparsity)
        np.testing.assert_allclose(masked_matmul(x, w, msk),
                                   ref.masked_matmul_ref(x, w, msk),
                                   rtol=5e-5, atol=5e-5)

    @settings(max_examples=10, deadline=None)
    @given(
        bm=st.sampled_from([16, 32, 64]),
        bn=st.sampled_from([16, 32, 64]),
        bk=st.sampled_from([16, 32, 64]),
        seed=st.integers(0, 2**16),
    )
    def test_multiblock_grids(self, bm, bn, bk, seed):
        """Blocks strictly smaller than the dims: real multi-tile grid."""
        mm, kk, nn = 128, 64, 128
        x, w = _rand(seed, (mm, kk)), _rand(seed + 1, (kk, nn))
        msk = _mask(seed + 2, (kk, nn), 0.75)
        out = _masked_matmul_impl(x, w, msk,
                                  blocks=(bm, bn, min(bk, kk)))
        np.testing.assert_allclose(out, ref.masked_matmul_ref(x, w, msk),
                                   rtol=5e-5, atol=5e-5)

    def test_grad_x_and_w_match_ref(self):
        x, w = _rand(0, (32, 16)), _rand(1, (16, 24))
        m = _mask(2, (16, 24), 0.5)

        def f_pallas(x, w):
            return (masked_matmul(x, w, m) ** 2).sum()

        def f_ref(x, w):
            return (ref.masked_matmul_ref(x, w, m) ** 2).sum()

        gx, gw = jax.grad(f_pallas, argnums=(0, 1))(x, w)
        rx, rw = jax.grad(f_ref, argnums=(0, 1))(x, w)
        np.testing.assert_allclose(gx, rx, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(gw, rw, rtol=2e-4, atol=2e-4)

    def test_grad_respects_mask(self):
        """d/dw of the loss is exactly zero where the mask is zero."""
        x, w = _rand(0, (32, 16)), _rand(1, (16, 24))
        m = _mask(2, (16, 24), 0.75)
        gw = jax.grad(lambda w: (masked_matmul(x, w, m) ** 2).sum())(w)
        assert float(jnp.abs(gw * (1 - m)).max()) == 0.0

    def test_jit_compatible(self):
        x, w = _rand(0, (32, 16)), _rand(1, (16, 16))
        m = _mask(2, (16, 16), 0.5)
        out = jax.jit(masked_matmul)(x, w, m)
        np.testing.assert_allclose(out, ref.masked_matmul_ref(x, w, m),
                                   rtol=2e-5, atol=2e-5)


class TestPallasMatmul:
    @settings(max_examples=15, deadline=None)
    @given(
        m=st.sampled_from([8, 32, 60, 128]),
        k=st.sampled_from([8, 32, 48]),
        n=st.sampled_from([8, 32, 56]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, m, k, n, seed):
        x, w = _rand(seed, (m, k)), _rand(seed + 1, (k, n))
        np.testing.assert_allclose(pallas_matmul(x, w),
                                   ref.matmul_ref(x, w),
                                   rtol=5e-5, atol=5e-5)


# ---------------------------------------------------------------------------
# causal attention
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# sparse (CSR-fed) matmul: the serving decode kernel
# ---------------------------------------------------------------------------

def _assert_bitwise(a, b):
    """f32 bit-pattern equality — the dense-equivalence pin is *exact*,
    not assert_allclose."""
    a = np.asarray(a, dtype=np.float32)
    b = np.asarray(b, dtype=np.float32)
    assert a.shape == b.shape, f"{a.shape} vs {b.shape}"
    np.testing.assert_array_equal(a.view(np.uint32), b.view(np.uint32))


def _sparse_weights(key, shape, sparsity):
    """Dense f32 weights with exact zeros at ``sparsity`` fraction —
    the masked shape a sparse-pre-trained checkpoint actually has."""
    w = np.asarray(_rand(key, shape))
    return w * np.asarray(_mask(key + 1, shape, sparsity))


class TestSparseMatmul:
    def test_csr_round_trip_is_bitwise_exact(self):
        """Canonical (+0.0-zeroed) weights round-trip bit-for-bit."""
        w = _sparse_weights(0, (48, 40), 0.75)
        w = np.where(w != 0.0, w, np.float32(0.0))
        _assert_bitwise(csr_to_dense(csr_from_dense(w)), w)

    def test_csr_round_trip_canonicalizes_masked_zeros(self):
        """``w * mask`` sparsification writes -0.0 where the weight was
        negative; the round trip restores every stored value exactly
        and canonicalizes those holes to +0.0 (the rust upload pin)."""
        w = _sparse_weights(0, (48, 40), 0.75)
        assert np.signbit(w[w == 0.0]).any()  # -0.0 holes are real
        back = csr_to_dense(csr_from_dense(w))
        keep = w != 0.0
        _assert_bitwise(back[keep], w[keep])
        assert not np.signbit(back[~keep]).any()
        assert (back[~keep] == 0.0).all()

    def test_csr_drops_negative_zero_like_rust(self):
        """rust from_dense keeps ``v != 0.0`` — false for -0.0, so the
        round trip canonicalizes -0.0 to +0.0 (dense_matmul skips it
        identically, keeping the spmm pin intact)."""
        w = np.array([[1.0, -0.0], [0.0, 2.0]], dtype=np.float32)
        csr = csr_from_dense(w)
        assert csr.nnz == 2
        back = csr_to_dense(csr)
        assert np.signbit(back).sum() == 0

    def test_spmm_ref_matches_dense_matmul_ref_bitwise(self):
        """Python port of the rust elementwise pin: identical k-major
        loops, zeros skipped on both sides."""
        a = _sparse_weights(3, (24, 32), 0.75)
        b = np.asarray(_rand(5, (32, 16)))
        _assert_bitwise(spmm_ref(csr_from_dense(a), b),
                        dense_matmul_ref(a, b))

    def test_kernel_matches_dense_pallas_bitwise_basic(self):
        x = _rand(0, (64, 32))
        w = _sparse_weights(1, (32, 48), 0.75)
        _assert_bitwise(sparse_pallas_matmul(x, csr_from_dense(w)),
                        pallas_matmul(x, jnp.asarray(w)))

    def test_kernel_edge_shapes_bitwise(self):
        """1-row activations, 1-column weights, fully-dense weights."""
        for (m, k, n), sparsity in [((1, 16, 8), 0.75),
                                    ((16, 16, 1), 0.75),
                                    ((1, 8, 1), 0.5),
                                    ((8, 8, 8), 0.0)]:
            x = _rand(m * 7 + n, (m, k))
            w = _sparse_weights(k + n, (k, n), sparsity)
            _assert_bitwise(sparse_pallas_matmul(x, csr_from_dense(w)),
                            pallas_matmul(x, jnp.asarray(w)))

    def test_kernel_empty_weight_rows_bitwise(self):
        """Rows of W with no nonzeros (whole k-slices dead) — the case
        CSR row_ptr represents with equal consecutive entries."""
        w = _sparse_weights(9, (32, 32), 0.5)
        w[8:16] = 0.0
        csr = csr_from_dense(w)
        assert (csr.row_ptr[9:17] == csr.row_ptr[9]).all()
        x = _rand(2, (16, 32))
        _assert_bitwise(sparse_pallas_matmul(x, csr),
                        pallas_matmul(x, jnp.asarray(w)))

    def test_kernel_multiblock_grid_skips_tiles_bitwise(self):
        """A real multi-tile grid where some (bk, bn) weight tiles are
        all-zero and actually get skipped."""
        blocks = (8, 16, 16)
        w = _sparse_weights(11, (32, 32), 0.5)
        w[16:] = 0.0  # k-tiles 1 are all-zero for every n-tile
        csr = csr_from_dense(w)
        nz = block_nonzero_map(csr, 16, 16)
        assert nz.shape == (2, 2)
        assert (nz[1] == 0).all() and (nz[0] > 0).all()
        x = _rand(4, (16, 32))
        _assert_bitwise(sparse_pallas_matmul(x, csr, blocks=blocks),
                        pallas_matmul(x, jnp.asarray(w), blocks=blocks))

    def test_nan_propagates_identically_through_nonzero_tiles(self):
        """NaN activations against *stored* weight regions must poison
        both paths with bit-identical NaNs."""
        w = _sparse_weights(13, (16, 16), 0.75)
        assert csr_from_dense(w).nnz > 0
        x = np.array(_rand(4, (8, 16)))
        x[3, 2] = np.nan
        sp = np.asarray(sparse_pallas_matmul(jnp.asarray(x),
                                             csr_from_dense(w)))
        dn = np.asarray(pallas_matmul(jnp.asarray(x), jnp.asarray(w)))
        assert np.isnan(sp[3]).all()
        _assert_bitwise(sp, dn)

    def test_nan_against_skipped_tile_is_not_manufactured(self):
        """The documented caveat: a NaN activation aligned with an
        all-zero (skipped) weight tile must NOT leak into the output —
        the sparse result equals the same kernel run with the dead
        k-range cut away, while the dense path manufactures NaN."""
        blocks = (8, 16, 16)
        w = _sparse_weights(17, (32, 16), 0.5)
        w[16:] = 0.0
        x = np.array(_rand(6, (8, 32)))
        x[0, 20] = np.nan  # k index 20 lives in the dead tile
        sp = np.asarray(sparse_pallas_matmul(jnp.asarray(x),
                                             csr_from_dense(w),
                                             blocks=blocks))
        truncated = pallas_matmul(jnp.asarray(x[:, :16]),
                                  jnp.asarray(w[:16]),
                                  blocks=blocks)
        _assert_bitwise(sp, truncated)
        dn = np.asarray(pallas_matmul(jnp.asarray(x), jnp.asarray(w),
                                      blocks=blocks))
        assert np.isnan(dn[0]).all()

    def test_checkpoint_sweep_layer_weights_bitwise(self):
        """The SPDF sweep pin at kernel granularity: for each sparsity
        level of the checkpoint family, every sparsifiable gpt-nano
        layer matrix routed through the CSR kernel must reproduce the
        dense-path logits contribution bit-for-bit."""
        from compile.model import SIM_CONFIGS, init_params, \
            masked_param_names
        cfg = SIM_CONFIGS["gpt-nano"]
        params = init_params(cfg, jax.random.PRNGKey(0))
        for sweep_ix, sparsity in enumerate([0.0, 0.5, 0.75]):
            for name_ix, name in enumerate(masked_param_names(cfg)):
                w = np.asarray(params[name])
                wm = w * np.asarray(_mask(31 * sweep_ix + name_ix,
                                          w.shape, sparsity))
                x = _rand(sweep_ix + name_ix, (4, wm.shape[0]))
                _assert_bitwise(
                    sparse_pallas_matmul(x, csr_from_dense(wm)),
                    pallas_matmul(x, jnp.asarray(wm)))

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.sampled_from([1, 8, 32, 60]),
        k=st.sampled_from([8, 32, 48]),
        n=st.sampled_from([8, 16, 56]),
        sparsity=st.sampled_from([0.0, 0.5, 0.75, 0.95]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_bitwise_pin(self, m, k, n, sparsity, seed):
        x = _rand(seed, (m, k))
        w = _sparse_weights(seed + 1, (k, n), sparsity)
        _assert_bitwise(sparse_pallas_matmul(x, csr_from_dense(w)),
                        pallas_matmul(x, jnp.asarray(w)))

    def test_sparse_kernel_stats(self):
        w = _sparse_weights(23, (32, 32), 0.5)
        w[16:] = 0.0
        csr = csr_from_dense(w)
        stats = sparse_kernel_stats(8, csr, blocks=(8, 16, 16))
        assert stats["total_tiles"] == 4
        assert stats["nonzero_tiles"] == 2
        assert stats["flops"] == stats["dense_flops"] // 2
        assert stats["csr_bytes"] == 8 * csr.nnz + 8 * 33
        assert stats["dense_bytes"] == 4 * 32 * 32
        assert stats["csr_bytes"] < stats["dense_bytes"]


class TestCausalAttention:
    @settings(max_examples=15, deadline=None)
    @given(
        t=st.sampled_from([16, 32, 64, 128]),
        d=st.sampled_from([8, 16, 32]),
        bq=st.sampled_from([8, 16, 128]),
        bk=st.sampled_from([8, 16, 128]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, t, d, bq, bk, seed):
        q = _rand(seed, (t, d))
        k = _rand(seed + 1, (t, d))
        v = _rand(seed + 2, (t, d))
        out = causal_attention(q, k, v, block_q=bq, block_k=bk)
        np.testing.assert_allclose(out, ref.causal_attention_ref(q, k, v),
                                   rtol=2e-4, atol=2e-4)

    def test_causality(self):
        """Changing future keys/values must not change earlier outputs."""
        t, d = 32, 16
        q, k, v = _rand(0, (t, d)), _rand(1, (t, d)), _rand(2, (t, d))
        out1 = causal_attention(q, k, v)
        k2 = k.at[t - 1].set(99.0)
        v2 = v.at[t - 1].set(-99.0)
        out2 = causal_attention(q, k2, v2)
        np.testing.assert_allclose(out1[: t - 1], out2[: t - 1],
                                   rtol=1e-6, atol=1e-6)

    def test_first_position_is_v0(self):
        """Position 0 attends only to itself."""
        t, d = 16, 8
        q, k, v = _rand(0, (t, d)), _rand(1, (t, d)), _rand(2, (t, d))
        out = causal_attention(q, k, v)
        np.testing.assert_allclose(out[0], v[0], rtol=1e-5, atol=1e-5)

    def test_vmap(self):
        b, t, d = 4, 32, 16
        q = _rand(0, (b, t, d))
        k = _rand(1, (b, t, d))
        v = _rand(2, (b, t, d))
        out = jax.vmap(causal_attention)(q, k, v)
        for i in range(b):
            np.testing.assert_allclose(
                out[i], ref.causal_attention_ref(q[i], k[i], v[i]),
                rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# block heuristic + analytic stats
# ---------------------------------------------------------------------------

class TestBlockHeuristic:
    @settings(max_examples=30, deadline=None)
    @given(
        m=st.integers(1, 4096),
        n=st.integers(1, 4096),
        k=st.integers(1, 2048),
    )
    def test_blocks_divide_and_fit(self, m, n, k):
        bm, bn, bk = pick_blocks(m, n, k)
        assert m % bm == 0 and n % bn == 0 and k % bk == 0
        assert _tile_bytes(bm, bn, bk) <= 16 * 1024 * 1024

    def test_paper_scale_12k(self):
        """The CS-2 kernel demo shape (12k x 12k, App. C) must tile to a
        real multi-block grid within VMEM."""
        stats = kernel_stats(12288, 12288, 12288)
        assert stats["vmem_bytes"] <= 16 * 1024 * 1024
        gm, gn, gk = stats["grid"]
        assert gm * gn * gk > 1
        assert stats["mxu_utilization"] == 1.0

    def test_mxu_utilization_penalizes_ragged(self):
        full = kernel_stats(256, 256, 256)["mxu_utilization"]
        ragged = kernel_stats(100, 100, 100)["mxu_utilization"]
        assert ragged < full <= 1.0
