"""L1 kernel correctness: Pallas vs pure-jnp oracle (ref.py).

hypothesis sweeps shapes, block sizes, sparsity levels and value scales;
assert_allclose against the reference is the core correctness signal.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (masked_matmul, pallas_matmul, causal_attention,
                             pick_blocks, kernel_stats)
from compile.kernels import ref
from compile.kernels.masked_matmul import _masked_matmul_impl, _tile_bytes

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape,
                                     dtype=jnp.float32)


def _mask(key, shape, sparsity):
    u = jax.random.uniform(jax.random.PRNGKey(key), shape)
    return (u >= sparsity).astype(jnp.float32)


# ---------------------------------------------------------------------------
# masked_matmul
# ---------------------------------------------------------------------------

class TestMaskedMatmul:
    def test_matches_ref_basic(self):
        x, w = _rand(0, (64, 32)), _rand(1, (32, 48))
        m = _mask(2, (32, 48), 0.75)
        np.testing.assert_allclose(masked_matmul(x, w, m),
                                   ref.masked_matmul_ref(x, w, m),
                                   rtol=2e-5, atol=2e-5)

    def test_zero_mask_gives_zero(self):
        x, w = _rand(0, (16, 8)), _rand(1, (8, 8))
        m = jnp.zeros((8, 8), jnp.float32)
        assert float(jnp.abs(masked_matmul(x, w, m)).max()) == 0.0

    def test_ones_mask_is_dense(self):
        x, w = _rand(0, (16, 8)), _rand(1, (8, 8))
        m = jnp.ones((8, 8), jnp.float32)
        np.testing.assert_allclose(masked_matmul(x, w, m), x @ w,
                                   rtol=2e-5, atol=2e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.sampled_from([8, 16, 33, 64, 128]),
        k=st.sampled_from([8, 16, 24, 64]),
        n=st.sampled_from([8, 16, 40, 96]),
        sparsity=st.sampled_from([0.0, 0.5, 0.75, 0.9]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes_sparsity(self, m, k, n, sparsity, seed):
        x = _rand(seed, (m, k))
        w = _rand(seed + 1, (k, n))
        msk = _mask(seed + 2, (k, n), sparsity)
        np.testing.assert_allclose(masked_matmul(x, w, msk),
                                   ref.masked_matmul_ref(x, w, msk),
                                   rtol=5e-5, atol=5e-5)

    @settings(max_examples=10, deadline=None)
    @given(
        bm=st.sampled_from([16, 32, 64]),
        bn=st.sampled_from([16, 32, 64]),
        bk=st.sampled_from([16, 32, 64]),
        seed=st.integers(0, 2**16),
    )
    def test_multiblock_grids(self, bm, bn, bk, seed):
        """Blocks strictly smaller than the dims: real multi-tile grid."""
        mm, kk, nn = 128, 64, 128
        x, w = _rand(seed, (mm, kk)), _rand(seed + 1, (kk, nn))
        msk = _mask(seed + 2, (kk, nn), 0.75)
        out = _masked_matmul_impl(x, w, msk,
                                  blocks=(bm, bn, min(bk, kk)))
        np.testing.assert_allclose(out, ref.masked_matmul_ref(x, w, msk),
                                   rtol=5e-5, atol=5e-5)

    def test_grad_x_and_w_match_ref(self):
        x, w = _rand(0, (32, 16)), _rand(1, (16, 24))
        m = _mask(2, (16, 24), 0.5)

        def f_pallas(x, w):
            return (masked_matmul(x, w, m) ** 2).sum()

        def f_ref(x, w):
            return (ref.masked_matmul_ref(x, w, m) ** 2).sum()

        gx, gw = jax.grad(f_pallas, argnums=(0, 1))(x, w)
        rx, rw = jax.grad(f_ref, argnums=(0, 1))(x, w)
        np.testing.assert_allclose(gx, rx, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(gw, rw, rtol=2e-4, atol=2e-4)

    def test_grad_respects_mask(self):
        """d/dw of the loss is exactly zero where the mask is zero."""
        x, w = _rand(0, (32, 16)), _rand(1, (16, 24))
        m = _mask(2, (16, 24), 0.75)
        gw = jax.grad(lambda w: (masked_matmul(x, w, m) ** 2).sum())(w)
        assert float(jnp.abs(gw * (1 - m)).max()) == 0.0

    def test_jit_compatible(self):
        x, w = _rand(0, (32, 16)), _rand(1, (16, 16))
        m = _mask(2, (16, 16), 0.5)
        out = jax.jit(masked_matmul)(x, w, m)
        np.testing.assert_allclose(out, ref.masked_matmul_ref(x, w, m),
                                   rtol=2e-5, atol=2e-5)


class TestPallasMatmul:
    @settings(max_examples=15, deadline=None)
    @given(
        m=st.sampled_from([8, 32, 60, 128]),
        k=st.sampled_from([8, 32, 48]),
        n=st.sampled_from([8, 32, 56]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, m, k, n, seed):
        x, w = _rand(seed, (m, k)), _rand(seed + 1, (k, n))
        np.testing.assert_allclose(pallas_matmul(x, w),
                                   ref.matmul_ref(x, w),
                                   rtol=5e-5, atol=5e-5)


# ---------------------------------------------------------------------------
# causal attention
# ---------------------------------------------------------------------------

class TestCausalAttention:
    @settings(max_examples=15, deadline=None)
    @given(
        t=st.sampled_from([16, 32, 64, 128]),
        d=st.sampled_from([8, 16, 32]),
        bq=st.sampled_from([8, 16, 128]),
        bk=st.sampled_from([8, 16, 128]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, t, d, bq, bk, seed):
        q = _rand(seed, (t, d))
        k = _rand(seed + 1, (t, d))
        v = _rand(seed + 2, (t, d))
        out = causal_attention(q, k, v, block_q=bq, block_k=bk)
        np.testing.assert_allclose(out, ref.causal_attention_ref(q, k, v),
                                   rtol=2e-4, atol=2e-4)

    def test_causality(self):
        """Changing future keys/values must not change earlier outputs."""
        t, d = 32, 16
        q, k, v = _rand(0, (t, d)), _rand(1, (t, d)), _rand(2, (t, d))
        out1 = causal_attention(q, k, v)
        k2 = k.at[t - 1].set(99.0)
        v2 = v.at[t - 1].set(-99.0)
        out2 = causal_attention(q, k2, v2)
        np.testing.assert_allclose(out1[: t - 1], out2[: t - 1],
                                   rtol=1e-6, atol=1e-6)

    def test_first_position_is_v0(self):
        """Position 0 attends only to itself."""
        t, d = 16, 8
        q, k, v = _rand(0, (t, d)), _rand(1, (t, d)), _rand(2, (t, d))
        out = causal_attention(q, k, v)
        np.testing.assert_allclose(out[0], v[0], rtol=1e-5, atol=1e-5)

    def test_vmap(self):
        b, t, d = 4, 32, 16
        q = _rand(0, (b, t, d))
        k = _rand(1, (b, t, d))
        v = _rand(2, (b, t, d))
        out = jax.vmap(causal_attention)(q, k, v)
        for i in range(b):
            np.testing.assert_allclose(
                out[i], ref.causal_attention_ref(q[i], k[i], v[i]),
                rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# block heuristic + analytic stats
# ---------------------------------------------------------------------------

class TestBlockHeuristic:
    @settings(max_examples=30, deadline=None)
    @given(
        m=st.integers(1, 4096),
        n=st.integers(1, 4096),
        k=st.integers(1, 2048),
    )
    def test_blocks_divide_and_fit(self, m, n, k):
        bm, bn, bk = pick_blocks(m, n, k)
        assert m % bm == 0 and n % bn == 0 and k % bk == 0
        assert _tile_bytes(bm, bn, bk) <= 16 * 1024 * 1024

    def test_paper_scale_12k(self):
        """The CS-2 kernel demo shape (12k x 12k, App. C) must tile to a
        real multi-block grid within VMEM."""
        stats = kernel_stats(12288, 12288, 12288)
        assert stats["vmem_bytes"] <= 16 * 1024 * 1024
        gm, gn, gk = stats["grid"]
        assert gm * gn * gk > 1
        assert stats["mxu_utilization"] == 1.0

    def test_mxu_utilization_penalizes_ragged(self):
        full = kernel_stats(256, 256, 256)["mxu_utilization"]
        ragged = kernel_stats(100, 100, 100)["mxu_utilization"]
        assert ragged < full <= 1.0
