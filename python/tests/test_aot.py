"""AOT pipeline integrity: manifest structure and HLO interchange."""

import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    """Build artifacts for the smallest model once, into a temp dir."""
    out = str(tmp_path_factory.mktemp("artifacts"))
    cfg = M.GPTConfig("aot-test", n_layers=1, d_model=32, n_heads=2,
                      vocab_size=64, ctx_len=32)
    entry = aot.build_artifacts(cfg, out)
    return out, cfg, entry


class TestManifest:
    def test_artifact_files_exist_and_are_hlo_text(self, built):
        out, cfg, entry = built
        for name, art in entry["artifacts"].items():
            path = os.path.join(out, art["file"])
            assert os.path.exists(path), name
            head = open(path).read(200)
            assert "HloModule" in head, f"{name} is not HLO text"

    def test_input_order_params_first_sorted(self, built):
        """The contract rust relies on: params flatten sorted by name."""
        _, cfg, entry = built
        inputs = entry["artifacts"]["train_step"]["inputs"]
        n_params = len(entry["params"])
        param_inputs = [i["name"] for i in inputs[:n_params]]
        expected = sorted(n for n, _, _ in M.param_specs(cfg))
        assert param_inputs == [f"params/{n}" for n in expected]

    def test_train_step_output_count(self, built):
        _, cfg, entry = built
        n_params = len(entry["params"])
        outs = entry["artifacts"]["train_step"]["outputs"]
        # params' + m' + v' + loss
        assert len(outs) == 3 * n_params + 1

    def test_scalar_inputs_tail(self, built):
        _, _, entry = built
        inputs = entry["artifacts"]["train_step"]["inputs"]
        assert inputs[-2]["name"] == "step"
        assert inputs[-1]["name"] == "lr"
        assert inputs[-1]["shape"] == []

    def test_masked_params_subset_of_params(self, built):
        _, _, entry = built
        names = {p["name"] for p in entry["params"]}
        assert set(entry["masked_params"]) <= names

    def test_shapes_match_config(self, built):
        _, cfg, entry = built
        shapes = {p["name"]: p["shape"] for p in entry["params"]}
        assert shapes["wte"] == [cfg.vocab_size, cfg.d_model]
        assert shapes["h0.mlp.wi"] == [cfg.d_model, 4 * cfg.d_model]

    def test_decode_state_specs_and_artifact_wiring(self, built):
        """decode_step inputs = params ++ kv state ++ (next_token, pos);
        outputs = logits ++ kv state. prefill adds tokens/refill. The
        manifest's decode_state block is the rust SessionState spec."""
        _, cfg, entry = built
        st = entry["decode_state"]
        b, t, d = aot.DECODE_BATCH, cfg.ctx_len, cfg.d_model
        assert [s["name"] for s in st] == \
            sorted(s["name"] for s in st)
        assert len(st) == 2 * cfg.n_layers
        assert all(s["shape"] == [b, t, d] for s in st)
        n_params = len(entry["params"])
        n_state = len(st)

        dec = entry["artifacts"]["decode_step"]
        assert len(dec["inputs"]) == n_params + n_state + 2
        kv_in = dec["inputs"][n_params:n_params + n_state]
        assert [i["name"] for i in kv_in] == \
            [f"kv/{s['name']}" for s in st]
        assert dec["inputs"][-2]["shape"] == [b]  # next_token
        assert dec["inputs"][-1]["shape"] == [b]  # pos
        assert len(dec["outputs"]) == 1 + n_state
        assert dec["outputs"][0]["shape"] == [b, cfg.vocab_size]

        pre = entry["artifacts"]["prefill"]
        assert len(pre["inputs"]) == n_params + n_state + 3
        assert pre["inputs"][-3]["shape"] == [b, t]  # tokens
        assert pre["inputs"][-1]["dtype"] == "float32"  # refill
        assert len(pre["outputs"]) == 1 + n_state


class TestHloRoundTrip:
    def test_hlo_text_parameter_count_matches_manifest(self, built):
        out, _, entry = built
        art = entry["artifacts"]["eval_loss"]
        text = open(os.path.join(out, art["file"])).read()
        # Count ENTRY computation parameters in the HLO text.
        entry_comp = [blk for blk in text.split("\n\n")
                      if "ENTRY" in blk][0]
        n = entry_comp.count("parameter(")
        assert n == len(art["inputs"])

    def test_lowered_numerics_vs_python(self, built):
        """Execute the lowered eval_loss via jax's own HLO path and
        compare against the python function (catches flatten-order
        mistakes before rust ever sees the artifact)."""
        out, cfg, entry = built
        key = jax.random.PRNGKey(0)
        params = M.init_params(cfg, key)
        b, t = aot.TRAIN_BATCH, cfg.ctx_len
        tokens = jax.random.randint(key, (b, t), 0, cfg.vocab_size)
        targets = jnp.roll(tokens, -1, axis=1)
        lmask = jnp.ones((b, t), jnp.float32)

        fn = M.make_eval_loss(cfg, use_pallas=True)
        want_s, want_c = fn(params, tokens, targets, lmask)

        flat_inputs = [params[n["name"].split("/", 1)[1]]
                       for n in entry["artifacts"]["eval_loss"]["inputs"]
                       if n["name"].startswith("params/")]
        flat_inputs += [tokens, targets, lmask]
        got_s, got_c = jax.jit(fn)(params, tokens, targets, lmask)
        np.testing.assert_allclose(float(got_s), float(want_s),
                                   rtol=1e-5)
        assert float(got_c) == float(want_c)


class TestCliEndToEnd:
    def test_module_main_runs(self, tmp_path):
        """`python -m compile.aot` end-to-end for the nano model."""
        env = dict(os.environ)
        r = subprocess.run(
            [sys.executable, "-m", "compile.aot",
             "--out-dir", str(tmp_path), "--models", "gpt-nano"],
            cwd=os.path.dirname(os.path.dirname(__file__)),
            capture_output=True, text=True, env=env, timeout=600)
        assert r.returncode == 0, r.stderr[-2000:]
        manifest = json.load(open(tmp_path / "manifest.json"))
        assert "gpt-nano" in manifest["models"]
        m = manifest["models"]["gpt-nano"]
        assert set(m["artifacts"]) == {"train_step", "eval_loss",
                                       "logits_last", "prefill",
                                       "decode_step"}
        for art in m["artifacts"].values():
            assert (tmp_path / art["file"]).exists()
