"""scripts/bench_gate.py — the CI perf-regression gate.

Pure-stdlib tests (no jax): the gate must stay green on identical /
within-tolerance datapoints, demonstrably fail on synthetically
regressed ones, bootstrap when baselines are missing, and honor the
refresh knob.
"""

import importlib.util
import json
from pathlib import Path

import pytest

_GATE_PATH = (Path(__file__).resolve().parent.parent.parent
              / "scripts" / "bench_gate.py")
_spec = importlib.util.spec_from_file_location("bench_gate",
                                               _GATE_PATH)
gate = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(gate)


def decode_json(tps=100.0, p95=500.0, with_kv=True):
    j = {
        "engine": {"tokens_per_sec": tps},
        "serve": {
            "tokens_per_sec": tps * 2,
            "latency_ms": {"p95": p95},
        },
    }
    if with_kv:
        j["kv"] = {"tokens_per_sec": tps * 1.5}
    return j


def point(engine, p95, ttft, admission="unbounded", shed_rate=0.0,
          goodput=500.0):
    return {
        "engine": engine,
        "pattern": "poisson",
        "admission": admission,
        "shed_rate": shed_rate,
        "goodput_tokens_per_sec": goodput,
        "latency_ms": {"p95": p95},
        "ttft_ms": {"p95": ttft},
    }


def model_point(model, requests, completed, goodput, p95=80.0):
    return {
        "model": model,
        "engine": "literal",
        "requests": requests,
        "completed": completed,
        "shed_rate": 0.0,
        "goodput_tokens_per_sec": goodput,
        "latency_ms": {"p95": p95},
    }


def multi_model_json(goodput=400.0, p95=80.0):
    return {
        "models": ["m0", "m1"],
        "offered_rps": 100.0,
        "aggregate": model_point("", 64, 64, goodput, p95),
        "per_model": [
            model_point("m0", 34, 34, goodput * 0.55, p95),
            model_point("m1", 30, 30, goodput * 0.45, p95 * 1.1),
        ],
    }


def fault_variant(requests=32, failed=0, retries=0, degraded=0,
                  goodput=400.0):
    return {
        "requests": requests,
        "completed": requests - failed,
        "shed": 0,
        "expired": 0,
        "failed": failed,
        "retries": retries,
        "degraded": degraded,
        "goodput_tokens_per_sec": goodput,
        # raw throughput counts dropped work too, so it sits at or
        # above goodput (strictly above when anything failed)
        "tokens_per_vsec": goodput + (25.0 if failed else 0.0),
    }


def fault_rate_row(rate, no_goodput=200.0, fo_goodput=390.0):
    if rate == 0:
        return {
            "fault_rate": 0.0,
            "no_failover": fault_variant(goodput=no_goodput),
            "failover": fault_variant(goodput=fo_goodput),
        }
    return {
        "fault_rate": rate,
        "no_failover": fault_variant(failed=12, retries=3,
                                     goodput=no_goodput),
        "failover": fault_variant(retries=3, degraded=12,
                                  goodput=fo_goodput),
    }


def fault_json():
    return {
        "models": ["m0", "m1"],
        "offered_rps": 30.0,
        "kill_step": 4,
        "retry_max": 5,
        "rates": [
            fault_rate_row(0.0, no_goodput=400.0, fo_goodput=400.0),
            fault_rate_row(0.1),
        ],
    }


def sparse_variant(requests=32, tokens=1024, tpv=100.0):
    return {
        "model": "s75",
        "engine": "literal",
        "requests": requests,
        "completed": requests,
        "generated_tokens": tokens,
        "tokens_per_vsec": tpv,
    }


def sparse_json(measured=4.0, required=2.0):
    return {
        "sparsity": 0.75,
        "sparse_slots": 12,
        "step_scale": 0.25,
        "csr_host_bytes": 100_000,
        "dense_equiv_bytes": 160_000,
        "flops_speedup": 4.0,
        "required_speedup": required,
        "measured_speedup": measured,
        "dense_tokens_per_vsec": 100.0,
        "s75_tokens_per_vsec": 100.0 * measured,
        "dense": sparse_variant(tpv=100.0),
        "s75": sparse_variant(tpv=100.0 * measured),
    }


def spec_variant(requests=10, tokens=320, tpv=100.0):
    return {
        "model": "dense",
        "engine": "literal",
        "requests": requests,
        "completed": requests,
        "generated_tokens": tokens,
        "tokens_per_vsec": tpv,
    }


def speculative_json(mean_acceptance=3.0, floor=1.0, speedup=2.0,
                     bitwise=True, tokens=320):
    verifies = 100
    accepted = int(mean_acceptance * verifies)
    return {
        "draft": "s75",
        "verifier": "dense",
        "k": 4,
        "draft_step_scale": 0.25,
        "acceptance_floor": floor,
        "mean_acceptance": mean_acceptance,
        "acceptance_rate": accepted / 400.0,
        "tokens_per_verify": tokens / verifies,
        "drafted": 400,
        "accepted": accepted,
        # conservation by construction: every emitted token is either
        # an accepted draft or a verifier correction
        "corrections": tokens - accepted,
        "verifies": verifies,
        "wasted_drafts": 400 - accepted,
        "bitwise_equal": bitwise,
        "dense_tokens_per_vsec": 100.0,
        "spec_tokens_per_vsec": 100.0 * speedup,
        "measured_speedup": speedup,
        "dense": spec_variant(tpv=100.0, tokens=tokens),
        "spec": spec_variant(tpv=100.0 * speedup, tokens=tokens),
    }


def paged_variant(requests=16, tokens=640, lost=0, tpv=100.0):
    return {
        "requests": requests,
        "completed": requests,
        "generated_tokens": tokens,
        "lost_tokens": lost,
        "tokens_per_vsec": tpv,
        # goodput excludes the dropped work raw throughput includes
        "goodput_tokens_per_sec": tpv * tokens / (tokens + lost),
    }


def paged_json(full_seats=1, paged_seats=6, leaked=0, bitwise=True):
    return {
        "page_size": 4,
        "kv_pages": 32,
        "requests": 16,
        "full_peak_seated": full_seats,
        "paged_peak_seated": paged_seats,
        "leaked_pages": leaked,
        "preemptions": 3,
        "lost_tokens": 24,
        "bitwise_equal": bitwise,
        "full": paged_variant(),
        "paged": paged_variant(lost=24),
    }


def serve_load_json(ratio=0.9, p95=100.0, shed_ratio=0.6,
                    goodput=500.0):
    return {
        "kv_p95_vs_literal": ratio,
        "shed": {
            "offered_rps": 120.0,
            "shed_rate": 0.3,
            "p95_vs_unbounded": shed_ratio,
            "goodput_tokens_per_sec": goodput * 0.7,
        },
        "multi_model": multi_model_json(),
        "fault": fault_json(),
        "sparse": sparse_json(),
        "speculative": speculative_json(),
        "paged": paged_json(),
        "points": [
            point("literal", p95, p95 / 2, goodput=goodput),
            point("kv", p95 * 0.8, p95 / 3, goodput=goodput * 1.2),
        ],
    }


class TestMetricComparison:
    def test_identical_is_green(self):
        cur = decode_json()
        fails, _ = gate.check_file("BENCH_decode.json", cur, cur, 0.25)
        assert fails == []

    def test_within_tolerance_is_green(self):
        fails, _ = gate.check_file("BENCH_decode.json",
                                   decode_json(tps=80.0),
                                   decode_json(tps=100.0), 0.25)
        assert fails == []

    def test_tokens_per_sec_regression_fails(self):
        # 50% throughput drop >> 25% tolerance
        fails, _ = gate.check_file("BENCH_decode.json",
                                   decode_json(tps=50.0),
                                   decode_json(tps=100.0), 0.25)
        assert any("engine.tokens_per_sec" in f for f in fails)

    def test_latency_regression_fails(self):
        fails, _ = gate.check_file("BENCH_decode.json",
                                   decode_json(p95=800.0),
                                   decode_json(p95=500.0), 0.25)
        assert any("serve.latency_ms.p95" in f for f in fails)

    def test_improvement_is_green(self):
        fails, _ = gate.check_file("BENCH_decode.json",
                                   decode_json(tps=300.0, p95=100.0),
                                   decode_json(tps=100.0, p95=500.0),
                                   0.25)
        assert fails == []

    def test_missing_kv_leg_is_skipped(self):
        # a pre-KV manifest has no kv block: skip, don't crash/fail
        fails, _ = gate.check_file("BENCH_decode.json",
                                   decode_json(with_kv=False),
                                   decode_json(), 0.25)
        assert fails == []


class TestServeLoadGates:
    def test_identical_sweep_is_green(self):
        cur = serve_load_json()
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, cur,
                                   0.25)
        assert fails == []

    def test_point_p95_regression_fails(self):
        fails, _ = gate.check_file("BENCH_serve_load.json",
                                   serve_load_json(p95=200.0),
                                   serve_load_json(p95=100.0), 0.25)
        assert any("latency_ms.p95" in f for f in fails)

    def test_kv_worse_than_literal_fails_absolutely(self):
        # the acceptance invariant: KV p95 <= literal p95 (+tol) at
        # budgets >= 32, enforced even with NO baseline at all
        cur = serve_load_json(ratio=1.6)
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("kv_p95_vs_literal" in f for f in fails)

    def test_layout_change_skips_with_note(self):
        base = serve_load_json()
        cur = serve_load_json()
        cur["points"].append(point("literal", 50.0, 10.0))
        fails, notes = gate.check_file("BENCH_serve_load.json", cur,
                                       base, 0.25)
        assert fails == []
        assert any("layout changed" in n for n in notes)

    def test_goodput_regression_fails(self):
        # per-point goodput halving is a regression (higher is better)
        fails, _ = gate.check_file("BENCH_serve_load.json",
                                   serve_load_json(goodput=250.0),
                                   serve_load_json(goodput=500.0),
                                   0.25)
        assert any("goodput_tokens_per_sec" in f for f in fails)

    def test_nonzero_shed_rate_under_unbounded_fails_absolutely(self):
        # shedding with unbounded admission means the loop miscounted;
        # enforced with no baseline at all
        cur = serve_load_json()
        cur["points"][0]["shed_rate"] = 0.1
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("unbounded admission" in f for f in fails)
        # a bounded-admission point may shed freely
        cur = serve_load_json()
        cur["points"][0]["admission"] = "max-queue(2)"
        cur["points"][0]["shed_rate"] = 0.4
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert fails == []

    def test_missing_shed_datapoints_fails(self):
        # the smoke must carry the new datapoints on every point
        cur = serve_load_json()
        del cur["points"][1]["shed_rate"]
        del cur["points"][1]["goodput_tokens_per_sec"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("shed/goodput datapoints" in f for f in fails)

    def test_missing_shed_leg_fails_even_on_refresh(self, tmp_path,
                                                    monkeypatch):
        # a stale bench that stops producing the shed leg must not
        # pass green, and REFRESH must refuse to bake the gap into
        # the committed baseline (which would disable the shed gates)
        cur = serve_load_json()
        del cur["shed"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("shed: block missing" in f for f in fails)
        # truncated shed block is caught too
        cur = serve_load_json()
        del cur["shed"]["p95_vs_unbounded"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("shed: missing" in f for f in fails)
        # end to end: refresh refuses
        (tmp_path / "BENCH_decode.json").write_text(
            json.dumps(decode_json()))
        nolegs = serve_load_json()
        del nolegs["shed"]
        (tmp_path / "BENCH_serve_load.json").write_text(
            json.dumps(nolegs))
        monkeypatch.setenv("BENCH_GATE_REFRESH", "1")
        assert gate.main(["bench_gate.py", str(tmp_path)]) == 1
        assert not (tmp_path / "bench_baselines"
                    / "BENCH_serve_load.json").exists()

    def test_shed_p95_above_unbounded_fails_absolutely(self):
        # shedding must never make the completed tail WORSE than just
        # queueing unbounded — enforced without a baseline
        cur = serve_load_json(shed_ratio=1.5)
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("shed.p95_vs_unbounded" in f for f in fails)

    def test_shed_goodput_relative_regression_fails(self):
        base = serve_load_json()
        cur = serve_load_json()
        cur["shed"]["goodput_tokens_per_sec"] = \
            base["shed"]["goodput_tokens_per_sec"] * 0.5
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, base,
                                   0.25)
        assert any("shed.goodput_tokens_per_sec" in f for f in fails)

    def test_baseline_without_shed_fields_is_tolerated(self):
        # old committed baselines predate the shed/goodput datapoints:
        # relative gates skip them, fresh-side structure still holds
        cur = serve_load_json()
        base = serve_load_json()
        del base["shed"]
        for p in base["points"]:
            del p["shed_rate"]
            del p["goodput_tokens_per_sec"]
            del p["admission"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, base,
                                   0.25)
        assert fails == []


class TestMultiModelGates:
    def test_missing_multi_model_leg_fails(self):
        # the smoke must run the registry leg — with no baseline at
        # all its absence is already a hard failure
        cur = serve_load_json()
        del cur["multi_model"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("multi_model: block missing" in f for f in fails)

    def test_truncated_multi_model_leg_fails(self):
        # fewer than 2 per-model points means nothing was multiplexed
        cur = serve_load_json()
        cur["multi_model"]["per_model"] = \
            cur["multi_model"]["per_model"][:1]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any(">= 2 per-model points" in f for f in fails)
        # missing aggregate block is caught too
        cur = serve_load_json()
        del cur["multi_model"]["aggregate"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("multi_model.aggregate" in f for f in fails)
        # per-model points must carry the gated datapoints
        cur = serve_load_json()
        del cur["multi_model"]["per_model"][1]["goodput_tokens_per_sec"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("per_model[1]: missing" in f for f in fails)
        # ... and so must the aggregate block, whose goodput/p95 feed
        # two relative gates that would otherwise silently skip
        cur = serve_load_json()
        del cur["multi_model"]["aggregate"]["goodput_tokens_per_sec"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("aggregate: missing goodput_tokens_per_sec" in f
                   for f in fails)

    def test_per_model_sums_must_match_aggregate(self):
        # conservation in the gate: a registry loop that loses or
        # double-counts a request must not pass green
        cur = serve_load_json()
        cur["multi_model"]["per_model"][0]["completed"] -= 2
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("sum" in f and "aggregate" in f for f in fails)

    def test_per_model_goodput_regression_fails(self):
        base = serve_load_json()
        cur = serve_load_json()
        cur["multi_model"]["per_model"][1] \
            ["goodput_tokens_per_sec"] *= 0.5
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, base,
                                   0.25)
        assert any("per_model(m1).goodput_tokens_per_sec" in f
                   for f in fails)
        # the untouched model stays green
        assert not any("per_model(m0)" in f for f in fails)

    def test_dropping_a_baseline_model_fails(self):
        # a model gated in the baseline must not vanish silently from
        # the fresh leg — that would disable its gates forever
        base = serve_load_json()
        base["multi_model"]["per_model"].append(
            model_point("m2", 0, 0, 10.0))
        fails, _ = gate.check_file("BENCH_serve_load.json",
                                   serve_load_json(), base, 0.25)
        assert any("m2 in baseline but missing" in f for f in fails)

    def test_baseline_without_multi_model_skips_with_note(self):
        cur = serve_load_json()
        base = serve_load_json()
        del base["multi_model"]
        fails, notes = gate.check_file("BENCH_serve_load.json", cur,
                                       base, 0.25)
        assert fails == []
        assert any("predates the multi-model leg" in n for n in notes)

    def test_refresh_refuses_truncated_multi_model_leg(self, tmp_path,
                                                       monkeypatch):
        # REFRESH must not bake a multi-model-less file into the
        # committed baseline (which would disable the gates forever)
        (tmp_path / "BENCH_decode.json").write_text(
            json.dumps(decode_json()))
        noleg = serve_load_json()
        del noleg["multi_model"]
        (tmp_path / "BENCH_serve_load.json").write_text(
            json.dumps(noleg))
        monkeypatch.setenv("BENCH_GATE_REFRESH", "1")
        assert gate.main(["bench_gate.py", str(tmp_path)]) == 1
        assert not (tmp_path / "bench_baselines"
                    / "BENCH_serve_load.json").exists()


class TestFaultGates:
    def test_missing_fault_leg_fails(self):
        # the smoke must run the fault-injection leg — with no
        # baseline at all its absence is already a hard failure
        cur = serve_load_json()
        del cur["fault"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("fault: block missing" in f for f in fails)

    def test_truncated_fault_leg_fails(self):
        # an empty rate sweep means the leg never ran
        cur = serve_load_json()
        cur["fault"]["rates"] = []
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("fault.rates: missing or empty" in f
                   for f in fails)
        # a sweep of only zero rates never injected anything
        cur = serve_load_json()
        cur["fault"]["rates"] = [fault_rate_row(0.0)]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("no nonzero fault rate" in f for f in fails)
        # a rate row must carry both variants
        cur = serve_load_json()
        del cur["fault"]["rates"][1]["failover"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("missing failover datapoint" in f for f in fails)
        # ... and each variant the gated outcome counters
        cur = serve_load_json()
        del cur["fault"]["rates"][1]["no_failover"]["failed"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("rates[1].no_failover: missing failed" in f
                   for f in fails)

    def test_fault_outcome_conservation(self):
        # completed + shed + expired + failed must equal requests in
        # every variant — a mismatch means the loop lost a request
        cur = serve_load_json()
        cur["fault"]["rates"][1]["no_failover"]["completed"] -= 1
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("lost or double-counted" in f for f in fails)

    def test_failover_goodput_below_no_failover_fails(self):
        # the recovery invariant: at every nonzero fault rate the
        # failover run must be at least as good — enforced without a
        # baseline
        cur = serve_load_json()
        cur["fault"]["rates"][1]["failover"] \
            ["goodput_tokens_per_sec"] = 50.0
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("failover goodput" in f for f in fails)
        # at a zero fault rate the pair is unconstrained
        cur = serve_load_json()
        cur["fault"]["rates"][0]["failover"] \
            ["goodput_tokens_per_sec"] = 50.0
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert fails == []

    def test_fault_goodput_above_raw_throughput_fails(self):
        # completed-only tokens/sec can never beat the count that
        # includes dropped work — a higher goodput means the telemetry
        # is again counting failed requests' partial output as
        # delivered (the pre-fix bug)
        cur = serve_load_json()
        v = cur["fault"]["rates"][1]["no_failover"]
        v["goodput_tokens_per_sec"] = v["tokens_per_vsec"] * 1.5
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("cannot beat" in f for f in fails)
        # a variant missing the raw-throughput datapoint is truncated
        cur = serve_load_json()
        del cur["fault"]["rates"][1]["failover"]["tokens_per_vsec"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("rates[1].failover: missing tokens_per_vsec" in f
                   for f in fails)

    def test_refresh_refuses_missing_fault_leg(self, tmp_path,
                                               monkeypatch):
        # REFRESH must not bake a fault-leg-less file into the
        # committed baseline (which would disable the gates forever)
        (tmp_path / "BENCH_decode.json").write_text(
            json.dumps(decode_json()))
        noleg = serve_load_json()
        del noleg["fault"]
        (tmp_path / "BENCH_serve_load.json").write_text(
            json.dumps(noleg))
        monkeypatch.setenv("BENCH_GATE_REFRESH", "1")
        assert gate.main(["bench_gate.py", str(tmp_path)]) == 1
        assert not (tmp_path / "bench_baselines"
                    / "BENCH_serve_load.json").exists()

    def test_baseline_without_fault_leg_is_tolerated(self):
        # old committed baselines predate the fault leg: the checks
        # are fresh-side only, so a healthy fresh file stays green
        cur = serve_load_json()
        base = serve_load_json()
        del base["fault"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, base,
                                   0.25)
        assert fails == []


class TestSparseGates:
    def test_missing_sparse_leg_fails(self):
        # the smoke must run the CSR-resident sparse leg — with no
        # baseline at all its absence is already a hard failure
        cur = serve_load_json()
        del cur["sparse"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("sparse: block missing" in f for f in fails)

    def test_truncated_sparse_leg_fails(self):
        # a keyless block would silently disable the speedup gate
        cur = serve_load_json()
        del cur["sparse"]["measured_speedup"]
        del cur["sparse"]["required_speedup"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("sparse: missing" in f for f in fails)
        # both routed runs must be present with their counters
        cur = serve_load_json()
        del cur["sparse"]["s75"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("missing s75 datapoint" in f for f in fails)
        cur = serve_load_json()
        del cur["sparse"]["dense"]["tokens_per_vsec"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("sparse.dense: missing tokens_per_vsec" in f
                   for f in fails)

    def test_speedup_below_required_fails_absolutely(self):
        # the acceptance gate: s75 tokens/vs over dense tokens/vs must
        # be at least sqrt of the FLOPs ratio — with no baseline at all
        cur = serve_load_json()
        cur["sparse"] = sparse_json(measured=1.5, required=2.0)
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("measured speedup" in f for f in fails)

    def test_incomplete_routed_run_fails(self):
        # the leg serves an unbounded queue: a dropped request means
        # the registry loop lost it, not that load was shed
        cur = serve_load_json()
        cur["sparse"]["s75"]["completed"] -= 1
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("sparse.s75" in f and "must" in f for f in fails)

    def test_csr_residency_must_save_bytes(self):
        # holding the checkpoint CSR-resident must actually beat the
        # dense byte cost at the sweep's sparsity
        cur = serve_load_json()
        cur["sparse"]["csr_host_bytes"] = \
            cur["sparse"]["dense_equiv_bytes"] + 1
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("residency" in f for f in fails)

    def test_measured_speedup_relative_regression_fails(self):
        # beyond the absolute floor, a big drop vs the committed
        # baseline is still a regression (e.g. a clock calibration
        # change that halves the sparse advantage)
        base = serve_load_json()
        base["sparse"] = sparse_json(measured=8.0)
        fails, _ = gate.check_file("BENCH_serve_load.json",
                                   serve_load_json(), base, 0.25)
        assert any("sparse.measured_speedup" in f for f in fails)

    def test_refresh_refuses_missing_sparse_leg(self, tmp_path,
                                                monkeypatch):
        # REFRESH must not bake a sparse-leg-less file into the
        # committed baseline (which would disable the gates forever)
        (tmp_path / "BENCH_decode.json").write_text(
            json.dumps(decode_json()))
        noleg = serve_load_json()
        del noleg["sparse"]
        (tmp_path / "BENCH_serve_load.json").write_text(
            json.dumps(noleg))
        monkeypatch.setenv("BENCH_GATE_REFRESH", "1")
        assert gate.main(["bench_gate.py", str(tmp_path)]) == 1
        assert not (tmp_path / "bench_baselines"
                    / "BENCH_serve_load.json").exists()

    def test_baseline_without_sparse_leg_is_tolerated(self):
        # old committed baselines predate the sparse leg: the checks
        # are fresh-side only and the relative speedup gate skips
        cur = serve_load_json()
        base = serve_load_json()
        del base["sparse"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, base,
                                   0.25)
        assert fails == []


class TestSpeculativeGates:
    def test_missing_speculative_leg_fails(self):
        # the smoke must run the speculative leg — with no baseline
        # at all its absence is already a hard failure
        cur = serve_load_json()
        del cur["speculative"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("speculative: block missing" in f for f in fails)

    def test_truncated_speculative_leg_fails(self):
        # a keyless block would silently disable the bitwise and
        # break-even gates
        cur = serve_load_json()
        del cur["speculative"]["bitwise_equal"]
        del cur["speculative"]["measured_speedup"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("speculative: missing" in f for f in fails)
        # both routed runs must be present with their counters
        cur = serve_load_json()
        del cur["speculative"]["spec"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("missing spec datapoint" in f for f in fails)
        cur = serve_load_json()
        del cur["speculative"]["dense"]["tokens_per_vsec"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("speculative.dense: missing tokens_per_vsec" in f
                   for f in fails)

    def test_bitwise_mismatch_fails_absolutely(self):
        # THE speculation invariant: spec output must be bit-identical
        # to the plain dense stream — enforced with no baseline at all
        cur = serve_load_json()
        cur["speculative"] = speculative_json(bitwise=False)
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("bit-identical" in f for f in fails)

    def test_verify_without_progress_fails(self):
        # every verify commits the agreeing prefix plus a correction;
        # only the terminal EOS verify emits nothing, so verifies is
        # bounded by emitted tokens + one per completed request —
        # here 400 > 320 tokens + 10 completions
        cur = serve_load_json()
        cur["speculative"]["verifies"] = 400
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("committed no progress" in f for f in fails)

    def test_eos_heavy_verify_count_passes(self):
        # tokens_per_verify below 1.0 is legitimate when streams end
        # on an EOS verify: 325 verifies vs 320 tokens + 10 requests
        cur = serve_load_json()
        cur["speculative"]["verifies"] = 325
        cur["speculative"]["tokens_per_verify"] = 320 / 325
        cur["speculative"]["mean_acceptance"] = \
            cur["speculative"]["accepted"] / 325
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert fails == []

    def test_bookkeeping_must_conserve_tokens(self):
        # accepted + corrections must equal the spec run's emitted
        # tokens — a mismatch means a counter drifted from the stream
        cur = serve_load_json()
        cur["speculative"]["accepted"] += 3
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("lost or invented a token" in f for f in fails)

    def test_never_engaged_leg_fails(self):
        cur = serve_load_json()
        cur["speculative"]["drafted"] = 0
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("never engaged" in f for f in fails)

    def test_acceptance_threshold_gate(self):
        # acceptance above the k(1-s) floor with no throughput win is
        # a regression — enforced without a baseline
        cur = serve_load_json()
        cur["speculative"] = speculative_json(mean_acceptance=3.0,
                                              floor=1.0, speedup=0.8)
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("break-even floor" in f for f in fails)
        # below the floor speculation is allowed to lose: the drafts
        # were too wrong to pay for themselves
        cur = serve_load_json()
        cur["speculative"] = speculative_json(mean_acceptance=0.8,
                                              floor=1.0, speedup=0.8)
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert fails == []

    def test_incomplete_routed_run_fails(self):
        # the leg serves an unbounded queue: speculating must never
        # drop a request (draft-lane loss degrades to plain dense)
        cur = serve_load_json()
        cur["speculative"]["spec"]["completed"] -= 1
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("speculative.spec" in f and "must" in f
                   for f in fails)

    def test_measured_speedup_relative_regression_fails(self):
        # beyond the absolute gates, a big drop vs the committed
        # baseline is still a regression (e.g. an acceptance collapse
        # after a drafting change)
        base = serve_load_json()
        base["speculative"] = speculative_json(speedup=8.0)
        fails, _ = gate.check_file("BENCH_serve_load.json",
                                   serve_load_json(), base, 0.25)
        assert any("speculative.measured_speedup" in f for f in fails)

    def test_refresh_refuses_missing_speculative_leg(self, tmp_path,
                                                     monkeypatch):
        # REFRESH must not bake a speculative-leg-less file into the
        # committed baseline (which would disable the gates forever)
        (tmp_path / "BENCH_decode.json").write_text(
            json.dumps(decode_json()))
        noleg = serve_load_json()
        del noleg["speculative"]
        (tmp_path / "BENCH_serve_load.json").write_text(
            json.dumps(noleg))
        monkeypatch.setenv("BENCH_GATE_REFRESH", "1")
        assert gate.main(["bench_gate.py", str(tmp_path)]) == 1
        assert not (tmp_path / "bench_baselines"
                    / "BENCH_serve_load.json").exists()

    def test_refresh_refuses_bitwise_mismatch(self, tmp_path,
                                              monkeypatch):
        # nor may a bitwise-diverging run ever become the norm
        (tmp_path / "BENCH_decode.json").write_text(
            json.dumps(decode_json()))
        bad = serve_load_json()
        bad["speculative"] = speculative_json(bitwise=False)
        (tmp_path / "BENCH_serve_load.json").write_text(
            json.dumps(bad))
        monkeypatch.setenv("BENCH_GATE_REFRESH", "1")
        assert gate.main(["bench_gate.py", str(tmp_path)]) == 1
        assert not (tmp_path / "bench_baselines"
                    / "BENCH_serve_load.json").exists()

    def test_baseline_without_speculative_leg_is_tolerated(self):
        # old committed baselines predate the speculative leg: the
        # checks are fresh-side only and the relative gates skip
        cur = serve_load_json()
        base = serve_load_json()
        del base["speculative"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, base,
                                   0.25)
        assert fails == []


class TestPagedGates:
    def test_missing_paged_leg_fails(self):
        # the smoke must run the paged-KV leg — with no baseline at
        # all its absence is already a hard failure
        cur = serve_load_json()
        del cur["paged"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("paged: block missing" in f for f in fails)

    def test_truncated_paged_leg_fails(self):
        # a keyless block would silently disable the bitwise and
        # concurrency gates
        cur = serve_load_json()
        del cur["paged"]["bitwise_equal"]
        del cur["paged"]["leaked_pages"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("paged: missing" in f for f in fails)
        # both reservation arms must be present with their counters
        cur = serve_load_json()
        del cur["paged"]["paged"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("missing paged datapoint" in f for f in fails)
        cur = serve_load_json()
        del cur["paged"]["full"]["tokens_per_vsec"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("paged.full: missing tokens_per_vsec" in f
                   for f in fails)

    def test_bitwise_mismatch_fails_absolutely(self):
        # THE paging invariant: an unconstrained paged run must decode
        # bit-identically to the monolithic loop — enforced with no
        # baseline at all
        cur = serve_load_json()
        cur["paged"] = paged_json(bitwise=False)
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("bit-identically" in f for f in fails)

    def test_leaked_pages_fail_absolutely(self):
        # a page unaccounted for at drain means the allocator lost it
        cur = serve_load_json()
        cur["paged"] = paged_json(leaked=2)
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("pages leaked" in f for f in fails)

    def test_paged_concurrency_must_beat_full_reservation(self):
        # the headline claim: prompt-sized reservation seats strictly
        # more concurrent requests than full-context reservation at
        # the same page budget
        cur = serve_load_json()
        cur["paged"] = paged_json(full_seats=4, paged_seats=4)
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("buys no concurrency" in f for f in fails)

    def test_incomplete_arm_fails(self):
        # the leg serves an unbounded queue and preempted requests
        # requeue: a dropped request means the loop lost it
        cur = serve_load_json()
        cur["paged"]["paged"]["completed"] -= 1
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("paged.paged" in f and "requeue" in f
                   for f in fails)

    def test_goodput_above_raw_throughput_fails(self):
        # preemption rollbacks drop work: goodput counting only
        # delivered tokens can never exceed the raw rate
        cur = serve_load_json()
        v = cur["paged"]["paged"]
        v["goodput_tokens_per_sec"] = v["tokens_per_vsec"] * 2.0
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, None,
                                   0.25)
        assert any("paged.paged: goodput" in f for f in fails)

    def test_refresh_refuses_missing_paged_leg(self, tmp_path,
                                               monkeypatch):
        # REFRESH must not bake a paged-leg-less file into the
        # committed baseline (which would disable the gates forever)
        (tmp_path / "BENCH_decode.json").write_text(
            json.dumps(decode_json()))
        noleg = serve_load_json()
        del noleg["paged"]
        (tmp_path / "BENCH_serve_load.json").write_text(
            json.dumps(noleg))
        monkeypatch.setenv("BENCH_GATE_REFRESH", "1")
        assert gate.main(["bench_gate.py", str(tmp_path)]) == 1
        assert not (tmp_path / "bench_baselines"
                    / "BENCH_serve_load.json").exists()

    def test_refresh_refuses_leaked_pages(self, tmp_path,
                                          monkeypatch):
        # nor may a leaking allocator ever become the norm
        (tmp_path / "BENCH_decode.json").write_text(
            json.dumps(decode_json()))
        bad = serve_load_json()
        bad["paged"] = paged_json(leaked=1)
        (tmp_path / "BENCH_serve_load.json").write_text(
            json.dumps(bad))
        monkeypatch.setenv("BENCH_GATE_REFRESH", "1")
        assert gate.main(["bench_gate.py", str(tmp_path)]) == 1
        assert not (tmp_path / "bench_baselines"
                    / "BENCH_serve_load.json").exists()

    def test_baseline_without_paged_leg_is_tolerated(self):
        # old committed baselines predate the paged leg: the checks
        # are fresh-side only, so a healthy fresh file stays green
        cur = serve_load_json()
        base = serve_load_json()
        del base["paged"]
        fails, _ = gate.check_file("BENCH_serve_load.json", cur, base,
                                   0.25)
        assert fails == []


class TestBootstrapAndRefresh:
    def test_missing_baseline_bootstraps_green(self):
        fails, notes = gate.check_file("BENCH_decode.json",
                                       decode_json(), None, 0.25)
        assert fails == []
        assert any("bootstrap" in n for n in notes)

    def _write_fresh(self, root, ratio=0.9, tps=100.0, p95=100.0):
        (root / "BENCH_decode.json").write_text(
            json.dumps(decode_json(tps=tps)))
        (root / "BENCH_serve_load.json").write_text(
            json.dumps(serve_load_json(ratio=ratio, p95=p95)))

    def test_main_end_to_end(self, tmp_path, monkeypatch):
        root = tmp_path
        self._write_fresh(root)
        # bootstrap: no baselines committed yet -> green
        monkeypatch.delenv("BENCH_GATE_REFRESH", raising=False)
        monkeypatch.delenv("BENCH_GATE_TOL", raising=False)
        assert gate.main(["bench_gate.py", str(root)]) == 0

        # refresh knob commits the fresh datapoints as baselines
        monkeypatch.setenv("BENCH_GATE_REFRESH", "1")
        assert gate.main(["bench_gate.py", str(root)]) == 0
        assert (root / "bench_baselines"
                / "BENCH_decode.json").exists()
        monkeypatch.delenv("BENCH_GATE_REFRESH")

        # same numbers vs the new baselines -> green
        assert gate.main(["bench_gate.py", str(root)]) == 0

        # synthetically regressed datapoint -> the gate demonstrably
        # fails
        self._write_fresh(root, tps=40.0, p95=300.0)
        assert gate.main(["bench_gate.py", str(root)]) == 1

        # a looser tolerance waves the same numbers through
        monkeypatch.setenv("BENCH_GATE_TOL", "5.0")
        assert gate.main(["bench_gate.py", str(root)]) == 0

    def test_main_fails_on_missing_fresh_datapoint(self, tmp_path):
        # smoke produced nothing: hard failure, not a silent pass
        assert gate.main(["bench_gate.py", str(tmp_path)]) == 1

    def test_refresh_refuses_invariant_violating_baseline(
            self, tmp_path, monkeypatch):
        # a kv-worse-than-literal datapoint must not be committable as
        # the new norm via the refresh knob
        self._write_fresh(tmp_path, ratio=1.6)
        monkeypatch.setenv("BENCH_GATE_REFRESH", "1")
        assert gate.main(["bench_gate.py", str(tmp_path)]) == 1
        assert not (tmp_path / "bench_baselines"
                    / "BENCH_serve_load.json").exists()
        # the healthy file still refreshes
        assert (tmp_path / "bench_baselines"
                / "BENCH_decode.json").exists()


@pytest.mark.parametrize("dotted,expect", [
    ("engine.tokens_per_sec", 100.0),
    ("serve.latency_ms.p95", 500.0),
    ("missing.path", None),
    ("engine", None),  # non-leaf is not a number
])
def test_get_path(dotted, expect):
    assert gate.get_path(decode_json(), dotted) == expect
