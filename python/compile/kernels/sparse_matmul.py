"""CSR-fed block-sparse matmul as a Pallas kernel, bitwise-pinned to dense.

The serving side (rust `runtime::LiteralCache`) holds sparse-pre-trained
checkpoints as CSR and the decode step computes ``y = x @ W`` where most
of ``W`` is zero.  This kernel is the compute mirror of that storage
decision: the weight matrix is tiled exactly like ``pallas_matmul`` and
an int32 **block-nonzero map** (one count per ``(bk, bn)`` weight tile,
derived from the CSR structure) lets the kernel skip the dot-accumulate
for tiles that hold no nonzeros.

The skip is *bitwise* invisible, not approximately so.  The output tile
is a float32 accumulator initialized to +0.0, and in IEEE-754
round-to-nearest arithmetic adding a product of an all-zero weight tile
can only add ``+0.0`` or ``-0.0`` to each accumulator element:

* ``acc + (+-0.0) == acc`` bit-for-bit whenever ``acc`` is nonzero, and
* the accumulator can never itself be ``-0.0`` (it starts at ``+0.0``
  and a float32 sum only produces ``-0.0`` when *both* addends are
  ``-0.0``), so ``+0.0 + (-0.0) == +0.0`` covers the zero case.

Dropping an all-zero tile therefore changes time, never bits — the same
argument by which rust's ``Csr::spmm`` skips stored zeros yet stays
bit-identical to ``dense_matmul``.  The one caveat: the products are
only ±0 for *finite* activations.  A NaN/Inf activation lined up
against an all-zero weight tile would be manufactured into NaN by the
dense path (``NaN * 0 = NaN``); the sparse path's skip is the
semantically correct behaviour there, and the tests pin both the
identical NaN propagation through *nonzero* tiles and the divergence on
skipped ones.  The pin enforced by the tests is

    sparse_pallas_matmul(x, csr) == pallas_matmul(x, csr_to_dense(csr))

with NumPy bit-pattern equality (``float32.view(uint32)``), for every
checkpoint sparsity in the SPDF sweep.  (Note the pin is against the
*same tiling*: the blocked accumulation order differs from the k-major
order of ``spmm_ref`` below, so those two references are each bitwise
against their own dense mirror, not against each other.)

Like every kernel in this package the Pallas call is lowered with
``interpret=True`` so the HLO runs on any PJRT backend, including the
rust CPU client.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .masked_matmul import kernel_stats, pick_blocks


# ---------------------------------------------------------------------------
# CSR host format (mirror of rust `sparse_compute::Csr`)
# ---------------------------------------------------------------------------

class Csr:
    """Row-major CSR with the exact semantics of rust ``Csr::from_dense``:
    stored entries are the values ``v != 0.0`` — which drops ``-0.0`` too,
    since ``-0.0 != 0.0`` is false — so ``to_dense`` is an exact inverse.
    """

    def __init__(self, rows, cols, row_ptr, col_idx, values):
        self.rows = int(rows)
        self.cols = int(cols)
        self.row_ptr = np.asarray(row_ptr, dtype=np.int64)
        self.col_idx = np.asarray(col_idx, dtype=np.int32)
        self.values = np.asarray(values, dtype=np.float32)

    @property
    def nnz(self):
        return int(self.values.size)

    def density(self):
        total = self.rows * self.cols
        return self.nnz / total if total else 0.0


def csr_from_dense(w):
    """Compress a dense (k, n) float32 matrix, dropping exact zeros."""
    w = np.asarray(w, dtype=np.float32)
    assert w.ndim == 2, f"expected a matrix, got shape {w.shape}"
    rows, cols = w.shape
    # `w != 0.0` is the rust keep-predicate verbatim (False for -0.0).
    keep = w != 0.0
    row_ptr = np.zeros(rows + 1, dtype=np.int64)
    np.cumsum(keep.sum(axis=1), out=row_ptr[1:])
    col_idx = np.nonzero(keep)[1].astype(np.int32)
    return Csr(rows, cols, row_ptr, col_idx, w[keep])


def csr_to_dense(csr):
    """Exact inverse of :func:`csr_from_dense` (bit-for-bit)."""
    out = np.zeros((csr.rows, csr.cols), dtype=np.float32)
    for r in range(csr.rows):
        lo, hi = csr.row_ptr[r], csr.row_ptr[r + 1]
        out[r, csr.col_idx[lo:hi]] = csr.values[lo:hi]
    return out


# ---------------------------------------------------------------------------
# Elementwise references (ports of rust spmm / dense_matmul)
# ---------------------------------------------------------------------------

def spmm_ref(csr, b):
    """Port of rust ``Csr::spmm``: ``csr.to_dense() @ b`` walking stored
    entries in k-major order per output row (f32 mul then add, no FMA)."""
    b = np.asarray(b, dtype=np.float32)
    assert b.shape[0] == csr.cols
    out = np.zeros((csr.rows, b.shape[1]), dtype=np.float32)
    for r in range(csr.rows):
        for e in range(csr.row_ptr[r], csr.row_ptr[r + 1]):
            out[r] += csr.values[e] * b[csr.col_idx[e]]
    return out


def dense_matmul_ref(a, b):
    """Port of rust ``dense_matmul``: same k-major loop over *all* of
    ``a``, skipping ``av == 0.0`` (true for -0.0 as well) — the dense
    mirror that :func:`spmm_ref` must match bitwise."""
    a = np.asarray(a, dtype=np.float32)
    b = np.asarray(b, dtype=np.float32)
    out = np.zeros((a.shape[0], b.shape[1]), dtype=np.float32)
    for r in range(a.shape[0]):
        for k in range(a.shape[1]):
            av = a[r, k]
            if av == 0.0:
                continue
            out[r] += av * b[k]
    return out


# ---------------------------------------------------------------------------
# Block-sparse Pallas kernel
# ---------------------------------------------------------------------------

def block_nonzero_map(csr, bk, bn):
    """Per-tile stored-entry counts, shape ``(k // bk, n // bn)`` int32.

    Built from the CSR structure directly (row_ptr/col_idx), not from a
    densified copy — the map is the kernel-facing summary of what the
    storage layer already knows.
    """
    k, n = csr.rows, csr.cols
    assert k % bk == 0 and n % bn == 0, \
        f"blocks ({bk},{bn}) must divide weight dims ({k},{n})"
    nz = np.zeros((k // bk, n // bn), dtype=np.int32)
    for r in range(k):
        lo, hi = csr.row_ptr[r], csr.row_ptr[r + 1]
        tiles, counts = np.unique(csr.col_idx[lo:hi] // bn,
                                  return_counts=True)
        nz[r // bk, tiles] += counts.astype(np.int32)
    return nz


def _sparse_mm_kernel(x_ref, w_ref, nz_ref, o_ref, *, nk):
    """Tiled matmul that skips all-zero weight tiles.

    Identical to ``_mm_kernel`` except the dot-accumulate is predicated
    on the tile's nonzero count — bitwise-safe by the +0-accumulator
    argument in the module docstring."""
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(nz_ref[0, 0] > 0)
    def _accumulate():
        o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                              preferred_element_type=jnp.float32)


def sparse_pallas_matmul(x, csr, blocks=None):
    """``x @ csr.to_dense()`` via the block-skipping Pallas kernel.

    Bitwise-equal to ``pallas_matmul(x, csr_to_dense(csr))`` at the same
    ``blocks`` — the dense-equivalence pin (see module docstring)."""
    m, k = x.shape
    assert k == csr.rows, f"inner dims mismatch: {k} vs {csr.rows}"
    n = csr.cols
    if blocks is None:
        blocks = pick_blocks(m, n, k, n_operands=2)
    bm, bn, bk = blocks
    grid = (m // bm, n // bn, k // bk)
    w = jnp.asarray(csr_to_dense(csr))
    nz = jnp.asarray(block_nonzero_map(csr, bk, bn))
    return pl.pallas_call(
        functools.partial(_sparse_mm_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, nz)


def sparse_kernel_stats(m, csr, blocks=None):
    """:func:`kernel_stats` for the sparse decode step, extended with
    what the block skip and the CSR residency actually buy.

    Adds to the dense-kernel dict:
      ``nonzero_tiles`` / ``total_tiles`` — block-map occupancy,
      ``flops``          — rescaled by the visited-tile fraction,
      ``dense_flops``    — the unskipped count, for the ratio,
      ``csr_bytes`` / ``dense_bytes`` — host residency cost (CSR layout
      as in rust ``SlotResidency::host_bytes``: 8 bytes per stored
      entry + 8 per row-pointer vs 4 per dense element).
    """
    k, n = csr.rows, csr.cols
    stats = kernel_stats(m, n, k, blocks=blocks, masked=False)
    bm, bn, bk = stats["blocks"]
    nz = block_nonzero_map(csr, bk, bn)
    total_tiles = int(nz.size)
    nonzero_tiles = int(np.count_nonzero(nz))
    visited = nonzero_tiles / total_tiles if total_tiles else 0.0
    stats["nonzero_tiles"] = nonzero_tiles
    stats["total_tiles"] = total_tiles
    stats["dense_flops"] = stats["flops"]
    stats["flops"] = int(stats["dense_flops"] * visited)
    stats["csr_bytes"] = 8 * csr.nnz + 8 * (csr.rows + 1)
    stats["dense_bytes"] = 4 * csr.rows * csr.cols
    return stats
