"""Masked (sparse-weight) matmul as a Pallas kernel.

This is the compute hot-spot of SPDF: every sparsified linear layer
computes ``y = x @ (m * w)`` where ``m`` is a static binary mask.  On the
Cerebras CS-2 the hardware skips the zero weights; on a TPU-shaped target
the insight maps to a VMEM-tiled schedule where the mask is applied at
tile granularity on the way into the MXU, and all-zero mask tiles
contribute nothing (see DESIGN.md §Hardware-Adaptation).

The kernel is written for TPU structure (BlockSpec HBM->VMEM schedule,
MXU-friendly ``jnp.dot`` inner loop) but is always lowered with
``interpret=True`` so the resulting HLO runs on any PJRT backend,
including the rust CPU client.  Correctness is pinned against the
pure-jnp oracle in ``ref.py``.

Autodiff: Pallas calls are not differentiable in interpret mode, so
``masked_matmul`` carries a custom VJP whose backward pass is itself
built from Pallas matmuls:

    dx = g @ (m * w)^T        dw = m * (x^T @ g)

The mask is not differentiated (it is a constant of the training phase);
its cotangent is a symbolic zero that XLA dead-code-eliminates.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Simulated TPU core limits used by the block-size heuristic and the
# analytic performance model (v4-ish numbers).
VMEM_BYTES = 16 * 1024 * 1024
MXU_DIM = 128


def pick_blocks(m, n, k, max_block=512, vmem_bytes=VMEM_BYTES, n_operands=3):
    """Choose (bm, bn, bk) tile sizes for an (m,k) @ (k,n) matmul.

    Strategy: the largest power-of-two-ish divisors of each dim capped at
    ``max_block`` such that the working set (x-tile + w-tile + optional
    mask-tile + out-tile, all f32) fits in VMEM.  For the tiny simulation
    models the blocks collapse to the full dims (grid = 1), which also
    minimizes interpret-mode overhead; at paper scale (12k x 12k) the same
    heuristic yields a real multi-tile schedule (exercised in tests).
    """

    def divisor_cap(dim, cap):
        b = min(dim, cap)
        while dim % b != 0:
            b -= 1
        return b

    bm, bn, bk = (divisor_cap(m, max_block), divisor_cap(n, max_block),
                  divisor_cap(k, max_block))
    # shrink until the tile working set fits in VMEM
    while _tile_bytes(bm, bn, bk, n_operands) > vmem_bytes:
        # shrink the largest tile dimension first
        if bm >= bn and bm >= bk and bm > 1:
            bm = divisor_cap(m, bm // 2)
        elif bn >= bk and bn > 1:
            bn = divisor_cap(n, bn // 2)
        elif bk > 1:
            bk = divisor_cap(k, bk // 2)
        else:
            break
    return bm, bn, bk


def _tile_bytes(bm, bn, bk, n_operands=3):
    """f32 working-set bytes for one grid step.

    x-tile (bm,bk) + w-tile (bk,bn) [+ mask-tile (bk,bn)] + out (bm,bn).
    """
    w_tiles = 2 if n_operands >= 3 else 1
    return 4 * (bm * bk + w_tiles * bk * bn + bm * bn)


def kernel_stats(m, n, k, blocks=None, masked=True):
    """Analytic performance estimate for a tiling (DESIGN.md §Perf).

    Returns a dict with the VMEM working set, grid shape, and an MXU
    utilization estimate: the fraction of each 128x128 systolic pass that
    carries real data (tiles smaller than the MXU waste the remainder).
    """
    n_operands = 3 if masked else 2
    if blocks is None:
        blocks = pick_blocks(m, n, k, n_operands=n_operands)
    bm, bn, bk = blocks
    grid = (m // bm, n // bn, k // bk)

    def eff(dim):
        pad = -dim % MXU_DIM
        return dim / (dim + pad)

    mxu_utilization = eff(bm) * eff(bn) * eff(bk)
    return {
        "blocks": (bm, bn, bk),
        "grid": grid,
        "vmem_bytes": _tile_bytes(bm, bn, bk, n_operands),
        "vmem_fraction": _tile_bytes(bm, bn, bk, n_operands) / VMEM_BYTES,
        "mxu_utilization": mxu_utilization,
        "flops": 2 * m * n * k,
        "hbm_bytes": 4 * (grid[1] * m * k + grid[0] * k * n * n_operands
                          + m * n),
    }


def _mm_kernel(x_ref, w_ref, o_ref, *, nk):
    """Plain tiled matmul: accumulate over the k-grid into the out tile."""
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=jnp.float32)


def _masked_mm_kernel(x_ref, w_ref, m_ref, o_ref, *, nk):
    """Masked tiled matmul: the mask is applied at tile granularity on the
    way into the MXU — an all-zero mask tile contributes nothing."""
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    wm = w_ref[...] * m_ref[...]
    o_ref[...] += jnp.dot(x_ref[...], wm,
                          preferred_element_type=jnp.float32)


def pallas_matmul(x, w, blocks=None):
    """``x @ w`` via the tiled Pallas kernel (interpret mode)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    if blocks is None:
        blocks = pick_blocks(m, n, k, n_operands=2)
    bm, bn, bk = blocks
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_mm_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def _masked_matmul_impl(x, w, mask, blocks=None):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert w.shape == mask.shape, f"mask shape {mask.shape} != w {w.shape}"
    if blocks is None:
        blocks = pick_blocks(m, n, k, n_operands=3)
    bm, bn, bk = blocks
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_masked_mm_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, mask)


@jax.custom_vjp
def masked_matmul(x, w, mask):
    """``x @ (mask * w)`` — the SPDF sparse linear layer hot-spot.

    x: (m, k) activations, w: (k, n) weights, mask: (k, n) binary f32.
    Differentiable w.r.t. x and w; the mask cotangent is zero.
    """
    return _masked_matmul_impl(x, w, mask)


def _masked_matmul_fwd(x, w, mask):
    return _masked_matmul_impl(x, w, mask), (x, w, mask)


def _masked_matmul_bwd(res, g):
    x, w, mask = res
    wm = w * mask
    dx = pallas_matmul(g, wm.T)
    dw = mask * pallas_matmul(x.T, g)
    # The mask is a training-phase constant; a symbolic-zero cotangent
    # keeps XLA from materializing anything for it.
    dm = jnp.zeros_like(mask)
    return dx, dw, dm


masked_matmul.defvjp(_masked_matmul_fwd, _masked_matmul_bwd)
