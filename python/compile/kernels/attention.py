"""Fused causal attention as a Pallas kernel (inference path).

A flash-attention-style kernel restructured for TPU: the query block
lives in VMEM, K/V stream in along the sequence grid axis, and the
softmax is computed online (running max + running denominator) so the
(T, T) score matrix is never materialized in HBM.

Used by the ``logits_last`` decode artifact where no gradient flows;
the training graph uses the jnp reference attention (attention is ~13%
of training FLOPs and is not sparsified by the paper).  Correctness is
pinned against ``ref.causal_attention_ref``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale, bq, bk_seq, nk):
    """One (query-block, key-block) step of online-softmax attention.

    grid = (num_q_blocks, num_k_blocks); for each q block we sweep k
    blocks, maintaining the running max ``m``, the running normalizer
    ``l`` and the unnormalized accumulator ``acc`` in VMEM scratch.
    """
    qi = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]  # (bq, d)
    k = k_ref[...]  # (bk_seq, d)
    v = v_ref[...]  # (bk_seq, d)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    # causal mask: query position qi*bq + a may attend key ki*bk + b iff
    # key_pos <= query_pos.
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk_seq), 0)
    k_pos = ki * bk_seq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk_seq), 1)
    s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[...] = acc_ref[...] / l_ref[...]


def causal_attention(q, k, v, block_q=128, block_k=128):
    """Single-head causal attention ``softmax(qk^T / sqrt(d)) v``.

    q, k, v: (T, d) f32.  Multi-head callers vmap over heads/batch.
    """
    t, d = q.shape
    assert k.shape == (t, d) and v.shape == (t, d)
    bq = min(block_q, t)
    while t % bq != 0:
        bq -= 1
    bk_seq = min(block_k, t)
    while t % bk_seq != 0:
        bk_seq -= 1
    grid = (t // bq, t // bk_seq)
    scale = 1.0 / (d ** 0.5)
    return pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale, bq=bq, bk_seq=bk_seq,
                          nk=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk_seq, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk_seq, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=True,
    )(q, k, v)
