"""L1 Pallas kernels for the SPDF stack (build-time only).

Exports:
  masked_matmul       -- x @ (mask * w) as a tiled Pallas kernel w/ custom VJP
  pallas_matmul       -- plain tiled Pallas matmul (used by the VJP)
  sparse_pallas_matmul-- CSR-fed block-skipping matmul, bitwise == dense
  causal_attention    -- fused causal attention Pallas kernel (inference path)
  kernel_stats        -- analytic VMEM / MXU-utilization estimates for a tiling
  sparse_kernel_stats -- kernel_stats + block-skip FLOPs and CSR byte savings
"""

from .masked_matmul import (
    masked_matmul,
    pallas_matmul,
    pick_blocks,
    kernel_stats,
)
from .sparse_matmul import (
    Csr,
    csr_from_dense,
    csr_to_dense,
    sparse_pallas_matmul,
    sparse_kernel_stats,
    block_nonzero_map,
)
from .attention import causal_attention

__all__ = [
    "masked_matmul",
    "pallas_matmul",
    "pick_blocks",
    "kernel_stats",
    "Csr",
    "csr_from_dense",
    "csr_to_dense",
    "sparse_pallas_matmul",
    "sparse_kernel_stats",
    "block_nonzero_map",
    "causal_attention",
]
