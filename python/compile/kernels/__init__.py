"""L1 Pallas kernels for the SPDF stack (build-time only).

Exports:
  masked_matmul    -- x @ (mask * w) as a tiled Pallas kernel w/ custom VJP
  pallas_matmul    -- plain tiled Pallas matmul (used by the VJP)
  causal_attention -- fused causal attention Pallas kernel (inference path)
  kernel_stats     -- analytic VMEM / MXU-utilization estimates for a tiling
"""

from .masked_matmul import (
    masked_matmul,
    pallas_matmul,
    pick_blocks,
    kernel_stats,
)
from .attention import causal_attention

__all__ = [
    "masked_matmul",
    "pallas_matmul",
    "pick_blocks",
    "kernel_stats",
    "causal_attention",
]
