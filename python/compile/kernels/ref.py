"""Pure-jnp oracles for the L1 Pallas kernels.

These are the CORE correctness signal: every kernel must match its oracle
to float32 tolerance across a hypothesis-driven sweep of shapes, block
sizes and sparsity levels (python/tests/test_kernels.py).
"""

import jax.numpy as jnp


def masked_matmul_ref(x, w, mask):
    """``x @ (mask * w)`` — the sparse linear layer, dense math."""
    return x @ (mask * w)


def matmul_ref(x, w):
    return x @ w


def causal_attention_ref(q, k, v):
    """Single-head causal attention, materialized-scores reference."""
    t, d = q.shape
    s = (q @ k.T) / jnp.sqrt(jnp.float32(d))
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    s = jnp.where(causal, s, -1e30)
    p = jnp.exp(s - s.max(axis=1, keepdims=True))
    p = p / p.sum(axis=1, keepdims=True)
    return p @ v
