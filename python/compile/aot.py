"""AOT pipeline: lower the L2/L1 graphs to XLA HLO text + manifest.

For every simulation model config this emits:

  artifacts/<model>.train_step.hlo.txt   sparse/dense AdamW step
  artifacts/<model>.eval_loss.hlo.txt    summed CE + token count
  artifacts/<model>.logits_last.hlo.txt  decode primitive (full recompute)
  artifacts/<model>.prefill.hlo.txt      KV-cache population per slot
  artifacts/<model>.decode_step.hlo.txt  KV-cache incremental decode
  artifacts/manifest.json                everything rust needs to marshal

Interchange format is HLO *text*, not serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

The manifest records, per artifact, the exact flattened input/output
order (tree paths), shapes and dtypes, plus the parameter init spec and
optimizer constants — the rust coordinator marshals buffers from this
alone and never imports python.

Run:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# Fixed artifact shapes: one training/eval/decode geometry per model.
TRAIN_BATCH = 16
EVAL_BATCH = 16
DECODE_BATCH = 16


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _path_str(prefix, path):
    """Render a jax tree path like (DictKey('wte'),) as 'params/wte'."""
    parts = [prefix]
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _spec_entries(prefix, tree):
    """Flattened (path, shape, dtype) entries in jax flatten order."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        out.append({
            "name": _path_str(prefix, path),
            "shape": list(leaf.shape),
            "dtype": str(leaf.dtype),
        })
    return out


def _zeros_like_tree(specs):
    return {n: jnp.zeros(s, jnp.float32) for n, s, _ in specs}


def build_artifacts(cfg, out_dir, use_pallas=True):
    """Lower all artifacts for one model config; return manifest entry."""
    specs = M.param_specs(cfg)
    masked = M.masked_param_names(cfg)

    params = _zeros_like_tree(specs)
    m_state = _zeros_like_tree(specs)
    v_state = _zeros_like_tree(specs)
    masks = {n: jnp.zeros(dict((a, b) for a, b, _ in specs)[n],
                          jnp.float32) for n in masked}

    b, t = TRAIN_BATCH, cfg.ctx_len
    tokens = jnp.zeros((b, t), jnp.int32)
    targets = jnp.zeros((b, t), jnp.int32)
    loss_mask = jnp.zeros((b, t), jnp.float32)
    step = jnp.zeros((), jnp.float32)
    lr = jnp.zeros((), jnp.float32)
    pos = jnp.zeros((DECODE_BATCH,), jnp.int32)
    dec_tokens = jnp.zeros((DECODE_BATCH, t), jnp.int32)

    artifacts = {}

    def emit(name, fn, example_args, arg_prefixes):
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{cfg.name}.{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        inputs = []
        for prefix, arg in zip(arg_prefixes, example_args):
            inputs += _spec_entries(prefix, arg)
        out_shape = jax.eval_shape(fn, *example_args)
        outputs = _spec_entries("out", out_shape)
        artifacts[name] = {
            "file": fname,
            "inputs": inputs,
            "outputs": outputs,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        print(f"  {fname}: {len(text)} chars, "
              f"{len(inputs)} inputs, {len(outputs)} outputs")

    train_step = M.make_train_step(cfg, use_pallas=use_pallas)
    emit("train_step", train_step,
         (params, m_state, v_state, masks, tokens, targets, loss_mask,
          step, lr),
         ("params", "m", "v", "masks", "tokens", "targets", "loss_mask",
          "step", "lr"))

    eval_loss = M.make_eval_loss(cfg, use_pallas=use_pallas)
    emit("eval_loss", eval_loss, (params, tokens, targets, loss_mask),
         ("params", "tokens", "targets", "loss_mask"))

    logits_last = M.make_logits_last(cfg, use_pallas=use_pallas)
    emit("logits_last", logits_last, (params, dec_tokens, pos),
         ("params", "tokens", "pos"))

    # KV-cache serving pair: prefill populates a slot's per-layer K/V
    # state from its prompt; decode_step advances one token per call.
    # The cache crosses the artifact boundary as explicit inputs and
    # outputs — the rust runtime holds it as session state and feeds
    # each step's output literals back in.
    kv_specs = M.kv_cache_specs(cfg, DECODE_BATCH)
    kv_cache = {n: jnp.zeros(s, jnp.float32) for n, s in kv_specs}
    next_token = jnp.zeros((DECODE_BATCH,), jnp.int32)
    refill = jnp.zeros((DECODE_BATCH,), jnp.float32)

    prefill = M.make_prefill(cfg, use_pallas=use_pallas)
    emit("prefill", prefill, (params, kv_cache, dec_tokens, pos, refill),
         ("params", "kv", "tokens", "pos", "refill"))

    decode_step = M.make_decode_step(cfg)
    emit("decode_step", decode_step, (params, kv_cache, next_token, pos),
         ("params", "kv", "next_token", "pos"))

    return {
        "config": cfg.to_dict(),
        "train_batch": TRAIN_BATCH,
        "eval_batch": EVAL_BATCH,
        "decode_batch": DECODE_BATCH,
        "params": [{"name": n, "shape": list(s), "init": k}
                   for n, s, k in specs],
        # decode session-state tensors (KV cache), in flatten order —
        # the rust SessionState zero-initializes and round-trips these
        "decode_state": [{"name": n, "shape": list(s),
                          "dtype": "float32"} for n, s in kv_specs],
        "masked_params": masked,
        "decay_params": M.decay_param_names(cfg),
        "artifacts": artifacts,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=",".join(M.SIM_CONFIGS),
                    help="comma-separated model names")
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower with plain-jnp linears (ablation)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {
        "format_version": 1,
        "optimizer": {
            "adam_b1": M.ADAM_B1,
            "adam_b2": M.ADAM_B2,
            "adam_eps": M.ADAM_EPS,
            "weight_decay": M.WEIGHT_DECAY,
            "grad_clip_norm": M.GRAD_CLIP_NORM,
        },
        "models": {},
    }
    for name in args.models.split(","):
        cfg = M.SIM_CONFIGS[name]
        print(f"lowering {name} ...")
        manifest["models"][name] = build_artifacts(
            cfg, args.out_dir, use_pallas=not args.no_pallas)

    path = os.path.join(args.out_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
