"""L2: the SPDF GPT model — forward/backward + AdamW as pure JAX.

This module is the single source of truth for:
  * the GPT architecture (pre-LN, learned positions, tied output
    embedding — the GPT-2/GPT-3 family the paper trains),
  * the parameter tree layout (flat string-keyed dict; the AOT manifest
    records the flattening order so the rust coordinator can marshal
    buffers without ever importing python),
  * the SPDF training semantics: every sparsifiable linear layer computes
    ``x @ (mask * W)`` (L1 Pallas kernel), gradients are masked, and the
    updated weights are re-masked — so a single ``train_step`` artifact
    serves sparse pre-training (random mask), dense fine-tuning (all-ones
    mask) and the sparse fine-tuning baseline of Figure 2.

Only ever executed at build time: ``aot.py`` lowers the jitted functions
to HLO text which the rust runtime loads via PJRT.
"""

from dataclasses import dataclass, asdict

import jax
import jax.numpy as jnp

from .kernels import masked_matmul, causal_attention
# masked-score fill value shared with the fused kernel so the KV
# decode path's softmax reproduces its masked-lane math exactly
from .kernels.attention import NEG_INF

# ---------------------------------------------------------------------------
# Optimizer / training constants (paper Appendix A.1)
# ---------------------------------------------------------------------------
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
WEIGHT_DECAY = 0.1
GRAD_CLIP_NORM = 1.0


@dataclass(frozen=True)
class GPTConfig:
    """Architecture hyperparameters (paper Appendix Table 1 shape)."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    vocab_size: int
    ctx_len: int

    @property
    def d_head(self):
        return self.d_model // self.n_heads

    @property
    def d_ff(self):
        # feedforward bottleneck is 4x the base size (App. A.1)
        return 4 * self.d_model

    def to_dict(self):
        return asdict(self)


# The simulation-scale stand-ins for GPT-2 Small (125M) and GPT-3 XL
# (1.3B). DESIGN.md §2 records the substitution; the paper's real configs
# live in the rust config registry for the analytic FLOP tables.
SIM_CONFIGS = {
    "gpt-nano": GPTConfig("gpt-nano", n_layers=2, d_model=64, n_heads=2,
                          vocab_size=512, ctx_len=128),
    "gpt-micro": GPTConfig("gpt-micro", n_layers=4, d_model=128, n_heads=4,
                           vocab_size=512, ctx_len=128),
}


# ---------------------------------------------------------------------------
# Parameter tree
# ---------------------------------------------------------------------------

def param_specs(cfg: GPTConfig):
    """Ordered (name, shape, init) spec for every trainable tensor.

    init is one of "normal" (std 0.02), "normal_resid" (std scaled by
    1/sqrt(2*n_layers), GPT-2 style residual projections), "zeros",
    "ones".
    """
    specs = [
        ("wte", (cfg.vocab_size, cfg.d_model), "normal"),
        ("wpe", (cfg.ctx_len, cfg.d_model), "normal"),
    ]
    d, f = cfg.d_model, cfg.d_ff
    for i in range(cfg.n_layers):
        p = f"h{i}."
        specs += [
            (p + "ln1.b", (d,), "zeros"),
            (p + "ln1.g", (d,), "ones"),
            (p + "attn.wq", (d, d), "normal"),
            (p + "attn.wk", (d, d), "normal"),
            (p + "attn.wv", (d, d), "normal"),
            (p + "attn.wd", (d, d), "normal_resid"),
            (p + "attn.bq", (d,), "zeros"),
            (p + "attn.bk", (d,), "zeros"),
            (p + "attn.bv", (d,), "zeros"),
            (p + "attn.bd", (d,), "zeros"),
            (p + "ln2.b", (d,), "zeros"),
            (p + "ln2.g", (d,), "ones"),
            (p + "mlp.wi", (d, f), "normal"),
            (p + "mlp.bi", (f,), "zeros"),
            (p + "mlp.wo", (f, d), "normal_resid"),
            (p + "mlp.bo", (d,), "zeros"),
        ]
    specs += [
        ("lnf.b", (d,), "zeros"),
        ("lnf.g", (d,), "ones"),
    ]
    return specs


def masked_param_names(cfg: GPTConfig):
    """The six linear weights per block the paper sparsifies
    (W_Q, W_K, W_V, W_D, W_I, W_O). Embeddings/LayerNorm/bias stay dense."""
    names = []
    for i in range(cfg.n_layers):
        p = f"h{i}."
        names += [p + "attn.wq", p + "attn.wk", p + "attn.wv",
                  p + "attn.wd", p + "mlp.wi", p + "mlp.wo"]
    return names


def decay_param_names(cfg: GPTConfig):
    """Weight decay applies to matmul weights + embeddings only
    (GPT-2/3 convention)."""
    return [n for n, shape, _ in param_specs(cfg) if len(shape) == 2]


def init_params(cfg: GPTConfig, key):
    """Reference initializer (rust re-implements this from the manifest;
    distribution parity is asserted in integration tests)."""
    params = {}
    for name, shape, kind in param_specs(cfg):
        key, sub = jax.random.split(key)
        if kind == "zeros":
            params[name] = jnp.zeros(shape, jnp.float32)
        elif kind == "ones":
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            std = 0.02
            if kind == "normal_resid":
                std = 0.02 / (2.0 * cfg.n_layers) ** 0.5
            params[name] = std * jax.random.normal(key=sub, shape=shape,
                                                   dtype=jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _linear(x, w, b, mask=None, use_pallas=True):
    """The sparsifiable linear layer.

    x: (..., k); flattened to 2-D for the Pallas kernel.  When ``mask``
    is None the layer is an un-sparsified dense matmul.
    """
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    if mask is not None and use_pallas:
        y = masked_matmul(x2, w, mask)
    elif mask is not None:
        y = x2 @ (mask * w)
    else:
        y = x2 @ w
    y = y + b
    return y.reshape(lead + (w.shape[-1],))


def _attention_jnp(q, k, v, n_heads):
    """Causal MHA over (B, T, D), materialized-scores math.

    Used in the training graph (autodiff-friendly); the fused Pallas
    kernel serves the decode artifact (see gpt_forward ``fused_attn``).
    """
    b, t, d = q.shape
    dh = d // n_heads

    def split(x):
        return x.reshape(b, t, n_heads, dh).transpose(0, 2, 1, 3)

    q, k, v = split(q), split(k), split(v)  # (B, H, T, dh)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(dh))
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    s = jnp.where(causal, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v)
    return o.transpose(0, 2, 1, 3).reshape(b, t, d)


def _attention_pallas(q, k, v, n_heads):
    """Causal MHA via the fused L1 kernel, vmapped over batch x heads."""
    b, t, d = q.shape
    dh = d // n_heads

    def split(x):
        return x.reshape(b, t, n_heads, dh).transpose(0, 2, 1, 3) \
                .reshape(b * n_heads, t, dh)

    q, k, v = split(q), split(k), split(v)
    o = jax.vmap(causal_attention)(q, k, v)  # (B*H, T, dh)
    return o.reshape(b, n_heads, t, dh).transpose(0, 2, 1, 3) \
            .reshape(b, t, d)


def gpt_forward(cfg: GPTConfig, params, tokens, masks=None,
                use_pallas=True, fused_attn=False, return_kv=False):
    """Token logits for a (B, T) int32 batch.

    masks: dict name->f32 mask for the sparsified weights, or None for a
    fully dense forward (valid whenever params are stored masked, which
    the train_step output invariant guarantees).

    return_kv: also return the per-layer attention K/V activations
    (pre-head-split, post-bias) as a dict ``{"h<i>.k": (B, T, D), ...}``
    — the tensors the KV-cache decode path (``make_decode_step``) reads
    back.  The logits computation is unchanged.
    """
    b, t = tokens.shape

    def mask_of(name):
        if masks is None:
            return None
        return masks.get(name)

    kv = {}
    h = params["wte"][tokens] + params["wpe"][:t][None, :, :]
    for i in range(cfg.n_layers):
        p = f"h{i}."
        x = _layer_norm(h, params[p + "ln1.g"], params[p + "ln1.b"])
        q = _linear(x, params[p + "attn.wq"], params[p + "attn.bq"],
                    mask_of(p + "attn.wq"), use_pallas)
        k = _linear(x, params[p + "attn.wk"], params[p + "attn.bk"],
                    mask_of(p + "attn.wk"), use_pallas)
        v = _linear(x, params[p + "attn.wv"], params[p + "attn.bv"],
                    mask_of(p + "attn.wv"), use_pallas)
        if return_kv:
            kv[f"h{i}.k"] = k
            kv[f"h{i}.v"] = v
        attn = _attention_pallas(q, k, v, cfg.n_heads) if fused_attn \
            else _attention_jnp(q, k, v, cfg.n_heads)
        h = h + _linear(attn, params[p + "attn.wd"], params[p + "attn.bd"],
                        mask_of(p + "attn.wd"), use_pallas)
        x = _layer_norm(h, params[p + "ln2.g"], params[p + "ln2.b"])
        x = _linear(x, params[p + "mlp.wi"], params[p + "mlp.bi"],
                    mask_of(p + "mlp.wi"), use_pallas)
        x = jax.nn.gelu(x)
        h = h + _linear(x, params[p + "mlp.wo"], params[p + "mlp.bo"],
                        mask_of(p + "mlp.wo"), use_pallas)
    h = _layer_norm(h, params["lnf.g"], params["lnf.b"])
    # tied output embedding
    logits = h @ params["wte"].T
    if return_kv:
        return logits, kv
    return logits


# ---------------------------------------------------------------------------
# Loss + training step
# ---------------------------------------------------------------------------

def lm_loss(cfg: GPTConfig, params, tokens, targets, loss_mask,
            masks=None, use_pallas=True):
    """Mean next-token cross entropy over positions where loss_mask=1."""
    logits = gpt_forward(cfg, params, tokens, masks, use_pallas)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = logz - tgt
    total = jnp.sum(ce * loss_mask)
    count = jnp.maximum(jnp.sum(loss_mask), 1.0)
    return total / count


def make_train_step(cfg: GPTConfig, use_pallas=True):
    """Build the AdamW train step.

    signature (all f32 unless noted):
      (params, m, v, masks, tokens i32, targets i32, loss_mask, step, lr)
      -> (params', m', v', loss)

    The sparsity mask is an input applied to (a) the gradients and (b)
    the updated weights, so masked weights and their moments stay exactly
    zero through sparse pre-training, and an all-ones mask makes the same
    artifact perform dense training.
    """
    masked_names = set(masked_param_names(cfg))
    decay_names = set(decay_param_names(cfg))

    def train_step(params, m, v, masks, tokens, targets, loss_mask,
                   step, lr):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, tokens, targets, loss_mask,
                              masks=masks, use_pallas=use_pallas)
        )(params)

        # mask gradients of sparsified weights
        grads = {n: (g * masks[n] if n in masked_names else g)
                 for n, g in grads.items()}

        # global-norm clip at 1.0 (App. A.1)
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
        scale = jnp.minimum(1.0, GRAD_CLIP_NORM / (gnorm + 1e-12))
        grads = {n: g * scale for n, g in grads.items()}

        b1t = 1.0 - ADAM_B1 ** step
        b2t = 1.0 - ADAM_B2 ** step
        new_params, new_m, new_v = {}, {}, {}
        for n, p in params.items():
            g = grads[n]
            mn = ADAM_B1 * m[n] + (1.0 - ADAM_B1) * g
            vn = ADAM_B2 * v[n] + (1.0 - ADAM_B2) * g * g
            update = (mn / b1t) / (jnp.sqrt(vn / b2t) + ADAM_EPS)
            if n in decay_names:
                update = update + WEIGHT_DECAY * p
            pn = p - lr * update
            if n in masked_names:
                pn = pn * masks[n]
            new_params[n], new_m[n], new_v[n] = pn, mn, vn
        return new_params, new_m, new_v, loss

    return train_step


def make_eval_loss(cfg: GPTConfig, use_pallas=True):
    """(params, tokens, targets, loss_mask) -> (loss_sum, token_count).

    Sum form so the coordinator can aggregate exact corpus perplexity
    across batches.  Params are stored masked, so no mask input.
    """

    def eval_loss(params, tokens, targets, loss_mask):
        logits = gpt_forward(cfg, params, tokens, masks=None,
                             use_pallas=use_pallas)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, targets[..., None],
                                  axis=-1)[..., 0]
        ce = (logz - tgt) * loss_mask
        return jnp.sum(ce), jnp.sum(loss_mask)

    return eval_loss


def make_logits_last(cfg: GPTConfig, use_pallas=True, fused_attn=True):
    """(params, tokens, pos i32 (B,)) -> (B, vocab) logits at ``pos``.

    The decode primitive: the coordinator right-pads prompts, reads the
    logits of the last real position, samples/beams in rust, appends, and
    calls again.  Causality makes right-padding invisible to ``pos``.
    Uses the fused Pallas attention kernel (no gradient flows here).
    """

    def logits_last(params, tokens, pos):
        logits = gpt_forward(cfg, params, tokens, masks=None,
                             use_pallas=use_pallas, fused_attn=fused_attn)
        b = tokens.shape[0]
        return logits[jnp.arange(b), pos, :]

    return logits_last


# ---------------------------------------------------------------------------
# KV-cache incremental decode
# ---------------------------------------------------------------------------
#
# ``logits_last`` recomputes the full (B, T) forward per generated token
# — O(T^2) total work per request. The incremental pair below converts
# decode to O(T): ``prefill`` populates a slot's per-layer K/V cache
# from its prompt (one full forward), then ``decode_step`` advances one
# token per call, touching only (B,)-sized token/pos buffers plus the
# cache state tensors the runtime feeds back output→input.

def kv_cache_specs(cfg: GPTConfig, batch: int):
    """Ordered (name, shape) specs of the decode session state: one K
    and one V tensor per layer, (batch, ctx_len, d_model) f32, stored
    pre-head-split exactly as the attention linears emit them. Names
    sort in layer order for n_layers < 10, so jax dict-flatten order ==
    spec order — the contract the rust session state relies on."""
    specs = []
    for i in range(cfg.n_layers):
        specs.append((f"h{i}.k", (batch, cfg.ctx_len, cfg.d_model)))
        specs.append((f"h{i}.v", (batch, cfg.ctx_len, cfg.d_model)))
    return specs


def init_kv_cache(cfg: GPTConfig, batch: int):
    """Zero-initialized cache tree (the pre-first-prefill state)."""
    return {n: jnp.zeros(s, jnp.float32)
            for n, s in kv_cache_specs(cfg, batch)}


def _cache_write(cache, vec, pos):
    """Write ``vec[b]`` into ``cache[b, pos[b], :]`` (per-layer
    dynamic_update_slice, vmapped over the batch)."""

    def write_row(c, x, p):
        return jax.lax.dynamic_update_slice(c, x[None, :], (p, 0))

    return jax.vmap(write_row)(cache, vec, pos)


def _cached_attention(q, ck, cv, pos, n_heads):
    """One-query-per-row attention over a (B, T, D) K/V cache.

    Mirrors the single-block numerics of ``kernels.causal_attention``
    (interpret-mode online softmax with one key block at T <= 128):
    scale by multiplication, mask invalid lanes to NEG_INF, subtract
    the running max, and normalize ``p @ v`` by the summed denominator
    *after* the value contraction. Keeping the op sequence identical is
    what lets KV greedy decode stay bit-compatible with the
    ``logits_last`` path.
    """
    b, t, d = ck.shape
    dh = d // n_heads
    scale = 1.0 / (dh ** 0.5)
    qh = q.reshape(b, n_heads, dh)
    kh = ck.reshape(b, t, n_heads, dh)
    vh = cv.reshape(b, t, n_heads, dh)
    s = jnp.einsum("bhd,bthd->bht", qh, kh) * scale
    valid = jnp.arange(t)[None, None, :] <= pos[:, None, None]
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    acc = jnp.einsum("bht,bthd->bhd", p, vh)
    return (acc / l.reshape(b, n_heads, 1)).reshape(b, d)


def make_decode_step(cfg: GPTConfig):
    """Build the incremental decode step.

    signature:
      (params, kv_cache, next_token i32 (B,), pos i32 (B,))
      -> (logits (B, vocab), kv_cache')

    ``next_token[b]`` is the token at position ``pos[b]`` (already
    appended by the host); the step writes its K/V into the cache at
    ``pos`` and returns the logits predicting position ``pos + 1``.
    The cache rows above ``pos`` may hold garbage — attention masks
    them out, and generation overwrites them before they ever become
    visible. Params are stored masked (the train_step invariant), so
    the forward is dense.
    """
    # The incremental softmax mirrors the fused kernel's *single-block*
    # numerics; at ctx_len > 128 the kernel sweeps multiple key blocks
    # with a running max and last-bit equality would silently break.
    # Longer-context configs need block-aware math here first.
    assert cfg.ctx_len <= 128, (
        f"decode_step bit-identity contract only holds for ctx_len <= "
        f"128 (single attention key block); got {cfg.ctx_len}"
    )

    def decode_step(params, kv_cache, next_token, pos):
        h = params["wte"][next_token] + params["wpe"][pos]
        new_kv = {}
        for i in range(cfg.n_layers):
            p = f"h{i}."
            x = _layer_norm(h, params[p + "ln1.g"], params[p + "ln1.b"])
            q = _linear(x, params[p + "attn.wq"], params[p + "attn.bq"])
            k = _linear(x, params[p + "attn.wk"], params[p + "attn.bk"])
            v = _linear(x, params[p + "attn.wv"], params[p + "attn.bv"])
            ck = _cache_write(kv_cache[f"h{i}.k"], k, pos)
            cv = _cache_write(kv_cache[f"h{i}.v"], v, pos)
            new_kv[f"h{i}.k"] = ck
            new_kv[f"h{i}.v"] = cv
            attn = _cached_attention(q, ck, cv, pos, cfg.n_heads)
            h = h + _linear(attn, params[p + "attn.wd"],
                            params[p + "attn.bd"])
            x = _layer_norm(h, params[p + "ln2.g"], params[p + "ln2.b"])
            x = _linear(x, params[p + "mlp.wi"], params[p + "mlp.bi"])
            x = jax.nn.gelu(x)
            h = h + _linear(x, params[p + "mlp.wo"],
                            params[p + "mlp.bo"])
        h = _layer_norm(h, params["lnf.g"], params["lnf.b"])
        logits = h @ params["wte"].T
        return logits, new_kv

    return decode_step


def make_prefill(cfg: GPTConfig, use_pallas=True, fused_attn=True):
    """Build the per-slot cache prefill.

    signature:
      (params, kv_cache, tokens i32 (B, T), pos i32 (B,),
       refill f32 (B,))
      -> (logits (B, vocab), kv_cache')

    Rows with ``refill > 0.5`` get their cache recomputed from
    ``tokens`` (one full forward — the same graph as ``logits_last``
    plus the K/V taps); rows with ``refill == 0`` pass their cache
    through untouched, so one batch slot can be re-prompted mid-flight
    without disturbing its neighbours. Returned logits are read at
    ``pos`` for every row; callers use the refilled rows' entries.
    """

    def prefill(params, kv_cache, tokens, pos, refill):
        logits, new_kv = gpt_forward(cfg, params, tokens, masks=None,
                                     use_pallas=use_pallas,
                                     fused_attn=fused_attn,
                                     return_kv=True)
        b = tokens.shape[0]
        sel = refill[:, None, None] > 0.5
        out_kv = {n: jnp.where(sel, new_kv[n], kv_cache[n])
                  for n in kv_cache}
        return logits[jnp.arange(b), pos, :], out_kv

    return prefill
