//! Appendix-C style demo: realized vs theoretical speedup of the
//! unstructured-sparse matmul engine (no PJRT needed).
//!
//!   cargo run --release --example sparse_speedup -- [dim]
//!
//! Quick version of benches/appc_sparse_speedup.rs: one shape, four
//! sparsity levels, plus a CSR correctness spot-check.

use spdf::bench_support::{bench_for, fmt_time};
use spdf::sparse_compute::{dense_matmul, theoretical_speedup, Csr};
use spdf::util::rng::Rng;

fn main() {
    let dim: usize = std::env::args().nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(512);
    let n = 32;
    let mut rng = Rng::new(0);
    let b: Vec<f32> = (0..dim * n).map(|_| rng.normal_f32(0.0, 1.0))
        .collect();
    let dense_a: Vec<f32> = (0..dim * dim)
        .map(|_| rng.normal_f32(0.0, 1.0)).collect();

    let sd = bench_for(0.5, 8, || dense_matmul(&dense_a, &b, dim, dim, n));
    println!("{dim}x{dim} weight @ {n} cols — dense: {}",
             fmt_time(sd.mean));
    for s in [0.5, 0.75, 0.9, 0.99] {
        let csr = Csr::random(dim, dim, s, &mut rng);
        // spot-check numerics vs the dense kernel on this matrix
        let want = dense_matmul(&csr.to_dense(), &b, dim, dim, n);
        let got = csr.spmm(&b, n);
        let max_err = want.iter().zip(&got)
            .map(|(a, c)| (a - c).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-3, "CSR numerics drifted: {max_err}");

        let sm = bench_for(0.5, 8, || csr.spmm(&b, n));
        println!("  S={:>5.1}%  {}  speedup {:>5.2}x  (theory {:>5.2}x)",
                 s * 100.0, fmt_time(sm.mean), sd.mean / sm.mean,
                 theoretical_speedup(s));
    }
}
