//! Tour of the data + metrics substrates (no PJRT needed):
//! generate each synthetic task, show examples, and demonstrate the
//! official-metric suite on perfect / perturbed hypotheses — a sanity
//! harness for the evaluation stack.
//!
//!   cargo run --release --example task_data_tour

use spdf::data::Task;
use spdf::eval::{bleu, cider, meteor, nist, rouge, ter};
use spdf::tokenizer::Tokenizer;
use spdf::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(7);
    for task in Task::all() {
        let d = task.generate(&mut rng, 0.01);
        println!("== {} ==  train/valid/test = {}/{}/{}",
                 d.name, d.train.len(), d.valid.len(), d.test.len());
        let ex = &d.train[0];
        println!("  IN : {}", clip(&ex.input, 100));
        println!("  REF: {}", clip(&ex.refs[0], 100));
    }

    // tokenizer round trip over task text
    let d = Task::E2e.generate(&mut Rng::new(0), 0.01);
    let corpus: String = d.train.iter().take(50)
        .map(|e| format!("{} {}", e.input, e.refs[0]))
        .collect::<Vec<_>>().join(" ");
    let tok = Tokenizer::train(&corpus, 512);
    let text = &d.train[0].refs[0];
    assert_eq!(&tok.decode(&tok.encode(text)), text);
    println!("\ntokenizer: {} merges, round-trip exact", tok.n_merges());

    // metric suite behaviour on controlled degradations
    let refs: Vec<(String, Vec<String>)> = d.test.iter().take(32)
        .map(|e| (e.refs[0].clone(), e.refs.clone()))
        .collect();
    let degraded: Vec<(String, Vec<String>)> = refs.iter()
        .map(|(h, rs)| {
            let mut words: Vec<&str> = h.split(' ').collect();
            if words.len() > 4 {
                words.truncate(words.len() - 3); // drop the tail
            }
            (words.join(" "), rs.clone())
        })
        .collect();
    println!("\nmetric      perfect   degraded(tail cut)");
    let rows: [(&str, fn(&[(String, Vec<String>)]) -> f64); 6] = [
        ("BLEU", bleu::corpus_bleu),
        ("NIST", nist::corpus_nist),
        ("METEOR", meteor::corpus_meteor),
        ("ROUGE-L", rouge::corpus_rouge_l),
        ("CIDEr", cider::corpus_cider),
        ("TER", ter::corpus_ter),
    ];
    for (name, f) in rows {
        println!("{name:<10} {:>8.3}  {:>8.3}", f(&refs), f(&degraded));
    }
    println!("\n(perfect >= degraded on all ↑ metrics; TER ↓ inverts)");
}

fn clip(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        format!("{}…", &s[..n])
    }
}
