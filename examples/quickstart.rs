//! Quickstart: the SPDF pipeline in ~60 seconds on the nano model.
//!
//!   cargo run --release --example quickstart
//!
//! Walks all three paper steps on a postage-stamp budget:
//!   1. sparsify  — 75% uniform random static mask
//!   2. pre-train — 60 steps on SynthPile through the PJRT artifact
//!   3. dense fine-tune — 1 epoch on E2E-sim, then decode + score

use spdf::coordinator::{self, World, WorldConfig};
use spdf::data::Task;
use spdf::generate::DecodeParams;
use spdf::runtime::Engine;

fn main() -> anyhow::Result<()> {
    // data world: synthetic corpus + tasks + tokenizer (seeded)
    let world = World::build(&WorldConfig {
        seed: 0,
        corpus_words: 30_000,
        vocab_size: 512,
        task_scale: 0.02,
    });
    println!("world: {} corpus tokens, {} e2e train examples",
             world.stream.len(), world.task(Task::E2e).train.len());

    // runtime: compile the AOT artifacts once (python was only involved
    // at `make artifacts` time; this binary never imports it)
    let engine = Engine::cpu(spdf::runtime::default_artifact_dir())?;
    let runtime = engine.load_model("gpt-nano")?;

    // steps 1+2: sparsify + sparse pre-train
    let pt = coordinator::pretrain(&runtime, &world,
        &coordinator::PretrainConfig {
            sparsity: 0.75,
            steps: 60,
            peak_lr: 2e-3,
            seed: 0,
            log_every: 20,
            ..Default::default()
        })?;
    println!("pre-trained @75% sparsity: eval loss {:.3} (ppl {:.1}), \
              {:.2e} train FLOPs",
             pt.final_eval_loss,
             spdf::train::perplexity(pt.final_eval_loss),
             pt.train_flops);

    // step 3: densify + dense fine-tune on E2E
    let ft = coordinator::finetune(&runtime, &world, pt.state,
        &coordinator::FinetuneConfig {
            task: Task::E2e,
            epochs: 1,
            peak_lr: 4e-4,
            ..Default::default()
        })?;
    println!("fine-tuned dense: best val loss {:.3}", ft.best_val_loss);

    // evaluate with the official-metric suite
    let m = coordinator::evaluate_task(
        &runtime, &ft.state, &world, Task::E2e, 16,
        &DecodeParams { max_new_tokens: 24, ..Default::default() })?;
    println!("E2E-sim test (n={}): BLEU {:.2}  NIST {:.2}  \
              METEOR {:.3}  ROUGE-L {:.2}  CIDEr {:.2}  TER {:.3}  \
              PPL {:.2}",
             m.n_examples, m.bleu, m.nist, m.meteor, m.rouge_l,
             m.cider, m.ter, m.ppl);
    println!("\n(quality is meaningless at 60 pre-train steps — run \
              examples/spdf_pipeline.rs for a real curve)");
    Ok(())
}
