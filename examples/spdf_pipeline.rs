//! End-to-end driver (EXPERIMENTS.md §E2E): train a GPT through the
//! full SPDF pipeline on a real (synthetic) workload, logging the loss
//! curve, then fine-tune dense and report downstream metrics — the
//! "does everything compose" proof for all three layers.
//!
//!   cargo run --release --example spdf_pipeline -- [steps] [sparsity]
//!
//! Defaults: 300 pre-train steps @ 75% sparsity on gpt-nano. The loss
//! curve is written to runs/spdf_pipeline_loss.csv.

use std::io::Write;

use spdf::coordinator::{self, World, WorldConfig};
use spdf::data::Task;
use spdf::generate::DecodeParams;
use spdf::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: u64 = args.first().and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let sparsity: f64 = args.get(1).and_then(|s| s.parse().ok())
        .unwrap_or(0.75);
    let model = args.get(2).map(|s| s.as_str()).unwrap_or("gpt-nano");

    let world = World::build(&WorldConfig {
        seed: 0,
        corpus_words: 200_000,
        vocab_size: 512,
        task_scale: 0.1,
    });
    let engine = Engine::cpu(spdf::runtime::default_artifact_dir())?;
    let runtime = engine.load_model(model)?;
    println!("model {model}: {:.2}M params, {} pre-train steps @ \
              {:.0}% sparsity",
             runtime.manifest.total_params() as f64 / 1e6, steps,
             sparsity * 100.0);

    // ---- sparse pre-training with loss-curve logging ----------------
    let pt = coordinator::pretrain(&runtime, &world,
        &coordinator::PretrainConfig {
            sparsity,
            steps,
            peak_lr: 1.5e-3,
            seed: 0,
            log_every: 50,
            ..Default::default()
        })?;
    std::fs::create_dir_all("runs")?;
    let mut f = std::fs::File::create("runs/spdf_pipeline_loss.csv")?;
    writeln!(f, "step,lr,loss,wall_ms")?;
    for s in &pt.history {
        writeln!(f, "{},{:.3e},{:.5},{:.1}", s.step, s.lr, s.loss,
                 s.wall_ms)?;
    }
    println!("loss curve ({} pts) -> runs/spdf_pipeline_loss.csv; \
              first {:.3} -> last {:.3}; eval ppl {:.2}",
             pt.history.len(),
             pt.history.first().map(|s| s.loss).unwrap_or(f32::NAN),
             pt.history.last().map(|s| s.loss).unwrap_or(f32::NAN),
             spdf::train::perplexity(pt.final_eval_loss));

    // ---- dense fine-tune on two tasks of opposite difficulty --------
    for task in [Task::E2e, Task::Curation] {
        let ft = coordinator::finetune(&runtime, &world,
            pt.state.clone(),
            &coordinator::FinetuneConfig {
                task,
                epochs: 2,
                peak_lr: 4e-4,
                ..Default::default()
            })?;
        let m = coordinator::evaluate_task(
            &runtime, &ft.state, &world, task, 32,
            &DecodeParams::default())?;
        println!("{:<9} BLEU {:>6.2}  ROUGE-L {:>6.2}  PPL {:>7.2}  \
                  (val loss {:.3}, {} epochs)",
                 task.name(), m.bleu, m.rouge_l, m.ppl,
                 ft.best_val_loss, ft.epochs_ran);
    }

    // ---- FLOPs statement --------------------------------------------
    println!("\npre-train FLOPs spent: {:.3e} (dense-equivalent would \
              be {:.3e} → {:.2}x reduction)",
             pt.train_flops, pt.train_flops /
             (1.0 - sparsity * fraction_sparsifiable(&runtime)),
             1.0 / (1.0 - sparsity * fraction_sparsifiable(&runtime)));
    Ok(())
}

/// Fraction of per-seq train FLOPs in the sparsifiable matmuls.
fn fraction_sparsifiable(rt: &spdf::runtime::ModelRuntime) -> f64 {
    let cfg = &rt.manifest.config;
    let t = cfg.ctx_len as u64;
    let dense = spdf::flops::forward_flops(cfg, t, 0.0);
    let all_sparse = spdf::flops::forward_flops(cfg, t, 1.0);
    (dense - all_sparse) / dense
}
