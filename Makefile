# SPDF reproduction — top-level convenience targets.
#
#   make artifacts   lower the JAX graphs to HLO artifacts + manifest
#   make check       full tier-1+ gate (scripts/check.sh)
#   make test        cargo test only
#   make bench       decode perf bench (refreshes BENCH_decode.json)
#
# Every rust binary loads the AOT artifacts at startup, so `make
# artifacts` must run before `make check`/`make test`. The target also
# links rust/artifacts -> ../artifacts so cargo invocations from the
# rust/ workspace find them without setting SPDF_ARTIFACTS.

.PHONY: artifacts check test bench clean-artifacts

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts
	ln -sfn ../artifacts rust/artifacts

check:
	scripts/check.sh

test:
	cd rust && cargo test -q

bench:
	cd rust && cargo bench --bench perf_decode

clean-artifacts:
	rm -rf artifacts rust/artifacts
