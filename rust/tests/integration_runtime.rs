//! End-to-end integration over the real PJRT runtime + AOT artifacts.
//!
//! Requires `make artifacts` (the Makefile test target guarantees it).
//! These tests validate the python↔rust contract: flatten order, shapes,
//! training semantics (loss decreases, masked weights stay zero), eval
//! and decode artifacts.

use spdf::coordinator::{self, World, WorldConfig};
use spdf::data::{PackedStream, Task};
use spdf::generate::loadgen::{self, Pattern, StepCosts, TraceConfig};
use spdf::generate::{reference, DecodeEngine, DecodeParams,
                     DecodeRequest};
use spdf::runtime::{Engine, HostTensor};
use spdf::sparsity::{MaskScheme, MaskSet};
use spdf::tokenizer::{BOS, SEP};
use spdf::train::{Schedule, TrainState, Trainer};
use spdf::util::rng::Rng;

fn engine() -> Engine {
    Engine::cpu(spdf::runtime::default_artifact_dir()).expect(
        "PJRT engine + artifacts/manifest.json — run `make artifacts`",
    )
}

fn tiny_world() -> World {
    World::build(&WorldConfig {
        seed: 11,
        corpus_words: 12_000,
        vocab_size: 512,
        task_scale: 0.01,
    })
}

#[test]
fn manifest_matches_config_registry() {
    let engine = engine();
    for (name, mm) in &engine.manifest.models {
        let reg = spdf::config::by_name(name)
            .unwrap_or_else(|| panic!("{name} missing from registry"));
        assert_eq!(reg, mm.config,
                   "manifest/registry drift for {name}");
        // six masked matrices per layer
        assert_eq!(mm.masked_params.len(), 6 * mm.config.n_layers);
    }
}

#[test]
fn train_step_loss_decreases_and_masks_hold() {
    let engine = engine();
    let runtime = engine.load_model("gpt-nano").unwrap();
    let mm = &runtime.manifest;

    let mut rng = Rng::new(0);
    let mut state = TrainState::init(mm, &mut rng);
    let masks = MaskSet::random(mm, 0.75, MaskScheme::Uniform, &mut rng);
    state.sparsify(masks.clone());

    // tiny synthetic stream with strong structure
    let stream: Vec<u32> = (0..40_000)
        .map(|i| 4 + ((i * 7 + (i / 3) % 5) % 97) as u32)
        .collect();
    let mut ps = PackedStream::new(stream, mm.train_batch,
                                   mm.config.ctx_len);
    let batch = ps.next_batch();

    let mut trainer = Trainer::new(&runtime, state,
                                   Schedule::Constant { peak: 2e-3 });
    let mut losses = Vec::new();
    for _ in 0..12 {
        losses.push(trainer.step(&batch).unwrap() as f64);
    }
    assert!(
        losses[11] < losses[0] - 0.5,
        "loss should drop when overfitting one batch: {losses:?}"
    );
    trainer.sync().unwrap();
    // SPDF invariant: holes stay exactly zero through real training
    masks.check_holes_zero(&trainer.state.params).unwrap();
    // moments too
    for (name, mask) in &masks.masks {
        let m = &trainer.state.opt_m[name];
        for (i, (&x, &b)) in m.iter().zip(mask).enumerate() {
            assert!(b != 0.0 || x == 0.0, "{name}[{i}] moment leaked");
        }
    }
}

#[test]
fn dense_mask_trains_all_weights() {
    let engine = engine();
    let runtime = engine.load_model("gpt-nano").unwrap();
    let mm = &runtime.manifest;
    let mut rng = Rng::new(1);
    let state = TrainState::init(mm, &mut rng);

    let stream: Vec<u32> = (0..30_000)
        .map(|i| 4 + ((i * 11) % 89) as u32)
        .collect();
    let mut ps = PackedStream::new(stream, mm.train_batch,
                                   mm.config.ctx_len);
    let batch = ps.next_batch();
    let before = state.params["h0.attn.wq"].clone();
    let mut trainer = Trainer::new(&runtime, state,
                                   Schedule::Constant { peak: 1e-3 });
    trainer.step(&batch).unwrap();
    trainer.sync().unwrap();
    let after = &trainer.state.params["h0.attn.wq"];
    let changed = before.iter().zip(after).filter(|(a, b)| a != b)
        .count();
    assert!(changed > before.len() / 2,
            "dense training changed only {changed}/{}", before.len());
}

#[test]
fn eval_loss_of_uniform_model_is_log_vocab() {
    // An untrained (zero-init-logits-ish) model's CE over random tokens
    // should be near ln(V). We zero the embeddings to force uniform
    // logits exactly.
    let engine = engine();
    let runtime = engine.load_model("gpt-nano").unwrap();
    let mm = &runtime.manifest;
    let mut rng = Rng::new(2);
    let mut state = TrainState::init(mm, &mut rng);
    for w in state.params.values_mut() {
        w.iter_mut().for_each(|x| *x = 0.0);
    }
    // LayerNorm gains to 1 keep the forward finite
    for spec in &mm.params {
        if spec.name.ends_with(".g") || spec.name == "lnf.g" {
            state.params.get_mut(&spec.name).unwrap()
                .iter_mut().for_each(|x| *x = 1.0);
        }
    }
    let stream: Vec<u32> = (0..20_000)
        .map(|i| 4 + (i % 500) as u32)
        .collect();
    let mut ps = PackedStream::new(stream, mm.eval_batch,
                                   mm.config.ctx_len);
    let batches = vec![ps.next_batch()];
    let loss = spdf::train::evaluate_loss(&runtime, &state, &batches)
        .unwrap();
    let want = (mm.config.vocab_size as f64).ln();
    assert!((loss - want).abs() < 0.02,
            "uniform CE {loss} vs ln(V) {want}");
}

#[test]
fn logits_last_decode_runs_and_respects_position() {
    let engine = engine();
    let runtime = engine.load_model("gpt-nano").unwrap();
    let mm = &runtime.manifest;
    let mut rng = Rng::new(3);
    let state = TrainState::init(mm, &mut rng);
    let params = state.param_tensors(mm);

    let b = mm.decode_batch;
    let t = mm.config.ctx_len;
    let mut tokens = vec![0i32; b * t];
    for j in 0..6 {
        tokens[j] = (10 + j) as i32;
        tokens[t + j] = (10 + j) as i32; // row 1 same prefix
    }
    tokens[t + 20] = 99; // row 1 junk AFTER pos: must not matter
    let pos = vec![5i32; b];
    let exe = runtime.artifact("logits_last").unwrap();
    let mut inputs = params.clone();
    inputs.push(HostTensor::from_i32(&[b, t], tokens));
    inputs.push(HostTensor::from_i32(&[b], pos));
    let out = exe.run(&inputs).unwrap();
    let lv = out[0].as_f32().unwrap();
    let v = mm.config.vocab_size;
    for k in 0..v {
        assert!((lv[k] - lv[v + k]).abs() < 1e-4,
                "padding after pos changed logits at {k}");
    }
}

#[test]
fn greedy_decode_generates_tokens() {
    let engine = engine();
    let runtime = engine.load_model("gpt-nano").unwrap();
    let mm = &runtime.manifest;
    let mut rng = Rng::new(4);
    let state = TrainState::init(mm, &mut rng);
    let params = state.param_tensors(mm);
    let prompts = vec![vec![BOS, 40, 41, SEP], vec![BOS, 50, SEP]];
    let dp = DecodeParams { max_new_tokens: 8, ..Default::default() };
    let outs = spdf::generate::greedy(&runtime, &params, &prompts, &dp)
        .unwrap();
    assert_eq!(outs.len(), 2);
    for o in &outs {
        assert!(o.len() <= 8);
        assert!(o.iter().all(|&t| (t as usize) < mm.config.vocab_size));
    }
}

#[test]
fn decode_engine_matches_reference_bit_for_bit() {
    // the literal-resident engine (run_raw + partial top-k) must be
    // indistinguishable from the old path (per-step upload + full
    // sort), with and without n-gram blocking
    let engine = engine();
    let runtime = engine.load_model("gpt-nano").unwrap();
    let mm = &runtime.manifest;
    let state = TrainState::init(mm, &mut Rng::new(42));
    let params = state.param_tensors(mm);
    let prompts = vec![
        vec![BOS, 40, 41, SEP],
        vec![BOS, 50, 51, 52, SEP],
    ];
    for ngram in [0usize, 2] {
        let dp = DecodeParams {
            max_new_tokens: 10,
            no_repeat_ngram: ngram,
            ..Default::default()
        };
        let old = reference::greedy(&runtime, &params, &prompts, &dp)
            .unwrap();
        let new = spdf::generate::greedy(&runtime, &params, &prompts,
                                         &dp).unwrap();
        assert_eq!(old, new, "greedy diverged at ngram={ngram}");
    }
    let dp = DecodeParams {
        max_new_tokens: 8,
        beam_size: 3,
        ..Default::default()
    };
    let old = reference::beam(&runtime, &params, &prompts[0], &dp)
        .unwrap();
    let new = spdf::generate::beam(&runtime, &params, &prompts[0], &dp)
        .unwrap();
    assert_eq!(old, new, "beam diverged");
}

#[test]
fn slot_refill_serve_matches_solo_greedy() {
    // oversubscribe the batch with mixed budgets so slots refill
    // mid-flight; every request must decode exactly as it would alone
    let engine = engine();
    let runtime = engine.load_model("gpt-nano").unwrap();
    let mm = &runtime.manifest;
    let state = TrainState::init(mm, &mut Rng::new(6));
    let params = state.param_tensors(mm);
    let decode = DecodeEngine::new(&runtime, &params).unwrap();

    let b = mm.decode_batch;
    let n = 2 * b + 1;
    let prompts: Vec<Vec<u32>> = (0..n)
        .map(|i| vec![BOS, 30 + i as u32, SEP])
        .collect();
    let requests: Vec<DecodeRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| DecodeRequest::new(i as u64, p.clone(),
                                         4 + i % 5))
        .collect();
    let report = decode.serve(&requests,
                              &DecodeParams::default()).unwrap();

    assert_eq!(report.results.len(), n);
    for (i, (res, p)) in
        report.results.iter().zip(&prompts).enumerate()
    {
        assert_eq!(res.id, i as u64);
        let dp = DecodeParams {
            max_new_tokens: 4 + i % 5,
            ..Default::default()
        };
        // oracle is the independent pre-engine path, NOT
        // DecodeEngine::greedy (which is itself built on serve and
        // would self-compare away shared bugs)
        let solo = reference::greedy(&runtime, &params,
                                     std::slice::from_ref(p), &dp)
            .unwrap();
        assert_eq!(res.tokens, solo[0],
                   "slot-refilled request {i} diverged");
    }
    let st = &report.stats;
    assert!(st.engine_steps > 0);
    assert!(st.occupancy > 0.0 && st.occupancy <= 1.0);
    assert_eq!(
        st.generated_tokens,
        report.results.iter()
            .map(|r| r.tokens.len() as u64)
            .sum::<u64>()
    );
    // the queue really waited: someone entered after step 0
    assert!(report.results.iter().any(|r| r.queue_steps > 0),
            "oversubscribed stream should have queued requests");
}

/// Decode-only runtime (logits_last + the KV pair) — keeps the serving
/// tests from paying the train_step compile.
fn decode_runtime(engine: &Engine) -> spdf::runtime::ModelRuntime {
    engine
        .load_model_artifacts("gpt-nano",
                              &["logits_last", "decode_step",
                                "prefill"])
        .expect("decode artifacts — run `make artifacts`")
}

#[test]
fn kv_greedy_matches_reference_bit_for_bit() {
    // the KV-resident incremental path (prefill + decode_step session
    // state) must be indistinguishable from the full-recompute oracle,
    // with and without n-gram blocking
    let engine = engine();
    let runtime = decode_runtime(&engine);
    let mm = &runtime.manifest;
    let state = TrainState::init(mm, &mut Rng::new(42));
    let params = state.param_tensors(mm);
    let decode = DecodeEngine::new(&runtime, &params).unwrap();
    assert!(decode.kv_available(), "manifest should carry KV artifacts");
    let prompts = vec![
        vec![BOS, 40, 41, SEP],
        vec![BOS, 50, 51, 52, SEP],
        vec![BOS, 60, SEP],
    ];
    for ngram in [0usize, 2] {
        let dp = DecodeParams {
            max_new_tokens: 12,
            no_repeat_ngram: ngram,
            ..Default::default()
        };
        let old = reference::greedy(&runtime, &params, &prompts, &dp)
            .unwrap();
        let kv = decode.greedy_kv(&prompts, &dp).unwrap();
        assert_eq!(old, kv, "KV greedy diverged at ngram={ngram}");
    }
}

#[test]
fn kv_serve_matches_solo_greedy_across_slot_refills() {
    // acceptance: a refilled slot must decode exactly as it would
    // alone — in particular it must never see the previous occupant's
    // cache rows
    let engine = engine();
    let runtime = decode_runtime(&engine);
    let mm = &runtime.manifest;
    let state = TrainState::init(mm, &mut Rng::new(6));
    let params = state.param_tensors(mm);
    let decode = DecodeEngine::new(&runtime, &params).unwrap();

    let b = mm.decode_batch;
    let n = 2 * b + 1;
    let prompts: Vec<Vec<u32>> = (0..n)
        .map(|i| vec![BOS, 30 + i as u32, SEP])
        .collect();
    let requests: Vec<DecodeRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| DecodeRequest::new(i as u64, p.clone(),
                                         4 + i % 5))
        .collect();
    let report = decode.serve_kv(&requests,
                                 &DecodeParams::default()).unwrap();
    assert_eq!(report.results.len(), n);
    for (i, (res, p)) in
        report.results.iter().zip(&prompts).enumerate()
    {
        assert_eq!(res.id, i as u64);
        let dp = DecodeParams {
            max_new_tokens: 4 + i % 5,
            ..Default::default()
        };
        let solo = reference::greedy(&runtime, &params,
                                     std::slice::from_ref(p), &dp)
            .unwrap();
        assert_eq!(res.tokens, solo[0],
                   "KV slot-refilled request {i} diverged");
    }
    let st = &report.stats;
    // initial fill is one prefill; every refill wave adds another
    assert!(st.prefill_steps >= 2,
            "oversubscribed KV serve should have refilled slots \
             (prefill_steps = {})", st.prefill_steps);
    assert!(st.engine_steps > 0 && st.occupancy > 0.0);
    assert!(report.results.iter().any(|r| r.queue_steps > 0));
}

#[test]
fn serve_mixed_zero_budget_stream_both_paths() {
    // zero-budget requests must complete instantly without occupying
    // a slot, on the literal and the KV path alike
    let engine = engine();
    let runtime = decode_runtime(&engine);
    let mm = &runtime.manifest;
    let state = TrainState::init(mm, &mut Rng::new(7));
    let params = state.param_tensors(mm);
    let decode = DecodeEngine::new(&runtime, &params).unwrap();

    let n = mm.decode_batch + 3;
    let requests: Vec<DecodeRequest> = (0..n)
        .map(|i| DecodeRequest::new(
            i as u64,
            vec![BOS, 20 + i as u32, SEP],
            if i % 2 == 0 { 0 } else { 5 }))
        .collect();
    let dp = DecodeParams::default();
    for kv in [false, true] {
        let report = if kv {
            decode.serve_kv(&requests, &dp).unwrap()
        } else {
            decode.serve(&requests, &dp).unwrap()
        };
        assert_eq!(report.results.len(), n, "kv={kv}");
        for (i, res) in report.results.iter().enumerate() {
            if i % 2 == 0 {
                assert!(res.tokens.is_empty(), "kv={kv} req {i}");
                assert_eq!(res.decode_steps, 0, "kv={kv} req {i}");
            } else {
                let solo = reference::greedy(
                    &runtime, &params,
                    &[requests[i].prompt.clone()],
                    &DecodeParams { max_new_tokens: 5,
                                    ..Default::default() })
                    .unwrap();
                assert_eq!(res.tokens, solo[0], "kv={kv} req {i}");
            }
        }
    }
}

#[test]
fn serve_max_length_prompt_both_paths() {
    // the longest admissible prompt (t - 1 tokens) decodes exactly one
    // token (or zero on EOS) and must agree with the oracle
    let engine = engine();
    let runtime = decode_runtime(&engine);
    let mm = &runtime.manifest;
    let t = mm.config.ctx_len;
    let state = TrainState::init(mm, &mut Rng::new(8));
    let params = state.param_tensors(mm);
    let decode = DecodeEngine::new(&runtime, &params).unwrap();

    let mut prompt = vec![BOS];
    prompt.extend((0..t - 3).map(|j| 4 + (j % 400) as u32));
    prompt.push(SEP);
    assert_eq!(prompt.len(), t - 1);

    let dp = DecodeParams { max_new_tokens: 8, ..Default::default() };
    let solo = reference::greedy(&runtime, &params,
                                 &[prompt.clone()], &dp).unwrap();
    assert!(solo[0].len() <= 1, "context-edge prompt over-generated");
    let requests =
        vec![DecodeRequest::new(0, prompt.clone(), dp.max_new_tokens)];
    for kv in [false, true] {
        let report = if kv {
            decode.serve_kv(&requests, &dp).unwrap()
        } else {
            decode.serve(&requests, &dp).unwrap()
        };
        assert_eq!(report.results[0].tokens, solo[0], "kv={kv}");
    }
}

#[test]
fn loadgen_timed_serve_deterministic_and_decode_exact() {
    // acceptance: the same seed + pinned virtual step costs reproduce
    // identical per-request latencies, and arrival-gated admission
    // must not change WHAT is decoded — every request still decodes
    // exactly as it would alone
    let engine = engine();
    let runtime = decode_runtime(&engine);
    let mm = &runtime.manifest;
    let state = TrainState::init(mm, &mut Rng::new(21));
    let params = state.param_tensors(mm);
    let decode = DecodeEngine::new(&runtime, &params).unwrap();

    let cfg = TraceConfig {
        seed: 5,
        requests: mm.decode_batch + 3,
        rate_rps: 300.0,
        pattern: Pattern::Poisson,
        prompt_lens: (3, 6),
        budgets: (2, 6),
        vocab: mm.config.vocab_size,
        priority_classes: 1,
        model_mix: Vec::new(),
    };
    let trace = loadgen::generate_trace(&cfg).unwrap();
    let dp = DecodeParams::default();
    let costs = StepCosts::default();
    let (pa, ra) =
        loadgen::run_trace(&decode, &trace, &dp, false, &costs)
            .unwrap();
    let (_pb, rb) =
        loadgen::run_trace(&decode, &trace, &dp, false, &costs)
            .unwrap();
    assert_eq!(ra.results.len(), rb.results.len());
    for (x, y) in ra.results.iter().zip(&rb.results) {
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(
            (x.arrival_ms, x.queue_ms, x.ttft_ms, x.latency_ms),
            (y.arrival_ms, y.queue_ms, y.ttft_ms, y.latency_ms),
            "virtual-clock latencies not reproducible for request {}",
            x.id
        );
    }
    assert_eq!(ra.stats.sim_ms, rb.stats.sim_ms);
    // results are id-sorted and trace ids are indices
    for (res, req) in ra.results.iter().zip(&trace.requests) {
        let solo = reference::greedy(
            &runtime, &params, std::slice::from_ref(&req.prompt),
            &DecodeParams { max_new_tokens: req.max_new_tokens,
                            ..Default::default() })
            .unwrap();
        assert_eq!(res.tokens, solo[0],
                   "timed request {} diverged from solo decode",
                   res.id);
    }
    assert!(pa.latency_ms.p95 >= pa.latency_ms.p50);
    assert!(pa.sim_ms > 0.0);
}

#[test]
fn loadgen_kv_and_literal_decode_same_trace_identically() {
    // both engines under the exact same trace: identical tokens,
    // with the KV path re-populating caches across timed refills
    let engine = engine();
    let runtime = decode_runtime(&engine);
    let mm = &runtime.manifest;
    let state = TrainState::init(mm, &mut Rng::new(22));
    let params = state.param_tensors(mm);
    let decode = DecodeEngine::new(&runtime, &params).unwrap();
    assert!(decode.kv_available());

    let cfg = TraceConfig {
        seed: 9,
        requests: 2 * mm.decode_batch + 1,
        rate_rps: 500.0,
        pattern: Pattern::Bursty { burst: 4 },
        prompt_lens: (3, 5),
        budgets: (2, 5),
        vocab: mm.config.vocab_size,
        priority_classes: 1,
        model_mix: Vec::new(),
    };
    let trace = loadgen::generate_trace(&cfg).unwrap();
    let dp = DecodeParams::default();
    let costs = StepCosts::default();
    let (_, rl) =
        loadgen::run_trace(&decode, &trace, &dp, false, &costs)
            .unwrap();
    let (_, rk) =
        loadgen::run_trace(&decode, &trace, &dp, true, &costs)
            .unwrap();
    assert_eq!(rl.results.len(), rk.results.len());
    for (x, y) in rl.results.iter().zip(&rk.results) {
        assert_eq!(x.tokens, y.tokens,
                   "kv/literal diverged on timed request {}", x.id);
    }
    // oversubscribed: the initial fill plus at least one refill wave
    assert!(rk.stats.prefill_steps >= 2,
            "timed KV serve should have refilled slots \
             (prefill_steps = {})", rk.stats.prefill_steps);
}

#[test]
fn serve_policies_fifo_unbounded_bit_identical_to_default() {
    // tentpole acceptance: threading the explicit FIFO + unbounded
    // policies through the refactored serve core must reproduce the
    // default `serve_timed` path bit-for-bit on a real trace — token
    // streams AND telemetry — on both engine paths
    use spdf::generate::serve::admission::Unbounded;
    use spdf::generate::serve::policy::Fifo;
    use spdf::generate::ServeConfig;

    let engine = engine();
    let runtime = decode_runtime(&engine);
    let mm = &runtime.manifest;
    let state = TrainState::init(mm, &mut Rng::new(31));
    let params = state.param_tensors(mm);
    let decode = DecodeEngine::new(&runtime, &params).unwrap();

    let cfg = TraceConfig {
        seed: 13,
        requests: mm.decode_batch + 5,
        rate_rps: 400.0,
        pattern: Pattern::Poisson,
        prompt_lens: (3, 6),
        budgets: (2, 6),
        vocab: mm.config.vocab_size,
        priority_classes: 1,
        model_mix: Vec::new(),
    };
    let trace = loadgen::generate_trace(&cfg).unwrap();
    let sched = trace.schedule(&StepCosts::default());
    let dp = DecodeParams::default();
    for kv in [false, true] {
        let default_report = spdf::generate::serve::core::serve_timed(
            &decode, &trace.requests, &dp, kv, &sched).unwrap();
        let explicit_report = decode.serve_with(
            &trace.requests, &dp,
            &ServeConfig {
                use_kv: kv,
                schedule: Some(&sched),
                scheduler: &Fifo,
                admission: &Unbounded,
                recovery: spdf::generate::RecoveryConfig::default(),
                faults: Vec::new(),
                fallback: None,
                speculate: None,
                paged: None,
            }).unwrap();
        assert_eq!(default_report.results.len(),
                   explicit_report.results.len(), "kv={kv}");
        for (x, y) in default_report.results.iter()
            .zip(&explicit_report.results)
        {
            assert_eq!(x.tokens, y.tokens, "kv={kv} req {}", x.id);
            assert_eq!(
                (x.arrival_ms, x.queue_ms, x.ttft_ms, x.latency_ms,
                 x.queue_steps, x.decode_steps),
                (y.arrival_ms, y.queue_ms, y.ttft_ms, y.latency_ms,
                 y.queue_steps, y.decode_steps),
                "kv={kv} req {}", x.id
            );
            assert!(x.outcome.is_completed(), "kv={kv}");
        }
        let (ds, es) = (&default_report.stats,
                        &explicit_report.stats);
        assert_eq!(ds.engine_steps, es.engine_steps, "kv={kv}");
        assert_eq!(ds.prefill_steps, es.prefill_steps, "kv={kv}");
        assert_eq!(ds.slot_steps, es.slot_steps, "kv={kv}");
        assert_eq!(ds.sim_ms, es.sim_ms, "kv={kv}");
        assert_eq!(ds.latency_ms, es.latency_ms, "kv={kv}");
        assert_eq!(ds.queue_ms, es.queue_ms, "kv={kv}");
        assert_eq!(ds.ttft_ms, es.ttft_ms, "kv={kv}");
        // unbounded admission: the pre-refactor invariants hold
        assert_eq!(es.completed, trace.requests.len(), "kv={kv}");
        assert_eq!((es.shed, es.expired), (0, 0), "kv={kv}");
        assert_eq!(es.shed_rate, 0.0, "kv={kv}");
        // and every completed request still decodes exactly as alone
        for (res, req) in explicit_report.results.iter()
            .zip(&trace.requests)
        {
            let solo = reference::greedy(
                &runtime, &params,
                std::slice::from_ref(&req.prompt),
                &DecodeParams { max_new_tokens: req.max_new_tokens,
                                ..Default::default() })
                .unwrap();
            assert_eq!(res.tokens, solo[0], "kv={kv} req {}", res.id);
        }
    }
}

#[test]
fn serve_with_shedding_policies_decodes_survivors_exactly() {
    // scheduling + admission on the real engine: a reordered, bounded
    // queue changes WHO is served, never WHAT a survivor decodes —
    // and bounding the queue past the knee caps the completed p95
    use spdf::generate::serve::admission::MaxQueueDepth;
    use spdf::generate::serve::policy::SmallestBudgetFirst;
    use spdf::generate::RequestOutcome;

    let engine = engine();
    let runtime = decode_runtime(&engine);
    let mm = &runtime.manifest;
    let state = TrainState::init(mm, &mut Rng::new(32));
    let params = state.param_tensors(mm);
    let decode = DecodeEngine::new(&runtime, &params).unwrap();

    // everything arrives in one burst: with B slots free and a
    // depth-2 queue, exactly B + 2 requests survive, deterministically
    let n = 2 * mm.decode_batch + 4;
    let cfg = TraceConfig {
        seed: 17,
        requests: n,
        rate_rps: 900.0,
        pattern: Pattern::Bursty { burst: n },
        prompt_lens: (3, 6),
        budgets: (2, 6),
        vocab: mm.config.vocab_size,
        priority_classes: 1,
        model_mix: Vec::new(),
    };
    let trace = loadgen::generate_trace(&cfg).unwrap();
    let costs = StepCosts::default();
    let dp = DecodeParams::default();
    let (unb_pt, _) =
        loadgen::run_trace(&decode, &trace, &dp, false, &costs)
            .unwrap();
    let (pt, report) = loadgen::run_trace_with(
        &decode, &trace, &dp, false, &costs, &SmallestBudgetFirst,
        &MaxQueueDepth(2),
        &spdf::generate::ChaosConfig::default(), None).unwrap();
    assert_eq!(pt.completed, mm.decode_batch + 2);
    assert_eq!(pt.shed, n - mm.decode_batch - 2);
    assert_eq!(pt.expired, 0);
    assert!(pt.shed_rate > 0.0);
    assert_eq!(pt.scheduler, "smallest-budget");
    assert_eq!(pt.admission, "max-queue(2)");
    // bounded queue keeps the completed tail at or below unbounded
    assert!(pt.latency_ms.p95 <= unb_pt.latency_ms.p95,
            "bounded p95 {} > unbounded p95 {}",
            pt.latency_ms.p95, unb_pt.latency_ms.p95);
    assert_eq!(unb_pt.shed_rate, 0.0);
    // survivors decode bit-identically to solo reference decodes
    for res in &report.results {
        match res.outcome {
            RequestOutcome::Completed => {
                let req = &trace.requests[res.id as usize];
                let solo = reference::greedy(
                    &runtime, &params,
                    std::slice::from_ref(&req.prompt),
                    &DecodeParams {
                        max_new_tokens: req.max_new_tokens,
                        ..Default::default()
                    })
                    .unwrap();
                assert_eq!(res.tokens, solo[0], "req {}", res.id);
            }
            _ => assert!(res.tokens.is_empty(), "req {}", res.id),
        }
    }
    // determinism of the full policy pipeline
    let (pt2, report2) = loadgen::run_trace_with(
        &decode, &trace, &dp, false, &costs, &SmallestBudgetFirst,
        &MaxQueueDepth(2),
        &spdf::generate::ChaosConfig::default(), None).unwrap();
    assert_eq!(pt.shed_rate, pt2.shed_rate);
    assert_eq!(pt.latency_ms.p95, pt2.latency_ms.p95);
    for (x, y) in report.results.iter().zip(&report2.results) {
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.outcome, y.outcome);
    }
}

#[test]
fn registry_single_model_is_bit_identical_to_serve_timed() {
    // acceptance (ISSUE 5): a registry holding only the default model
    // must reproduce today's serve_timed output bit-for-bit — token
    // streams AND telemetry — on both engine paths
    use spdf::generate::ModelRegistry;

    let engine = engine();
    let runtime = decode_runtime(&engine);
    let mm = &runtime.manifest;
    let state = TrainState::init(mm, &mut Rng::new(41));
    let params = state.param_tensors(mm);
    let decode = DecodeEngine::new(&runtime, &params).unwrap();
    let registry = ModelRegistry::new("gpt-nano", &decode).unwrap();

    let cfg = TraceConfig {
        seed: 23,
        requests: mm.decode_batch + 4,
        rate_rps: 350.0,
        pattern: Pattern::Poisson,
        prompt_lens: (3, 6),
        budgets: (2, 6),
        vocab: mm.config.vocab_size,
        priority_classes: 1,
        model_mix: Vec::new(),
    };
    let trace = loadgen::generate_trace(&cfg).unwrap();
    let sched = trace.schedule(&StepCosts::default());
    let dp = DecodeParams::default();
    for kv in [false, true] {
        let plain = spdf::generate::serve::core::serve_timed(
            &decode, &trace.requests, &dp, kv, &sched).unwrap();
        let routed = registry
            .serve_timed(&trace.requests, &dp, kv, &sched)
            .unwrap();
        assert_eq!(plain.results.len(), routed.results.len(),
                   "kv={kv}");
        for (x, y) in plain.results.iter().zip(&routed.results) {
            assert_eq!(x.tokens, y.tokens, "kv={kv} req {}", x.id);
            assert_eq!(
                (x.arrival_ms, x.queue_ms, x.ttft_ms, x.latency_ms,
                 x.queue_steps, x.decode_steps),
                (y.arrival_ms, y.queue_ms, y.ttft_ms, y.latency_ms,
                 y.queue_steps, y.decode_steps),
                "kv={kv} req {}", x.id
            );
        }
        // telemetry bit-identical too (wall-clock fields excluded:
        // they measure host time, not loop behavior)
        let (ps, rs) = (&plain.stats, &routed.stats);
        assert_eq!(ps.engine_steps, rs.engine_steps, "kv={kv}");
        assert_eq!(ps.prefill_steps, rs.prefill_steps, "kv={kv}");
        assert_eq!(ps.slot_steps, rs.slot_steps, "kv={kv}");
        assert_eq!(ps.occupancy, rs.occupancy, "kv={kv}");
        assert_eq!(ps.sim_ms, rs.sim_ms, "kv={kv}");
        assert_eq!(ps.latency_ms, rs.latency_ms, "kv={kv}");
        assert_eq!(ps.queue_ms, rs.queue_ms, "kv={kv}");
        assert_eq!(ps.ttft_ms, rs.ttft_ms, "kv={kv}");
        // the registry's one per-model block mirrors the aggregate
        assert_eq!(routed.per_model.len(), 1, "kv={kv}");
        assert_eq!(routed.per_model[0].model, "gpt-nano");
        assert_eq!(routed.per_model[0].stats.generated_tokens,
                   rs.generated_tokens, "kv={kv}");
    }
}

#[test]
fn registry_cross_engine_golden_mixed_trace() {
    // cross-engine golden (ISSUE 5 satellite): the SAME artifacts
    // registered under two model names, a mixed trace routed across
    // them — each model's survivors must decode bit-identical to the
    // solo reference oracle, on both the literal and the KV path, and
    // the per-model telemetry must partition the aggregate
    use spdf::generate::ModelRegistry;

    let engine = engine();
    let runtime = decode_runtime(&engine);
    let mm = &runtime.manifest;
    let state = TrainState::init(mm, &mut Rng::new(43));
    let params = state.param_tensors(mm);
    let decode = DecodeEngine::new(&runtime, &params).unwrap();
    let mut registry = ModelRegistry::new("dense", &decode).unwrap();
    registry.register("s75", &decode).unwrap();
    assert_eq!(registry.names(), vec!["dense", "s75"]);
    assert_eq!(registry.default_model(), "dense");
    assert!(registry.register("s75", &decode).is_err(),
            "duplicate registration must fail");
    // routing resolution: None → default, names exact, unknown errors
    assert_eq!(registry.resolve(None).unwrap(), 0);
    assert_eq!(registry.resolve(Some("s75")).unwrap(), 1);
    let err = registry.resolve(Some("s99")).unwrap_err();
    assert!(err.to_string().contains("s99"), "{err}");
    assert!(err.to_string().contains("dense"), "{err}");

    let cfg = TraceConfig {
        seed: 29,
        requests: 2 * mm.decode_batch + 3,
        rate_rps: 500.0,
        pattern: Pattern::Bursty { burst: 4 },
        prompt_lens: (3, 6),
        budgets: (2, 6),
        vocab: mm.config.vocab_size,
        priority_classes: 1,
        model_mix: vec![("dense".into(), 0.5), ("s75".into(), 0.5)],
    };
    let trace = loadgen::generate_trace(&cfg).unwrap();
    assert!(trace.requests.iter().any(
        |r| r.model.as_deref() == Some("dense")));
    assert!(trace.requests.iter().any(
        |r| r.model.as_deref() == Some("s75")));
    let sched = trace.schedule(&StepCosts::default());
    let dp = DecodeParams::default();
    for kv in [false, true] {
        let report = registry
            .serve_timed(&trace.requests, &dp, kv, &sched)
            .unwrap();
        assert_eq!(report.results.len(), trace.requests.len(),
                   "kv={kv}");
        for res in &report.results {
            assert!(res.outcome.is_completed(), "kv={kv}");
            let req = &trace.requests[res.id as usize];
            let solo = reference::greedy(
                &runtime, &params, std::slice::from_ref(&req.prompt),
                &DecodeParams { max_new_tokens: req.max_new_tokens,
                                ..Default::default() })
                .unwrap();
            assert_eq!(res.tokens, solo[0],
                       "kv={kv} model {:?} req {} diverged from solo \
                        reference", req.model, res.id);
        }
        // per-model blocks partition the aggregate
        let st = &report.stats;
        assert_eq!(report.per_model.len(), 2, "kv={kv}");
        let sum_req: usize = report.per_model.iter()
            .map(|m| m.stats.requests).sum();
        let sum_tok: u64 = report.per_model.iter()
            .map(|m| m.stats.generated_tokens).sum();
        let sum_steps: u64 = report.per_model.iter()
            .map(|m| m.stats.engine_steps).sum();
        assert_eq!(sum_req, st.requests, "kv={kv}");
        assert_eq!(sum_tok, st.generated_tokens, "kv={kv}");
        assert_eq!(sum_steps, st.engine_steps, "kv={kv}");
        for m in &report.per_model {
            assert!(m.stats.requests > 0,
                    "kv={kv}: model {} got no requests from a 50/50 \
                     mix", m.model);
            assert_eq!(m.stats.completed, m.stats.requests,
                       "kv={kv}");
        }
        if kv {
            // each lane owns its own session state and prefills it
            assert!(st.prefill_steps >= 2,
                    "both KV lanes should have prefilled \
                     (prefill_steps = {})", st.prefill_steps);
        }
        // routing an unknown model errors up front
        let bad = vec![spdf::generate::DecodeRequest::new(
            0, vec![BOS, 40, SEP], 2).with_model("s99")];
        assert!(registry.serve_timed(
            &bad, &dp, kv,
            &loadgen::generate_trace(&TraceConfig {
                requests: 1, ..cfg.clone()
            }).unwrap().schedule(&StepCosts::default())).is_err());
    }
}

#[test]
fn sparse_residency_artifact_golden() {
    // tentpole acceptance (ISSUE 8): CSR residency never changes
    // compute. An s75 checkpoint loaded through the auto-detecting
    // path (held CSR-resident) must decode bit-identically to its
    // dense-loaded twin and to the reference oracle; registering the
    // CSR lane next to a dense lane must not perturb the dense lane's
    // streams; and on the calibrated clock the sparse lane's cheaper
    // steps must finish the same trace no later than the dense lane.
    use spdf::generate::serve::admission::Unbounded;
    use spdf::generate::serve::policy::Fifo;
    use spdf::generate::{ChaosConfig, ModelRegistry};

    let engine = engine();
    let runtime = decode_runtime(&engine);
    let mm = &runtime.manifest;
    let mut rng = Rng::new(57);
    let mut state = TrainState::init(mm, &mut rng);
    state.sparsify(MaskSet::random(
        mm, 0.75, MaskScheme::Uniform, &mut rng));
    let s75_params = state.param_tensors(mm);

    let auto = DecodeEngine::new(&runtime, &s75_params).unwrap();
    let dense_loaded =
        DecodeEngine::new_dense(&runtime, &s75_params).unwrap();
    assert_eq!(auto.sparse_slots(), mm.masked_params.len(),
               "auto-detect must hold every masked param CSR");
    assert_eq!(dense_loaded.sparse_slots(), 0);
    let s = auto.sparsity().expect("sparse slots detected");
    assert!((s - 0.75).abs() < 0.01, "realized sparsity {s}");
    let (csr_bytes, dense_bytes) = auto.sparse_host_bytes();
    assert!(csr_bytes < dense_bytes,
            "CSR residency must save host bytes ({csr_bytes} vs \
             {dense_bytes})");
    let scale = auto.lane_cost().step_scale;
    assert!((scale - (1.0 - s)).abs() < 1e-12,
            "lane cost must calibrate from realized sparsity");
    assert!((dense_loaded.lane_cost().step_scale - 1.0).abs() == 0.0);

    // greedy: CSR-resident == dense-loaded == reference oracle,
    // token-for-token
    let prompts: Vec<Vec<u32>> = (0..mm.decode_batch)
        .map(|i| vec![BOS, 7 + i as u32, SEP])
        .collect();
    let dp = DecodeParams { max_new_tokens: 8, ..Default::default() };
    let a = auto.greedy(&prompts, &dp).unwrap();
    let d = dense_loaded.greedy(&prompts, &dp).unwrap();
    let r = reference::greedy(&runtime, &s75_params, &prompts, &dp)
        .unwrap();
    assert_eq!(a, d, "CSR residency changed greedy decode");
    assert_eq!(a, r, "engine diverged from the reference oracle");

    // cross-lane golden: the same default-routed trace through a
    // dense-only registry and a dense+s75 registry — adding the CSR
    // lane must leave every survivor's stream bit-identical
    let reg_a = ModelRegistry::new("dense", &dense_loaded).unwrap();
    let mut reg_b = ModelRegistry::new("dense", &dense_loaded).unwrap();
    reg_b.register("s75", &auto).unwrap();
    let cfg = TraceConfig {
        seed: 31,
        requests: mm.decode_batch + 3,
        rate_rps: 400.0,
        pattern: Pattern::Bursty { burst: mm.decode_batch + 3 },
        prompt_lens: (3, 6),
        budgets: (2, 6),
        vocab: mm.config.vocab_size,
        priority_classes: 1,
        model_mix: Vec::new(),
    };
    let trace = loadgen::generate_trace(&cfg).unwrap();
    let dp = DecodeParams::default();
    let costs = StepCosts::default();
    let run = |reg: &ModelRegistry, t: &loadgen::Trace| {
        loadgen::run_trace_registry(
            reg, t, &dp, false, &costs, &Fifo, &Unbounded,
            &ChaosConfig::default(), None, None)
            .unwrap()
    };
    let (_, _, rep_a) = run(&reg_a, &trace);
    let (_, _, rep_b) = run(&reg_b, &trace);
    assert_eq!(rep_a.results.len(), rep_b.results.len());
    for (x, y) in rep_a.results.iter().zip(&rep_b.results) {
        assert_eq!(x.tokens, y.tokens,
                   "registering a CSR lane perturbed the dense lane \
                    (req {})", x.id);
    }

    // calibrated clock: route the whole trace to each lane in turn.
    // Same weights on both lanes, so the streams stay bitwise equal —
    // only the virtual makespan may differ, and the sparse lane's
    // cheaper steps must never finish later
    let route_all = |name: &str| {
        let mut t = trace.clone();
        for r in t.requests.iter_mut() {
            r.model = Some(name.into());
        }
        t
    };
    let (dense_pt, _, rep_d) = run(&reg_b, &route_all("dense"));
    let (s75_pt, _, rep_s) = run(&reg_b, &route_all("s75"));
    for pt in [&dense_pt, &s75_pt] {
        assert_eq!(pt.completed, pt.requests,
                   "unbounded admission must complete every request");
    }
    for (x, y) in rep_d.results.iter().zip(&rep_s.results) {
        assert_eq!(x.tokens, y.tokens,
                   "dense-routed and s75-routed streams diverged \
                    (req {})", x.id);
    }
    assert!(s75_pt.sim_ms < dense_pt.sim_ms,
            "s75 lane (step scale {scale:.2}) should beat the dense \
             lane on the virtual clock ({} vs {} ms)",
            s75_pt.sim_ms, dense_pt.sim_ms);
    assert!(s75_pt.tokens_per_vsec > dense_pt.tokens_per_vsec);
}

#[test]
fn speculative_decode_bitwise_matches_dense_reference() {
    // tentpole acceptance (ISSUE 9): self-speculative decoding over
    // real artifacts. A genuinely different draft (the s75-sparsified
    // checkpoint) proposing for the dense verifier must leave every
    // greedy stream bitwise identical to the plain dense serve AND to
    // the reference oracle — rejections only cost speed, never
    // output — while the acceptance bookkeeping conserves every
    // emitted token.
    use spdf::generate::serve::admission::Unbounded;
    use spdf::generate::serve::policy::Fifo;
    use spdf::generate::{ChaosConfig, ModelRegistry, SpecConfig};

    let engine = engine();
    let runtime = decode_runtime(&engine);
    let mm = &runtime.manifest;
    let mut rng = Rng::new(61);
    let mut state = TrainState::init(mm, &mut rng);
    let dense_params = state.param_tensors(mm);
    state.sparsify(MaskSet::random(
        mm, 0.75, MaskScheme::Uniform, &mut rng));
    let s75_params = state.param_tensors(mm);
    let dense = DecodeEngine::new(&runtime, &dense_params).unwrap();
    let s75 = DecodeEngine::new(&runtime, &s75_params).unwrap();
    assert!(s75.sparse_slots() > 0, "draft lane must be the CSR twin");

    let mut reg = ModelRegistry::new("dense", &dense).unwrap();
    reg.register("s75", &s75).unwrap();

    let cfg = TraceConfig {
        seed: 43,
        requests: mm.decode_batch + 2,
        rate_rps: 400.0,
        pattern: Pattern::Bursty { burst: mm.decode_batch + 2 },
        prompt_lens: (3, 6),
        budgets: (3, 8),
        vocab: mm.config.vocab_size,
        priority_classes: 1,
        model_mix: Vec::new(),
    };
    let trace = {
        let mut t = loadgen::generate_trace(&cfg).unwrap();
        for r in t.requests.iter_mut() {
            // everyone targets the verifier; the draft lane only leases
            r.model = Some("dense".into());
        }
        t
    };
    let dp = DecodeParams::default();
    let costs = StepCosts::default();
    let spec = SpecConfig::new("s75", "dense", 4).unwrap();
    let run = |speculate: Option<&SpecConfig>| {
        loadgen::run_trace_registry(
            &reg, &trace, &dp, false, &costs, &Fifo, &Unbounded,
            &ChaosConfig::default(), speculate, None)
            .unwrap()
    };
    let (_, _, plain) = run(None);
    let (_, _, spec_rep) = run(Some(&spec));

    // multi-token commits can reorder completion instants, so compare
    // by request id, not by completion order
    assert_eq!(plain.results.len(), spec_rep.results.len());
    let by_id = |rep: &spdf::generate::ServeReport| {
        let mut v: Vec<(u64, Vec<u32>)> = rep.results.iter()
            .map(|r| (r.id, r.tokens.clone()))
            .collect();
        v.sort_by_key(|(id, _)| *id);
        v
    };
    assert_eq!(by_id(&plain), by_id(&spec_rep),
               "speculation changed a greedy stream");
    for s in &spec_rep.results {
        // per-request conservation: every emitted token was either an
        // accepted draft or a verifier correction
        assert_eq!(s.tokens.len() as u64,
                   s.spec.accepted + s.spec.corrections,
                   "req {} emitted {} tokens but booked {} + {}",
                   s.id, s.tokens.len(), s.spec.accepted,
                   s.spec.corrections);
    }
    // the draft lane really ran, and verifies never lost ground
    let sc = &spec_rep.stats.spec;
    assert!(sc.verifies > 0 && sc.drafted > 0,
            "speculation never engaged ({sc:?})");
    // every verify advances its request; only the terminal EOS verify
    // emits nothing, so verifies <= emitted + one per completed stream
    assert!(sc.verifies <= sc.accepted + sc.corrections
                + spec_rep.stats.completed as u64,
            "a verify committed no progress ({sc:?}, completed {})",
            spec_rep.stats.completed);
    // and each spec stream is still the dense reference oracle's
    for res in &spec_rep.results {
        let req = trace.requests.iter().find(|q| q.id == res.id)
            .expect("result id from the trace");
        let solo = reference::greedy(
            &runtime, &dense_params,
            std::slice::from_ref(&req.prompt),
            &DecodeParams { max_new_tokens: req.max_new_tokens,
                            ..Default::default() })
            .unwrap();
        assert_eq!(res.tokens, solo[0],
                   "spec decode diverged from the dense reference \
                    (req {})", res.id);
    }
}

#[test]
fn beam_capacity_boundary_emits_scored_token() {
    // regression (ISSUE 2): a beam finished by the capacity check used
    // to accumulate the candidate's log-prob but drop the token — the
    // winner was scored on a token it never emitted. At the context
    // edge beam must agree with greedy's boundary semantics.
    let engine = engine();
    let runtime = decode_runtime(&engine);
    let mm = &runtime.manifest;
    let t = mm.config.ctx_len;
    let state = TrainState::init(mm, &mut Rng::new(13));
    let params = state.param_tensors(mm);
    let decode = DecodeEngine::new(&runtime, &params).unwrap();

    let mut prompt = vec![BOS];
    prompt.extend((0..t - 4).map(|j| 4 + (j % 399) as u32));
    prompt.push(SEP);
    assert_eq!(prompt.len(), t - 2); // every candidate hits capacity

    let dp = DecodeParams {
        max_new_tokens: 4,
        beam_size: 3,
        ..Default::default()
    };
    let out = decode.beam(&prompt, &dp).unwrap();
    let old = reference::beam(&runtime, &params, &prompt, &dp).unwrap();
    assert_eq!(out, old, "engine/oracle beam diverged at capacity");
    // with a single expansion step the length penalty is degenerate,
    // so the beam winner is exactly the greedy boundary token
    let greedy = decode
        .greedy(&[prompt.clone()],
                &DecodeParams { max_new_tokens: 1,
                                ..Default::default() })
        .unwrap();
    assert_eq!(out, greedy[0],
               "capacity-finished beam must emit the token it was \
                scored on");
}

#[test]
fn run_and_run_raw_decompose_outputs_identically() {
    // `run` and `run_raw` share one result-decomposition helper; both
    // must hand back the same logits for the same inputs (`run` used
    // to fail on single-output non-tuple artifacts)
    let engine = engine();
    let runtime = decode_runtime(&engine);
    let mm = &runtime.manifest;
    let state = TrainState::init(mm, &mut Rng::new(14));
    let params = state.param_tensors(mm);
    let b = mm.decode_batch;
    let t = mm.config.ctx_len;
    let mut tokens = vec![0i32; b * t];
    for (j, tok) in [BOS, 40, 41, SEP].iter().enumerate() {
        tokens[j] = *tok as i32;
    }
    let pos = vec![3i32; b];
    let exe = runtime.artifact("logits_last").unwrap();

    let mut inputs = params.clone();
    inputs.push(HostTensor::from_i32(&[b, t], tokens.clone()));
    inputs.push(HostTensor::from_i32(&[b], pos.clone()));
    let via_run = exe.run(&inputs).unwrap();

    let literals: Vec<xla::Literal> = inputs
        .iter()
        .map(|h| h.to_literal().unwrap())
        .collect();
    let refs: Vec<&xla::Literal> = literals.iter().collect();
    let via_raw = exe.run_raw(&refs).unwrap();
    assert_eq!(via_run.len(), via_raw.len());
    assert_eq!(via_run[0].as_f32().unwrap(),
               &via_raw[0].to_vec::<f32>().unwrap()[..]);
}

#[test]
fn run_rejects_malformed_inputs_and_stays_usable() {
    // error containment at the runtime layer: a malformed call must
    // come back as a contextful Err — never a panic — and the
    // executable must keep serving valid calls afterwards (the serve
    // loop's retry path depends on that)
    let engine = engine();
    let runtime = decode_runtime(&engine);
    let mm = &runtime.manifest;
    let state = TrainState::init(mm, &mut Rng::new(14));
    let params = state.param_tensors(mm);
    let b = mm.decode_batch;
    let t = mm.config.ctx_len;
    let exe = runtime.artifact("logits_last").unwrap();

    // too few inputs: the arity error names the counts
    let err = exe.run(&params).unwrap_err().to_string();
    assert!(err.contains("inputs, expected"), "unhelpful: {err}");

    // right arity, truncated tokens tensor: the slot error names the
    // offending input and both shapes
    let mut bad = params.clone();
    bad.push(HostTensor::from_i32(&[b, t - 1], vec![0; b * (t - 1)]));
    bad.push(HostTensor::from_i32(&[b], vec![0; b]));
    let err = exe.run(&bad).unwrap_err().to_string();
    assert!(err.contains("does not match manifest"),
            "unhelpful: {err}");

    // right arity and shape, wrong dtype
    let mut bad = params.clone();
    bad.push(HostTensor::zeros_f32(&[b, t]));
    bad.push(HostTensor::from_i32(&[b], vec![0; b]));
    assert!(exe.run(&bad).is_err());

    // run_raw skips spec validation but an arity mismatch must still
    // surface as a clean Err from the execute layer
    let lone = HostTensor::from_i32(&[b], vec![0; b])
        .to_literal()
        .unwrap();
    assert!(exe.run_raw(&[&lone]).is_err());

    // none of the failed calls poisoned the executable
    let mut good = params.clone();
    good.push(HostTensor::from_i32(&[b, t], vec![0; b * t]));
    good.push(HostTensor::from_i32(&[b], vec![0; b]));
    exe.run(&good).unwrap();
}

#[test]
fn compile_rejects_missing_and_truncated_artifacts() {
    // a deleted or half-written HLO artifact must fail compilation
    // with a clean Err that names the file
    let engine = engine();
    let mm = engine.manifest.models.get("gpt-nano").unwrap();
    let spec = mm.artifacts.get("logits_last").unwrap();

    let mut missing = spec.clone();
    missing.file = std::path::PathBuf::from(
        "/nonexistent/spdf/gone.hlo.txt");
    let err = spdf::runtime::Executable::compile(&engine.client,
                                                 &missing)
        .expect_err("compiled a nonexistent artifact")
        .to_string();
    assert!(err.contains("gone.hlo.txt"), "unhelpful: {err}");

    let text = std::fs::read_to_string(&spec.file).unwrap();
    let dir = std::env::temp_dir().join("spdf_truncated_hlo");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("truncated.hlo.txt");
    std::fs::write(&path, &text[..text.len() / 3]).unwrap();
    let mut broken = spec.clone();
    broken.file = path;
    assert!(
        spdf::runtime::Executable::compile(&engine.client, &broken)
            .is_err(),
        "a truncated HLO artifact compiled cleanly"
    );
}

#[test]
fn literal_cache_and_session_state_validate_specs() {
    use spdf::runtime::{Dtype, LiteralCache, SessionState,
                        TensorSpec};
    let specs = vec![
        TensorSpec { name: "kv.k".into(), shape: vec![2, 3],
                     dtype: Dtype::F32 },
        TensorSpec { name: "pos".into(), shape: vec![2],
                     dtype: Dtype::I32 },
    ];
    // zero state matches the specs and round-trips to host tensors
    let st = SessionState::zeros(&specs).unwrap();
    assert_eq!(st.len(), 2);
    let ts = st.to_tensors().unwrap();
    assert_eq!(ts[0].shape(), &[2, 3]);
    assert_eq!(ts[1].dtype(), Dtype::I32);

    // tensor/spec count mismatch is rejected up front
    let err = LiteralCache::upload_validated(
        &[HostTensor::zeros_f32(&[2, 3])], &specs)
        .unwrap_err()
        .to_string();
    assert!(err.contains("spec slots"), "unhelpful: {err}");

    // a mismatched slot is rejected by name
    let bad = vec![HostTensor::zeros_f32(&[2, 3]),
                   HostTensor::zeros_f32(&[2])];
    let err = LiteralCache::upload_validated(&bad, &specs)
        .unwrap_err()
        .to_string();
    assert!(err.contains("pos"), "unhelpful: {err}");
}

#[test]
fn beam_decode_runs() {
    let engine = engine();
    let runtime = engine.load_model("gpt-nano").unwrap();
    let mm = &runtime.manifest;
    let mut rng = Rng::new(5);
    let state = TrainState::init(mm, &mut rng);
    let params = state.param_tensors(mm);
    let dp = DecodeParams {
        max_new_tokens: 6,
        beam_size: 3,
        ..Default::default()
    };
    let out = spdf::generate::beam(&runtime, &params,
                                   &[BOS, 40, 41, SEP], &dp).unwrap();
    assert!(out.len() <= 6);
}

#[test]
fn sparse_finetune_keeps_masks_and_erk_magnitude_schemes_train() {
    // Fig. 2 baseline semantics: sparse fine-tuning must preserve the
    // pre-training mask exactly; plus the ERK and magnitude mask
    // schemes must survive a real train step (ablation paths).
    let engine = engine();
    let runtime = engine.load_model("gpt-nano").unwrap();
    let mm = &runtime.manifest;
    let world = tiny_world();

    // ERK masks through a real step
    let mut rng = Rng::new(9);
    let mut state = TrainState::init(mm, &mut rng);
    let erk = MaskSet::random(mm, 0.75, MaskScheme::Erk, &mut rng);
    state.sparsify(erk.clone());
    let stream: Vec<u32> = (0..40_000).map(|i| 4 + (i % 97) as u32)
        .collect();
    let mut ps = PackedStream::new(stream, mm.train_batch,
                                   mm.config.ctx_len);
    let mut trainer = Trainer::new(&runtime, state,
                                   Schedule::Constant { peak: 1e-3 });
    let b = ps.next_batch();
    trainer.step(&b).unwrap();
    trainer.sync().unwrap();
    erk.check_holes_zero(&trainer.state.params).unwrap();

    // magnitude masks
    let mut state2 = TrainState::init(mm, &mut Rng::new(10));
    let mag = MaskSet::magnitude(mm, 0.5, &state2.params);
    state2.sparsify(mag.clone());
    let mut trainer2 = Trainer::new(&runtime, state2,
                                    Schedule::Constant { peak: 1e-3 });
    trainer2.step(&b).unwrap();
    trainer2.sync().unwrap();
    mag.check_holes_zero(&trainer2.state.params).unwrap();

    // sparse fine-tuning (dense=false) keeps target sparsity through
    // a full epoch of task batches
    let mut state3 = TrainState::init(mm, &mut Rng::new(11));
    let masks = MaskSet::random(mm, 0.75, MaskScheme::Uniform,
                                &mut Rng::new(12));
    state3.sparsify(masks.clone());
    let ft = coordinator::finetune(
        &runtime, &world, state3,
        &coordinator::FinetuneConfig {
            task: Task::WebNlg,
            epochs: 1,
            peak_lr: 3e-4,
            dense: false,
            seed: 0,
            patience: 2,
            log_every: 0,
        }).unwrap();
    assert!(ft.state.masks.realized_sparsity() > 0.74);
    masks.check_holes_zero(&ft.state.params).unwrap();
}

#[test]
fn checkpoint_resume_through_runtime() {
    // save mid-training, load, continue: the resumed step must match a
    // continuous run bit-for-bit (same literals in → same program).
    let engine = engine();
    let runtime = engine.load_model("gpt-nano").unwrap();
    let mm = &runtime.manifest;
    let stream: Vec<u32> = (0..40_000).map(|i| 4 + (i % 89) as u32)
        .collect();
    let mut ps = PackedStream::new(stream, mm.train_batch,
                                   mm.config.ctx_len);
    let b1 = ps.next_batch();
    let b2 = ps.next_batch();

    let state = TrainState::init(mm, &mut Rng::new(20));
    let mut t1 = Trainer::new(&runtime, state.clone(),
                              Schedule::Constant { peak: 1e-3 });
    t1.step(&b1).unwrap();
    t1.sync().unwrap();

    let path = std::env::temp_dir().join("spdf-resume-test.ckpt");
    spdf::train::checkpoint::save(&t1.state, &path).unwrap();
    let loaded = spdf::train::checkpoint::load(&path).unwrap();
    assert_eq!(loaded.step, 1);

    let mut t_resumed = Trainer::new(&runtime, loaded,
                                     Schedule::Constant { peak: 1e-3 });
    let loss_resumed = t_resumed.step(&b2).unwrap();
    let loss_cont = t1.step(&b2).unwrap();
    assert!((loss_resumed - loss_cont).abs() < 1e-6,
            "{loss_resumed} vs {loss_cont}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn spdf_pipeline_micro_run() {
    // The whole paper pipeline at postage-stamp scale: sparsify →
    // pre-train (40 steps) → densify → fine-tune (1 epoch of a tiny
    // task) → evaluate metrics. Checks wiring, not quality.
    let engine = engine();
    let runtime = engine.load_model("gpt-nano").unwrap();
    let world = tiny_world();

    let pt = coordinator::pretrain(
        &runtime, &world,
        &coordinator::PretrainConfig {
            sparsity: 0.75,
            steps: 40,
            peak_lr: 2e-3,
            seed: 0,
            log_every: 0,
            ..Default::default()
        }).unwrap();
    assert!(pt.final_eval_loss.is_finite());
    assert!(pt.train_flops > 0.0);
    // masked weights zero after pre-training
    assert!(pt.state.masks.realized_sparsity() > 0.74);
    pt.state.masks.check_holes_zero(&pt.state.params).unwrap();

    let ft = coordinator::finetune(
        &runtime, &world, pt.state,
        &coordinator::FinetuneConfig {
            task: Task::E2e,
            epochs: 1,
            peak_lr: 3e-4,
            dense: true,
            seed: 0,
            patience: 2,
            log_every: 0,
        }).unwrap();
    assert!(ft.best_val_loss.is_finite());
    // densified: revived weights allowed to be nonzero now
    assert_eq!(ft.state.masks.realized_sparsity(), 0.0);

    let metrics = coordinator::evaluate_task(
        &runtime, &ft.state, &world, Task::E2e, 8,
        &DecodeParams { max_new_tokens: 12, ..Default::default() })
        .unwrap();
    assert_eq!(metrics.n_examples, 8);
    assert!(metrics.ppl.is_finite() && metrics.ppl > 1.0);
    assert!(metrics.bleu >= 0.0 && metrics.bleu <= 100.0);
    assert!(metrics.ter >= 0.0);
}
