//! Property-based serve-invariant suite (ISSUE 5 satellite; chaos
//! properties from ISSUE 6).
//!
//! The serve loop's contracts are now richer than pinned examples can
//! cover: outcome conservation, completed-only latency percentiles,
//! per-model-sums-to-aggregate, run-to-run bit-determinism, and
//! shed-requests-never-hold-a-slot must hold for *every* trace ×
//! scheduler × admission × lane-count combination — and, since the
//! recovery layer landed, under every seeded fault plan too:
//! conservation still closes with the `failed` bucket, lane death
//! leaks no slot, survivors of transient faults stay bitwise equal to
//! the fault-free decode, and same-seed chaos runs serialize
//! byte-identically. This suite drives `util::proptest::check` over
//! random scenarios through `serve::core::run_lanes_with` with
//! deterministic mock backends — no compiled artifacts needed, so it
//! runs under plain `cargo test -q` (tier 1).
//!
//! The speculative properties (ISSUE 9) run the same machinery
//! through `run_lanes_spec` with a content-dependent backend pair
//! (the draft lane deliberately disagrees with the verifier so
//! rejections actually occur): spec output must stay byte-identical
//! to the dense-only run across seeds × schedulers, the acceptance
//! bookkeeping must conserve every emitted token, and killing the
//! draft lane must degrade to plain dense decode — never a `Failed`
//! request.

use spdf::generate::serve::admission::{AdmissionPolicy, Bounded,
                                       MaxQueueDepth, PagePressure,
                                       QueueDeadline, Unbounded};
use spdf::generate::serve::core::mock::MockBackend;
use spdf::generate::serve::core::{run_lanes_spec,
                                  run_lanes_with_costs,
                                  run_lanes_with, LogitsBackend};
use spdf::generate::serve::policy::{Fifo, PriorityClass, Scheduler,
                                    ShortestPromptFirst,
                                    SmallestBudgetFirst};
use spdf::generate::serve::{FaultPlan, FaultyBackend, LaneCost,
                            PageReserve, PagedKvConfig, Schedule,
                            SpecPlan};
use spdf::generate::{DecodeParams, DecodeRequest, RecoveryConfig,
                     RequestOutcome, RetryPolicy, ServeReport};
use spdf::tokenizer::EOS;
use spdf::util::proptest::check;
use spdf::util::rng::Rng;

const CTX: usize = 16;

/// One random serving scenario: a trace (prompts, budgets,
/// priorities, arrivals), a lane layout, and a policy/admission pair
/// (encoded as indices so the scenario stays `Debug`-printable on
/// shrink).
#[derive(Debug, Clone)]
struct Scenario {
    lane_b: Vec<usize>,
    lane_of: Vec<usize>,
    requests: Vec<DecodeRequest>,
    arrivals: Vec<f64>,
    kv: bool,
    scheduler: usize,
    admission: usize,
}

fn scheduler_of(i: usize) -> Box<dyn Scheduler> {
    match i % 4 {
        0 => Box::new(Fifo),
        1 => Box::new(ShortestPromptFirst),
        2 => Box::new(SmallestBudgetFirst),
        _ => Box::new(PriorityClass),
    }
}

fn admission_of(i: usize) -> Box<dyn AdmissionPolicy> {
    match i % 4 {
        0 => Box::new(Unbounded),
        1 => Box::new(MaxQueueDepth(i % 3)),
        2 => Box::new(QueueDeadline(2.5)),
        _ => Box::new(Bounded { max_queue: 1, deadline_ms: 3.5 }),
    }
}

fn gen_scenario(rng: &mut Rng, size: usize) -> Scenario {
    let lanes = 1 + rng.below(3);
    let lane_b: Vec<usize> =
        (0..lanes).map(|_| 1 + rng.below(3)).collect();
    let n = 1 + rng.below(size.min(14));
    let mut requests = Vec::with_capacity(n);
    let mut lane_of = Vec::with_capacity(n);
    let mut arrivals = Vec::with_capacity(n);
    for i in 0..n {
        let plen = 1 + rng.below(6);
        let prompt: Vec<u32> =
            (0..plen).map(|_| 1 + rng.below(9) as u32).collect();
        // budgets include 0 (never occupies a slot) on purpose
        let budget = rng.below(5);
        requests.push(
            DecodeRequest::new(i as u64, prompt, budget)
                .with_priority(rng.below(3) as u8));
        lane_of.push(rng.below(lanes));
        // arrivals in a tight window so queues actually form
        arrivals.push((rng.below(80) as f64) / 10.0);
    }
    Scenario {
        lane_b,
        lane_of,
        requests,
        arrivals,
        kv: rng.below(2) == 1,
        scheduler: rng.below(4),
        admission: rng.below(4),
    }
}

fn run(sc: &Scenario) -> ServeReport {
    let mut backends: Vec<MockBackend> = sc
        .lane_b
        .iter()
        .map(|&b| MockBackend::new(b, CTX, sc.kv))
        .collect();
    let mut refs: Vec<&mut dyn LogitsBackend> = backends
        .iter_mut()
        .map(|b| b as &mut dyn LogitsBackend)
        .collect();
    let names: Vec<String> = (0..sc.lane_b.len())
        .map(|l| format!("m{l}"))
        .collect();
    let schedule = Schedule::open(sc.arrivals.clone(), 1.0, 1.0);
    run_lanes_with(&mut refs, &names, &sc.lane_of, &sc.requests,
                   &DecodeParams::default(), Some(&schedule),
                   scheduler_of(sc.scheduler).as_ref(),
                   admission_of(sc.admission).as_ref(),
                   &RecoveryConfig::default())
        .expect("serve loop errored on a valid scenario")
}

/// A [`Scenario`] plus a seeded fault plan. Chaos scenarios pin
/// admission to Unbounded so the set of admitted requests cannot
/// depend on fault-injected timing — only outcomes and latencies may.
#[derive(Debug, Clone)]
struct ChaosScenario {
    sc: Scenario,
    seed: u64,
    fail_p: f64,
    spike_p: f64,
    spike_ms: f64,
}

fn gen_chaos(rng: &mut Rng, size: usize) -> ChaosScenario {
    let mut sc = gen_scenario(rng, size);
    sc.admission = 0; // Unbounded
    ChaosScenario {
        sc,
        seed: rng.below(1 << 16) as u64,
        // strictly < 1.0 so retry loops terminate
        fail_p: (rng.below(5) as f64) / 10.0,
        spike_p: (rng.below(6) as f64) / 10.0,
        spike_ms: (rng.below(40) as f64) / 10.0,
    }
}

fn run_chaos(cs: &ChaosScenario, die_at: Option<u64>,
             recovery: &RecoveryConfig) -> ServeReport {
    let sc = &cs.sc;
    let mut backends: Vec<FaultyBackend<MockBackend>> = sc
        .lane_b
        .iter()
        .enumerate()
        .map(|(l, &b)| {
            let mut plan = FaultPlan::new(cs.seed);
            plan.step_fail_p = cs.fail_p;
            plan.spike_p = cs.spike_p;
            plan.spike_ms = cs.spike_ms;
            plan.die_at_step = die_at;
            FaultyBackend::new(MockBackend::new(b, CTX, sc.kv),
                               &plan, l)
                .expect("generated fault plan is valid")
        })
        .collect();
    let mut refs: Vec<&mut dyn LogitsBackend> = backends
        .iter_mut()
        .map(|b| b as &mut dyn LogitsBackend)
        .collect();
    let names: Vec<String> = (0..sc.lane_b.len())
        .map(|l| format!("m{l}"))
        .collect();
    let schedule = Schedule::open(sc.arrivals.clone(), 1.0, 1.0);
    run_lanes_with(&mut refs, &names, &sc.lane_of, &sc.requests,
                   &DecodeParams::default(), Some(&schedule),
                   scheduler_of(sc.scheduler).as_ref(), &Unbounded,
                   recovery)
        .expect("serve loop errored on a chaos scenario")
}

/// completed + shed + expired + failed == submitted, in the results,
/// the aggregate stats, and every per-model block.
#[test]
fn prop_outcome_conservation() {
    check(11, 80, 14, gen_scenario, |sc: &Scenario| {
        let report = run(sc);
        let n = sc.requests.len();
        let st = &report.stats;
        report.results.len() == n
            && st.requests == n
            && st.completed + st.shed + st.expired + st.failed == n
            && report.per_model.iter().all(|m| {
                m.stats.completed + m.stats.shed + m.stats.expired
                    + m.stats.failed
                    == m.stats.requests
            })
    });
}

/// Latency percentiles are computed over completed requests only —
/// the summary's sample count must equal the completed count, never
/// the offered count.
#[test]
fn prop_latency_percentiles_cover_completed_only() {
    check(13, 80, 14, gen_scenario, |sc: &Scenario| {
        let report = run(sc);
        let st = &report.stats;
        st.latency_ms.n == st.completed
            && st.ttft_ms.n == st.completed
            && st.queue_ms.n == st.completed
            && report.per_model.iter().all(|m| {
                m.stats.latency_ms.n == m.stats.completed
            })
    });
}

/// Per-model stats partition the aggregate: every countable field
/// sums across models to the aggregate block.
#[test]
fn prop_per_model_stats_sum_to_aggregate() {
    check(17, 80, 14, gen_scenario, |sc: &Scenario| {
        let report = run(sc);
        let st = &report.stats;
        let sum = |f: &dyn Fn(&spdf::generate::ServeStats) -> u64| {
            report.per_model.iter().map(|m| f(&m.stats)).sum::<u64>()
        };
        report.per_model.len() == sc.lane_b.len()
            && sum(&|s| s.requests as u64) == st.requests as u64
            && sum(&|s| s.completed as u64) == st.completed as u64
            && sum(&|s| s.shed as u64) == st.shed as u64
            && sum(&|s| s.expired as u64) == st.expired as u64
            && sum(&|s| s.failed as u64) == st.failed as u64
            && sum(&|s| s.degraded as u64) == st.degraded as u64
            && sum(&|s| s.retries) == st.retries
            && sum(&|s| s.generated_tokens) == st.generated_tokens
            && sum(&|s| s.lost_tokens) == st.lost_tokens
            && sum(&|s| s.engine_steps) == st.engine_steps
            && sum(&|s| s.prefill_steps) == st.prefill_steps
            && sum(&|s| s.slot_steps) == st.slot_steps
    });
}

/// Same seed ⇒ byte-identical telemetry: two runs of the same
/// scenario serialize to exactly the same ServeStats JSON (aggregate
/// and per-model), and identical per-request outcomes/latencies.
#[test]
fn prop_same_seed_is_byte_identical() {
    check(19, 60, 14, gen_scenario, |sc: &Scenario| {
        let (a, b) = (run(sc), run(sc));
        a.stats_json().to_string() == b.stats_json().to_string()
            && a.stats.to_json().to_string()
                == b.stats.to_json().to_string()
            && a.results.len() == b.results.len()
            && a.results.iter().zip(&b.results).all(|(x, y)| {
                x.tokens == y.tokens
                    && x.outcome == y.outcome
                    && x.latency_ms == y.latency_ms
                    && x.ttft_ms == y.ttft_ms
                    && x.queue_ms == y.queue_ms
            })
    });
}

/// Shed requests are rejected at arrival and never hold a slot:
/// no tokens, no decode steps, zero reported wait. Expired requests
/// decode nothing either and report exactly the deadline as their
/// wait.
#[test]
fn prop_failed_requests_never_hold_a_slot() {
    check(23, 80, 14, gen_scenario, |sc: &Scenario| {
        let report = run(sc);
        report.results.iter().all(|r| match r.outcome {
            RequestOutcome::Completed => true,
            RequestOutcome::Shed => {
                r.tokens.is_empty()
                    && r.decode_steps == 0
                    && r.queue_ms == 0.0
                    && r.latency_ms == 0.0
            }
            RequestOutcome::Expired => {
                r.tokens.is_empty() && r.decode_steps == 0
            }
            // failed requests may have briefly held a slot, but they
            // never deliver partial output
            RequestOutcome::Failed => r.tokens.is_empty(),
        })
    });
}

/// Unbounded admission completes everything: the policy matrix's
/// degenerate corner stays exact under every scheduler and lane
/// layout.
#[test]
fn prop_unbounded_admission_never_sheds() {
    check(29, 60, 14, |rng: &mut Rng, size: usize| {
        let mut sc = gen_scenario(rng, size);
        sc.admission = 0; // Unbounded
        sc
    }, |sc: &Scenario| {
        let report = run(sc);
        report.stats.shed == 0
            && report.stats.expired == 0
            && report.stats.shed_rate == 0.0
            && report.stats.completed == sc.requests.len()
            && report.results.iter().all(|r| {
                r.outcome.is_completed()
                    && r.tokens.len() == sc.requests[r.id as usize]
                        .max_new_tokens
            })
    });
}

/// Chaos conservation: under seeded transient faults + spikes with a
/// finite retry budget, every request still lands in exactly one
/// outcome bucket — aggregate and per-model — and failed requests
/// never deliver partial output.
#[test]
fn prop_chaos_outcome_conservation() {
    check(31, 60, 14, gen_chaos, |cs: &ChaosScenario| {
        let report = run_chaos(cs, None, &RecoveryConfig::default());
        let n = cs.sc.requests.len();
        let st = &report.stats;
        report.results.len() == n
            && st.completed + st.shed + st.expired + st.failed == n
            && report.per_model.iter().all(|m| {
                m.stats.completed + m.stats.shed + m.stats.expired
                    + m.stats.failed
                    == m.stats.requests
            })
            && report.results.iter().all(|r| {
                r.outcome != RequestOutcome::Failed
                    || r.tokens.is_empty()
            })
    });
}

/// Permanent lane death leaks nothing: every lane dies on its k-th
/// step attempt, the loop still terminates cleanly, every request is
/// accounted for, and whatever completed before the deaths kept its
/// full token stream.
#[test]
fn prop_no_slot_leaked_on_lane_death() {
    check(37, 60, 14, gen_chaos, |cs: &ChaosScenario| {
        let die_at = Some((cs.seed % 7) as u64);
        let report =
            run_chaos(cs, die_at, &RecoveryConfig::default());
        let n = cs.sc.requests.len();
        let st = &report.stats;
        report.results.len() == n
            && st.completed + st.shed + st.expired + st.failed == n
            && report.results.iter().all(|r| match r.outcome {
                RequestOutcome::Completed => {
                    r.tokens.len()
                        == cs.sc.requests[r.id as usize]
                            .max_new_tokens
                }
                RequestOutcome::Failed => r.tokens.is_empty(),
                _ => false, // Unbounded admission never sheds
            })
    });
}

/// The headline chaos invariant: transient faults + unlimited retries
/// + no permanent death ⇒ every admitted request completes, and every
/// token stream is bitwise identical to the fault-free run of the
/// same scenario.
#[test]
fn prop_chaos_survivors_bitwise_equal_fault_free() {
    check(41, 60, 14, gen_chaos, |cs: &ChaosScenario| {
        let recovery = RecoveryConfig {
            retry: RetryPolicy::unlimited(),
            ..RecoveryConfig::default()
        };
        let chaos = run_chaos(cs, None, &recovery);
        let clean = run(&cs.sc);
        chaos.stats.completed == cs.sc.requests.len()
            && chaos.stats.failed == 0
            && chaos.results.len() == clean.results.len()
            && chaos.results.iter().zip(&clean.results).all(
                |(a, b)| {
                    a.id == b.id
                        && a.outcome.is_completed()
                        && a.tokens == b.tokens
                })
    });
}

/// Same seed + same fault plan ⇒ byte-identical stats JSON, retry and
/// degraded counters included.
#[test]
fn prop_chaos_same_seed_byte_identical() {
    check(43, 40, 14, gen_chaos, |cs: &ChaosScenario| {
        let recovery = RecoveryConfig::default();
        let a = run_chaos(cs, None, &recovery);
        let b = run_chaos(cs, None, &recovery);
        a.stats_json().to_string() == b.stats_json().to_string()
            && a.stats.to_json().to_string()
                == b.stats.to_json().to_string()
            && a.results.iter().zip(&b.results).all(|(x, y)| {
                x.to_json().to_string() == y.to_json().to_string()
            })
    });
}

// ---------- speculative decoding properties (ISSUE 9) ----------

/// A content-dependent mock: each row's argmax is a deterministic
/// hash of (token under the cursor, position, salt), occasionally
/// EOS so the termination edge gets exercised. Crucially the logits
/// depend only on the row *content*, never on which physical row or
/// step served it — the uniformity a real (stateless-logits) model
/// has and the speculative staging relies on. Two instances with
/// different salts model a draft checkpoint that genuinely disagrees
/// with its verifier.
struct VaryingBackend {
    b: usize,
    t: usize,
    vocab: usize,
    salt: u64,
}

impl VaryingBackend {
    fn new(b: usize, salt: u64) -> VaryingBackend {
        VaryingBackend { b, t: CTX, vocab: 16, salt }
    }
}

impl LogitsBackend for VaryingBackend {
    fn dims(&self) -> (usize, usize, usize) {
        (self.b, self.t, self.vocab)
    }

    fn step(&mut self, tokens: &[i32], pos: &[i32])
            -> anyhow::Result<Vec<f32>> {
        let mut lv = vec![0.0f32; self.b * self.vocab];
        for s in 0..self.b {
            let p = pos[s];
            if p < 0 || p as usize >= self.t {
                continue; // unoccupied row: logits are never read
            }
            let cur = tokens[s * self.t + p as usize] as u64;
            let h = cur
                .wrapping_mul(1_000_003)
                .wrapping_add((p as u64).wrapping_mul(7919))
                .wrapping_add(self.salt.wrapping_mul(104_729));
            let tok = if h % 11 == 0 {
                EOS as usize
            } else {
                4 + (h % (self.vocab as u64 - 4)) as usize
            };
            lv[s * self.vocab + tok] = 1.0;
        }
        Ok(lv)
    }
}

/// A [`Scenario`] narrowed to the speculative layout: lane 0 is the
/// dense verifier, lane 1 the (cheaper) draft, requests split across
/// both, Unbounded admission so the admitted set is
/// schedule-independent.
#[derive(Debug, Clone)]
struct SpecScenario {
    sc: Scenario,
    k: usize,
    draft_salt: u64,
}

fn gen_spec(rng: &mut Rng, size: usize) -> SpecScenario {
    let mut sc = gen_scenario(rng, size);
    sc.kv = false; // VaryingBackend is literal-path
    sc.admission = 0; // Unbounded
    sc.lane_b = vec![1 + rng.below(3), 1 + rng.below(3)];
    for l in sc.lane_of.iter_mut() {
        // most requests target the verifier so speculation engages;
        // some ride the draft lane to prove leasing never perturbs
        // its resident decodes
        *l = usize::from(rng.below(4) == 3);
    }
    SpecScenario {
        sc,
        k: 1 + rng.below(4),
        // salt 0 = draft ≡ verifier (full acceptance); others
        // disagree and force rejections + corrections
        draft_salt: rng.below(3) as u64,
    }
}

fn run_spec(ss: &SpecScenario, spec_on: bool,
            draft_die_at: Option<u64>) -> ServeReport {
    let sc = &ss.sc;
    let verifier = VaryingBackend::new(sc.lane_b[0], 0);
    let draft = VaryingBackend::new(sc.lane_b[1], ss.draft_salt);
    let mut dead_draft = draft_die_at.map(|at| {
        let mut plan = FaultPlan::new(7);
        plan.die_at_step = Some(at);
        FaultyBackend::new(VaryingBackend::new(sc.lane_b[1],
                                               ss.draft_salt),
                           &plan, 1)
            .expect("die-only fault plan is valid")
    });
    let (mut v, mut d) = (verifier, draft);
    let mut refs: Vec<&mut dyn LogitsBackend> = match &mut dead_draft {
        Some(fd) => vec![&mut v, fd],
        None => vec![&mut v, &mut d],
    };
    let names = vec!["dense".to_string(), "s75".to_string()];
    let schedule = Schedule::open(sc.arrivals.clone(), 1.0, 1.0);
    let costs = [LaneCost::unit(), LaneCost::from_sparsity(0.75)];
    let plan = SpecPlan { draft_lane: 1, verifier_lane: 0, k: ss.k };
    let spec = if spec_on { Some(&plan) } else { None };
    run_lanes_spec(&mut refs, &names, &sc.lane_of, &sc.requests,
                   &DecodeParams::default(), Some(&schedule),
                   scheduler_of(sc.scheduler).as_ref(), &Unbounded,
                   &RecoveryConfig::default(), &costs, spec, None)
        .expect("spec serve loop errored on a valid scenario")
}

/// THE speculative invariant: for every seed × scheduler × k × draft
/// divergence, the spec run's greedy streams are byte-identical to
/// the dense-only run of the same scenario — on the verifier lane
/// (accept/reject only reshuffles *when* tokens commit, never
/// *which*) and on the draft lane (leasing free rows must not
/// perturb resident decodes).
#[test]
fn prop_spec_output_bitwise_equals_dense() {
    check(47, 60, 14, gen_spec, |ss: &SpecScenario| {
        let spec = run_spec(ss, true, None);
        let plain = run_spec(ss, false, None);
        let key = |r: &ServeReport| {
            let mut v: Vec<(u64, Vec<u32>)> = r.results.iter()
                .map(|x| (x.id, x.tokens.clone()))
                .collect();
            v.sort_by_key(|(id, _)| *id);
            v
        };
        spec.stats.completed == ss.sc.requests.len()
            && key(&spec) == key(&plain)
    });
}

/// Acceptance bookkeeping conserves tokens: on the verifier lane
/// every emitted token is either an accepted draft or a verifier
/// correction (per request and in the aggregate), every verify
/// advances its request (only the terminal EOS verify emits nothing,
/// so verifies ≤ emitted + 1 per stream), wasted = drafted −
/// accepted, and draft-lane residents never carry spec counters.
#[test]
fn prop_spec_bookkeeping_conserves_tokens() {
    check(53, 60, 14, gen_spec, |ss: &SpecScenario| {
        let report = run_spec(ss, true, None);
        let st = &report.stats;
        let per_request = report.results.iter().all(|r| {
            if ss.sc.lane_of[r.id as usize] == 0 {
                r.tokens.len() as u64
                    == r.spec.accepted + r.spec.corrections
                    && r.spec.verifies <= r.tokens.len() as u64 + 1
            } else {
                r.spec == Default::default()
            }
        });
        per_request
            && st.spec.accepted + st.spec.corrections
                == report.results.iter()
                    .filter(|r| ss.sc.lane_of[r.id as usize] == 0)
                    .map(|r| r.tokens.len() as u64)
                    .sum::<u64>()
            && st.spec.wasted() == st.spec.drafted - st.spec.accepted
            && st.spec.accepted <= st.spec.drafted
    });
}

/// Degrade-to-dense: killing the draft lane mid-run (on its k-th
/// step attempt, k swept from 0) must never fail or stall a verifier
/// request — every request still completes, and the streams stay
/// byte-identical to the dense-only run. Draft-lane *residents* may
/// legitimately fail (their lane died); they just never take a
/// verifier request down with them.
#[test]
fn prop_spec_draft_death_degrades_to_dense() {
    check(59, 60, 14, gen_spec, |ss: &SpecScenario| {
        let die_at = (ss.draft_salt + ss.k as u64) % 5;
        let spec = run_spec(ss, true, Some(die_at));
        let plain = run_spec(ss, false, None);
        // a draft that dies before proposing anything leaves
        // drafted == 0 — acceptance must read 0.0, never NaN
        if !spec.stats.acceptance_rate.is_finite()
            || (spec.stats.spec.drafted == 0
                && spec.stats.acceptance_rate != 0.0)
        {
            return false;
        }
        let verifier_ids: Vec<u64> = ss.sc.requests.iter()
            .filter(|r| ss.sc.lane_of[r.id as usize] == 0)
            .map(|r| r.id)
            .collect();
        let stream = |rep: &ServeReport, id: u64| {
            rep.results.iter().find(|r| r.id == id)
                .map(|r| (r.outcome, r.tokens.clone()))
        };
        verifier_ids.iter().all(|&id| {
            match (stream(&spec, id), stream(&plain, id)) {
                (Some((o, toks)), Some((po, ptoks))) => {
                    o == RequestOutcome::Completed
                        && po == RequestOutcome::Completed
                        && toks == ptoks
                }
                _ => false,
            }
        })
    });
}

/// Speculation off ⇄ absent: `run_lanes_spec` with `spec: None` is
/// byte-for-byte `run_lanes_with_costs` at the same cost vector
/// (same stats JSON, same per-request telemetry) — the plumbing is
/// provably inert without a plan.
#[test]
fn prop_spec_none_is_plain_run_lanes() {
    check(61, 40, 14, gen_spec, |ss: &SpecScenario| {
        let via_spec = run_spec(ss, false, None);
        let sc = &ss.sc;
        let mut v = VaryingBackend::new(sc.lane_b[0], 0);
        let mut d = VaryingBackend::new(sc.lane_b[1], ss.draft_salt);
        let mut refs: Vec<&mut dyn LogitsBackend> =
            vec![&mut v, &mut d];
        let names = vec!["dense".to_string(), "s75".to_string()];
        let schedule = Schedule::open(sc.arrivals.clone(), 1.0, 1.0);
        let costs = [LaneCost::unit(), LaneCost::from_sparsity(0.75)];
        let plain = run_lanes_with_costs(
            &mut refs, &names, &sc.lane_of, &sc.requests,
            &DecodeParams::default(), Some(&schedule),
            scheduler_of(sc.scheduler).as_ref(), &Unbounded,
            &RecoveryConfig::default(), &costs)
            .expect("plain serve loop errored on a valid scenario");
        via_spec.stats.to_json().to_string()
            == plain.stats.to_json().to_string()
            && via_spec.results.iter().zip(&plain.results).all(
                |(x, y)| {
                    x.to_json().to_string() == y.to_json().to_string()
                })
    });
}

// ---------- paged KV-memory properties (ISSUE 10) ----------

/// A [`Scenario`] narrowed to one lane plus a paged-KV layout: page
/// size, optional page budget (tight enough to force queueing and
/// preemption), optional eviction window, reservation policy, and
/// whether admission is memory-aware ([`PagePressure`]).
#[derive(Debug, Clone)]
struct PagedScenario {
    sc: Scenario,
    page_size: usize,
    budget: Option<usize>,
    window: Option<usize>,
    full_reserve: bool,
    pressure: bool,
}

fn gen_paged(rng: &mut Rng, size: usize) -> PagedScenario {
    let mut sc = gen_scenario(rng, size);
    sc.kv = false; // VaryingBackend is literal-path
    sc.lane_b = vec![1 + rng.below(3)];
    for l in sc.lane_of.iter_mut() {
        *l = 0;
    }
    let page_size = 1 + rng.below(6);
    let per_row = CTX.div_ceil(page_size);
    let b = sc.lane_b[0];
    // budgets sweep from "one full-context row barely fits" (the
    // validated floor — queueing, preemption and shedding all
    // engage) up to the unconstrained default b × per_row
    let budget = match rng.below(3) {
        0 => None,
        _ => Some(per_row + rng.below(per_row * (b - 1) + 1)),
    };
    // low windows actually trigger eviction on these short traces
    let window = (rng.below(3) == 0)
        .then(|| (page_size + rng.below(4)).min(CTX - 2));
    PagedScenario {
        sc,
        page_size,
        budget,
        window,
        full_reserve: rng.below(3) == 0,
        pressure: rng.below(2) == 1,
    }
}

fn paged_cfg(ps: &PagedScenario) -> PagedKvConfig {
    let mut cfg = PagedKvConfig::new(ps.page_size);
    if let Some(total) = ps.budget {
        cfg = cfg.with_total_pages(total);
    }
    if let Some(w) = ps.window {
        cfg = cfg.with_window(w);
    }
    if ps.full_reserve {
        cfg = cfg.with_reserve(PageReserve::FullContext);
    }
    cfg
}

fn run_paged(ps: &PagedScenario, paged: Option<&PagedKvConfig>)
             -> ServeReport {
    let sc = &ps.sc;
    let mut v = VaryingBackend::new(sc.lane_b[0], 0);
    let mut refs: Vec<&mut dyn LogitsBackend> = vec![&mut v];
    let names = vec!["dense".to_string()];
    let schedule = Schedule::open(sc.arrivals.clone(), 1.0, 1.0);
    let costs = [LaneCost::unit()];
    let admission: Box<dyn AdmissionPolicy> =
        if ps.pressure && paged.is_some() {
            Box::new(PagePressure::new())
        } else {
            Box::new(Unbounded)
        };
    run_lanes_spec(&mut refs, &names, &sc.lane_of, &sc.requests,
                   &DecodeParams::default(), Some(&schedule),
                   scheduler_of(sc.scheduler).as_ref(),
                   admission.as_ref(), &RecoveryConfig::default(),
                   &costs, None, paged)
        .expect("paged serve loop errored on a valid scenario")
}

/// The allocator ledger closes on every paged layout: no page is
/// leaked (every page is back on the free list at exit), the peak
/// never exceeds the budget, and outcomes still conserve. Double
/// ownership can't pass silently — the allocator errors the whole
/// run on a double-alloc or foreign free, which `run_paged` turns
/// into a property failure.
#[test]
fn prop_paged_no_page_leaked_and_peak_bounded() {
    check(67, 60, 14, gen_paged, |ps: &PagedScenario| {
        let report = run_paged(ps, Some(&paged_cfg(ps)));
        let st = &report.stats;
        let n = ps.sc.requests.len();
        st.pages.leaked_pages == 0
            && st.pages.page_size == ps.page_size
            && st.pages.peak_pages <= st.pages.total_pages
            && st.completed + st.shed + st.expired + st.failed == n
    });
}

/// Page-count conservation under memory-pressure shedding: with a
/// tight budget and [`PagePressure`] admission, every page-shed
/// request exits empty at arrival, the page-shed counter never
/// exceeds the shed bucket, and the allocator still drains to zero
/// pages in use.
#[test]
fn prop_paged_pressure_sheds_conserve_pages() {
    check(71, 60, 14, |rng: &mut Rng, size: usize| {
        let mut ps = gen_paged(rng, size);
        ps.pressure = true;
        if ps.budget.is_none() {
            // pressure needs something to press against
            ps.budget = Some(CTX.div_ceil(ps.page_size));
        }
        ps
    }, |ps: &PagedScenario| {
        let report = run_paged(ps, Some(&paged_cfg(ps)));
        let st = &report.stats;
        st.pages.leaked_pages == 0
            && st.pages.page_sheds <= st.shed as u64
            && report.results.iter().all(|r| {
                r.outcome != RequestOutcome::Shed
                    || (r.tokens.is_empty() && r.decode_steps == 0)
            })
    });
}

/// Survivors are bitwise monolithic: across seeds × schedulers ×
/// budgets × reservation policies (eviction off — a shifted window
/// legitimately changes the streams), every request the paged run
/// completes carries exactly the token stream the monolithic loop
/// produces — preemption replays a request from scratch, it never
/// splices a stream.
#[test]
fn prop_paged_survivors_bitwise_equal_monolithic() {
    check(73, 60, 14, |rng: &mut Rng, size: usize| {
        let mut ps = gen_paged(rng, size);
        ps.window = None;
        ps
    }, |ps: &PagedScenario| {
        let paged = run_paged(ps, Some(&paged_cfg(ps)));
        let mono = run_paged(ps, None);
        let stream = |rep: &ServeReport, id: u64| {
            rep.results.iter().find(|r| r.id == id)
                .map(|r| r.tokens.clone())
        };
        mono.stats.completed == ps.sc.requests.len()
            && paged.results.iter()
                .filter(|r| r.outcome.is_completed())
                .all(|r| stream(&mono, r.id)
                    .is_some_and(|toks| toks == r.tokens))
    });
}

/// Unconstrained paging is provably inert: no budget, no window, no
/// pressure ⇒ per-request telemetry is byte-identical to the
/// monolithic run and the stats agree on everything except the page
/// ledger itself.
#[test]
fn prop_paged_unconstrained_bitwise_identical() {
    check(79, 40, 14, |rng: &mut Rng, size: usize| {
        let mut ps = gen_paged(rng, size);
        ps.budget = None;
        ps.window = None;
        ps.pressure = false;
        ps
    }, |ps: &PagedScenario| {
        let mut paged = run_paged(ps, Some(&paged_cfg(ps)));
        let mono = run_paged(ps, None);
        if paged.stats.pages.leaked_pages != 0
            || paged.stats.pages.preemptions != 0
            || paged.stats.pages.page_sheds != 0
        {
            return false;
        }
        // the page ledger is the one intended difference
        paged.stats.pages = Default::default();
        for m in paged.per_model.iter_mut() {
            m.stats.pages = Default::default();
        }
        paged.stats_json().to_string()
            == mono.stats_json().to_string()
            && paged.results.iter().zip(&mono.results).all(
                |(x, y)| {
                    x.to_json().to_string() == y.to_json().to_string()
                })
    });
}
