//! The literal-resident decode engine (§Perf serving path).
//!
//! The old decode loop re-validated and re-uploaded the **full
//! parameter set** to PJRT on every step, then full-sorted the
//! vocabulary per batch slot. `DecodeEngine` is the session form:
//! parameters go to XLA literals once at construction (the `LitCache`
//! pattern proven in `train/session.rs`), every step runs through
//! `Executable::run_raw` with only the small token/pos buffers
//! re-marshalled, and candidate selection is the partial top-k of
//! [`super::topk`]. Greedy output is bit-identical to the pre-engine
//! path when `no_repeat_ngram == 0`; with blocking on, both this and
//! [`super::reference`] carry the *fixed* fallback semantics (the old
//! code could emit a blocked token — see ISSUE 1).

use crate::runtime::{Dtype, Executable, HostTensor, LiteralCache,
                     ModelRuntime};
use crate::tokenizer::EOS;

use super::topk;
use super::DecodeParams;

pub struct DecodeEngine<'a> {
    exe: &'a Executable,
    params: LiteralCache,
    b: usize,
    t: usize,
    vocab: usize,
}

impl<'a> DecodeEngine<'a> {
    /// Validate the parameter set against the `logits_last` spec and
    /// upload it once. All spec checking happens here; the step loop
    /// never validates again.
    pub fn new(runtime: &'a ModelRuntime, params: &[HostTensor])
               -> anyhow::Result<DecodeEngine<'a>> {
        let mm = &runtime.manifest;
        let exe = runtime.artifact("logits_last")?;
        let spec = &exe.spec;
        let b = mm.decode_batch;
        let t = mm.config.ctx_len;
        anyhow::ensure!(
            spec.inputs.len() == params.len() + 2,
            "logits_last expects {} inputs ({} params + tokens + pos), \
             got {} params",
            spec.inputs.len(), spec.inputs.len().saturating_sub(2),
            params.len()
        );
        let tok_spec = &spec.inputs[params.len()];
        let pos_spec = &spec.inputs[params.len() + 1];
        anyhow::ensure!(
            tok_spec.shape[..] == [b, t] && tok_spec.dtype == Dtype::I32,
            "logits_last token slot {:?}/{:?} does not match decode \
             geometry ({b}, {t})/i32",
            tok_spec.shape, tok_spec.dtype
        );
        anyhow::ensure!(
            pos_spec.shape[..] == [b] && pos_spec.dtype == Dtype::I32,
            "logits_last pos slot {:?}/{:?} does not match ({b})/i32",
            pos_spec.shape, pos_spec.dtype
        );
        let params = LiteralCache::upload_validated(
            params, &spec.inputs[..params.len()])?;
        Ok(DecodeEngine {
            exe,
            params,
            b,
            t,
            vocab: mm.config.vocab_size,
        })
    }

    pub fn decode_batch(&self) -> usize {
        self.b
    }

    pub fn ctx_len(&self) -> usize {
        self.t
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// One model step: flat `(B*T)` token buffer + `(B)` positions in,
    /// flat `(B*V)` last-token logits out. Only the two small i32
    /// buffers cross the host boundary.
    pub(crate) fn step_logits(&self, tokens: &[i32], pos: &[i32])
                              -> anyhow::Result<Vec<f32>> {
        debug_assert_eq!(tokens.len(), self.b * self.t);
        debug_assert_eq!(pos.len(), self.b);
        let tok_l = HostTensor::literal_i32(&[self.b, self.t], tokens)?;
        let pos_l = HostTensor::literal_i32(&[self.b], pos)?;
        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(self.params.len() + 2);
        inputs.extend(self.params.refs());
        inputs.push(&tok_l);
        inputs.push(&pos_l);
        let outs = self.exe.run_raw(&inputs)?;
        Ok(outs[0].to_vec::<f32>()?)
    }

    /// Greedy decode a batch of prompts (token ids, unpadded). Returns
    /// the generated continuations (without the prompt, without EOS).
    /// Bit-identical to `generate::reference::greedy` (and, for
    /// `no_repeat_ngram == 0`, to the pre-engine implementation) for
    /// prompts that fit the context (`len <= ctx_len - 1`). Longer
    /// prompts now error instead of being silently head-truncated to
    /// garbage — pre-truncate (keeping the tail) with
    /// `coordinator::prompt_tokens`.
    ///
    /// This is the one-slot-per-prompt special case of the slot-refill
    /// state machine in [`super::batching`] — one implementation, one
    /// set of EOS/length-cap edge cases.
    pub fn greedy(&self, prompts: &[Vec<u32>], dp: &DecodeParams)
                  -> anyhow::Result<Vec<Vec<u32>>> {
        anyhow::ensure!(prompts.len() <= self.b,
                        "batch of {} prompts exceeds decode_batch {}",
                        prompts.len(), self.b);
        let requests: Vec<super::DecodeRequest> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| super::DecodeRequest::new(
                i as u64, p.clone(), dp.max_new_tokens))
            .collect();
        let report = super::batching::serve(self, &requests, dp)?;
        Ok(report.results.into_iter().map(|r| r.tokens).collect())
    }

    /// Beam-search decode a *single* prompt using the batch slots as
    /// beams. Expansion candidates come from a partial top-2k instead
    /// of a full-vocab sort — the exact same 2k-prefix the old path
    /// read off its stable full sort. Like [`Self::greedy`], prompts
    /// must fit the context (`len <= ctx_len - 2`, one step of
    /// headroom); over-length prompts error instead of being silently
    /// head-truncated — pre-truncate (keeping the tail) with
    /// `coordinator::prompt_tokens`.
    pub fn beam(&self, prompt: &[u32], dp: &DecodeParams)
                -> anyhow::Result<Vec<u32>> {
        let (b, t, vocab) = (self.b, self.t, self.vocab);
        let k = dp.beam_size.clamp(1, b);
        anyhow::ensure!(!prompt.is_empty(), "empty beam prompt");
        anyhow::ensure!(
            prompt.len() <= t - 2,
            "beam prompt longer than ctx_len - 2 ({}) — pre-truncate \
             (keeping the tail) with coordinator::prompt_tokens",
            t - 2
        );

        #[derive(Clone)]
        struct Beam {
            seq: Vec<u32>, // prompt + generated
            logp: f64,
        }
        let plen = prompt.len();
        let mut beams = vec![Beam {
            seq: prompt.to_vec(),
            logp: 0.0,
        }];
        let mut finished: Vec<Beam> = Vec::new();

        let mut tokens = vec![0i32; b * t];
        let mut pos = vec![0i32; b];
        for _ in 0..dp.max_new_tokens {
            if beams.is_empty() {
                break;
            }
            // pack live beams into the batch
            tokens.fill(0);
            pos.fill(0);
            for (i, bm) in beams.iter().enumerate() {
                for (j, &tok) in bm.seq.iter().enumerate() {
                    tokens[i * t + j] = tok as i32;
                }
                pos[i] = bm.seq.len() as i32 - 1;
            }
            let lv = self.step_logits(&tokens, &pos)?;

            let mut candidates: Vec<Beam> = Vec::new();
            for (i, bm) in beams.iter().enumerate() {
                let row = &lv[i * vocab..(i + 1) * vocab];
                // log-softmax
                let mx = row.iter().cloned().fold(f32::MIN, f32::max);
                let logz: f64 = row.iter()
                    .map(|&x| ((x - mx) as f64).exp())
                    .sum::<f64>()
                    .ln() + mx as f64;
                for &tok in &topk::top_k(row, 2 * k) {
                    if super::repeats_ngram(&bm.seq, tok,
                                            dp.no_repeat_ngram) {
                        continue;
                    }
                    let lp = row[tok as usize] as f64 - logz;
                    let mut nb = bm.clone();
                    nb.logp += lp;
                    if tok == EOS || nb.seq.len() + 1 >= t - 1 {
                        finished.push(nb);
                    } else {
                        nb.seq.push(tok);
                        candidates.push(nb);
                    }
                }
            }
            candidates.sort_by(|a, c| {
                c.logp.partial_cmp(&a.logp).unwrap()
            });
            candidates.truncate(k);
            beams = candidates;
            if finished.len() >= 2 * k {
                break;
            }
        }
        finished.extend(beams);
        // length-penalized selection: logp / len^alpha
        let best = finished
            .into_iter()
            .max_by(|a, c| {
                let la = a.logp
                    / ((a.seq.len() - plen).max(1) as f64)
                        .powf(dp.length_penalty);
                let lc = c.logp
                    / ((c.seq.len() - plen).max(1) as f64)
                        .powf(dp.length_penalty);
                la.partial_cmp(&lc).unwrap()
            })
            .map(|bm| bm.seq[plen..].to_vec())
            .unwrap_or_default();
        Ok(best)
    }

    /// Serve a request stream through continuous slot-refill batching;
    /// see [`super::batching`].
    pub fn serve(&self, requests: &[super::DecodeRequest],
                 dp: &DecodeParams)
                 -> anyhow::Result<super::ServeReport> {
        super::batching::serve(self, requests, dp)
    }
}
