//! The literal-resident decode engine (§Perf serving path).
//!
//! The old decode loop re-validated and re-uploaded the **full
//! parameter set** to PJRT on every step, then full-sorted the
//! vocabulary per batch slot. `DecodeEngine` is the session form:
//! parameters go to XLA literals once at construction (the `LitCache`
//! pattern proven in `train/session.rs`), every step runs through
//! `Executable::run_raw` with only the small token/pos buffers
//! re-marshalled, and candidate selection is the partial top-k of
//! [`super::topk`]. Greedy output is bit-identical to the pre-engine
//! path when `no_repeat_ngram == 0`; with blocking on, both this and
//! [`super::reference`] carry the *fixed* fallback semantics (the old
//! code could emit a blocked token — see ISSUE 1).

use crate::generate::serve::LaneCost;
use crate::runtime::{Dtype, Executable, HostTensor, LiteralCache,
                     ModelRuntime, SessionState};
use crate::tokenizer::EOS;

use super::topk;
use super::DecodeParams;

/// Density at or below which a 2-D f32 parameter slot is held
/// CSR-resident by [`DecodeEngine::new`]. Half density is the break-
/// even point where CSR bytes (8 per nnz) stop beating dense bytes
/// (4 per element) — dense and lightly-pruned checkpoints detect zero
/// sparse slots and load exactly as before.
pub const SPARSE_RESIDENCY_MAX_DENSITY: f64 = 0.5;

/// The compiled KV serving pair (present when the manifest carries the
/// incremental artifacts).
struct KvExes<'a> {
    step: &'a Executable,
    prefill: &'a Executable,
}

/// The literal-resident decode session over one compiled model:
/// params validated and uploaded once, then every step re-marshals
/// only the small token/pos buffers. Sparse checkpoints are detected
/// at load and held CSR-resident (see [`DecodeEngine::new`]); serving
/// entry points hang off this type ([`DecodeEngine::serve`],
/// [`DecodeEngine::greedy`], [`DecodeEngine::beam`]).
pub struct DecodeEngine<'a> {
    exe: &'a Executable,
    kv: Option<KvExes<'a>>,
    params: LiteralCache,
    b: usize,
    t: usize,
    vocab: usize,
    /// KV state tensors per session (2 per layer), 0 without KV.
    n_state: usize,
}

impl<'a> DecodeEngine<'a> {
    /// Validate the parameter set against the `logits_last` spec and
    /// upload it once. All spec checking happens here; the step loop
    /// never validates again. When the runtime also compiled the
    /// `decode_step`/`prefill` pair, the KV-resident path
    /// ([`Self::serve_kv`], [`Self::greedy_kv`]) is validated and made
    /// available too.
    ///
    /// Sparse residency: 2-D f32 params at or under
    /// [`SPARSE_RESIDENCY_MAX_DENSITY`] are detected here and kept as
    /// host-side `sparse_compute::Csr`, while their literals are
    /// built from the source bytes exactly as a dense upload would —
    /// the XLA programs see bit-identical inputs, so decoded tokens
    /// cannot change (pinned against [`Self::new_dense`] in the
    /// integration suite). The realized sparsity over the detected
    /// slots calibrates [`Self::lane_cost`].
    pub fn new(runtime: &'a ModelRuntime, params: &[HostTensor])
               -> anyhow::Result<DecodeEngine<'a>> {
        Self::build(runtime, params,
                    Some(SPARSE_RESIDENCY_MAX_DENSITY))
    }

    /// [`Self::new`] with sparse-residency detection disabled: every
    /// param uploads dense, [`Self::sparsity`] is `None`, and
    /// [`Self::lane_cost`] is unit — the pre-sparsity load path, kept
    /// for A/B pins and callers that want uniform lane costs.
    pub fn new_dense(runtime: &'a ModelRuntime, params: &[HostTensor])
                     -> anyhow::Result<DecodeEngine<'a>> {
        Self::build(runtime, params, None)
    }

    fn build(runtime: &'a ModelRuntime, params: &[HostTensor],
             sparse_max_density: Option<f64>)
             -> anyhow::Result<DecodeEngine<'a>> {
        let mm = &runtime.manifest;
        let exe = runtime.artifact("logits_last")?;
        let spec = &exe.spec;
        let b = mm.decode_batch;
        let t = mm.config.ctx_len;
        let vocab = mm.config.vocab_size;
        let n_params = params.len();
        anyhow::ensure!(
            spec.inputs.len() == n_params + 2,
            "logits_last expects {} inputs ({} params + tokens + pos), \
             got {} params",
            spec.inputs.len(), spec.inputs.len().saturating_sub(2),
            params.len()
        );
        let tok_spec = &spec.inputs[n_params];
        let pos_spec = &spec.inputs[n_params + 1];
        anyhow::ensure!(
            tok_spec.shape[..] == [b, t] && tok_spec.dtype == Dtype::I32,
            "logits_last token slot {:?}/{:?} does not match decode \
             geometry ({b}, {t})/i32",
            tok_spec.shape, tok_spec.dtype
        );
        anyhow::ensure!(
            pos_spec.shape[..] == [b] && pos_spec.dtype == Dtype::I32,
            "logits_last pos slot {:?}/{:?} does not match ({b})/i32",
            pos_spec.shape, pos_spec.dtype
        );

        let n_state = mm.decode_state.len();
        let kv = match (runtime.executables.get("decode_step"),
                        runtime.executables.get("prefill")) {
            (Some(step), Some(prefill)) => {
                Self::validate_kv_specs(step, prefill, n_params,
                                        n_state, b, t, vocab)?;
                Some(KvExes { step, prefill })
            }
            _ => None,
        };

        let params = match sparse_max_density {
            Some(d) => LiteralCache::upload_sparse_validated(
                params, &spec.inputs[..n_params], d)?,
            None => LiteralCache::upload_validated(
                params, &spec.inputs[..n_params])?,
        };
        Ok(DecodeEngine {
            exe,
            kv,
            params,
            b,
            t,
            vocab,
            n_state,
        })
    }

    /// Once-per-session spec check of the KV pair: both artifacts take
    /// the same leading parameter slots as `logits_last`, then the
    /// state tensors, then their small host-marshalled buffers.
    fn validate_kv_specs(step: &Executable, prefill: &Executable,
                         n_params: usize, n_state: usize, b: usize,
                         t: usize, vocab: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            n_state > 0,
            "manifest carries decode_step/prefill artifacts but no \
             decode_state specs — regenerate with `make artifacts`"
        );
        let sspec = &step.spec;
        anyhow::ensure!(
            sspec.inputs.len() == n_params + n_state + 2,
            "decode_step expects {} inputs, want {} params + {} state \
             + next_token + pos",
            sspec.inputs.len(), n_params, n_state
        );
        let tok = &sspec.inputs[n_params + n_state];
        let pos = &sspec.inputs[n_params + n_state + 1];
        anyhow::ensure!(
            tok.shape[..] == [b] && tok.dtype == Dtype::I32
                && pos.shape[..] == [b] && pos.dtype == Dtype::I32,
            "decode_step token/pos slots do not match ({b},)/i32"
        );
        anyhow::ensure!(
            sspec.outputs.len() == 1 + n_state
                && sspec.outputs[0].shape[..] == [b, vocab],
            "decode_step outputs {:?} do not match (logits, state...)",
            sspec.outputs.len()
        );
        let pspec = &prefill.spec;
        anyhow::ensure!(
            pspec.inputs.len() == n_params + n_state + 3,
            "prefill expects {} inputs, want {} params + {} state + \
             tokens + pos + refill",
            pspec.inputs.len(), n_params, n_state
        );
        let ptok = &pspec.inputs[n_params + n_state];
        let ppos = &pspec.inputs[n_params + n_state + 1];
        let refill = &pspec.inputs[n_params + n_state + 2];
        anyhow::ensure!(
            ptok.shape[..] == [b, t] && ptok.dtype == Dtype::I32
                && ppos.shape[..] == [b] && ppos.dtype == Dtype::I32
                && refill.shape[..] == [b]
                && refill.dtype == Dtype::F32,
            "prefill tokens/pos/refill slots do not match \
             ({b},{t})/i32 + ({b},)/i32 + ({b},)/f32"
        );
        anyhow::ensure!(
            pspec.outputs.len() == 1 + n_state
                && pspec.outputs[0].shape[..] == [b, vocab],
            "prefill outputs {:?} do not match (logits, state...)",
            pspec.outputs.len()
        );
        // state tensors must round-trip across BOTH artifacts: each
        // step adopts the previous output (from either program) as the
        // next input, so all four slots per state tensor must agree —
        // a stale prefill HLO next to a regenerated decode_step should
        // fail here, not mid-serve with an opaque XLA shape error
        for i in 0..n_state {
            let slots = [
                ("decode_step input", &sspec.inputs[n_params + i]),
                ("decode_step output", &sspec.outputs[1 + i]),
                ("prefill input", &pspec.inputs[n_params + i]),
                ("prefill output", &pspec.outputs[1 + i]),
            ];
            let (_, first) = slots[0];
            for (what, s) in &slots[1..] {
                anyhow::ensure!(
                    s.shape == first.shape && s.dtype == first.dtype,
                    "KV state slot #{i} ({}): {what} {:?} vs {:?} — \
                     state cannot round-trip",
                    first.name, s.shape, first.shape
                );
            }
        }
        Ok(())
    }

    /// Batch rows per model step (the manifest's `decode_batch`).
    pub fn decode_batch(&self) -> usize {
        self.b
    }

    /// Context length the decode artifacts were compiled for.
    pub fn ctx_len(&self) -> usize {
        self.t
    }

    /// Vocabulary size of the logits rows.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// How many parameter slots loaded CSR-resident (0 for dense
    /// checkpoints and for [`Self::new_dense`] engines).
    pub fn sparse_slots(&self) -> usize {
        self.params.sparse_slots()
    }

    /// Realized weight sparsity over the CSR-resident slots only, or
    /// `None` when nothing loaded sparse. Embeddings and other
    /// dense-held params are excluded on purpose: they cost the same
    /// on every lane, so including them would understate the FLOPs
    /// savings of the masked matmuls this number calibrates.
    pub fn sparsity(&self) -> Option<f64> {
        self.params.sparse_sparsity()
    }

    /// Extra host bytes the CSR-resident copies occupy, next to the
    /// dense bytes those slots would have cost as host copies —
    /// `(csr_bytes, dense_bytes_of_sparse_slots)` for telemetry.
    pub fn sparse_host_bytes(&self) -> (usize, usize) {
        let mut csr = 0usize;
        let mut dense = 0usize;
        for r in self.params.residency() {
            if let crate::runtime::SlotResidency::Sparse(c) = r {
                csr += r.host_bytes();
                dense += c.rows * c.cols * 4;
            }
        }
        (csr, dense)
    }

    /// Virtual step-cost multiplier for a serve lane on this engine:
    /// `LaneCost::from_sparsity` of the realized sparsity (unit for
    /// dense-loaded engines), so an s75 lane advances the shared
    /// clock at a quarter of the dense step cost — the calibration
    /// `ModelRegistry::serve_with` feeds `run_lanes_with_costs`.
    pub fn lane_cost(&self) -> LaneCost {
        match self.sparsity() {
            Some(s) => LaneCost::from_sparsity(s),
            None => LaneCost::unit(),
        }
    }

    /// Is the KV-resident incremental path available (manifest carried
    /// the `decode_step`/`prefill` artifacts and they were compiled)?
    pub fn kv_available(&self) -> bool {
        self.kv.is_some()
    }

    fn kv_exes(&self) -> anyhow::Result<&KvExes<'a>> {
        self.kv.as_ref().ok_or_else(|| anyhow::anyhow!(
            "KV decode artifacts (decode_step/prefill) not compiled \
             for this model — regenerate with `make artifacts` and \
             load them alongside logits_last"
        ))
    }

    /// Fresh zero-filled KV session state (one per `serve_kv` call).
    pub fn kv_state(&self) -> anyhow::Result<SessionState> {
        let kv = self.kv_exes()?;
        let p = self.params.len();
        SessionState::zeros(&kv.step.spec.inputs[p..p + self.n_state])
    }

    /// Strip the logits off an output list and adopt the remaining
    /// literals as the next step's KV state.
    fn adopt_state(state: &mut SessionState, mut outs: Vec<xla::Literal>)
                   -> anyhow::Result<Vec<f32>> {
        let logits = outs.remove(0).to_vec::<f32>()?;
        state.replace(outs);
        Ok(logits)
    }

    /// Populate the cache rows with `refill[s] > 0` from the token
    /// buffer (one full forward); rows with `refill[s] == 0` pass
    /// their cache through untouched. Returns `(B * vocab)` logits
    /// read at `pos` (valid for every row whose token-buffer row is
    /// current — callers use the refilled rows' entries).
    pub(crate) fn kv_prefill(&self, state: &mut SessionState,
                             tokens: &[i32], pos: &[i32],
                             refill: &[f32])
                             -> anyhow::Result<Vec<f32>> {
        let kv = self.kv_exes()?;
        debug_assert_eq!(tokens.len(), self.b * self.t);
        debug_assert_eq!(pos.len(), self.b);
        debug_assert_eq!(refill.len(), self.b);
        debug_assert_eq!(state.len(), self.n_state);
        let tok_l = HostTensor::literal_i32(&[self.b, self.t], tokens)?;
        let pos_l = HostTensor::literal_i32(&[self.b], pos)?;
        let ref_l = HostTensor::literal_f32(&[self.b], refill)?;
        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(self.params.len() + self.n_state + 3);
        inputs.extend(self.params.refs());
        inputs.extend(state.refs());
        inputs.push(&tok_l);
        inputs.push(&pos_l);
        inputs.push(&ref_l);
        let outs = kv.prefill.run_raw(&inputs)?;
        Self::adopt_state(state, outs)
    }

    /// One incremental model step: `next[s]` is the token at position
    /// `pos[s]` (already appended by the serve loop); the program
    /// writes its K/V into the cache at `pos` and returns the logits
    /// predicting `pos + 1`. Only the two `(B,)` i32 buffers cross the
    /// host boundary as fresh uploads — O(1) work per token instead of
    /// `logits_last`'s O(context) recompute.
    pub(crate) fn kv_step(&self, state: &mut SessionState,
                          next: &[i32], pos: &[i32])
                          -> anyhow::Result<Vec<f32>> {
        let kv = self.kv_exes()?;
        debug_assert_eq!(next.len(), self.b);
        debug_assert_eq!(pos.len(), self.b);
        debug_assert_eq!(state.len(), self.n_state);
        let tok_l = HostTensor::literal_i32(&[self.b], next)?;
        let pos_l = HostTensor::literal_i32(&[self.b], pos)?;
        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(self.params.len() + self.n_state + 2);
        inputs.extend(self.params.refs());
        inputs.extend(state.refs());
        inputs.push(&tok_l);
        inputs.push(&pos_l);
        let outs = kv.step.run_raw(&inputs)?;
        Self::adopt_state(state, outs)
    }

    /// One model step: flat `(B*T)` token buffer + `(B)` positions in,
    /// flat `(B*V)` last-token logits out. Only the two small i32
    /// buffers cross the host boundary.
    pub(crate) fn step_logits(&self, tokens: &[i32], pos: &[i32])
                              -> anyhow::Result<Vec<f32>> {
        debug_assert_eq!(tokens.len(), self.b * self.t);
        debug_assert_eq!(pos.len(), self.b);
        let tok_l = HostTensor::literal_i32(&[self.b, self.t], tokens)?;
        let pos_l = HostTensor::literal_i32(&[self.b], pos)?;
        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(self.params.len() + 2);
        inputs.extend(self.params.refs());
        inputs.push(&tok_l);
        inputs.push(&pos_l);
        let outs = self.exe.run_raw(&inputs)?;
        Ok(outs[0].to_vec::<f32>()?)
    }

    /// Greedy decode a batch of prompts (token ids, unpadded). Returns
    /// the generated continuations (without the prompt, without EOS).
    /// Bit-identical to `generate::reference::greedy` (and, for
    /// `no_repeat_ngram == 0`, to the pre-engine implementation) for
    /// prompts that fit the context (`len <= ctx_len - 1`). Longer
    /// prompts now error instead of being silently head-truncated to
    /// garbage — pre-truncate (keeping the tail) with
    /// `coordinator::prompt_tokens`.
    ///
    /// This is the one-slot-per-prompt special case of the slot-refill
    /// state machine in [`super::batching`] — one implementation, one
    /// set of EOS/length-cap edge cases.
    pub fn greedy(&self, prompts: &[Vec<u32>], dp: &DecodeParams)
                  -> anyhow::Result<Vec<Vec<u32>>> {
        self.greedy_impl(prompts, dp, false)
    }

    /// [`Self::greedy`] over the KV-resident incremental path —
    /// bit-identical output (enforced by the integration suite and the
    /// perf bench), O(T) total work per request instead of O(T²).
    pub fn greedy_kv(&self, prompts: &[Vec<u32>], dp: &DecodeParams)
                     -> anyhow::Result<Vec<Vec<u32>>> {
        self.greedy_impl(prompts, dp, true)
    }

    fn greedy_impl(&self, prompts: &[Vec<u32>], dp: &DecodeParams,
                   use_kv: bool) -> anyhow::Result<Vec<Vec<u32>>> {
        anyhow::ensure!(prompts.len() <= self.b,
                        "batch of {} prompts exceeds decode_batch {}",
                        prompts.len(), self.b);
        let requests: Vec<super::DecodeRequest> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| super::DecodeRequest::new(
                i as u64, p.clone(), dp.max_new_tokens))
            .collect();
        let report = if use_kv {
            super::serve::core::serve_kv(self, &requests, dp)?
        } else {
            super::serve::core::serve(self, &requests, dp)?
        };
        Ok(report.results.into_iter().map(|r| r.tokens).collect())
    }

    /// Beam-search decode a *single* prompt using the batch slots as
    /// beams. Expansion candidates come from a partial top-2k instead
    /// of a full-vocab sort — the exact same 2k-prefix the old path
    /// read off its stable full sort. Like [`Self::greedy`], prompts
    /// must fit the context (`len <= ctx_len - 2`, one step of
    /// headroom); over-length prompts error instead of being silently
    /// head-truncated — pre-truncate (keeping the tail) with
    /// `coordinator::prompt_tokens`.
    pub fn beam(&self, prompt: &[u32], dp: &DecodeParams)
                -> anyhow::Result<Vec<u32>> {
        let (b, t, vocab) = (self.b, self.t, self.vocab);
        let k = dp.beam_size.clamp(1, b);
        anyhow::ensure!(!prompt.is_empty(), "empty beam prompt");
        anyhow::ensure!(
            prompt.len() <= t - 2,
            "beam prompt longer than ctx_len - 2 ({}) — pre-truncate \
             (keeping the tail) with coordinator::prompt_tokens",
            t - 2
        );

        #[derive(Clone)]
        struct Beam {
            seq: Vec<u32>, // prompt + generated
            logp: f64,
        }
        let plen = prompt.len();
        let mut beams = vec![Beam {
            seq: prompt.to_vec(),
            logp: 0.0,
        }];
        let mut finished: Vec<Beam> = Vec::new();

        let mut tokens = vec![0i32; b * t];
        let mut pos = vec![0i32; b];
        for _ in 0..dp.max_new_tokens {
            if beams.is_empty() {
                break;
            }
            // pack live beams into the batch
            tokens.fill(0);
            pos.fill(0);
            for (i, bm) in beams.iter().enumerate() {
                for (j, &tok) in bm.seq.iter().enumerate() {
                    tokens[i * t + j] = tok as i32;
                }
                pos[i] = bm.seq.len() as i32 - 1;
            }
            let lv = self.step_logits(&tokens, &pos)?;

            let mut candidates: Vec<Beam> = Vec::new();
            for (i, bm) in beams.iter().enumerate() {
                let row = &lv[i * vocab..(i + 1) * vocab];
                // log-softmax
                let mx = row.iter().cloned().fold(f32::MIN, f32::max);
                let logz: f64 = row.iter()
                    .map(|&x| ((x - mx) as f64).exp())
                    .sum::<f64>()
                    .ln() + mx as f64;
                for &tok in &topk::top_k(row, 2 * k) {
                    if super::repeats_ngram(&bm.seq, tok,
                                            dp.no_repeat_ngram) {
                        continue;
                    }
                    let lp = row[tok as usize] as f64 - logz;
                    let mut nb = bm.clone();
                    nb.logp += lp;
                    if tok == EOS {
                        // EOS is scored but never emitted
                        finished.push(nb);
                    } else if nb.seq.len() + 1 >= t - 1 {
                        // context capacity: the candidate token IS
                        // emitted (matching greedy/serve, which push
                        // the boundary token) — a beam must not be
                        // scored on a token it doesn't produce
                        nb.seq.push(tok);
                        finished.push(nb);
                    } else {
                        nb.seq.push(tok);
                        candidates.push(nb);
                    }
                }
            }
            // total_cmp: ordering is identical to the oracle's
            // partial_cmp sort on real (finite) logps — ties keep
            // insertion order under both, so beam selection stays
            // bitwise equal to generate::reference — but a NaN logp
            // accumulation can no longer panic the serve path
            candidates.sort_by(|a, c| c.logp.total_cmp(&a.logp));
            candidates.truncate(k);
            beams = candidates;
            if finished.len() >= 2 * k {
                break;
            }
        }
        finished.extend(beams);
        // length-penalized selection: logp / len^alpha
        let best = finished
            .into_iter()
            .max_by(|a, c| {
                let la = a.logp
                    / ((a.seq.len() - plen).max(1) as f64)
                        .powf(dp.length_penalty);
                let lc = c.logp
                    / ((c.seq.len() - plen).max(1) as f64)
                        .powf(dp.length_penalty);
                la.total_cmp(&lc)
            })
            .map(|bm| bm.seq[plen..].to_vec())
            .unwrap_or_default();
        Ok(best)
    }

    /// Serve a request stream through continuous slot-refill batching
    /// (FIFO, unbounded admission); see [`super::serve`].
    pub fn serve(&self, requests: &[super::DecodeRequest],
                 dp: &DecodeParams)
                 -> anyhow::Result<super::ServeReport> {
        super::serve::core::serve(self, requests, dp)
    }

    /// [`Self::serve`] over the KV-resident incremental path; see
    /// [`super::serve::core::serve_kv`].
    pub fn serve_kv(&self, requests: &[super::DecodeRequest],
                    dp: &DecodeParams)
                    -> anyhow::Result<super::ServeReport> {
        super::serve::core::serve_kv(self, requests, dp)
    }

    /// Fully configurable serving: engine path, arrival schedule,
    /// scheduling policy and admission control; see
    /// [`super::serve::core::serve_with`].
    pub fn serve_with(&self, requests: &[super::DecodeRequest],
                      dp: &DecodeParams,
                      cfg: &super::serve::ServeConfig)
                      -> anyhow::Result<super::ServeReport> {
        super::serve::core::serve_with(self, requests, dp, cfg)
    }
}

#[cfg(test)]
mod tests {
    //! The beam comparators' NaN-safety regressions (ISSUE 7). The
    //! full engine-vs-reference bitwise pin lives in
    //! `tests/integration_runtime.rs`; these cover the comparator
    //! semantics the pin relies on, artifact-free.

    use crate::util::rng::Rng;

    /// The frozen oracle comparator (`generate::reference`): stable
    /// descending sort via `partial_cmp().unwrap()`.
    fn oracle_desc(xs: &[f64]) -> Vec<usize> {
        let mut order: Vec<usize> = (0..xs.len()).collect();
        order.sort_by(|&a, &c| xs[c].partial_cmp(&xs[a]).unwrap());
        order
    }

    fn total_desc(xs: &[f64]) -> Vec<usize> {
        let mut order: Vec<usize> = (0..xs.len()).collect();
        order.sort_by(|&a, &c| xs[c].total_cmp(&xs[a]));
        order
    }

    #[test]
    fn beam_sort_matches_oracle_on_finite_logps() {
        // real beam logps: finite, negative, tie-heavy when snapped —
        // the stable descending orders must agree index-for-index
        crate::util::proptest::check(
            13, 80, 48,
            |rng: &mut Rng, size: usize| {
                let n = 1 + rng.below(size);
                let snap = rng.below(2) == 0;
                (0..n)
                    .map(|_| {
                        let x = -(rng.uniform() * 20.0 + 1e-3);
                        if snap { (x * 4.0).round() / 4.0 } else { x }
                    })
                    .collect::<Vec<f64>>()
            },
            |xs| total_desc(xs) == oracle_desc(xs),
        );
    }

    #[test]
    fn beam_sort_no_longer_panics_on_nan() {
        // pre-ISSUE-7 this was the partial_cmp().unwrap() panic; now
        // the NaN orders deterministically and finite beams keep
        // their relative oracle order
        let xs = [-1.0, f64::NAN, -0.5, -1.0];
        let order = total_desc(&xs);
        let finite: Vec<usize> =
            order.iter().copied().filter(|&i| i != 1).collect();
        assert_eq!(finite, vec![2, 0, 3]);
    }

    #[test]
    fn length_penalty_selection_matches_oracle_max() {
        // max_by(total_cmp) equals max_by(partial_cmp().unwrap()) on
        // finite penalized scores (the selection at the end of beam())
        let scores = [-2.5, -0.25, -7.0, -0.25, -3.0];
        let oracle = scores
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, c)| a.partial_cmp(c).unwrap())
            .map(|(i, _)| i);
        let total = scores
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, c)| a.total_cmp(c))
            .map(|(i, _)| i);
        assert_eq!(total, oracle);
        // ties: max_by keeps the *last* maximal element under both
        assert_eq!(total, Some(3));
    }
}
