//! The pre-engine decode path, kept verbatim as an oracle.
//!
//! This is what `generate::{greedy, beam}` did before `DecodeEngine`:
//! every step re-validates and re-uploads the **full parameter set**
//! through `Executable::run`, and candidate selection is a full-vocab
//! *stable* descending sort (ties resolve to the lowest index — the
//! ordering contract `topk` reproduces). It exists for two reasons:
//!
//!  1. equivalence tests: the engine must produce byte-identical
//!     output (`tests/integration_runtime.rs`);
//!  2. `benches/perf_decode` measures the engine's speedup against it.
//!
//! The n-gram fallback here carries the *fixed* semantics (fall through
//! the full candidate order when the top-8 window is exhausted), so the
//! oracle also covers `no_repeat_ngram > 0`. Likewise the beam
//! capacity boundary (ISSUE 2): a beam finished by the length cap
//! emits the token it accumulated the log-prob of, exactly as greedy
//! and `batching::serve` emit their boundary token.

use crate::runtime::{HostTensor, ModelRuntime};
use crate::tokenizer::EOS;

use super::{repeats_ngram, DecodeParams};

/// Stable full descending sort of a logit row — O(V log V) per slot
/// per step, the cost `topk::top_k` eliminates.
fn full_sort_desc(row: &[f32]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..row.len()).collect();
    // lint:allow(float-sort) frozen oracle: the pinned outputs were
    // produced by this exact comparator; invariant: model logits are
    // finite by construction, a NaN is a divergence worth the panic
    order.sort_by(|&a, &c| row[c].partial_cmp(&row[a]).unwrap());
    order
}

fn pick_next_full_sort(row: &[f32], ctx: &[u32], n: usize) -> u32 {
    let order = full_sort_desc(row);
    let mut next = order[0] as u32;
    for &cand in &order {
        if !repeats_ngram(ctx, cand as u32, n) {
            next = cand as u32;
            break;
        }
    }
    next
}

/// Greedy decode, old slow path: per-step param upload + full sort.
pub fn greedy(
    runtime: &ModelRuntime,
    params: &[HostTensor],
    prompts: &[Vec<u32>],
    dp: &DecodeParams,
) -> anyhow::Result<Vec<Vec<u32>>> {
    let mm = &runtime.manifest;
    let exe = runtime.artifact("logits_last")?;
    let b = mm.decode_batch;
    let t = mm.config.ctx_len;
    let vocab = mm.config.vocab_size;
    anyhow::ensure!(prompts.len() <= b,
                    "batch of {} prompts exceeds decode_batch {b}",
                    prompts.len());

    let mut tokens = vec![0i32; b * t];
    let mut pos = vec![0i32; b];
    let mut out: Vec<Vec<u32>> = vec![Vec::new(); prompts.len()];
    let mut done = vec![false; prompts.len()];
    for (i, p) in prompts.iter().enumerate() {
        let plen = p.len().min(t - 1);
        for (j, &tok) in p.iter().take(plen).enumerate() {
            tokens[i * t + j] = tok as i32;
        }
        pos[i] = plen as i32 - 1;
    }

    for _ in 0..dp.max_new_tokens {
        if done.iter().all(|&d| d) {
            break;
        }
        let inputs = assemble_inputs(params, &tokens, &pos, b, t);
        let logits = exe.run(&inputs)?;
        let lv = logits[0].as_f32()?;
        for i in 0..prompts.len() {
            if done[i] {
                continue;
            }
            let row = &lv[i * vocab..(i + 1) * vocab];
            let ctx: Vec<u32> = (0..=pos[i] as usize)
                .map(|j| tokens[i * t + j] as u32)
                .collect();
            let next =
                pick_next_full_sort(row, &ctx, dp.no_repeat_ngram);
            let new_pos = pos[i] as usize + 1;
            if next == EOS || new_pos >= t - 1 {
                done[i] = true;
                if next != EOS && new_pos < t {
                    out[i].push(next);
                }
                continue;
            }
            tokens[i * t + new_pos] = next as i32;
            pos[i] = new_pos as i32;
            out[i].push(next);
        }
    }
    Ok(out)
}

/// Beam-search decode, old slow path.
pub fn beam(
    runtime: &ModelRuntime,
    params: &[HostTensor],
    prompt: &[u32],
    dp: &DecodeParams,
) -> anyhow::Result<Vec<u32>> {
    let mm = &runtime.manifest;
    let exe = runtime.artifact("logits_last")?;
    let b = mm.decode_batch;
    let t = mm.config.ctx_len;
    let vocab = mm.config.vocab_size;
    let k = dp.beam_size.clamp(1, b);

    #[derive(Clone)]
    struct Beam {
        seq: Vec<u32>, // prompt + generated
        logp: f64,
    }
    let plen = prompt.len().min(t - 2);
    let mut beams = vec![Beam {
        seq: prompt[..plen].to_vec(),
        logp: 0.0,
    }];
    let mut finished: Vec<Beam> = Vec::new();

    for _ in 0..dp.max_new_tokens {
        if beams.is_empty() {
            break;
        }
        let mut tokens = vec![0i32; b * t];
        let mut pos = vec![0i32; b];
        for (i, bm) in beams.iter().enumerate() {
            for (j, &tok) in bm.seq.iter().enumerate() {
                tokens[i * t + j] = tok as i32;
            }
            pos[i] = bm.seq.len() as i32 - 1;
        }
        let inputs = assemble_inputs(params, &tokens, &pos, b, t);
        let logits = exe.run(&inputs)?;
        let lv = logits[0].as_f32()?;

        let mut candidates: Vec<Beam> = Vec::new();
        for (i, bm) in beams.iter().enumerate() {
            let row = &lv[i * vocab..(i + 1) * vocab];
            let mx = row.iter().cloned().fold(f32::MIN, f32::max);
            let logz: f64 = row.iter()
                .map(|&x| ((x - mx) as f64).exp())
                .sum::<f64>()
                .ln() + mx as f64;
            let idx = full_sort_desc(row);
            for &tok in idx.iter().take(2 * k) {
                if repeats_ngram(&bm.seq, tok as u32,
                                 dp.no_repeat_ngram) {
                    continue;
                }
                let lp = row[tok] as f64 - logz;
                let mut nb = bm.clone();
                nb.logp += lp;
                if tok as u32 == EOS {
                    finished.push(nb);
                } else if nb.seq.len() + 1 >= t - 1 {
                    // capacity-finished beams emit the token they were
                    // scored on (the fixed boundary semantics; see
                    // engine::DecodeEngine::beam)
                    nb.seq.push(tok as u32);
                    finished.push(nb);
                } else {
                    nb.seq.push(tok as u32);
                    candidates.push(nb);
                }
            }
        }
        // lint:allow(float-sort) frozen oracle comparator; invariant:
        // beam logps are sums of finite log-softmax terms
        candidates.sort_by(|a, c| c.logp.partial_cmp(&a.logp).unwrap());
        candidates.truncate(k);
        beams = candidates;
        if finished.len() >= 2 * k {
            break;
        }
    }
    finished.extend(beams);
    let best = finished
        .into_iter()
        .max_by(|a, c| {
            let la = a.logp
                / ((a.seq.len() - plen).max(1) as f64)
                    .powf(dp.length_penalty);
            let lc = c.logp
                / ((c.seq.len() - plen).max(1) as f64)
                    .powf(dp.length_penalty);
            // lint:allow(float-sort) frozen oracle; invariant: finite
            // logp over a nonzero length — the penalty cannot NaN
            la.partial_cmp(&lc).unwrap()
        })
        .map(|bm| bm.seq[plen..].to_vec())
        .unwrap_or_default();
    Ok(best)
}

fn assemble_inputs(
    params: &[HostTensor],
    tokens: &[i32],
    pos: &[i32],
    b: usize,
    t: usize,
) -> Vec<HostTensor> {
    let mut inputs: Vec<HostTensor> = params.to_vec();
    inputs.push(HostTensor::from_i32(&[b, t], tokens.to_vec()));
    inputs.push(HostTensor::from_i32(&[b], pos.to_vec()));
    inputs
}
