//! Decoding over the `logits_last` artifact: greedy and beam search
//! with length penalty and no-repeat-ngram blocking (the knobs Hu et
//! al. 2022 / the paper use for NLG fine-tuning evaluation).
//!
//! The artifact computes full-context logits at an explicit position, so
//! the host owns the loop: right-pad prompts into the fixed (B, T)
//! geometry, read row logits, extend, repeat. Causality makes the right
//! padding invisible.
//!
//! Structure (§Perf serving path):
//!  * [`engine::DecodeEngine`] — the literal-resident decode session:
//!    parameters upload to XLA literals once, steps go through
//!    `Executable::run_raw`, next-token selection is a partial top-k.
//!    When the manifest carries the `decode_step`/`prefill` artifacts
//!    it also exposes the KV-resident path: per-layer K/V caches live
//!    as session-state literals fed back output→input, so each step
//!    does O(1) model work per token (vs `logits_last`'s O(context)
//!    recompute) and only `(B,)` token/pos vectors cross the host
//!    boundary.
//!  * [`serve`] — the scheduler-driven serving core: continuous
//!    slot-refill batching (any number of requests stream through the
//!    fixed `(decode_batch, ctx_len)` geometry, finished slots are
//!    refilled mid-flight, with per-slot cache prefill on the KV
//!    path), with pluggable queue policies ([`serve::policy`]:
//!    FIFO / shortest-prompt / smallest-budget / priority classes)
//!    and admission control ([`serve::admission`]: unbounded /
//!    max-queue-depth / queue-deadline shedding). Admission timing is
//!    either immediate ([`serve::core::serve`] /
//!    [`serve::core::serve_kv`]) or arrival-gated on a deterministic
//!    virtual clock ([`serve::core::serve_timed`]);
//!    [`serve::core::serve_with`] exposes every axis. The old
//!    [`batching`] module remains as a re-export shim.
//!  * [`loadgen`] — seeded arrival-time traces (Poisson / bursty /
//!    closed-loop) and the offered-load sweep producing
//!    latency-under-load curves (`spdf loadgen`,
//!    `BENCH_serve_load.json`).
//!  * [`topk`] — O(V + k log k) candidate selection, exactly equal to
//!    the old full-vocab stable sort's prefix.
//!  * [`reference`] — the pre-engine path (per-step param upload +
//!    full-vocab sort), kept as the equivalence oracle and the bench
//!    baseline; both serve paths decode bit-identically to it.
//!
//! The free functions [`greedy`] and [`beam`] remain the drop-in API;
//! they build a throwaway engine per call.

pub mod batching;
pub mod engine;
pub mod loadgen;
pub mod reference;
pub mod serve;
pub mod topk;

pub use engine::DecodeEngine;
pub use serve::{ChaosConfig, DecodeRequest, FaultPlan, FaultSpec,
                ModelRegistry, ModelStats, PageCounters,
                PagedKvConfig, RecoveryConfig, RequestOutcome,
                RequestResult, RetryPolicy, Schedule, ServeConfig,
                ServeReport, ServeStats, SpecConfig, SpecCounters,
                SpecPlan};

use crate::runtime::{HostTensor, ModelRuntime};

#[derive(Debug, Clone)]
pub struct DecodeParams {
    pub max_new_tokens: usize,
    pub beam_size: usize,
    pub length_penalty: f64,
    pub no_repeat_ngram: usize,
}

impl Default for DecodeParams {
    fn default() -> Self {
        // Hu et al. (2022) E2E settings, adapted to this scale: beam 4
        // in the paper (greedy default here, beam via --beam); the
        // paper's no-repeat-ngram operates on words, but at a 512-BPE
        // vocab a token-level block garbles subword sequences that
        // legitimately repeat ("it is …" templates), so it is off by
        // default and exercised explicitly in tests/ablations.
        DecodeParams {
            max_new_tokens: 64,
            beam_size: 1,
            length_penalty: 0.9,
            no_repeat_ngram: 0,
        }
    }
}

/// Would appending `next` create a repeated n-gram of size `n`?
pub(crate) fn repeats_ngram(seq: &[u32], next: u32, n: usize) -> bool {
    if n == 0 || seq.len() + 1 < 2 * n {
        return false;
    }
    let mut cand: Vec<u32> = seq[seq.len() - (n - 1)..].to_vec();
    cand.push(next);
    seq.windows(n).any(|w| w == cand.as_slice())
}

/// Greedy decode a batch of prompts (token ids, unpadded). Returns the
/// generated continuations (without the prompt, without EOS).
pub fn greedy(
    runtime: &ModelRuntime,
    params: &[HostTensor],
    prompts: &[Vec<u32>],
    dp: &DecodeParams,
) -> anyhow::Result<Vec<Vec<u32>>> {
    DecodeEngine::new(runtime, params)?.greedy(prompts, dp)
}

/// Beam-search decode a *single* prompt using the batch slots as beams.
pub fn beam(
    runtime: &ModelRuntime,
    params: &[HostTensor],
    prompt: &[u32],
    dp: &DecodeParams,
) -> anyhow::Result<Vec<u32>> {
    DecodeEngine::new(runtime, params)?.beam(prompt, dp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ngram_blocking_detects_repeat() {
        // seq: a b c a b, next c would repeat "a b c" (n=3)
        let seq = [10, 11, 12, 10, 11];
        assert!(repeats_ngram(&seq, 12, 3));
        assert!(!repeats_ngram(&seq, 13, 3));
        // too short for a repeat
        assert!(!repeats_ngram(&[1, 2], 3, 3));
        // n=0 disables
        assert!(!repeats_ngram(&seq, 12, 0));
    }

    #[test]
    fn ngram_blocking_bigram() {
        // appending 6 to [5,6,7] forms candidate bigram [7,6]: no repeat
        assert!(!repeats_ngram(&[5, 6, 7], 6, 2));
        // appending 6 to [5,6,5] forms [5,6] which already occurred
        assert!(repeats_ngram(&[5, 6, 5], 6, 2));
    }
}
