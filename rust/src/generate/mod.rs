//! Decoding over the `logits_last` artifact: greedy and beam search
//! with length penalty and no-repeat-ngram blocking (the knobs Hu et
//! al. 2022 / the paper use for NLG fine-tuning evaluation).
//!
//! The artifact computes full-context logits at an explicit position, so
//! the coordinator owns the loop: right-pad prompts into the fixed
//! (B, T) geometry, read row logits, extend, repeat. Causality makes the
//! right padding invisible.

use crate::runtime::{HostTensor, ModelRuntime};
use crate::tokenizer::EOS;

#[derive(Debug, Clone)]
pub struct DecodeParams {
    pub max_new_tokens: usize,
    pub beam_size: usize,
    pub length_penalty: f64,
    pub no_repeat_ngram: usize,
}

impl Default for DecodeParams {
    fn default() -> Self {
        // Hu et al. (2022) E2E settings, adapted to this scale: beam 4
        // in the paper (greedy default here, beam via --beam); the
        // paper's no-repeat-ngram operates on words, but at a 512-BPE
        // vocab a token-level block garbles subword sequences that
        // legitimately repeat ("it is …" templates), so it is off by
        // default and exercised explicitly in tests/ablations.
        DecodeParams {
            max_new_tokens: 64,
            beam_size: 1,
            length_penalty: 0.9,
            no_repeat_ngram: 0,
        }
    }
}

/// Would appending `next` create a repeated n-gram of size `n`?
fn repeats_ngram(seq: &[u32], next: u32, n: usize) -> bool {
    if n == 0 || seq.len() + 1 < 2 * n {
        return false;
    }
    let mut cand: Vec<u32> = seq[seq.len() - (n - 1)..].to_vec();
    cand.push(next);
    seq.windows(n).any(|w| w == cand.as_slice())
}

/// Greedy decode a batch of prompts (token ids, unpadded). Returns the
/// generated continuations (without the prompt, without EOS).
pub fn greedy(
    runtime: &ModelRuntime,
    params: &[HostTensor],
    prompts: &[Vec<u32>],
    dp: &DecodeParams,
) -> anyhow::Result<Vec<Vec<u32>>> {
    let mm = &runtime.manifest;
    let exe = runtime.artifact("logits_last")?;
    let b = mm.decode_batch;
    let t = mm.config.ctx_len;
    let vocab = mm.config.vocab_size;
    anyhow::ensure!(prompts.len() <= b,
                    "batch of {} prompts exceeds decode_batch {b}",
                    prompts.len());

    let mut tokens = vec![0i32; b * t];
    let mut pos = vec![0i32; b];
    let mut out: Vec<Vec<u32>> = vec![Vec::new(); prompts.len()];
    let mut done = vec![false; prompts.len()];
    for (i, p) in prompts.iter().enumerate() {
        let plen = p.len().min(t - 1);
        for (j, &tok) in p.iter().take(plen).enumerate() {
            tokens[i * t + j] = tok as i32;
        }
        pos[i] = plen as i32 - 1;
    }

    for _ in 0..dp.max_new_tokens {
        if done.iter().all(|&d| d) {
            break;
        }
        let inputs = assemble_inputs(params, &tokens, &pos, b, t);
        let logits = exe.run(&inputs)?;
        let lv = logits[0].as_f32()?;
        for i in 0..prompts.len() {
            if done[i] {
                continue;
            }
            let row = &lv[i * vocab..(i + 1) * vocab];
            // argmax avoiding blocked n-grams
            let ctx: Vec<u32> = (0..=pos[i] as usize)
                .map(|j| tokens[i * t + j] as u32)
                .collect();
            let mut order: Vec<usize> = (0..vocab).collect();
            order.sort_by(|&a, &c| {
                row[c].partial_cmp(&row[a]).unwrap()
            });
            let mut next = order[0] as u32;
            for &cand in order.iter().take(8) {
                if !repeats_ngram(&ctx, cand as u32, dp.no_repeat_ngram) {
                    next = cand as u32;
                    break;
                }
            }
            let new_pos = pos[i] as usize + 1;
            if next == EOS || new_pos >= t - 1 {
                done[i] = true;
                if next != EOS && new_pos < t {
                    out[i].push(next);
                }
                continue;
            }
            tokens[i * t + new_pos] = next as i32;
            pos[i] = new_pos as i32;
            out[i].push(next);
        }
    }
    Ok(out)
}

/// Beam-search decode a *single* prompt using the batch slots as beams.
pub fn beam(
    runtime: &ModelRuntime,
    params: &[HostTensor],
    prompt: &[u32],
    dp: &DecodeParams,
) -> anyhow::Result<Vec<u32>> {
    let mm = &runtime.manifest;
    let exe = runtime.artifact("logits_last")?;
    let b = mm.decode_batch;
    let t = mm.config.ctx_len;
    let vocab = mm.config.vocab_size;
    let k = dp.beam_size.clamp(1, b);

    #[derive(Clone)]
    struct Beam {
        seq: Vec<u32>,       // prompt + generated
        logp: f64,
        finished: bool,
    }
    let plen = prompt.len().min(t - 2);
    let mut beams = vec![Beam {
        seq: prompt[..plen].to_vec(),
        logp: 0.0,
        finished: false,
    }];
    let mut finished: Vec<Beam> = Vec::new();

    for _ in 0..dp.max_new_tokens {
        if beams.is_empty() {
            break;
        }
        // pack live beams into the batch
        let mut tokens = vec![0i32; b * t];
        let mut pos = vec![0i32; b];
        for (i, bm) in beams.iter().enumerate() {
            for (j, &tok) in bm.seq.iter().enumerate() {
                tokens[i * t + j] = tok as i32;
            }
            pos[i] = bm.seq.len() as i32 - 1;
        }
        let inputs = assemble_inputs(params, &tokens, &pos, b, t);
        let logits = exe.run(&inputs)?;
        let lv = logits[0].as_f32()?;

        let mut candidates: Vec<Beam> = Vec::new();
        for (i, bm) in beams.iter().enumerate() {
            let row = &lv[i * vocab..(i + 1) * vocab];
            // log-softmax
            let mx = row.iter().cloned().fold(f32::MIN, f32::max);
            let logz: f64 = row.iter()
                .map(|&x| ((x - mx) as f64).exp())
                .sum::<f64>()
                .ln() + mx as f64;
            let mut idx: Vec<usize> = (0..vocab).collect();
            idx.sort_by(|&a, &c| row[c].partial_cmp(&row[a]).unwrap());
            let gen = &bm.seq[plen.min(bm.seq.len())..];
            let _ = gen;
            for &tok in idx.iter().take(2 * k) {
                if repeats_ngram(&bm.seq, tok as u32,
                                 dp.no_repeat_ngram) {
                    continue;
                }
                let lp = row[tok] as f64 - logz;
                let mut nb = bm.clone();
                nb.logp += lp;
                if tok as u32 == EOS || nb.seq.len() + 1 >= t - 1 {
                    nb.finished = true;
                    finished.push(nb);
                } else {
                    nb.seq.push(tok as u32);
                    candidates.push(nb);
                }
            }
        }
        candidates.sort_by(|a, c| c.logp.partial_cmp(&a.logp).unwrap());
        candidates.truncate(k);
        beams = candidates;
        if finished.len() >= 2 * k {
            break;
        }
    }
    finished.extend(beams);
    // length-penalized selection: logp / len^alpha
    let best = finished
        .into_iter()
        .max_by(|a, c| {
            let la = a.logp
                / ((a.seq.len() - plen).max(1) as f64)
                    .powf(dp.length_penalty);
            let lc = c.logp
                / ((c.seq.len() - plen).max(1) as f64)
                    .powf(dp.length_penalty);
            la.partial_cmp(&lc).unwrap()
        })
        .map(|bm| bm.seq[plen..].to_vec())
        .unwrap_or_default();
    Ok(best)
}

fn assemble_inputs(
    params: &[HostTensor],
    tokens: &[i32],
    pos: &[i32],
    b: usize,
    t: usize,
) -> Vec<HostTensor> {
    let mut inputs: Vec<HostTensor> = params.to_vec();
    inputs.push(HostTensor::from_i32(&[b, t], tokens.to_vec()));
    inputs.push(HostTensor::from_i32(&[b], pos.to_vec()));
    inputs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ngram_blocking_detects_repeat() {
        // seq: a b c a b, next c would repeat "a b c" (n=3)
        let seq = [10, 11, 12, 10, 11];
        assert!(repeats_ngram(&seq, 12, 3));
        assert!(!repeats_ngram(&seq, 13, 3));
        // too short for a repeat
        assert!(!repeats_ngram(&[1, 2], 3, 3));
        // n=0 disables
        assert!(!repeats_ngram(&seq, 12, 0));
    }

    #[test]
    fn ngram_blocking_bigram() {
        // appending 6 to [5,6,7] forms candidate bigram [7,6]: no repeat
        assert!(!repeats_ngram(&[5, 6, 7], 6, 2));
        // appending 6 to [5,6,5] forms [5,6] which already occurred
        assert!(repeats_ngram(&[5, 6, 5], 6, 2));
    }
}
