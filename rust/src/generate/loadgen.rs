//! Workload-driven load generation: arrival-time traces and
//! latency-under-load sweeps over the slot-refill serve loop.
//!
//! `BENCH_decode.json` tracks a single saturated-throughput point;
//! deployment behavior is governed by what happens *under load* — how
//! queue wait, time-to-first-token and end-to-end latency degrade as
//! the offered request rate approaches the engine's capacity. This
//! module supplies the missing scenario layer:
//!
//!  * [`generate_trace`] — a **seeded, deterministic** trace of timed
//!    [`DecodeRequest`]s: Poisson or bursty open-loop arrivals at a
//!    configurable rate, or closed-loop client chains
//!    ([`Pattern::Closed`]), with uniform prompt-length and
//!    generation-budget distributions. The same seed always yields
//!    the same prompts/budgets regardless of the arrival rate, so a
//!    rate sweep varies *only* the arrival process.
//!  * [`run_trace`] — drives the timed serve loop
//!    (`serve::core::serve_with`): requests are
//!    injected as their arrival times pass on the **virtual clock**
//!    (each engine step costs [`StepCosts::step_ms`], each KV prefill
//!    pass [`StepCosts::prefill_ms`]), and per-request queue wait /
//!    TTFT / latency are read off that clock. With pinned step costs
//!    the whole simulation is bit-deterministic; [`calibrate`]
//!    measures real per-step costs so the curves can be denominated
//!    in honest milliseconds per engine path.
//!  * [`sweep`] — the offered-load sweep feeding
//!    `coordinator::report::load_table`, `spdf loadgen` and
//!    `benches/perf_serve_load` (`BENCH_serve_load.json`).
//!
//! The model steps are real (the decoded tokens are exactly what
//! `serve`/`serve_kv` would produce); only *time* is simulated, which
//! is what makes the latency curves reproducible in CI.

use crate::tokenizer::N_SPECIAL;
use crate::tokenizer::{BOS, SEP};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

use super::serve::admission::{AdmissionPolicy, Unbounded};
use super::serve::core as serve_core;
use super::serve::core::ServeConfig;
use super::serve::policy::{Fifo, Scheduler};
use super::serve::registry::ModelRegistry;
use super::serve::speculative::SpecConfig;
use super::serve::{ChaosConfig, PagedKvConfig, Schedule, ServeReport,
                   ServeStats};
use super::{DecodeEngine, DecodeParams, DecodeRequest};

/// Seed salt for the priority-class phase: priorities come from their
/// own stream so enabling them never perturbs prompts, budgets or
/// arrivals (and `priority_classes: 1` traces are bit-identical to
/// traces generated before priorities existed).
const PRIORITY_SALT: u64 = 0x7072_696f;

/// Seed salt for the model-mix phase: model tags come from their own
/// stream (like priorities) so enabling a mix never perturbs prompts,
/// budgets, priorities or arrivals — an empty `model_mix` leaves the
/// trace bit-identical to traces generated before the registry
/// existed.
const MODEL_SALT: u64 = 0x6d6f_6465;

/// Arrival process shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Pattern {
    /// Memoryless open-loop arrivals: exponential inter-arrival times
    /// at the configured rate.
    Poisson,
    /// Open-loop bursts: groups of `burst` requests arrive at the
    /// same instant, with exponential gaps between groups sized so
    /// the mean rate is preserved.
    Bursty { burst: usize },
    /// Closed loop: `clients` concurrent callers, each issuing its
    /// next request `think_ms` after its previous one completes. The
    /// offered rate is an outcome, not an input.
    Closed { clients: usize, think_ms: f64 },
}

impl Pattern {
    pub fn name(&self) -> &'static str {
        match self {
            Pattern::Poisson => "poisson",
            Pattern::Bursty { .. } => "bursty",
            Pattern::Closed { .. } => "closed",
        }
    }

    /// Parse the `spdf loadgen --pattern` flag, taking the burst /
    /// client knobs from their own flags.
    pub fn parse(s: &str, burst: usize, clients: usize, think_ms: f64)
                 -> anyhow::Result<Pattern> {
        match s {
            "poisson" => Ok(Pattern::Poisson),
            "bursty" => {
                anyhow::ensure!(burst >= 1, "--burst must be >= 1");
                Ok(Pattern::Bursty { burst })
            }
            "closed" => {
                anyhow::ensure!(clients >= 1,
                                "--clients must be >= 1");
                anyhow::ensure!(think_ms >= 0.0 && think_ms.is_finite(),
                                "--think-ms must be non-negative");
                Ok(Pattern::Closed { clients, think_ms })
            }
            other => anyhow::bail!(
                "unknown --pattern {other} (want poisson | bursty | \
                 closed)"
            ),
        }
    }
}

/// Trace-generation knobs. Prompt lengths and budgets are inclusive
/// uniform ranges; prompts are `BOS <body> SEP` with body tokens drawn
/// from the non-special vocabulary, mirroring the perf benches.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub seed: u64,
    pub requests: usize,
    /// Offered load, requests per (virtual) second — open-loop
    /// patterns only.
    pub rate_rps: f64,
    pub pattern: Pattern,
    /// Prompt body length range (tokens between BOS and SEP).
    pub prompt_lens: (usize, usize),
    /// `max_new_tokens` range.
    pub budgets: (usize, usize),
    pub vocab: usize,
    /// Number of priority classes to draw per request (uniform over
    /// `0..classes`, higher = more urgent — the feed for
    /// `serve::policy::PriorityClass`). 1 = everything priority 0,
    /// bit-identical to pre-priority traces.
    pub priority_classes: u8,
    /// Weighted model mix for `serve::registry::ModelRegistry`
    /// routing (`spdf loadgen --model-mix dense=0.5,s75=0.5`): each
    /// request's [`DecodeRequest::model`] tag is drawn from this
    /// distribution on its own salted stream. Weights need not sum to
    /// 1 (they are normalized); empty = untagged requests (all routed
    /// to the default model), bit-identical to pre-registry traces.
    pub model_mix: Vec<(String, f64)>,
}

/// A generated workload: requests plus their (virtual-ms) arrival
/// times and closed-loop release chains.
#[derive(Debug, Clone)]
pub struct Trace {
    pub requests: Vec<DecodeRequest>,
    pub arrivals: Vec<f64>,
    pub release: Vec<Option<(usize, f64)>>,
    pub pattern: Pattern,
    pub rate_rps: f64,
    pub mean_budget: f64,
}

impl Trace {
    /// Bind the trace to virtual step costs for `serve_timed`.
    pub fn schedule(&self, costs: &StepCosts) -> Schedule {
        Schedule {
            arrivals: self.arrivals.clone(),
            release: self.release.clone(),
            step_ms: costs.step_ms,
            prefill_ms: costs.prefill_ms,
        }
    }
}

/// Generate a deterministic timed request trace. Two calls with the
/// same config are identical; prompts/budgets depend only on
/// `(seed, requests, prompt_lens, budgets, vocab)` — not on the
/// pattern or rate — so load sweeps reuse the exact same work items.
pub fn generate_trace(cfg: &TraceConfig) -> anyhow::Result<Trace> {
    anyhow::ensure!(cfg.requests > 0, "trace needs at least 1 request");
    let (plo, phi) = cfg.prompt_lens;
    let (blo, bhi) = cfg.budgets;
    anyhow::ensure!(plo >= 1 && plo <= phi,
                    "bad prompt length range {plo}..={phi}");
    anyhow::ensure!(blo <= bhi, "bad budget range {blo}..={bhi}");
    anyhow::ensure!(cfg.vocab > N_SPECIAL as usize + 1,
                    "vocab {} leaves no non-special tokens", cfg.vocab);
    anyhow::ensure!(cfg.priority_classes >= 1,
                    "need at least 1 priority class");
    for (name, w) in &cfg.model_mix {
        anyhow::ensure!(!name.is_empty(),
                        "model-mix entries need a model name");
        anyhow::ensure!(w.is_finite() && *w > 0.0,
                        "model-mix weight for {name} must be a \
                         positive finite number (got {w})");
        anyhow::ensure!(
            cfg.model_mix.iter().filter(|(n, _)| n == name).count()
                == 1,
            "model {name} appears twice in the model mix"
        );
    }
    match cfg.pattern {
        Pattern::Closed { clients, .. } => {
            anyhow::ensure!(clients >= 1,
                            "closed loop needs at least 1 client");
        }
        Pattern::Bursty { burst } => {
            anyhow::ensure!(burst >= 1, "bursts need at least 1 \
                                         request");
        }
        Pattern::Poisson => {}
    }
    if !matches!(cfg.pattern, Pattern::Closed { .. }) {
        anyhow::ensure!(cfg.rate_rps > 0.0 && cfg.rate_rps.is_finite(),
                        "open-loop patterns need a positive rate");
    }

    let n = cfg.requests;
    let mut rng = Rng::new(cfg.seed);
    // phase 1: work items (prompts + budgets) — consumed draws do not
    // depend on the arrival process
    let mut requests = Vec::with_capacity(n);
    let mut budget_sum = 0usize;
    for i in 0..n {
        let len = plo + rng.below(phi - plo + 1);
        let mut p = Vec::with_capacity(len + 2);
        p.push(BOS);
        let span = cfg.vocab - N_SPECIAL as usize;
        p.extend((0..len).map(|_| N_SPECIAL + rng.below(span) as u32));
        p.push(SEP);
        let budget = blo + rng.below(bhi - blo + 1);
        budget_sum += budget;
        requests.push(DecodeRequest::new(i as u64, p, budget));
    }

    // phase 1b: priority classes, from their own seeded stream so the
    // draws never shift the prompt/budget/arrival sequences
    if cfg.priority_classes > 1 {
        let mut prng = Rng::new(cfg.seed ^ PRIORITY_SALT);
        for r in requests.iter_mut() {
            r.priority =
                prng.below(cfg.priority_classes as usize) as u8;
        }
    }

    // phase 1c: model tags, again from their own salted stream — a
    // weighted draw over the normalized mix, so adding/removing a mix
    // never shifts prompts, budgets, priorities or arrivals
    if !cfg.model_mix.is_empty() {
        let weights: Vec<f64> =
            cfg.model_mix.iter().map(|(_, w)| *w).collect();
        let mut mrng = Rng::new(cfg.seed ^ MODEL_SALT);
        for r in requests.iter_mut() {
            let pick = mrng.weighted(&weights);
            r.model = Some(cfg.model_mix[pick].0.clone());
        }
    }

    // phase 2: the arrival process
    let mut arrivals = vec![0.0f64; n];
    let mut release: Vec<Option<(usize, f64)>> = vec![None; n];
    match cfg.pattern {
        Pattern::Poisson => {
            let mut t = 0.0f64;
            for a in arrivals.iter_mut() {
                t += exp_ms(&mut rng, cfg.rate_rps);
                *a = t;
            }
        }
        Pattern::Bursty { burst } => {
            // groups of `burst` arrive together; the gap between
            // groups is exponential with mean `burst / rate`, so the
            // long-run request rate stays `rate_rps`
            let group_rate = cfg.rate_rps / burst as f64;
            let mut t = 0.0f64;
            for g in (0..n).step_by(burst) {
                t += exp_ms(&mut rng, group_rate);
                for a in arrivals.iter_mut().skip(g).take(burst) {
                    *a = t;
                }
            }
        }
        Pattern::Closed { clients, think_ms } => {
            let k = clients.min(n);
            for (i, a) in arrivals.iter_mut().enumerate() {
                *a = if i < k { 0.0 } else { f64::INFINITY };
            }
            for i in 0..n.saturating_sub(k) {
                release[i] = Some((i + k, think_ms));
            }
        }
    }

    Ok(Trace {
        requests,
        arrivals,
        release,
        pattern: cfg.pattern,
        rate_rps: match cfg.pattern {
            Pattern::Closed { .. } => 0.0,
            _ => cfg.rate_rps,
        },
        mean_budget: budget_sum as f64 / n as f64,
    })
}

/// Exponential inter-arrival draw, milliseconds, `rate` per second.
fn exp_ms(rng: &mut Rng, rate: f64) -> f64 {
    // uniform() is in [0, 1) so 1 - u is in (0, 1] — ln never sees 0
    -(1.0 - rng.uniform()).ln() / rate * 1000.0
}

/// Virtual cost of one engine invocation, per path. Pinned values
/// (the default `1.0/1.0`) make the whole simulation deterministic —
/// latencies then measure pure queueing in step units. [`calibrate`]
/// replaces them with measured wall costs for honest-ms curves.
#[derive(Debug, Clone, Copy)]
pub struct StepCosts {
    pub step_ms: f64,
    pub prefill_ms: f64,
}

impl Default for StepCosts {
    fn default() -> StepCosts {
        StepCosts { step_ms: 1.0, prefill_ms: 1.0 }
    }
}

/// Measure an engine path's real mean step cost (wall ms) with a
/// short saturated serve — one untimed warm pass first, so PJRT lazy
/// init never pollutes the sample.
///
/// The literal path has no prefill; its `prefill_ms` echoes `step_ms`.
/// For the KV path pass the literal calibration as `full_step_ms`: a
/// prefill pass is a full-context forward (the `logits_last` graph
/// plus cache taps), so it is costed at the literal step price and the
/// residual wall time is attributed to the cheap incremental steps.
pub fn calibrate(engine: &DecodeEngine, use_kv: bool,
                 full_step_ms: Option<f64>)
                 -> anyhow::Result<StepCosts> {
    let b = engine.decode_batch();
    let vocab = engine.vocab();
    let mk = |n: usize, budget: usize| -> Vec<DecodeRequest> {
        let mut rng = Rng::new(17);
        (0..n)
            .map(|i| {
                let mut p = vec![BOS];
                p.extend((0..4).map(|_| {
                    N_SPECIAL + rng.below(vocab - N_SPECIAL as usize)
                        as u32
                }));
                p.push(SEP);
                DecodeRequest::new(i as u64, p, budget)
            })
            .collect()
    };
    let dp = DecodeParams::default();
    let run = |requests: &[DecodeRequest]| {
        if use_kv {
            serve_core::serve_kv(engine, requests, &dp)
        } else {
            serve_core::serve(engine, requests, &dp)
        }
    };
    run(&mk(b.min(2), 2))?; // warm
    let report = run(&mk(2 * b, 8))?;
    let st = &report.stats;
    anyhow::ensure!(st.engine_steps > 0, "calibration serve ran no steps");
    let wall_ms = st.wall_secs * 1e3;
    if use_kv {
        let prefill_ms = full_step_ms
            .unwrap_or(wall_ms / st.engine_steps as f64);
        let residual =
            wall_ms - st.prefill_steps as f64 * prefill_ms;
        let step_ms =
            (residual / st.engine_steps as f64).max(1e-6);
        Ok(StepCosts { step_ms, prefill_ms })
    } else {
        let step_ms = wall_ms / st.engine_steps as f64;
        Ok(StepCosts { step_ms, prefill_ms: step_ms })
    }
}

/// Saturation request rate for a batch of `decode_batch` slots at
/// `step_ms` per step and `mean_budget` tokens per request: the serve
/// loop emits at most one token per slot per step.
pub fn capacity_rps(decode_batch: usize, step_ms: f64,
                    mean_budget: f64) -> f64 {
    (decode_batch as f64 * 1000.0 / step_ms.max(1e-9))
        / mean_budget.max(1.0)
}

/// One measured point on the latency-under-load curve.
#[derive(Debug, Clone)]
pub struct LoadPoint {
    /// Registry model this point covers, or "" for a whole-stream
    /// aggregate point (every point predating the registry).
    pub model: String,
    /// "literal" | "kv".
    pub engine: String,
    pub pattern: String,
    /// Scheduling policy name ("fifo", "priority", ...).
    pub scheduler: String,
    /// Admission policy name ("unbounded", "max-queue(8)", ...).
    pub admission: String,
    /// Offered request rate (0.0 for closed loop, where rate is an
    /// outcome).
    pub offered_rps: f64,
    pub requests: usize,
    /// Outcome buckets
    /// (completed + shed + expired + failed == requests).
    pub completed: usize,
    pub shed: usize,
    pub expired: usize,
    /// Requests lost to injected faults (retry budget exhausted or
    /// lane death with no failover) — 0 without a fault plan.
    pub failed: usize,
    /// `(shed + expired) / requests` — 0.0 under unbounded admission.
    /// Fault losses are counted by `failed`, not here.
    pub shed_rate: f64,
    /// Failed step attempts recovered by retry/backoff.
    pub retries: u64,
    /// Completions that were failed over to another model.
    pub degraded: usize,
    pub generated_tokens: u64,
    /// Tokens decoded into slots that were then dropped — a failed
    /// request's partial output, or a paged preemption's rolled-back
    /// decode. Work the engine did that no caller received.
    pub lost_tokens: u64,
    pub step_ms: f64,
    pub prefill_ms: f64,
    /// Virtual duration of the simulation.
    pub sim_ms: f64,
    /// **Completions** per virtual second (sheds don't count).
    pub achieved_rps: f64,
    /// Raw engine throughput: every token decoded per virtual second,
    /// dropped work included (`generated + lost`).
    pub tokens_per_vsec: f64,
    /// Tokens delivered to completed requests per virtual second —
    /// the goodput a caller-facing SLO cares about. Strictly below
    /// `tokens_per_vsec` whenever faults or preemptions drop partial
    /// output.
    pub goodput_tokens_per_sec: f64,
    /// Accepted drafts / drafted tokens across the point's verifier
    /// traffic — 0.0 outside speculative runs (see
    /// [`crate::generate::ServeStats::acceptance_rate`]).
    pub acceptance_rate: f64,
    pub occupancy: f64,
    pub queue_ms: Summary,
    pub ttft_ms: Summary,
    pub latency_ms: Summary,
    /// Real host time the simulation took (the model steps are real).
    pub wall_secs: f64,
}

impl LoadPoint {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.push_str("model", &self.model)
            .push_str("engine", &self.engine)
            .push_str("pattern", &self.pattern)
            .push_str("scheduler", &self.scheduler)
            .push_str("admission", &self.admission)
            .push_num("offered_rps", self.offered_rps)
            .push_num("requests", self.requests)
            .push_num("completed", self.completed)
            .push_num("shed", self.shed)
            .push_num("expired", self.expired)
            .push_num("failed", self.failed)
            .push_num("shed_rate", self.shed_rate)
            .push_num("retries", self.retries)
            .push_num("degraded", self.degraded)
            .push_num("generated_tokens", self.generated_tokens)
            .push_num("lost_tokens", self.lost_tokens)
            .push_num("step_ms", self.step_ms)
            .push_num("prefill_ms", self.prefill_ms)
            .push_num("sim_ms", self.sim_ms)
            .push_num("achieved_rps", self.achieved_rps)
            .push_num("tokens_per_vsec", self.tokens_per_vsec)
            .push_num("goodput_tokens_per_sec",
                      self.goodput_tokens_per_sec)
            .push_num("acceptance_rate", self.acceptance_rate)
            .push_num("occupancy", self.occupancy)
            .push("queue_ms", self.queue_ms.to_json())
            .push("ttft_ms", self.ttft_ms.to_json())
            .push("latency_ms", self.latency_ms.to_json())
            .push_num("wall_secs", self.wall_secs);
        j
    }
}

/// Drive one trace through the timed serve loop on the chosen path
/// with the default policies (FIFO, unbounded) and fold the report
/// into a [`LoadPoint`]. Deterministic for a given trace + costs (the
/// decoded tokens are real; only time is simulated).
pub fn run_trace(engine: &DecodeEngine, trace: &Trace,
                 dp: &DecodeParams, use_kv: bool, costs: &StepCosts)
                 -> anyhow::Result<(LoadPoint, ServeReport)> {
    run_trace_with(engine, trace, dp, use_kv, costs, &Fifo,
                   &Unbounded, &ChaosConfig::default(), None)
}

/// [`run_trace`] under explicit scheduling + admission policies and
/// an optional fault/recovery plan — the shedding/goodput measurement
/// driver (`chaos` = `ChaosConfig::default()` injects nothing and is
/// bit-identical to the pre-fault loop).
#[allow(clippy::too_many_arguments)]
pub fn run_trace_with(
    engine: &DecodeEngine,
    trace: &Trace,
    dp: &DecodeParams,
    use_kv: bool,
    costs: &StepCosts,
    scheduler: &dyn Scheduler,
    admission: &dyn AdmissionPolicy,
    chaos: &ChaosConfig,
    paged: Option<&PagedKvConfig>,
) -> anyhow::Result<(LoadPoint, ServeReport)> {
    let schedule = trace.schedule(costs);
    let report = serve_core::serve_with(
        engine, &trace.requests, dp,
        &ServeConfig {
            use_kv,
            schedule: Some(&schedule),
            scheduler,
            admission,
            recovery: chaos.recovery.clone(),
            faults: chaos.faults.clone(),
            fallback: chaos.fallback.clone(),
            speculate: None,
            paged: paged.cloned(),
        })?;
    let point = point_from_stats("", &report.stats, trace.rate_rps,
                                 trace, use_kv, costs, scheduler,
                                 admission);
    Ok((point, report))
}

/// Fold one [`ServeStats`] block (aggregate or per-model) into a
/// [`LoadPoint`]. `offered_rps` is the share of the trace's offered
/// rate this block covers.
#[allow(clippy::too_many_arguments)]
fn point_from_stats(
    model: &str,
    st: &ServeStats,
    offered_rps: f64,
    trace: &Trace,
    use_kv: bool,
    costs: &StepCosts,
    scheduler: &dyn Scheduler,
    admission: &dyn AdmissionPolicy,
) -> LoadPoint {
    let sim_secs = (st.sim_ms / 1e3).max(1e-9);
    LoadPoint {
        model: model.into(),
        engine: if use_kv { "kv" } else { "literal" }.into(),
        pattern: trace.pattern.name().into(),
        scheduler: scheduler.name().into(),
        admission: admission.name(),
        offered_rps,
        requests: st.requests,
        completed: st.completed,
        shed: st.shed,
        expired: st.expired,
        failed: st.failed,
        shed_rate: st.shed_rate,
        retries: st.retries,
        degraded: st.degraded,
        generated_tokens: st.generated_tokens,
        lost_tokens: st.lost_tokens,
        step_ms: costs.step_ms,
        prefill_ms: costs.prefill_ms,
        sim_ms: st.sim_ms,
        achieved_rps: st.completed as f64 / sim_secs,
        tokens_per_vsec: (st.generated_tokens + st.lost_tokens) as f64
            / sim_secs,
        goodput_tokens_per_sec: st.generated_tokens as f64 / sim_secs,
        acceptance_rate: st.acceptance_rate,
        occupancy: st.occupancy,
        queue_ms: st.queue_ms.clone(),
        ttft_ms: st.ttft_ms.clone(),
        latency_ms: st.latency_ms.clone(),
        wall_secs: st.wall_secs,
    }
}

/// [`run_trace_with`] across a [`ModelRegistry`]: the trace's
/// model-mix tags route each request to its registered engine, and
/// the returned points are the whole-stream aggregate followed by one
/// per-model point per registered model (the per-model `LoadPoint`
/// counters sum to the aggregate's; the shared virtual clock is the
/// common denominator). `speculate` serves the verifier model's
/// requests draft-then-verify (`spdf loadgen --speculate
/// DRAFT=VERIFIER:k`); `None` is plain registry serving.
/// Deterministic for a given trace + costs.
#[allow(clippy::too_many_arguments)]
pub fn run_trace_registry(
    registry: &ModelRegistry,
    trace: &Trace,
    dp: &DecodeParams,
    use_kv: bool,
    costs: &StepCosts,
    scheduler: &dyn Scheduler,
    admission: &dyn AdmissionPolicy,
    chaos: &ChaosConfig,
    speculate: Option<&SpecConfig>,
    paged: Option<&PagedKvConfig>,
) -> anyhow::Result<(LoadPoint, Vec<LoadPoint>, ServeReport)> {
    let schedule = trace.schedule(costs);
    let report = registry.serve_with(
        &trace.requests, dp,
        &ServeConfig {
            use_kv,
            schedule: Some(&schedule),
            scheduler,
            admission,
            recovery: chaos.recovery.clone(),
            faults: chaos.faults.clone(),
            fallback: chaos.fallback.clone(),
            speculate: speculate.cloned(),
            paged: paged.cloned(),
        })?;
    let total = trace.requests.len().max(1);
    let aggregate = point_from_stats("", &report.stats,
                                     trace.rate_rps, trace, use_kv,
                                     costs, scheduler, admission);
    let per_model: Vec<LoadPoint> = report
        .per_model
        .iter()
        .map(|m| {
            // the model's share of the offered load (closed-loop
            // traces report 0.0 overall, hence 0.0 per model too)
            let offered = trace.rate_rps * m.stats.requests as f64
                / total as f64;
            point_from_stats(&m.model, &m.stats, offered, trace,
                             use_kv, costs, scheduler, admission)
        })
        .collect();
    Ok((aggregate, per_model, report))
}

/// Offered-load sweep: one point per (rate, engine path), all points
/// at one rate sharing the exact same trace. `engines` holds
/// `use_kv` flags with their step costs. Default policies.
pub fn sweep(engine: &DecodeEngine, base: &TraceConfig,
             rates: &[f64], engines: &[(bool, StepCosts)],
             dp: &DecodeParams) -> anyhow::Result<Vec<LoadPoint>> {
    sweep_with(engine, base, rates, engines, dp, &Fifo, &Unbounded,
               &ChaosConfig::default(), None)
}

/// [`sweep`] under explicit scheduling + admission policies and an
/// optional fault/recovery plan (`spdf loadgen
/// --policy/--max-queue/--queue-deadline-ms/--fault-*`).
#[allow(clippy::too_many_arguments)]
pub fn sweep_with(
    engine: &DecodeEngine,
    base: &TraceConfig,
    rates: &[f64],
    engines: &[(bool, StepCosts)],
    dp: &DecodeParams,
    scheduler: &dyn Scheduler,
    admission: &dyn AdmissionPolicy,
    chaos: &ChaosConfig,
    paged: Option<&PagedKvConfig>,
) -> anyhow::Result<Vec<LoadPoint>> {
    let mut points = Vec::new();
    for &rate in rates {
        let cfg = TraceConfig { rate_rps: rate, ..base.clone() };
        let trace = generate_trace(&cfg)?;
        for (use_kv, costs) in engines {
            let (point, _) = run_trace_with(engine, &trace, dp,
                                            *use_kv, costs, scheduler,
                                            admission, chaos,
                                            paged)?;
            points.push(point);
        }
    }
    Ok(points)
}

/// [`sweep_with`] across a [`ModelRegistry`]: per (rate, engine
/// path), the aggregate point followed by the per-model points (see
/// [`run_trace_registry`]). All points at one rate share the exact
/// same trace, mix tags included. `speculate` applies to every point.
#[allow(clippy::too_many_arguments)]
pub fn sweep_registry(
    registry: &ModelRegistry,
    base: &TraceConfig,
    rates: &[f64],
    engines: &[(bool, StepCosts)],
    dp: &DecodeParams,
    scheduler: &dyn Scheduler,
    admission: &dyn AdmissionPolicy,
    chaos: &ChaosConfig,
    speculate: Option<&SpecConfig>,
    paged: Option<&PagedKvConfig>,
) -> anyhow::Result<Vec<LoadPoint>> {
    let mut points = Vec::new();
    for &rate in rates {
        let cfg = TraceConfig { rate_rps: rate, ..base.clone() };
        let trace = generate_trace(&cfg)?;
        for (use_kv, costs) in engines {
            let (aggregate, per_model, _) = run_trace_registry(
                registry, &trace, dp, *use_kv, costs, scheduler,
                admission, chaos, speculate, paged)?;
            points.push(aggregate);
            points.extend(per_model);
        }
    }
    Ok(points)
}

/// JSON array of sweep points (`BENCH_serve_load.json` / `--out`).
pub fn points_json(points: &[LoadPoint]) -> Json {
    Json::Arr(points.iter().map(|p| p.to_json()).collect())
}

#[cfg(test)]
mod tests {
    use super::super::serve::core::mock::MockBackend;
    use super::super::serve::core::run_loop;
    use super::*;

    fn cfg(pattern: Pattern, rate: f64) -> TraceConfig {
        TraceConfig {
            seed: 42,
            requests: 40,
            rate_rps: rate,
            pattern,
            prompt_lens: (3, 6),
            budgets: (2, 5),
            vocab: 16,
            priority_classes: 1,
            model_mix: Vec::new(),
        }
    }

    #[test]
    fn trace_is_seed_deterministic() {
        let c = cfg(Pattern::Poisson, 50.0);
        let (a, b) = (generate_trace(&c).unwrap(),
                      generate_trace(&c).unwrap());
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
        assert_eq!(a.arrivals, b.arrivals);
        let c2 = TraceConfig { seed: 43, ..c };
        let d = generate_trace(&c2).unwrap();
        assert_ne!(a.arrivals, d.arrivals);
    }

    #[test]
    fn work_items_independent_of_rate_and_pattern() {
        // a load sweep must vary only the arrival process
        let a = generate_trace(&cfg(Pattern::Poisson, 10.0)).unwrap();
        let b = generate_trace(&cfg(Pattern::Poisson, 500.0)).unwrap();
        let c = generate_trace(&cfg(Pattern::Bursty { burst: 4 },
                                    10.0)).unwrap();
        for ((x, y), z) in a.requests.iter().zip(&b.requests)
            .zip(&c.requests)
        {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.prompt, z.prompt);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
        assert_ne!(a.arrivals, b.arrivals);
    }

    #[test]
    fn poisson_arrivals_sorted_with_plausible_mean() {
        let c = TraceConfig { requests: 4000,
                              ..cfg(Pattern::Poisson, 100.0) };
        let t = generate_trace(&c).unwrap();
        assert!(t.arrivals.windows(2).all(|w| w[0] <= w[1]));
        // mean inter-arrival should be near 1000/rate = 10ms
        let mean = t.arrivals.last().unwrap() / 4000.0;
        assert!((mean - 10.0).abs() < 1.5, "mean gap {mean}");
        assert!(t.release.iter().all(|r| r.is_none()));
    }

    #[test]
    fn bursty_groups_share_arrival_instants() {
        let c = TraceConfig { requests: 32,
                              ..cfg(Pattern::Bursty { burst: 4 },
                                    80.0) };
        let t = generate_trace(&c).unwrap();
        for g in (0..32).step_by(4) {
            for i in g..g + 4 {
                assert_eq!(t.arrivals[i], t.arrivals[g]);
            }
        }
        // distinct groups at distinct instants
        assert!(t.arrivals[0] < t.arrivals[4]);
    }

    #[test]
    fn closed_loop_chains_clients() {
        let c = TraceConfig {
            requests: 7,
            ..cfg(Pattern::Closed { clients: 3, think_ms: 2.0 }, 0.0)
        };
        let t = generate_trace(&c).unwrap();
        assert_eq!(&t.arrivals[..3], &[0.0, 0.0, 0.0]);
        assert!(t.arrivals[3..].iter().all(|a| a.is_infinite()));
        assert_eq!(t.release[0], Some((3, 2.0)));
        assert_eq!(t.release[3], Some((6, 2.0)));
        assert_eq!(t.release[4], None);
        assert_eq!(t.rate_rps, 0.0);
    }

    #[test]
    fn trace_through_mock_serve_is_deterministic() {
        // the satellite guarantee: one seed → identical trace AND
        // identical ServeStats, end to end through the serve loop
        let c = TraceConfig { requests: 12,
                              ..cfg(Pattern::Poisson, 400.0) };
        let run = || {
            let trace = generate_trace(&c).unwrap();
            let sched = trace.schedule(&StepCosts::default());
            let mut be = MockBackend::new(2, 16, false);
            run_loop(&mut be, &trace.requests,
                     &DecodeParams::default(), Some(&sched)).unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.stats.engine_steps, b.stats.engine_steps);
        assert_eq!(a.stats.sim_ms, b.stats.sim_ms);
        assert_eq!(a.stats.latency_ms, b.stats.latency_ms);
        assert_eq!(a.stats.ttft_ms, b.stats.ttft_ms);
        assert_eq!(a.stats.queue_ms, b.stats.queue_ms);
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.latency_ms, y.latency_ms);
        }
        // and the latency percentiles are populated
        assert!(a.stats.latency_ms.p95 >= a.stats.latency_ms.p50);
        assert!(a.stats.latency_ms.p99 >= a.stats.latency_ms.p95);
    }

    #[test]
    fn closed_loop_trace_runs_through_mock_serve() {
        let c = TraceConfig {
            requests: 9,
            ..cfg(Pattern::Closed { clients: 2, think_ms: 1.5 }, 0.0)
        };
        let trace = generate_trace(&c).unwrap();
        let sched = trace.schedule(&StepCosts::default());
        let mut be = MockBackend::new(2, 16, false);
        let report = run_loop(&mut be, &trace.requests,
                              &DecodeParams::default(), Some(&sched))
            .unwrap();
        assert_eq!(report.results.len(), 9);
        // closed loop: a successor arrives only after its
        // predecessor completes (+ think), and with in-flight ≤
        // clients ≤ slots it waits at most one step of admission
        // quantization, never a real queue
        let r3 = &report.results[3];
        let r1 = &report.results[1];
        assert!(r3.arrival_ms >= r1.arrival_ms + r1.latency_ms,
                "successor arrived before predecessor finished");
        assert!(r3.queue_ms < sched.step_ms + 1e-9,
                "closed loop queued for {} ms", r3.queue_ms);
    }

    #[test]
    fn capacity_rps_scales() {
        // 16 slots, 1ms steps → 16k tokens/s; 32-token requests →
        // 500 rps
        assert!((capacity_rps(16, 1.0, 32.0) - 500.0).abs() < 1e-9);
        // slower steps halve it
        assert!((capacity_rps(16, 2.0, 32.0) - 250.0).abs() < 1e-9);
    }

    #[test]
    fn generate_trace_rejects_bad_configs() {
        assert!(generate_trace(&TraceConfig {
            requests: 0, ..cfg(Pattern::Poisson, 10.0)
        }).is_err());
        assert!(generate_trace(&TraceConfig {
            rate_rps: 0.0, ..cfg(Pattern::Poisson, 0.0)
        }).is_err());
        assert!(generate_trace(&TraceConfig {
            prompt_lens: (5, 3), ..cfg(Pattern::Poisson, 10.0)
        }).is_err());
        // degenerate patterns error instead of panicking (step_by 0)
        // or producing self-release chains
        assert!(generate_trace(&cfg(Pattern::Bursty { burst: 0 },
                                    10.0)).is_err());
        assert!(generate_trace(&cfg(
            Pattern::Closed { clients: 0, think_ms: 0.0 }, 0.0
        )).is_err());
        // closed loop ignores the rate entirely
        assert!(generate_trace(&cfg(
            Pattern::Closed { clients: 2, think_ms: 0.0 }, 0.0
        )).is_ok());
    }

    #[test]
    fn load_point_json_round_trips_percentiles() {
        let p = LoadPoint {
            model: "s75".into(),
            engine: "kv".into(),
            pattern: "poisson".into(),
            scheduler: "fifo".into(),
            admission: "max-queue(8)".into(),
            offered_rps: 120.0,
            requests: 64,
            completed: 58,
            shed: 3,
            expired: 1,
            failed: 2,
            shed_rate: 4.0 / 64.0,
            retries: 7,
            degraded: 5,
            generated_tokens: 900,
            lost_tokens: 25,
            step_ms: 0.8,
            prefill_ms: 2.0,
            sim_ms: 700.0,
            achieved_rps: 91.4,
            tokens_per_vsec: 1321.4,
            goodput_tokens_per_sec: 1285.7,
            acceptance_rate: 0.75,
            occupancy: 0.93,
            queue_ms: Summary::zero(),
            ttft_ms: Summary::zero(),
            latency_ms: crate::util::stats::summarize(
                &[10.0, 20.0, 80.0]),
            wall_secs: 1.25,
        };
        let j = p.to_json();
        assert_eq!(j.get("model").unwrap().as_str(), Some("s75"));
        assert_eq!(j.get("engine").unwrap().as_str(), Some("kv"));
        assert_eq!(j.get("scheduler").unwrap().as_str(), Some("fifo"));
        assert_eq!(j.get("admission").unwrap().as_str(),
                   Some("max-queue(8)"));
        assert_eq!(j.get("offered_rps").unwrap().as_f64(),
                   Some(120.0));
        assert_eq!(j.get("completed").unwrap().as_usize(), Some(58));
        assert_eq!(j.get("shed").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("expired").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("failed").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("shed_rate").unwrap().as_f64(),
                   Some(4.0 / 64.0));
        assert_eq!(j.get("retries").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("degraded").unwrap().as_usize(), Some(5));
        assert_eq!(j.get("lost_tokens").unwrap().as_usize(), Some(25));
        assert_eq!(j.get("tokens_per_vsec").unwrap().as_f64(),
                   Some(1321.4));
        assert_eq!(j.get("goodput_tokens_per_sec").unwrap().as_f64(),
                   Some(1285.7));
        assert_eq!(j.get("acceptance_rate").unwrap().as_f64(),
                   Some(0.75));
        assert_eq!(j.get("latency_ms").unwrap().get("p50")
                       .unwrap().as_f64(),
                   Some(20.0));
    }

    #[test]
    fn priority_classes_are_deterministic_and_isolated() {
        // priorities come from their own stream: enabling them must
        // not perturb prompts, budgets or arrivals
        let base = cfg(Pattern::Poisson, 50.0);
        let plain = generate_trace(&base).unwrap();
        assert!(plain.requests.iter().all(|r| r.priority == 0));
        let with = TraceConfig { priority_classes: 3, ..base.clone() };
        let (a, b) = (generate_trace(&with).unwrap(),
                      generate_trace(&with).unwrap());
        for ((x, y), z) in a.requests.iter().zip(&b.requests)
            .zip(&plain.requests)
        {
            assert_eq!(x.priority, y.priority);
            assert!(x.priority < 3);
            assert_eq!(x.prompt, z.prompt);
            assert_eq!(x.max_new_tokens, z.max_new_tokens);
        }
        assert_eq!(a.arrivals, plain.arrivals);
        // more than one class actually drawn
        assert!(a.requests.iter().any(|r| r.priority > 0));
        // zero classes rejected
        assert!(generate_trace(&TraceConfig {
            priority_classes: 0, ..base
        }).is_err());
    }

    #[test]
    fn model_mix_is_deterministic_and_isolated() {
        // model tags come from their own salted stream: enabling a
        // mix must not perturb prompts, budgets, priorities or
        // arrivals, and an empty mix leaves requests untagged
        let base = cfg(Pattern::Poisson, 50.0);
        let plain = generate_trace(&base).unwrap();
        assert!(plain.requests.iter().all(|r| r.model.is_none()));
        let mixed = TraceConfig {
            model_mix: vec![("dense".into(), 0.5),
                            ("s75".into(), 0.5)],
            priority_classes: 3,
            ..base.clone()
        };
        let (a, b) = (generate_trace(&mixed).unwrap(),
                      generate_trace(&mixed).unwrap());
        for ((x, y), z) in a.requests.iter().zip(&b.requests)
            .zip(&plain.requests)
        {
            assert_eq!(x.model, y.model);
            assert!(matches!(x.model.as_deref(),
                             Some("dense") | Some("s75")));
            assert_eq!(x.prompt, z.prompt);
            assert_eq!(x.max_new_tokens, z.max_new_tokens);
        }
        assert_eq!(a.arrivals, plain.arrivals);
        // both models actually drawn at 50/50 over 40 requests
        assert!(a.requests.iter()
                    .any(|r| r.model.as_deref() == Some("dense")));
        assert!(a.requests.iter()
                    .any(|r| r.model.as_deref() == Some("s75")));
        // priorities drawn independently of the mix
        let prio_only = TraceConfig { priority_classes: 3,
                                      ..base.clone() };
        let p = generate_trace(&prio_only).unwrap();
        for (x, y) in a.requests.iter().zip(&p.requests) {
            assert_eq!(x.priority, y.priority);
        }
    }

    #[test]
    fn model_mix_weights_skew_the_draw() {
        let c = TraceConfig {
            requests: 400,
            model_mix: vec![("heavy".into(), 9.0),
                            ("light".into(), 1.0)],
            ..cfg(Pattern::Poisson, 50.0)
        };
        let t = generate_trace(&c).unwrap();
        let heavy = t.requests.iter()
            .filter(|r| r.model.as_deref() == Some("heavy"))
            .count();
        // 90% expected; demand a loose majority band
        assert!(heavy > 300 && heavy < 400, "heavy drew {heavy}/400");
    }

    #[test]
    fn model_mix_rejects_bad_entries() {
        let base = cfg(Pattern::Poisson, 10.0);
        for mix in [
            vec![(String::new(), 1.0)],
            vec![("m".into(), 0.0)],
            vec![("m".into(), -1.0)],
            vec![("m".into(), f64::NAN)],
            vec![("m".into(), 1.0), ("m".into(), 2.0)],
        ] {
            assert!(generate_trace(&TraceConfig {
                model_mix: mix.clone(), ..base.clone()
            }).is_err(), "mix {mix:?} should be rejected");
        }
    }

    #[test]
    fn bounded_admission_through_mock_serve_sheds_and_keeps_goodput() {
        // trace + policies end to end at the mock level: overload one
        // slot hard, bound the queue, and the outcome buckets must
        // partition the trace deterministically
        use super::super::serve::admission::MaxQueueDepth;
        use super::super::serve::core::run_loop_with;
        use super::super::serve::policy::Fifo as FifoPolicy;
        let c = TraceConfig { requests: 12,
                              ..cfg(Pattern::Bursty { burst: 12 },
                                    400.0) };
        let trace = generate_trace(&c).unwrap();
        let sched = trace.schedule(&StepCosts::default());
        let run = || {
            let mut be = MockBackend::new(1, 16, false);
            run_loop_with(&mut be, &trace.requests,
                          &DecodeParams::default(), Some(&sched),
                          &FifoPolicy, &MaxQueueDepth(2))
                .unwrap()
        };
        let (a, b) = (run(), run());
        let st = &a.stats;
        // 1 seated + 2 queued admitted; the other 9 shed at arrival
        assert_eq!((st.completed, st.shed, st.expired), (3, 9, 0));
        assert!((st.shed_rate - 0.75).abs() < 1e-12);
        assert_eq!(st.latency_ms.n, 3);
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.outcome, y.outcome);
            assert_eq!(x.latency_ms, y.latency_ms);
        }
    }
}
