//! Admission control: whether an arriving request joins the queue, is
//! shed on the spot, or later expires waiting — the lever that turns
//! the loadgen knee from an observation into a controlled operating
//! point (past saturation, an open-loop queue grows without bound; a
//! bounded queue trades a nonzero shed rate for a bounded p95).
//!
//! The serve loop consults the policy at two points:
//!
//!  * **arrival** — [`AdmissionPolicy::admit`] sees how many requests
//!    are already *waiting* (excluding those about to seat in a free
//!    slot, so a cold server never sheds below its own batch size)
//!    and decides enqueue vs [`shed`](super::RequestOutcome::Shed);
//!  * **while queued** — a request whose wait exceeds
//!    [`AdmissionPolicy::deadline_ms`] is
//!    [`expired`](super::RequestOutcome::Expired) at
//!    `arrival + deadline` on the serve clock (virtual under a
//!    schedule, wall otherwise) — the instant the caller gave up.
//!
//! [`Unbounded`] is the default and reproduces the pre-split behavior
//! bit-for-bit (nothing is ever shed or expired).

/// Decide the fate of arriving and waiting requests.
///
/// ```
/// use spdf::generate::serve::admission::{AdmissionPolicy,
///                                        MaxQueueDepth, Unbounded};
///
/// assert!(Unbounded.admit(1_000_000));
/// assert_eq!(Unbounded.deadline_ms(), None);
///
/// let bounded = MaxQueueDepth(2);
/// assert!(bounded.admit(1)); // queue has room
/// assert!(!bounded.admit(2)); // full — this arrival is shed
/// ```
pub trait AdmissionPolicy {
    /// Flag/report name ("unbounded", "max-queue(8)", ...).
    fn name(&self) -> String;

    /// May a request that would have to wait behind `waiting` queued
    /// requests join the queue? (`waiting` excludes requests that
    /// will seat immediately in a free slot.)
    fn admit(&self, waiting: usize) -> bool {
        let _ = waiting;
        true
    }

    /// Shed a queued request once its wait exceeds this many (serve-
    /// clock) ms. `None` = requests wait forever.
    fn deadline_ms(&self) -> Option<f64> {
        None
    }
}

/// Everything is admitted and waits forever — the pre-split behavior.
pub struct Unbounded;

impl AdmissionPolicy for Unbounded {
    fn name(&self) -> String {
        "unbounded".into()
    }
}

/// At most this many requests may wait; later arrivals are shed.
pub struct MaxQueueDepth(pub usize);

impl AdmissionPolicy for MaxQueueDepth {
    fn name(&self) -> String {
        format!("max-queue({})", self.0)
    }

    fn admit(&self, waiting: usize) -> bool {
        waiting < self.0
    }
}

/// Queued requests give up after waiting this many ms.
pub struct QueueDeadline(pub f64);

impl AdmissionPolicy for QueueDeadline {
    fn name(&self) -> String {
        format!("deadline({}ms)", self.0)
    }

    fn deadline_ms(&self) -> Option<f64> {
        Some(self.0)
    }
}

/// Both knobs at once — what `--max-queue` + `--queue-deadline-ms`
/// build when the operator sets the two together.
pub struct Bounded {
    pub max_queue: usize,
    pub deadline_ms: f64,
}

impl AdmissionPolicy for Bounded {
    fn name(&self) -> String {
        format!("max-queue({})+deadline({}ms)", self.max_queue,
                self.deadline_ms)
    }

    fn admit(&self, waiting: usize) -> bool {
        waiting < self.max_queue
    }

    fn deadline_ms(&self) -> Option<f64> {
        Some(self.deadline_ms)
    }
}

/// Build the policy the CLI flags describe. `max_queue == 0` and
/// `deadline_ms <= 0.0` each mean "unlimited" (the flag defaults), so
/// plain `spdf serve`/`spdf loadgen` stay on [`Unbounded`].
pub fn from_flags(max_queue: usize, deadline_ms: f64)
                  -> anyhow::Result<Box<dyn AdmissionPolicy>> {
    anyhow::ensure!(deadline_ms.is_finite(),
                    "--queue-deadline-ms must be finite");
    let deadline = (deadline_ms > 0.0).then_some(deadline_ms);
    Ok(match (max_queue, deadline) {
        (0, None) => Box::new(Unbounded),
        (n, None) => Box::new(MaxQueueDepth(n)),
        (0, Some(d)) => Box::new(QueueDeadline(d)),
        (n, Some(d)) => {
            Box::new(Bounded { max_queue: n, deadline_ms: d })
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbounded_admits_everything_forever() {
        assert!(Unbounded.admit(0));
        assert!(Unbounded.admit(1_000_000));
        assert_eq!(Unbounded.deadline_ms(), None);
        assert_eq!(Unbounded.name(), "unbounded");
    }

    #[test]
    fn max_queue_depth_bounds_waiters() {
        let p = MaxQueueDepth(2);
        assert!(p.admit(0));
        assert!(p.admit(1));
        assert!(!p.admit(2));
        assert_eq!(p.deadline_ms(), None);
        assert_eq!(p.name(), "max-queue(2)");
        // depth 0: nothing may wait (immediate dispatch only)
        assert!(!MaxQueueDepth(0).admit(0));
    }

    #[test]
    fn queue_deadline_sets_expiry_only() {
        let p = QueueDeadline(250.0);
        assert!(p.admit(usize::MAX));
        assert_eq!(p.deadline_ms(), Some(250.0));
        assert_eq!(p.name(), "deadline(250ms)");
    }

    #[test]
    fn bounded_combines_both_knobs() {
        let p = Bounded { max_queue: 3, deadline_ms: 100.0 };
        assert!(p.admit(2));
        assert!(!p.admit(3));
        assert_eq!(p.deadline_ms(), Some(100.0));
        assert_eq!(p.name(), "max-queue(3)+deadline(100ms)");
    }

    #[test]
    fn from_flags_maps_zero_sentinels_to_unbounded() {
        assert_eq!(from_flags(0, 0.0).unwrap().name(), "unbounded");
        assert_eq!(from_flags(4, 0.0).unwrap().name(), "max-queue(4)");
        assert_eq!(from_flags(0, 50.0).unwrap().name(),
                   "deadline(50ms)");
        assert_eq!(from_flags(4, 50.0).unwrap().name(),
                   "max-queue(4)+deadline(50ms)");
        assert!(from_flags(1, f64::NAN).is_err());
        assert!(from_flags(1, f64::INFINITY).is_err());
        // negative deadline is treated as unset, like the 0 default
        assert_eq!(from_flags(0, -1.0).unwrap().name(), "unbounded");
    }
}
