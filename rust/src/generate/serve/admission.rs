//! Admission control: whether an arriving request joins the queue, is
//! shed on the spot, or later expires waiting — the lever that turns
//! the loadgen knee from an observation into a controlled operating
//! point (past saturation, an open-loop queue grows without bound; a
//! bounded queue trades a nonzero shed rate for a bounded p95).
//!
//! The serve loop consults the policy at three points:
//!
//!  * **arrival** — [`AdmissionPolicy::admit`] sees how many requests
//!    are already *waiting* (excluding those about to seat in a free
//!    slot, so a cold server never sheds below its own batch size)
//!    and decides enqueue vs [`shed`](super::RequestOutcome::Shed);
//!  * **arrival, memory-aware** — under paged KV
//!    ([`super::pages`]), [`AdmissionPolicy::admit_pages`] also sees
//!    the pages the request's prompt needs against the pages free on
//!    its lane's allocator. The default accepts (queue-depth and
//!    deadline policies are memory-oblivious); [`PagePressure`] sheds
//!    the request when its prompt's pages don't exist right now;
//!  * **while queued** — a request whose wait exceeds
//!    [`AdmissionPolicy::deadline_ms`] is
//!    [`expired`](super::RequestOutcome::Expired) at
//!    `arrival + deadline` on the serve clock (virtual under a
//!    schedule, wall otherwise) — the instant the caller gave up.
//!
//! [`Unbounded`] is the default and reproduces the pre-split behavior
//! bit-for-bit (nothing is ever shed or expired).

/// Decide the fate of arriving and waiting requests.
///
/// ```
/// use spdf::generate::serve::admission::{AdmissionPolicy,
///                                        MaxQueueDepth, Unbounded};
///
/// assert!(Unbounded.admit(1_000_000));
/// assert_eq!(Unbounded.deadline_ms(), None);
///
/// let bounded = MaxQueueDepth(2);
/// assert!(bounded.admit(1)); // queue has room
/// assert!(!bounded.admit(2)); // full — this arrival is shed
/// ```
pub trait AdmissionPolicy {
    /// Flag/report name ("unbounded", "max-queue(8)", ...).
    fn name(&self) -> String;

    /// May a request that would have to wait behind `waiting` queued
    /// requests join the queue? (`waiting` excludes requests that
    /// will seat immediately in a free slot.)
    fn admit(&self, waiting: usize) -> bool {
        let _ = waiting;
        true
    }

    /// Shed a queued request once its wait exceeds this many (serve-
    /// clock) ms. `None` = requests wait forever.
    fn deadline_ms(&self) -> Option<f64> {
        None
    }

    /// Memory-aware axis, consulted at arrival only by the paged
    /// serving loop: may a request whose prompt needs `needed` pages
    /// be admitted when `free` pages are free on its lane's
    /// allocator? The default accepts — the request waits in the
    /// queue for pages like it waits for a slot. [`PagePressure`]
    /// declines (`needed > free` → shed), turning a page-budget
    /// overload into bounded shedding instead of unbounded queueing.
    /// Non-paged serving never calls this.
    fn admit_pages(&self, needed: usize, free: usize) -> bool {
        let _ = (needed, free);
        true
    }
}

/// Everything is admitted and waits forever — the pre-split behavior.
pub struct Unbounded;

impl AdmissionPolicy for Unbounded {
    fn name(&self) -> String {
        "unbounded".into()
    }
}

/// At most this many requests may wait; later arrivals are shed.
pub struct MaxQueueDepth(pub usize);

impl AdmissionPolicy for MaxQueueDepth {
    fn name(&self) -> String {
        format!("max-queue({})", self.0)
    }

    fn admit(&self, waiting: usize) -> bool {
        waiting < self.0
    }
}

/// Queued requests give up after waiting this many ms.
pub struct QueueDeadline(pub f64);

impl AdmissionPolicy for QueueDeadline {
    fn name(&self) -> String {
        format!("deadline({}ms)", self.0)
    }

    fn deadline_ms(&self) -> Option<f64> {
        Some(self.0)
    }
}

/// Both knobs at once — what `--max-queue` + `--queue-deadline-ms`
/// build when the operator sets the two together.
pub struct Bounded {
    pub max_queue: usize,
    pub deadline_ms: f64,
}

impl AdmissionPolicy for Bounded {
    fn name(&self) -> String {
        format!("max-queue({})+deadline({}ms)", self.max_queue,
                self.deadline_ms)
    }

    fn admit(&self, waiting: usize) -> bool {
        waiting < self.max_queue
    }

    fn deadline_ms(&self) -> Option<f64> {
        Some(self.deadline_ms)
    }
}

/// Memory-aware admission for paged KV serving: a request is
/// admittable iff the pages its prompt needs are free on its lane's
/// allocator *right now* — otherwise it is shed at arrival (counted
/// as a page shed, [`super::pages::PageCounters::page_sheds`]).
/// Wraps any inner policy, whose queue-depth/deadline decisions still
/// apply; [`PagePressure::new`] wraps [`Unbounded`].
///
/// ```
/// use spdf::generate::serve::admission::{AdmissionPolicy,
///                                        MaxQueueDepth,
///                                        PagePressure};
///
/// let p = PagePressure::new();
/// assert!(p.admit_pages(2, 2)); // prompt's pages exist
/// assert!(!p.admit_pages(3, 2)); // dry allocator — shed
///
/// let p = PagePressure::wrapping(Box::new(MaxQueueDepth(2)));
/// assert!(!p.admit(2)); // inner queue bound still sheds
/// assert_eq!(p.name(), "max-queue(2)+page-pressure");
/// ```
pub struct PagePressure {
    inner: Box<dyn AdmissionPolicy>,
}

impl PagePressure {
    /// Page pressure over unbounded queueing: only memory sheds.
    pub fn new() -> PagePressure {
        PagePressure { inner: Box::new(Unbounded) }
    }

    /// Page pressure stacked on `inner`'s queue-depth/deadline
    /// decisions.
    pub fn wrapping(inner: Box<dyn AdmissionPolicy>) -> PagePressure {
        PagePressure { inner }
    }
}

impl Default for PagePressure {
    fn default() -> PagePressure {
        PagePressure::new()
    }
}

impl AdmissionPolicy for PagePressure {
    fn name(&self) -> String {
        let inner = self.inner.name();
        if inner == "unbounded" {
            "page-pressure".into()
        } else {
            format!("{inner}+page-pressure")
        }
    }

    fn admit(&self, waiting: usize) -> bool {
        self.inner.admit(waiting)
    }

    fn deadline_ms(&self) -> Option<f64> {
        self.inner.deadline_ms()
    }

    fn admit_pages(&self, needed: usize, free: usize) -> bool {
        needed <= free
    }
}

/// Build the policy the CLI flags describe. `max_queue == 0` and
/// `deadline_ms <= 0.0` each mean "unlimited" (the flag defaults), so
/// plain `spdf serve`/`spdf loadgen` stay on [`Unbounded`].
pub fn from_flags(max_queue: usize, deadline_ms: f64)
                  -> anyhow::Result<Box<dyn AdmissionPolicy>> {
    anyhow::ensure!(deadline_ms.is_finite(),
                    "--queue-deadline-ms must be finite");
    let deadline = (deadline_ms > 0.0).then_some(deadline_ms);
    Ok(match (max_queue, deadline) {
        (0, None) => Box::new(Unbounded),
        (n, None) => Box::new(MaxQueueDepth(n)),
        (0, Some(d)) => Box::new(QueueDeadline(d)),
        (n, Some(d)) => {
            Box::new(Bounded { max_queue: n, deadline_ms: d })
        }
    })
}

/// [`from_flags`], wrapped in [`PagePressure`] when the operator set
/// a finite page budget (`--kv-pages`): a fixed budget means
/// "admittable iff the prompt's pages exist", which is the paged
/// deployment contract. Without a budget the inner policy is
/// returned unchanged (unconstrained paging admits like the
/// monolithic loop — part of the bitwise-identity invariant).
pub fn from_flags_paged(max_queue: usize, deadline_ms: f64,
                        page_budget: bool)
                        -> anyhow::Result<Box<dyn AdmissionPolicy>> {
    let inner = from_flags(max_queue, deadline_ms)?;
    Ok(if page_budget {
        Box::new(PagePressure::wrapping(inner))
    } else {
        inner
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbounded_admits_everything_forever() {
        assert!(Unbounded.admit(0));
        assert!(Unbounded.admit(1_000_000));
        assert_eq!(Unbounded.deadline_ms(), None);
        assert_eq!(Unbounded.name(), "unbounded");
    }

    #[test]
    fn max_queue_depth_bounds_waiters() {
        let p = MaxQueueDepth(2);
        assert!(p.admit(0));
        assert!(p.admit(1));
        assert!(!p.admit(2));
        assert_eq!(p.deadline_ms(), None);
        assert_eq!(p.name(), "max-queue(2)");
        // depth 0: nothing may wait (immediate dispatch only)
        assert!(!MaxQueueDepth(0).admit(0));
    }

    #[test]
    fn queue_deadline_sets_expiry_only() {
        let p = QueueDeadline(250.0);
        assert!(p.admit(usize::MAX));
        assert_eq!(p.deadline_ms(), Some(250.0));
        assert_eq!(p.name(), "deadline(250ms)");
    }

    #[test]
    fn bounded_combines_both_knobs() {
        let p = Bounded { max_queue: 3, deadline_ms: 100.0 };
        assert!(p.admit(2));
        assert!(!p.admit(3));
        assert_eq!(p.deadline_ms(), Some(100.0));
        assert_eq!(p.name(), "max-queue(3)+deadline(100ms)");
    }

    #[test]
    fn default_policies_are_memory_oblivious() {
        // admit_pages defaults to true: a paged run under the stock
        // policies queues on pressure instead of shedding, which is
        // what keeps unconstrained paging bitwise identical
        assert!(Unbounded.admit_pages(100, 0));
        assert!(MaxQueueDepth(1).admit_pages(100, 0));
        assert!(QueueDeadline(5.0).admit_pages(100, 0));
    }

    #[test]
    fn page_pressure_sheds_on_dry_allocator_only() {
        let p = PagePressure::new();
        assert!(p.admit_pages(0, 0));
        assert!(p.admit_pages(2, 2));
        assert!(!p.admit_pages(3, 2));
        assert!(p.admit(usize::MAX)); // queueing still unbounded
        assert_eq!(p.deadline_ms(), None);
        assert_eq!(p.name(), "page-pressure");
        let p = PagePressure::wrapping(
            Box::new(Bounded { max_queue: 2, deadline_ms: 9.0 }));
        assert!(!p.admit(2));
        assert_eq!(p.deadline_ms(), Some(9.0));
        assert_eq!(p.name(),
                   "max-queue(2)+deadline(9ms)+page-pressure");
        assert!(!p.admit_pages(1, 0));
    }

    #[test]
    fn from_flags_paged_wraps_only_under_a_budget() {
        let p = from_flags_paged(0, 0.0, false).unwrap();
        assert_eq!(p.name(), "unbounded");
        assert!(p.admit_pages(9, 0));
        let p = from_flags_paged(0, 0.0, true).unwrap();
        assert_eq!(p.name(), "page-pressure");
        assert!(!p.admit_pages(9, 0));
        let p = from_flags_paged(4, 0.0, true).unwrap();
        assert_eq!(p.name(), "max-queue(4)+page-pressure");
    }

    #[test]
    fn from_flags_maps_zero_sentinels_to_unbounded() {
        assert_eq!(from_flags(0, 0.0).unwrap().name(), "unbounded");
        assert_eq!(from_flags(4, 0.0).unwrap().name(), "max-queue(4)");
        assert_eq!(from_flags(0, 50.0).unwrap().name(),
                   "deadline(50ms)");
        assert_eq!(from_flags(4, 50.0).unwrap().name(),
                   "max-queue(4)+deadline(50ms)");
        assert!(from_flags(1, f64::NAN).is_err());
        assert!(from_flags(1, f64::INFINITY).is_err());
        // negative deadline is treated as unset, like the 0 default
        assert_eq!(from_flags(0, -1.0).unwrap().name(), "unbounded");
    }
}
