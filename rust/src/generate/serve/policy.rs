//! Scheduling policy: which queued request fills a freed batch slot.
//!
//! The serve loop hands the scheduler the **ready set** — the indices
//! of admitted requests that have arrived and are waiting — ordered by
//! (arrival, request index). The scheduler picks one; everything else
//! about the loop (slot rewriting, EOS edges, telemetry) is identical
//! across policies, so policy choice can change *which* request waits
//! but never *what* any request decodes (integration-tested).
//!
//! [`Fifo`] is the default and reproduces the pre-split behavior
//! bit-for-bit. [`ShortestPromptFirst`] / [`SmallestBudgetFirst`] are
//! the classic shortest-job heuristics for the two cost axes a decode
//! request has (prefill cost ∝ prompt length, slot occupancy ∝
//! budget). [`PriorityClass`] serves higher
//! [`super::DecodeRequest::priority`] classes first, FIFO within a
//! class.

use super::DecodeRequest;

/// Pick which ready request fills the next free slot.
///
/// ```
/// use spdf::generate::serve::policy::{Fifo, Scheduler,
///                                     SmallestBudgetFirst};
/// use spdf::generate::DecodeRequest;
///
/// let requests = vec![
///     DecodeRequest::new(0, vec![1, 2, 3], 32),
///     DecodeRequest::new(1, vec![4], 4),
/// ];
/// let ready = vec![0, 1]; // both waiting, arrival order
/// assert_eq!(Fifo.pick(&ready, &requests), 0);
/// // request 1 has the smaller budget, so it frees its slot soonest
/// assert_eq!(SmallestBudgetFirst.pick(&ready, &requests), 1);
/// ```
pub trait Scheduler {
    /// Flag/report name ("fifo", "shortest-prompt", ...).
    fn name(&self) -> &'static str;

    /// Index *within `ready`* of the request to seat next. `ready` is
    /// non-empty and ordered by (arrival, request index); entries are
    /// indices into `requests`. Must return a value `< ready.len()`.
    fn pick(&self, ready: &[usize], requests: &[DecodeRequest])
            -> usize;
}

/// First come, first served — the pre-split behavior.
pub struct Fifo;

impl Scheduler for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn pick(&self, _ready: &[usize], _requests: &[DecodeRequest])
            -> usize {
        0
    }
}

/// Seat the shortest prompt first (cheapest prefill; FIFO ties).
pub struct ShortestPromptFirst;

impl Scheduler for ShortestPromptFirst {
    fn name(&self) -> &'static str {
        "shortest-prompt"
    }

    fn pick(&self, ready: &[usize], requests: &[DecodeRequest])
            -> usize {
        argbest(ready, |i| requests[i].prompt.len() as u64)
    }
}

/// Seat the smallest generation budget first (frees its slot soonest;
/// FIFO ties).
pub struct SmallestBudgetFirst;

impl Scheduler for SmallestBudgetFirst {
    fn name(&self) -> &'static str {
        "smallest-budget"
    }

    fn pick(&self, ready: &[usize], requests: &[DecodeRequest])
            -> usize {
        argbest(ready, |i| requests[i].max_new_tokens as u64)
    }
}

/// Serve the highest [`DecodeRequest::priority`] class first, FIFO
/// within a class (priority 255 beats 0; requests default to 0).
pub struct PriorityClass;

impl Scheduler for PriorityClass {
    fn name(&self) -> &'static str {
        "priority"
    }

    fn pick(&self, ready: &[usize], requests: &[DecodeRequest])
            -> usize {
        // minimize the inverted priority → stable argmin keeps FIFO
        // order within a class
        argbest(ready, |i| u64::from(u8::MAX - requests[i].priority))
    }
}

/// Stable argmin of `key` over the ready set: the first (i.e. FIFO-
/// earliest) entry with the smallest key.
fn argbest(ready: &[usize], key: impl Fn(usize) -> u64) -> usize {
    let mut best = 0;
    let mut best_key = key(ready[0]);
    for (k, &i) in ready.iter().enumerate().skip(1) {
        let ki = key(i);
        if ki < best_key {
            best = k;
            best_key = ki;
        }
    }
    best
}

/// Parse the `--policy` flag.
pub fn parse(name: &str) -> anyhow::Result<Box<dyn Scheduler>> {
    match name {
        "fifo" => Ok(Box::new(Fifo)),
        "shortest-prompt" => Ok(Box::new(ShortestPromptFirst)),
        "smallest-budget" => Ok(Box::new(SmallestBudgetFirst)),
        "priority" => Ok(Box::new(PriorityClass)),
        other => anyhow::bail!(
            "unknown --policy {other} (want fifo | shortest-prompt | \
             smallest-budget | priority)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reqs() -> Vec<DecodeRequest> {
        vec![
            DecodeRequest::new(0, vec![1, 2, 3, 4], 8),
            DecodeRequest::new(1, vec![1, 2], 16).with_priority(1),
            DecodeRequest::new(2, vec![1, 2, 3], 4).with_priority(3),
            DecodeRequest::new(3, vec![1, 2], 4).with_priority(3),
        ]
    }

    #[test]
    fn fifo_always_picks_the_head() {
        let r = reqs();
        assert_eq!(Fifo.pick(&[2, 0, 1], &r), 0);
        assert_eq!(Fifo.name(), "fifo");
    }

    #[test]
    fn shortest_prompt_picks_min_len_with_fifo_ties() {
        let r = reqs();
        // prompts: 0→4 tokens, 1→2, 2→3, 3→2
        assert_eq!(ShortestPromptFirst.pick(&[0, 2, 1], &r), 2);
        // tie between 1 and 3 (both len 2): earlier position wins
        assert_eq!(ShortestPromptFirst.pick(&[3, 1, 0], &r), 0);
        assert_eq!(ShortestPromptFirst.pick(&[0], &r), 0);
    }

    #[test]
    fn smallest_budget_picks_min_budget_with_fifo_ties() {
        let r = reqs();
        // budgets: 0→8, 1→16, 2→4, 3→4
        assert_eq!(SmallestBudgetFirst.pick(&[1, 0, 2], &r), 2);
        assert_eq!(SmallestBudgetFirst.pick(&[2, 3], &r), 0);
    }

    #[test]
    fn priority_picks_highest_class_with_fifo_ties() {
        let r = reqs();
        // priorities: 0→0, 1→1, 2→3, 3→3
        assert_eq!(PriorityClass.pick(&[0, 1, 2], &r), 2);
        // 2 and 3 tie at priority 3: earlier position wins
        assert_eq!(PriorityClass.pick(&[3, 2, 1], &r), 0);
        assert_eq!(PriorityClass.pick(&[0, 1], &r), 1);
    }

    #[test]
    fn parse_resolves_names_and_rejects_unknown() {
        for name in ["fifo", "shortest-prompt", "smallest-budget",
                     "priority"] {
            assert_eq!(parse(name).unwrap().name(), name);
        }
        assert!(parse("lifo").is_err());
    }
}
