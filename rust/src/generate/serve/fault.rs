//! Deterministic fault injection and the recovery policy knobs.
//!
//! Chaos testing a serving loop is only useful if a failing run can be
//! replayed bit-for-bit. [`FaultPlan`] therefore drives every injected
//! fault from its own salted seed stream ([`FAULT_SALT`]), exactly the
//! way `loadgen` salts its priority/model-mix draws: a
//! [`FaultyBackend`] wrapping lane `l` draws from
//! `Rng::new(seed ^ FAULT_SALT).fork(l)`, two draws per step attempt
//! (fail? spike?), so the fault sequence depends only on
//! `(seed, lane, attempt index)` — never on timing, policies or the
//! other lanes. Enabling faults on one lane cannot perturb another
//! lane's stream, and a fault-free plan leaves the serve loop
//! bit-identical to a run without the wrapper.
//!
//! Three fault classes, mirroring what a real accelerator lane does:
//!  * **transient step errors** (`step_fail_p`) — the step returns
//!    `Err` but the lane stays healthy; the loop's [`RetryPolicy`]
//!    backs off and re-prefills the affected slots from
//!    tokens-so-far, so survivors stay bitwise identical to the
//!    fault-free decode;
//!  * **permanent lane death** (`die_at_step`) — every step attempt
//!    from that index on fails and [`LogitsBackend::healthy`] turns
//!    false; the loop drains the lane (failover or
//!    `RequestOutcome::Failed`), never steps it again;
//!  * **latency spikes** (`spike_p` / `spike_ms`) — the step succeeds
//!    but reports extra virtual milliseconds through
//!    [`LogitsBackend::take_spike_ms`]; tokens are unaffected, only
//!    the clock (and thus latency telemetry) moves.
//!
//! [`RecoveryConfig`] bundles the loop-side half: the retry/backoff
//! policy, the per-lane circuit breaker (N consecutive failed
//! attempts open the lane for a cooldown) and the lane-indexed
//! failover route resolved by `ModelRegistry` from `--fallback`.

use crate::util::rng::Rng;

use super::core::LogitsBackend;

/// Seed salt for the fault-injection stream: faults come from their
/// own stream (like `loadgen`'s PRIORITY_SALT / MODEL_SALT) so
/// enabling them never perturbs prompts, budgets, priorities, model
/// tags or arrivals drawn from the same base seed.
pub const FAULT_SALT: u64 = 0x6661_756c; // "faul"

/// A deterministic, seeded fault schedule for one lane (or every
/// lane — each lane forks its own stream, so one plan shared across
/// lanes still yields independent per-lane fault sequences).
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// Base seed; the injection stream is
    /// `Rng::new(seed ^ FAULT_SALT).fork(lane)`.
    pub seed: u64,
    /// Probability that a step attempt fails transiently.
    pub step_fail_p: f64,
    /// Step-attempt index at which the lane dies permanently
    /// (`healthy()` turns false; every later attempt errors).
    pub die_at_step: Option<u64>,
    /// Probability that a successful step also carries a latency
    /// spike of `spike_ms` virtual milliseconds.
    pub spike_p: f64,
    pub spike_ms: f64,
}

impl FaultPlan {
    /// The no-fault plan for `seed` — fields are public, switch the
    /// knobs on individually.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            step_fail_p: 0.0,
            die_at_step: None,
            spike_p: 0.0,
            spike_ms: 0.0,
        }
    }

    /// Does this plan inject anything at all?
    pub fn is_noop(&self) -> bool {
        self.step_fail_p == 0.0
            && self.die_at_step.is_none()
            && (self.spike_p == 0.0 || self.spike_ms == 0.0)
    }

    /// Reject non-probability rates and non-finite spike durations
    /// before a plan reaches a serve loop.
    pub fn validate(&self) -> anyhow::Result<()> {
        for (name, p) in [("fault rate", self.step_fail_p),
                          ("spike rate", self.spike_p)] {
            anyhow::ensure!((0.0..=1.0).contains(&p) && p.is_finite(),
                            "{name} must be a probability in [0, 1] \
                             (got {p})");
        }
        anyhow::ensure!(self.spike_ms.is_finite() && self.spike_ms >= 0.0,
                        "spike duration must be finite and \
                         non-negative (got {} ms)", self.spike_ms);
        Ok(())
    }
}

/// A fault plan bound to a registry model (`None` = every lane) —
/// the `--fault-*` CLI flags resolve to one of these per target.
#[derive(Debug, Clone)]
pub struct FaultSpec {
    pub model: Option<String>,
    pub plan: FaultPlan,
}

/// Resolve fault specs against the lane name table: one optional plan
/// per lane, `model: None` applying to every lane. A spec naming an
/// unknown model, or two specs landing on one lane, is an error.
pub(crate) fn plans_for_lanes(
    faults: &[FaultSpec],
    names: &[String],
) -> anyhow::Result<Vec<Option<FaultPlan>>> {
    let mut plans: Vec<Option<FaultPlan>> = vec![None; names.len()];
    for spec in faults {
        spec.plan.validate()?;
        let lanes: Vec<usize> = match &spec.model {
            None => (0..names.len()).collect(),
            Some(m) => vec![names
                .iter()
                .position(|n| n == m)
                .ok_or_else(|| anyhow::anyhow!(
                    "fault plan targets model {m}, which is not \
                     registered (have: {})", names.join(", ")))?],
        };
        for l in lanes {
            anyhow::ensure!(plans[l].is_none(),
                            "two fault plans target model {}",
                            names[l]);
            plans[l] = Some(spec.plan.clone());
        }
    }
    Ok(plans)
}

/// Capped exponential backoff for transient step failures, on the
/// serve clock (virtual ms under a schedule, wall ms otherwise).
/// `max_retries == u32::MAX` means retry forever — with any transient
/// failure probability below 1 the lane eventually recovers, which is
/// what the chaos-invariant property suite runs under.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Failed attempts to retry before the affected slots fail
    /// (0 = fail the slots on the first error).
    pub max_retries: u32,
    /// Backoff before retry `k` (1-based):
    /// `min(base_ms * multiplier^(k-1), cap_ms)`.
    pub base_ms: f64,
    pub multiplier: f64,
    pub cap_ms: f64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_retries: 3,
            base_ms: 1.0,
            multiplier: 2.0,
            cap_ms: 32.0,
        }
    }
}

impl RetryPolicy {
    /// No retries: the first step error fails the affected slots.
    pub fn none() -> RetryPolicy {
        RetryPolicy { max_retries: 0, ..RetryPolicy::default() }
    }

    /// Retry forever (transient faults only delay, never fail, a
    /// request — the chaos-invariant configuration).
    pub fn unlimited() -> RetryPolicy {
        RetryPolicy { max_retries: u32::MAX, ..RetryPolicy::default() }
    }

    /// Backoff before 1-based retry attempt `k`, capped.
    pub fn backoff_ms(&self, attempt: u32) -> f64 {
        let exp = attempt.saturating_sub(1).min(62);
        (self.base_ms * self.multiplier.powi(exp as i32))
            .min(self.cap_ms)
    }

    /// Reject non-finite or shrinking backoff schedules before a
    /// policy reaches a serve loop.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.base_ms.is_finite() && self.base_ms >= 0.0
                && self.cap_ms.is_finite() && self.cap_ms >= 0.0,
            "retry backoff times must be finite and non-negative"
        );
        anyhow::ensure!(self.multiplier.is_finite()
                            && self.multiplier >= 1.0,
                        "retry backoff multiplier must be >= 1 \
                         (got {})", self.multiplier);
        Ok(())
    }
}

/// The serve loop's recovery knobs: retry/backoff for transient step
/// failures, the per-lane circuit breaker, and the failover routing
/// table. The default is containment-with-retries and no failover —
/// a fault-free run under the default config is bit-identical to the
/// pre-recovery loop (no draws, no extra clock movement).
#[derive(Debug, Clone)]
pub struct RecoveryConfig {
    pub retry: RetryPolicy,
    /// Consecutive failed step attempts that open a lane's circuit
    /// breaker (0 disables the breaker).
    pub breaker_threshold: u32,
    /// How long an opened breaker keeps the lane out of service, ms
    /// on the serve clock.
    pub breaker_cooldown_ms: f64,
    /// Lane-indexed failover route: requests bound for lane `l` with
    /// `fallback[l] = Some(f)` reroute to lane `f` when `l` is dead
    /// or its breaker is open, and complete tagged `degraded`. Empty
    /// = no failover anywhere (requests on a dead lane fail; a
    /// breaker-open lane's requests wait out the cooldown).
    pub fallback: Vec<Option<usize>>,
}

impl Default for RecoveryConfig {
    fn default() -> RecoveryConfig {
        RecoveryConfig {
            retry: RetryPolicy::default(),
            breaker_threshold: 0,
            breaker_cooldown_ms: 50.0,
            fallback: Vec::new(),
        }
    }
}

impl RecoveryConfig {
    pub(crate) fn validate(&self, n_lanes: usize)
                           -> anyhow::Result<()> {
        self.retry.validate()?;
        anyhow::ensure!(
            self.breaker_cooldown_ms.is_finite()
                && self.breaker_cooldown_ms >= 0.0,
            "breaker cooldown must be finite and non-negative"
        );
        if !self.fallback.is_empty() {
            anyhow::ensure!(self.fallback.len() == n_lanes,
                            "{} fallback entries for {} lanes",
                            self.fallback.len(), n_lanes);
            for (l, f) in self.fallback.iter().enumerate() {
                if let Some(f) = f {
                    anyhow::ensure!(*f < n_lanes,
                                    "lane {l} falls back to lane {f} \
                                     of {n_lanes}");
                    anyhow::ensure!(*f != l,
                                    "lane {l} falls back to itself");
                }
            }
        }
        Ok(())
    }
}

/// Everything the CLI / loadgen layers need to thread chaos through
/// a serve call: fault plans (by model name), the recovery knobs,
/// and the failover route (from-model, to-model) resolved to lane
/// indices by the registry.
#[derive(Debug, Clone, Default)]
pub struct ChaosConfig {
    pub faults: Vec<FaultSpec>,
    pub recovery: RecoveryConfig,
    pub fallback: Option<(String, String)>,
}

impl ChaosConfig {
    /// Does this config change anything over the fault-free default?
    pub fn is_noop(&self) -> bool {
        self.faults.iter().all(|s| s.plan.is_noop())
            && self.fallback.is_none()
    }
}

/// [`LogitsBackend`] wrapper injecting a [`FaultPlan`]'s faults in
/// front of the wrapped backend. Transient failures and deaths are
/// decided *before* the inner backend runs, so the inner state is
/// never half-mutated by an injected fault — which is exactly the
/// contract the recovery path's re-prefill restores for real faults.
///
/// Draw discipline: every step attempt consumes exactly two draws
/// (fail?, spike?) from the lane's forked stream, regardless of
/// outcome, so the fault sequence is a pure function of
/// `(seed, lane, attempt index)`.
pub struct FaultyBackend<B> {
    inner: B,
    plan: FaultPlan,
    rng: Rng,
    /// Step attempts observed (indexes `die_at_step`).
    attempts: u64,
    dead: bool,
    spike_ms_pending: f64,
}

impl<B: LogitsBackend> FaultyBackend<B> {
    /// Wrap `inner` with the plan's fault stream for one lane; each
    /// lane forks its own RNG stream so fault schedules stay
    /// deterministic under any lane interleaving.
    pub fn new(inner: B, plan: &FaultPlan, lane: usize)
               -> anyhow::Result<FaultyBackend<B>> {
        plan.validate()?;
        let mut base = Rng::new(plan.seed ^ FAULT_SALT);
        let rng = base.fork(lane as u64);
        Ok(FaultyBackend {
            inner,
            plan: plan.clone(),
            rng,
            attempts: 0,
            dead: false,
            spike_ms_pending: 0.0,
        })
    }

    /// Step attempts seen so far (tests pin fault sequences on this).
    pub fn attempts(&self) -> u64 {
        self.attempts
    }

    /// Unwrap the inner backend (tests inspect it after a run).
    pub fn into_inner(self) -> B {
        self.inner
    }
}

impl<B: LogitsBackend> LogitsBackend for FaultyBackend<B> {
    fn dims(&self) -> (usize, usize, usize) {
        self.inner.dims()
    }

    fn needs_prefill(&self) -> bool {
        self.inner.needs_prefill()
    }

    fn prefill(&mut self, tokens: &[i32], pos: &[i32],
               refill: &[f32]) -> anyhow::Result<()> {
        // faults are injected per step attempt (which covers the
        // prefill+step round); a dead lane still refuses prefills
        anyhow::ensure!(!self.dead,
                        "injected fault: lane is permanently dead");
        self.inner.prefill(tokens, pos, refill)
    }

    fn step(&mut self, tokens: &[i32], pos: &[i32])
            -> anyhow::Result<Vec<f32>> {
        let attempt = self.attempts;
        self.attempts += 1;
        // fixed draw count per attempt keeps the stream aligned
        let fail = self.rng.bernoulli(self.plan.step_fail_p);
        let spike = self.rng.bernoulli(self.plan.spike_p);
        if self.dead
            || self.plan.die_at_step.is_some_and(|k| attempt >= k)
        {
            self.dead = true;
            anyhow::bail!(
                "injected fault: lane died permanently at step \
                 attempt {attempt}"
            );
        }
        if fail {
            anyhow::bail!(
                "injected fault: transient step failure at attempt \
                 {attempt}"
            );
        }
        if spike {
            self.spike_ms_pending += self.plan.spike_ms;
        }
        self.inner.step(tokens, pos)
    }

    fn healthy(&self) -> bool {
        !self.dead
    }

    fn take_spike_ms(&mut self) -> f64 {
        std::mem::take(&mut self.spike_ms_pending)
    }
}

#[cfg(test)]
mod tests {
    use super::super::core::mock::MockBackend;
    use super::*;

    fn attempt_outcomes(plan: &FaultPlan, lane: usize, n: usize)
                        -> Vec<bool> {
        let mut be =
            FaultyBackend::new(MockBackend::new(1, 8, false), plan,
                               lane)
                .unwrap();
        let (tokens, pos) = (vec![0i32; 8], vec![0i32; 1]);
        (0..n).map(|_| be.step(&tokens, &pos).is_ok()).collect()
    }

    #[test]
    fn fault_stream_is_seeded_and_lane_forked() {
        let mut plan = FaultPlan::new(7);
        plan.step_fail_p = 0.5;
        let a = attempt_outcomes(&plan, 0, 64);
        let b = attempt_outcomes(&plan, 0, 64);
        assert_eq!(a, b, "same (seed, lane) must replay identically");
        assert!(a.iter().any(|ok| *ok) && a.iter().any(|ok| !ok),
                "p=0.5 over 64 attempts should mix outcomes");
        let c = attempt_outcomes(&plan, 1, 64);
        assert_ne!(a, c, "lanes fork independent streams");
        let mut other = plan.clone();
        other.seed = 8;
        assert_ne!(a, attempt_outcomes(&other, 0, 64),
                   "seed changes the stream");
    }

    #[test]
    fn noop_plan_passes_steps_through() {
        let plan = FaultPlan::new(3);
        assert!(plan.is_noop());
        let outcomes = attempt_outcomes(&plan, 0, 32);
        assert!(outcomes.iter().all(|ok| *ok));
    }

    #[test]
    fn die_at_step_is_permanent_and_reported_unhealthy() {
        let mut plan = FaultPlan::new(11);
        plan.die_at_step = Some(3);
        let mut be =
            FaultyBackend::new(MockBackend::new(1, 8, false), &plan, 0)
                .unwrap();
        let (tokens, pos) = (vec![0i32; 8], vec![0i32; 1]);
        for _ in 0..3 {
            assert!(be.step(&tokens, &pos).is_ok());
            assert!(be.healthy());
        }
        for _ in 0..4 {
            assert!(be.step(&tokens, &pos).is_err());
            assert!(!be.healthy());
        }
        assert!(be.prefill(&tokens, &pos, &[0.0]).is_err(),
                "a dead lane refuses prefills too");
    }

    #[test]
    fn spikes_accumulate_and_drain_on_take() {
        let mut plan = FaultPlan::new(5);
        plan.spike_p = 1.0;
        plan.spike_ms = 4.0;
        let mut be =
            FaultyBackend::new(MockBackend::new(1, 8, false), &plan, 0)
                .unwrap();
        let (tokens, pos) = (vec![0i32; 8], vec![0i32; 1]);
        be.step(&tokens, &pos).unwrap();
        be.step(&tokens, &pos).unwrap();
        assert_eq!(be.take_spike_ms(), 8.0);
        assert_eq!(be.take_spike_ms(), 0.0, "take drains the spike");
        be.step(&tokens, &pos).unwrap();
        assert_eq!(be.take_spike_ms(), 4.0);
    }

    #[test]
    fn plan_validation_rejects_bad_knobs() {
        for bad in [
            FaultPlan { step_fail_p: -0.1, ..FaultPlan::new(0) },
            FaultPlan { step_fail_p: 1.5, ..FaultPlan::new(0) },
            FaultPlan { spike_p: f64::NAN, ..FaultPlan::new(0) },
            FaultPlan { spike_ms: -1.0, ..FaultPlan::new(0) },
            FaultPlan { spike_ms: f64::INFINITY,
                        ..FaultPlan::new(0) },
        ] {
            assert!(bad.validate().is_err(), "{bad:?}");
            assert!(
                FaultyBackend::new(MockBackend::new(1, 8, false),
                                   &bad, 0)
                    .is_err(),
                "wrapper construction must validate the plan"
            );
        }
        assert!(FaultPlan::new(1).validate().is_ok());
    }

    #[test]
    fn backoff_is_capped_exponential() {
        let r = RetryPolicy {
            max_retries: 5,
            base_ms: 1.0,
            multiplier: 2.0,
            cap_ms: 6.0,
        };
        assert_eq!(r.backoff_ms(1), 1.0);
        assert_eq!(r.backoff_ms(2), 2.0);
        assert_eq!(r.backoff_ms(3), 4.0);
        assert_eq!(r.backoff_ms(4), 6.0, "capped");
        assert_eq!(r.backoff_ms(200), 6.0, "no overflow at depth");
        assert!(r.validate().is_ok());
        let bad = RetryPolicy { multiplier: 0.5, ..r.clone() };
        assert!(bad.validate().is_err());
        let bad = RetryPolicy { base_ms: f64::NAN, ..r };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn recovery_config_validates_fallback_table() {
        let mut rc = RecoveryConfig::default();
        assert!(rc.validate(2).is_ok());
        rc.fallback = vec![Some(1), None];
        assert!(rc.validate(2).is_ok());
        assert!(rc.validate(3).is_err(), "length must match lanes");
        rc.fallback = vec![Some(0), None];
        assert!(rc.validate(2).is_err(), "self-fallback rejected");
        rc.fallback = vec![Some(5), None];
        assert!(rc.validate(2).is_err(), "out-of-range rejected");
    }

    #[test]
    fn plans_for_lanes_resolves_models() {
        let names: Vec<String> =
            vec!["dense".into(), "s75".into()];
        let mut plan = FaultPlan::new(1);
        plan.step_fail_p = 0.1;
        let plans = plans_for_lanes(
            &[FaultSpec { model: Some("s75".into()),
                          plan: plan.clone() }],
            &names).unwrap();
        assert!(plans[0].is_none());
        assert!(plans[1].is_some());
        // None targets every lane
        let all = plans_for_lanes(
            &[FaultSpec { model: None, plan: plan.clone() }],
            &names).unwrap();
        assert!(all.iter().all(|p| p.is_some()));
        // unknown model is an error, mentioning the registry
        let err = plans_for_lanes(
            &[FaultSpec { model: Some("nope".into()),
                          plan: plan.clone() }],
            &names).unwrap_err().to_string();
        assert!(err.contains("nope") && err.contains("dense"), "{err}");
        // double assignment is an error
        assert!(plans_for_lanes(
            &[FaultSpec { model: None, plan: plan.clone() },
              FaultSpec { model: Some("dense".into()), plan }],
            &names).is_err());
    }
}
