//! The scheduler-driven serving core: continuous slot-refill batching
//! over the fixed decode geometry, with pluggable queue policies and
//! admission control.
//!
//! This tree is the split of the old `generate::batching` monolith
//! (which remains as a re-export shim). The two decisions that used to
//! be hard-coded into the loop are now traits:
//!
//!  * [`self::core`] — the backend-agnostic slot-refill state machine
//!    (`run_loop_with`) plus the public entry points ([`serve`],
//!    [`serve_kv`], [`serve_timed`], [`serve_with`]). The model
//!    behind the loop is a
//!    `LogitsBackend`: the literal-resident engine path, the
//!    KV-resident incremental path, or a deterministic test mock.
//!  * [`policy`] — the [`policy::Scheduler`] trait: which queued
//!    request fills a freed slot. FIFO (the old behavior, the
//!    default), shortest-prompt-first, smallest-budget-first, and a
//!    priority-class policy fed by [`DecodeRequest::priority`].
//!  * [`admission`] — the [`admission::AdmissionPolicy`] trait:
//!    whether an arriving request is enqueued, shed at arrival
//!    (bounded queue depth), or expired after waiting too long on the
//!    (virtual) clock. Unbounded admission — the old behavior — is
//!    the default.
//!  * [`clock`] — the loop's notion of time ([`clock::Schedule`],
//!    the virtual/wall `Clock`, the arrival queue).
//!  * [`telemetry`] — per-request results with a
//!    [`telemetry::RequestOutcome`] (completed / shed / expired),
//!    aggregate [`telemetry::ServeStats`] including shed-rate and
//!    goodput, and their JSON emitters (on the shared
//!    `util::json::push_num` helpers).
//!
//! Invariant: FIFO scheduling + unbounded admission reproduces the
//! pre-split `batching` behavior bit-for-bit — token streams and
//! telemetry alike — on both engine paths (pinned by the unit tests in
//! [`self::core`] and the integration suite).

pub mod admission;
pub mod clock;
pub mod core;
pub mod policy;
pub mod telemetry;

pub use self::admission::AdmissionPolicy;
pub use self::clock::Schedule;
pub use self::core::{serve, serve_kv, serve_timed, serve_with,
                     ServeConfig};
pub use self::policy::Scheduler;
pub use self::telemetry::{RequestOutcome, RequestResult, ServeReport,
                          ServeStats};

/// One queued decode request.
#[derive(Debug, Clone)]
pub struct DecodeRequest {
    /// Caller-chosen id, echoed in the result (results are returned
    /// sorted by id).
    pub id: u64,
    /// Prompt token ids (unpadded, non-empty).
    pub prompt: Vec<u32>,
    /// Per-request generation budget.
    pub max_new_tokens: usize,
    /// Priority class for [`policy::PriorityClass`] scheduling:
    /// higher values are served first, FIFO within a class. Ignored
    /// by every other scheduler; 0 by default.
    pub priority: u8,
}

impl DecodeRequest {
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize)
               -> DecodeRequest {
        DecodeRequest { id, prompt, max_new_tokens, priority: 0 }
    }

    /// Builder-style priority-class assignment.
    pub fn with_priority(mut self, priority: u8) -> DecodeRequest {
        self.priority = priority;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_priority_defaults_to_zero() {
        let r = DecodeRequest::new(3, vec![1, 2], 8);
        assert_eq!(r.priority, 0);
        let r = r.with_priority(5);
        assert_eq!(r.priority, 5);
        assert_eq!((r.id, r.max_new_tokens), (3, 8));
    }
}
