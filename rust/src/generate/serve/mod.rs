//! The scheduler-driven serving core: continuous slot-refill batching
//! over the fixed decode geometry, with pluggable queue policies and
//! admission control.
//!
//! This tree is the split of the old `generate::batching` monolith
//! (which remains as a re-export shim). The two decisions that used to
//! be hard-coded into the loop are now traits:
//!
//!  * [`self::core`] — the backend-agnostic slot-refill state machine
//!    (`run_loop_with`) plus the public entry points ([`serve`],
//!    [`serve_kv`], [`serve_timed`], [`serve_with`]). The model
//!    behind the loop is a
//!    `LogitsBackend`: the literal-resident engine path, the
//!    KV-resident incremental path, or a deterministic test mock.
//!  * [`policy`] — the [`policy::Scheduler`] trait: which queued
//!    request fills a freed slot. FIFO (the old behavior, the
//!    default), shortest-prompt-first, smallest-budget-first, and a
//!    priority-class policy fed by [`DecodeRequest::priority`].
//!  * [`admission`] — the [`admission::AdmissionPolicy`] trait:
//!    whether an arriving request is enqueued, shed at arrival
//!    (bounded queue depth), or expired after waiting too long on the
//!    (virtual) clock. Unbounded admission — the old behavior — is
//!    the default.
//!  * [`clock`] — the loop's notion of time ([`clock::Schedule`],
//!    the virtual/wall `Clock`, the arrival queue) and the per-lane
//!    step-cost multipliers ([`clock::LaneCost`]) that make a sparse
//!    lane step cheaper than a dense one on the virtual clock.
//!  * [`fault`] — deterministic fault injection and recovery:
//!    [`fault::FaultPlan`]-driven [`fault::FaultyBackend`] wrappers
//!    (seeded transient step errors, permanent lane death, latency
//!    spikes), the [`fault::RetryPolicy`]/[`fault::RecoveryConfig`]
//!    retry-backoff + circuit-breaker knobs, and the cross-model
//!    failover route. A failed step is contained to its lane; with
//!    retries enabled and no lane death, survivors stay bitwise
//!    identical to the fault-free decode.
//!  * [`pages`] — paged KV memory (vLLM-style): a lane's KV budget
//!    split into fixed-size pages behind a free-list
//!    [`pages::PageAllocator`]; a seated request owns a page table
//!    that grows as it decodes. Memory-aware admission
//!    ([`admission::PagePressure`]) sheds when a prompt's pages
//!    don't exist, a dry allocator preempts the youngest-seated slot
//!    (its decoded-so-far tokens are dropped and counted as lost),
//!    and a sliding eviction window frees the oldest pages so
//!    generation runs past `ctx_len`. Unconstrained paging is
//!    bitwise identical to the monolithic loop.
//!  * [`registry`] — the multi-model serving registry:
//!    [`registry::ModelRegistry`] owns N named engines (the SPDF
//!    checkpoint sweep: dense / s50 / s75) and routes one request
//!    stream across them by [`DecodeRequest::model`]; slots are
//!    (model, slot) pairs with per-model `decode_batch` budgets and
//!    the scheduling/admission decisions stay model-aware.
//!  * [`speculative`] — self-speculative decoding over the registry:
//!    the cheap sparse lane drafts `k` greedy tokens ahead, the dense
//!    lane verifies all of them in one batched step, and the engine
//!    commits the longest agreeing prefix plus the verifier's first
//!    correction — ≥ 1 pick per verify, output bitwise identical to
//!    plain dense greedy decode ([`SpecConfig`], `--speculate
//!    DRAFT=VERIFIER:k`). Draft-lane faults degrade to plain dense
//!    decode, never to a failure.
//!  * [`telemetry`] — per-request results with a
//!    [`telemetry::RequestOutcome`] (completed / shed / expired),
//!    aggregate [`telemetry::ServeStats`] including shed-rate and
//!    goodput, and their JSON emitters (on the shared
//!    `util::json::push_num` helpers).
//!
//! Invariant: FIFO scheduling + unbounded admission reproduces the
//! pre-split `batching` behavior bit-for-bit — token streams and
//! telemetry alike — on both engine paths (pinned by the unit tests in
//! [`self::core`] and the integration suite).

pub mod admission;
pub mod clock;
pub mod core;
pub mod fault;
pub mod pages;
pub mod policy;
pub mod registry;
pub mod speculative;
pub mod telemetry;

pub use self::admission::{AdmissionPolicy, PagePressure};
pub use self::clock::{LaneCost, Schedule};
pub use self::core::{serve, serve_kv, serve_timed, serve_with,
                     ServeConfig};
pub use self::fault::{ChaosConfig, FaultPlan, FaultSpec,
                      FaultyBackend, RecoveryConfig, RetryPolicy,
                      FAULT_SALT};
pub use self::pages::{PageAllocator, PageCounters, PageReserve,
                      PagedKvConfig};
pub use self::policy::Scheduler;
pub use self::registry::ModelRegistry;
pub use self::speculative::{SpecConfig, SpecPlan};
pub use self::telemetry::{ModelStats, RequestOutcome, RequestResult,
                          ServeReport, ServeStats, SpecCounters};

/// One queued decode request.
#[derive(Debug, Clone)]
pub struct DecodeRequest {
    /// Caller-chosen id, echoed in the result (results are returned
    /// sorted by id).
    pub id: u64,
    /// Prompt token ids (unpadded, non-empty).
    pub prompt: Vec<u32>,
    /// Per-request generation budget.
    pub max_new_tokens: usize,
    /// Priority class for [`policy::PriorityClass`] scheduling:
    /// higher values are served first, FIFO within a class. Ignored
    /// by every other scheduler; 0 by default.
    pub priority: u8,
    /// Target model for [`registry::ModelRegistry`] routing: `None`
    /// (the default) routes to the registry's default model; `Some`
    /// must name a registered model. The single-engine entry points
    /// ([`serve`], [`serve_kv`], [`serve_timed`], [`serve_with`])
    /// serve every request on their one engine and never consult it.
    pub model: Option<String>,
}

impl DecodeRequest {
    /// A default-priority request with no model preference.
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize)
               -> DecodeRequest {
        DecodeRequest { id, prompt, max_new_tokens, priority: 0,
                        model: None }
    }

    /// Builder-style priority-class assignment.
    pub fn with_priority(mut self, priority: u8) -> DecodeRequest {
        self.priority = priority;
        self
    }

    /// Builder-style model routing tag (see [`Self::model`]).
    pub fn with_model(mut self, model: impl Into<String>)
                      -> DecodeRequest {
        self.model = Some(model.into());
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_priority_defaults_to_zero() {
        let r = DecodeRequest::new(3, vec![1, 2], 8);
        assert_eq!(r.priority, 0);
        let r = r.with_priority(5);
        assert_eq!(r.priority, 5);
        assert_eq!((r.id, r.max_new_tokens), (3, 8));
    }

    #[test]
    fn request_model_defaults_to_none() {
        let r = DecodeRequest::new(1, vec![1], 4);
        assert_eq!(r.model, None);
        let r = r.with_model("s75");
        assert_eq!(r.model.as_deref(), Some("s75"));
        assert_eq!(r.priority, 0);
    }
}
