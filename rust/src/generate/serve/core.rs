//! The backend-agnostic slot-refill state machine.
//!
//! The `logits_last` artifact is compiled for a fixed
//! `(decode_batch, ctx_len)` shape, but serving traffic is an arbitrary
//! stream of prompts with wildly different generation lengths. Static
//! chunking (decode `B` prompts, wait for the *slowest*, repeat) burns
//! batch slots as padding the moment one slot finishes early. Here a
//! request queue feeds the batch instead: the moment a slot's request
//! finishes (EOS / length cap), the slot is rewritten with the next
//! queued prompt **mid-flight** — the model step never idles a slot
//! while work is waiting. Causal attention plus the explicit `pos`
//! input make each row independent, so a slot's output is bit-identical
//! to decoding its prompt alone (`tests/integration_runtime.rs` checks
//! this).
//!
//! One state machine, parameterized on three axes:
//!  * **backend** — the per-step logits producer is a
//!    [`LogitsBackend`]: the literal-resident engine path (full
//!    context recompute), the KV-resident incremental path (session
//!    state + per-slot prefill on refill), or a deterministic
//!    in-process mock (so every queueing/clock/policy edge is
//!    unit-testable without compiled artifacts);
//!  * **time** — wall clock, or a deterministic virtual clock under a
//!    [`Schedule`] (the `loadgen` workload driver): requests become
//!    visible as their arrival times pass, every model invocation
//!    advances the clock by a fixed cost, and per-request queue-wait /
//!    TTFT / end-to-end latencies are read off the virtual clock;
//!  * **policy** — a [`Scheduler`] picks which ready request fills a
//!    freed slot and an [`AdmissionPolicy`] decides enqueue / shed /
//!    expire ([`super::policy`], [`super::admission`]). The defaults
//!    (FIFO, unbounded) reproduce the pre-split `batching` behavior
//!    bit-for-bit; policies change *which* request waits or fails,
//!    never *what* an admitted request decodes.
//!
//! Entry points: [`serve`] / [`serve_kv`] (whole stream present at
//! entry, wall-clock latencies), [`serve_timed`] (arrival-gated on the
//! virtual clock), and [`serve_with`] (everything explicit via
//! [`ServeConfig`]).

use crate::generate::engine::DecodeEngine;
use crate::generate::{topk, DecodeParams};
use crate::runtime::SessionState;
use crate::tokenizer::EOS;

use super::admission::{AdmissionPolicy, Unbounded};
use super::clock::{ArrivalQueue, Clock, LaneCost, Schedule};
use super::fault::{plans_for_lanes, FaultyBackend, RecoveryConfig};
use super::pages::{LanePager, PageCounters, PagedKvConfig};
use super::policy::{Fifo, Scheduler};
use super::speculative::{SpecConfig, SpecPlan};
use super::telemetry::{ModelStats, RequestOutcome, RequestResult,
                       ServeReport, ServeStats, SpecCounters};
use super::DecodeRequest;

/// The per-step logits producer behind the slot-refill state machine:
/// the literal-resident engine path, the KV-resident path, and
/// deterministic test mocks (so queueing/clock behavior is testable
/// without compiled artifacts — see [`mock`]). Public so the
/// property-test harness in `rust/tests/` can drive [`run_lanes_with`]
/// over artifact-free backends.
pub trait LogitsBackend {
    /// `(decode_batch, ctx_len, vocab)`.
    fn dims(&self) -> (usize, usize, usize);
    /// true → the serve loop maintains per-slot refill marks and calls
    /// [`Self::prefill`] before a step whenever any slot was
    /// (re)written.
    fn needs_prefill(&self) -> bool {
        false
    }
    /// (Re)populate cache rows with `refill[s] > 0` from the token
    /// buffer; other rows pass through untouched.
    fn prefill(&mut self, _tokens: &[i32], _pos: &[i32],
               _refill: &[f32]) -> anyhow::Result<()> {
        Ok(())
    }
    /// Logits for every row read at its `pos` (flat `B * vocab`).
    fn step(&mut self, tokens: &[i32], pos: &[i32])
            -> anyhow::Result<Vec<f32>>;
    /// false → the backend has failed permanently: the serve loop
    /// drains the lane (failover or `Failed`) and never steps it
    /// again. A plain `step` error with `healthy()` still true is
    /// transient and retried per the `RetryPolicy`.
    fn healthy(&self) -> bool {
        true
    }
    /// Drain any extra latency the last step carried beyond the fixed
    /// step cost (injected spikes). The serve loop charges it to the
    /// virtual clock after the step; 0.0 for real backends.
    fn take_spike_ms(&mut self) -> f64 {
        0.0
    }
}

/// Boxed backends forward the whole trait — needed so the fault
/// wrapper can wrap the registry's `Box<dyn LogitsBackend>` lanes
/// without re-boxing or downcasting.
impl<B: LogitsBackend + ?Sized> LogitsBackend for Box<B> {
    fn dims(&self) -> (usize, usize, usize) {
        (**self).dims()
    }

    fn needs_prefill(&self) -> bool {
        (**self).needs_prefill()
    }

    fn prefill(&mut self, tokens: &[i32], pos: &[i32],
               refill: &[f32]) -> anyhow::Result<()> {
        (**self).prefill(tokens, pos, refill)
    }

    fn step(&mut self, tokens: &[i32], pos: &[i32])
            -> anyhow::Result<Vec<f32>> {
        (**self).step(tokens, pos)
    }

    fn healthy(&self) -> bool {
        (**self).healthy()
    }

    fn take_spike_ms(&mut self) -> f64 {
        (**self).take_spike_ms()
    }
}

/// Literal-resident backend: full-context recompute per step.
struct LiteralBackend<'e, 'a> {
    engine: &'e DecodeEngine<'a>,
}

impl LogitsBackend for LiteralBackend<'_, '_> {
    fn dims(&self) -> (usize, usize, usize) {
        (self.engine.decode_batch(), self.engine.ctx_len(),
         self.engine.vocab())
    }

    fn step(&mut self, tokens: &[i32], pos: &[i32])
            -> anyhow::Result<Vec<f32>> {
        self.engine.step_logits(tokens, pos)
    }
}

/// KV-resident backend: per-layer caches as session-state literals,
/// advanced by the incremental `decode_step` artifact. Each row steps
/// by its token at `pos` (for a freshly prefilled row that re-derives
/// the prompt tail's K/V — same values — and yields the same logits
/// the prefill already read; uniformity keeps every emitted logit on
/// the incremental program).
struct KvBackend<'e, 'a> {
    engine: &'e DecodeEngine<'a>,
    state: SessionState,
    next_tok: Vec<i32>,
}

impl LogitsBackend for KvBackend<'_, '_> {
    fn dims(&self) -> (usize, usize, usize) {
        (self.engine.decode_batch(), self.engine.ctx_len(),
         self.engine.vocab())
    }

    fn needs_prefill(&self) -> bool {
        true
    }

    fn prefill(&mut self, tokens: &[i32], pos: &[i32], refill: &[f32])
               -> anyhow::Result<()> {
        self.engine.kv_prefill(&mut self.state, tokens, pos, refill)?;
        Ok(())
    }

    fn step(&mut self, tokens: &[i32], pos: &[i32])
            -> anyhow::Result<Vec<f32>> {
        let t = self.engine.ctx_len();
        for (s, nt) in self.next_tok.iter_mut().enumerate() {
            *nt = tokens[s * t + pos[s] as usize];
        }
        self.engine.kv_step(&mut self.state, &self.next_tok, pos)
    }
}

/// A batch slot currently decoding one request. The slot's cursor
/// lives only in the shared `pos` buffer fed to the backend — a
/// slot-local copy would have to be advanced in lockstep and has
/// already caused one logits-read-at-stale-position bug.
struct Slot {
    req: usize, // index into `requests`
    out: Vec<u32>,
    entered_step: u64,
    /// Clock reading at slot entry.
    admit_ms: f64,
    /// Clock reading when the first token was emitted.
    first_tok_ms: Option<f64>,
    /// Speculative bookkeeping (drafted / accepted / corrections /
    /// verifies), copied into the result at completion.
    spec: SpecCounters,
    /// Draft tokens proposed for this slot and not yet consumed by a
    /// verify step. Non-empty only on the verifier lane of an active
    /// [`SpecPlan`].
    spec_pending: Vec<u32>,
}

/// Write a request's prompt into row `slot` of the token buffer,
/// clearing stale tokens from the previous occupant first (junk
/// *before* `pos` would leak into the new request's context).
/// `serve` validates up front that the prompt is non-empty and fits
/// the row (`len < t`).
fn fill_slot(
    tokens: &mut [i32],
    pos: &mut [i32],
    t: usize,
    slot: usize,
    prompt: &[u32],
) {
    debug_assert!(!prompt.is_empty() && prompt.len() < t,
                  "serve() validates prompt lengths up front");
    let row = &mut tokens[slot * t..(slot + 1) * t];
    row.fill(0);
    for (j, &tok) in prompt.iter().enumerate() {
        row[j] = tok as i32;
    }
    pos[slot] = prompt.len() as i32 - 1;
}

/// Apply one greedy-picked token to slot `s` exactly as the
/// sequential dense loop always has: EOS terminates without emitting,
/// the context cap emits-then-terminates, the budget cap terminates
/// after emitting. Returns true when the request finished. Shared by
/// the plain per-step commit and the speculative multi-token commit —
/// one edge implementation, so speculative output cannot drift from
/// dense output on the termination edges.
fn commit_next(tokens: &mut [i32], pos: &mut [i32], t: usize,
               s: usize, slot: &mut Slot, max_new: usize, next: u32,
               now: f64) -> bool {
    let cur = pos[s] as usize;
    let new_pos = cur + 1;
    let done = if next == EOS || new_pos >= t - 1 {
        if next != EOS && new_pos < t {
            slot.out.push(next);
        }
        true
    } else {
        tokens[s * t + new_pos] = next as i32;
        pos[s] = new_pos as i32;
        slot.out.push(next);
        slot.out.len() >= max_new
    };
    if slot.first_tok_ms.is_none() && !slot.out.is_empty() {
        slot.first_tok_ms = Some(now);
    }
    done
}

/// Commit one step's output for slot `s`. In plain mode (`leased`
/// empty, no pending drafts) that is a single greedy pick from the
/// slot's own row — the pre-speculative behavior, bit-for-bit. On the
/// verifier lane of an active [`SpecPlan`] the slot's pending drafts
/// are checked against the picks of the leased replica rows: the
/// longest agreeing prefix plus the verifier's next pick (first
/// correction, or the bonus token when everything matched) commit
/// sequentially through [`commit_next`], so every verify commits ≥ 1
/// pick (an EOS pick terminates without emitting) and the committed
/// stream is the dense greedy stream. Returns
/// true when the request finished.
fn commit_slot(lane: &mut Lane, s: usize, leased: &[usize],
               lv: &[f32], dp: &DecodeParams,
               requests: &[DecodeRequest], now: f64, spec_on: bool)
               -> bool {
    let (t, vocab) = (lane.t, lane.vocab);
    let max_new;
    let mut pending;
    {
        // invariant: commit_slot is only called on occupied slots
        let slot = lane.slots[s].as_mut()
            .expect("commit_slot on an empty slot");
        max_new = requests[slot.req].max_new_tokens;
        pending = std::mem::take(&mut slot.spec_pending);
        if spec_on {
            slot.spec.verifies += 1;
        }
    }
    // verifier picks v_0..v_u: the slot's own row reads the last
    // committed position, leased row i reads it at draft offset i —
    // each pick's ngram context is its row's tokens up to the read
    // position (committed prefix + the drafts staged before it)
    let mut picks: Vec<u32> = Vec::with_capacity(leased.len() + 1);
    for j in 0..=leased.len() {
        let row_idx = if j == 0 { s } else { leased[j - 1] };
        let row = &lv[row_idx * vocab..(row_idx + 1) * vocab];
        let cur = lane.pos[row_idx] as usize;
        let ctx: Vec<u32> = if dp.no_repeat_ngram > 0 {
            (0..=cur).map(|i| lane.tokens[row_idx * t + i] as u32)
                .collect()
        } else {
            Vec::new()
        };
        picks.push(topk::pick_next(row, &ctx, dp.no_repeat_ngram));
    }
    let avail = picks.len();
    let checked = pending.len().min(avail);
    let a = super::speculative::accept_len(&pending[..checked],
                                           &picks[..checked]);
    // tokens to commit: the agreeing prefix, then the verifier's next
    // pick — a correction after a rejection, the bonus pick when every
    // checked draft matched and a spare output exists. Only when the
    // outputs ran out with every draft so far accepted is the
    // unchecked tail retained for the next verify (lease starvation
    // still makes progress).
    let commit_n = if a < checked {
        a + 1
    } else if checked < avail {
        checked + 1
    } else {
        a
    };
    let mut finished = false;
    let mut committed = 0usize;
    {
        let (tokens, pos, slots) =
            (&mut lane.tokens, &mut lane.pos, &mut lane.slots);
        // invariant: same occupied slot the scope above borrowed
        let slot = slots[s].as_mut()
            .expect("occupancy checked at commit_slot entry");
        for (j, &next) in picks.iter().take(commit_n).enumerate() {
            let emitted_before = slot.out.len();
            finished = commit_next(tokens, pos, t, s, slot, max_new,
                                   next, now);
            committed += 1;
            // count only tokens actually emitted (an EOS pick
            // terminates without emitting), so a completed request
            // conserves tokens.len() == accepted + corrections
            if spec_on && slot.out.len() > emitted_before {
                if j < a {
                    slot.spec.accepted += 1;
                } else {
                    slot.spec.corrections += 1;
                }
            }
            if finished {
                break;
            }
        }
        if !finished && a == checked && checked == avail {
            pending.drain(..a);
            slot.spec_pending = pending;
        }
    }
    // a multi-token commit advances `pos` past what the verify step's
    // cache append covered; re-prefill the row from its committed
    // tokens before the next step (single-token commits keep the
    // plain-loop invariant and need nothing)
    if !finished && committed >= 2 && lane.needs_prefill {
        lane.refill[s] = 1.0;
        lane.any_refill = true;
    }
    finished
}

/// Emit the completed result for slot `s` and free it (returning its
/// KV pages on a paged lane).
#[allow(clippy::too_many_arguments)]
fn finish_slot(lane: &mut Lane, s: usize, now: f64,
               requests: &[DecodeRequest], route: &[usize],
               degraded: &[bool], lost: &[u64],
               pending: &mut ArrivalQueue,
               results: &mut Vec<(usize, RequestResult)>)
               -> anyhow::Result<()> {
    // invariant: recovery drains only run on failed attempts, never
    // after the successful step that set `finished`, so the slot is
    // still occupied.
    let slot = lane.slots[s].take().expect(
        "slot emptied between the finished-edge check and result \
         emission",
    );
    if let Some(pg) = lane.pager.as_mut() {
        pg.release(s)?;
    }
    let arrival = pending.arrival_of(slot.req);
    let lane_idx = route[slot.req];
    results.push((lane_idx, RequestResult {
        id: requests[slot.req].id,
        queue_steps: slot.entered_step,
        decode_steps: lane.engine_steps - slot.entered_step,
        arrival_ms: arrival,
        queue_ms: slot.admit_ms - arrival,
        ttft_ms: slot.first_tok_ms.unwrap_or(now) - arrival,
        latency_ms: now - arrival,
        tokens: slot.out,
        // work dropped on this request's way here (failover
        // restarts, paged preemptions) — delivered tokens ride in
        // `tokens`, dropped decode is accounted separately
        lost_tokens: lost[slot.req],
        outcome: RequestOutcome::Completed,
        degraded: degraded[slot.req],
        spec: slot.spec,
    }));
    pending.on_complete(slot.req, now);
    Ok(())
}

/// Paged-lane growth after a commit round: any occupied row whose
/// committed tokens crossed a page boundary allocates the next page.
/// A dry allocator preempts the youngest-seated *other* slot (largest
/// `entered_step`, highest index on ties): its pages free, its
/// decoded-so-far tokens are dropped into the lost-token account and
/// it requeues at its original arrival. [`LanePager::new`] validates
/// that one full-context request always fits the budget, so the
/// preemption loop terminates with the growing slot covered.
fn grow_paged(lane: &mut Lane, pending: &mut ArrivalQueue,
              lost: &mut [u64]) -> anyhow::Result<()> {
    let Lane { pager, slots, ready, pos, .. } = lane;
    let Some(pg) = pager else {
        return Ok(());
    };
    for s in 0..slots.len() {
        if slots[s].is_none() {
            continue;
        }
        pg.set_used(s, pos[s] as usize + 1);
        while !pg.try_cover(s) {
            let victim = (0..slots.len())
                .filter(|&v| v != s && slots[v].is_some())
                .max_by_key(|&v| {
                    // invariant: filtered to occupied slots just above
                    let sl = slots[v].as_ref().expect("occupied slot");
                    (sl.entered_step, v)
                });
            let Some(v) = victim else {
                anyhow::bail!(
                    "page allocator dry with no preemptable slot — \
                     the budget validation (one full-context request \
                     must fit) should make this unreachable"
                );
            };
            // invariant: victim indices are occupied by construction
            let sl = slots[v].take().expect("occupied victim slot");
            lost[sl.req] += sl.out.len() as u64;
            pg.release(v)?;
            pg.note_preempted();
            pending.insert_ready(ready, sl.req);
        }
    }
    Ok(())
}

/// Contain one failed lane attempt (prefill or step): transient →
/// schedule a retry with capped backoff and re-prefill marks;
/// permanently unhealthy → lane death, draining slots and queue
/// through the failover route or as `Failed`; exhausted retry budget
/// → fail only the in-flight slots; plus the per-lane circuit
/// breaker. Shared by the per-lane step loop and the speculative
/// draft microstep loop, so failure semantics are identical wherever
/// a backend is invoked.
#[allow(clippy::too_many_arguments)]
fn handle_step_failure(l: usize, lane: &mut Lane, healthy: bool,
                       now: f64, requests: &[DecodeRequest],
                       recovery: &RecoveryConfig, degraded: &[bool],
                       lost: &mut [u64],
                       pending: &mut ArrivalQueue,
                       results: &mut Vec<(usize, RequestResult)>,
                       reroutes: &mut Vec<(usize, usize, f64)>)
                       -> anyhow::Result<()> {
    lane.consec_fail = lane.consec_fail.saturating_add(1);
    let fb = recovery.fallback.get(l).copied().flatten();
    if !healthy {
        // permanent lane death: drain the in-flight slots and queue
        // (failover when configured, Failed otherwise) and never step
        // this lane again
        lane.dead = true;
        lane.open_until = f64::INFINITY;
        lane.refill.fill(0.0);
        lane.any_refill = false;
        for s in 0..lane.b {
            let Some(slot) = lane.slots[s].take() else {
                continue;
            };
            if let Some(pg) = lane.pager.as_mut() {
                pg.release(s)?;
            }
            // whichever way the slot drains, its decoded-so-far
            // tokens are dropped, not delivered: a reroute restarts
            // from scratch on the fallback lane, a failure delivers
            // nothing — either way the engine's work is lost and the
            // throughput/goodput split must see it
            lost[slot.req] += slot.out.len() as u64;
            match fb {
                Some(f) => {
                    reroutes.push((slot.req, f, now));
                }
                None => {
                    let arrival = pending.arrival_of(slot.req);
                    results.push((l, RequestResult {
                        id: requests[slot.req].id,
                        tokens: Vec::new(),
                        lost_tokens: lost[slot.req],
                        queue_steps: slot.entered_step,
                        decode_steps: lane.engine_steps
                            - slot.entered_step,
                        arrival_ms: arrival,
                        queue_ms: slot.admit_ms - arrival,
                        ttft_ms: now - arrival,
                        latency_ms: now - arrival,
                        outcome: RequestOutcome::Failed,
                        degraded: degraded[slot.req],
                        spec: SpecCounters::default(),
                    }));
                    pending.on_complete(slot.req, now);
                }
            }
        }
        for i in lane.ready.drain(..) {
            match fb {
                Some(f) => reroutes.push((i, f, now)),
                None => {
                    let arrival = pending.arrival_of(i);
                    results.push((l, RequestResult {
                        id: requests[i].id,
                        tokens: Vec::new(),
                        lost_tokens: lost[i],
                        queue_steps: 0,
                        decode_steps: 0,
                        arrival_ms: arrival,
                        queue_ms: now - arrival,
                        ttft_ms: now - arrival,
                        latency_ms: now - arrival,
                        outcome: RequestOutcome::Failed,
                        degraded: degraded[i],
                        spec: SpecCounters::default(),
                    }));
                    pending.on_complete(i, now);
                }
            }
        }
    } else if lane.attempt < recovery.retry.max_retries {
        // transient: schedule a retry with capped exponential backoff
        // and mark the occupied rows for re-prefill — each row's token
        // buffer already holds prompt + generated-so-far, so the
        // existing per-slot prefill path rebuilds the KV rows and the
        // resumed decode stays bitwise identical to an uninterrupted
        // one
        lane.attempt += 1;
        lane.retries += 1;
        lane.retry_at = now + recovery.retry.backoff_ms(lane.attempt);
        if lane.needs_prefill {
            for s in 0..lane.b {
                if lane.slots[s].is_some() {
                    lane.refill[s] = 1.0;
                    lane.any_refill = true;
                }
            }
        }
    } else {
        // retry budget exhausted: the in-flight slots fail (empty
        // token streams — partial output is dropped, not delivered);
        // the lane itself stays in service for later seatings
        lane.attempt = 0;
        for s in 0..lane.b {
            let Some(slot) = lane.slots[s].take() else {
                continue;
            };
            if let Some(pg) = lane.pager.as_mut() {
                pg.release(s)?;
            }
            // the decoded-but-undelivered partial is dropped work
            lost[slot.req] += slot.out.len() as u64;
            let arrival = pending.arrival_of(slot.req);
            results.push((l, RequestResult {
                id: requests[slot.req].id,
                tokens: Vec::new(),
                lost_tokens: lost[slot.req],
                queue_steps: slot.entered_step,
                decode_steps: lane.engine_steps - slot.entered_step,
                arrival_ms: arrival,
                queue_ms: slot.admit_ms - arrival,
                ttft_ms: now - arrival,
                latency_ms: now - arrival,
                outcome: RequestOutcome::Failed,
                degraded: degraded[slot.req],
                spec: SpecCounters::default(),
            }));
            pending.on_complete(slot.req, now);
        }
        lane.refill.fill(0.0);
        lane.any_refill = false;
    }
    // circuit breaker: N consecutive failed attempts open the lane
    // for a cooldown; with failover configured, its waiting requests
    // reroute instead of sitting the cooldown out
    if !lane.dead
        && recovery.breaker_threshold > 0
        && lane.consec_fail >= recovery.breaker_threshold
    {
        lane.open_until = now + recovery.breaker_cooldown_ms;
        lane.consec_fail = 0;
        if let Some(f) = fb {
            for i in lane.ready.drain(..) {
                reroutes.push((i, f, now));
            }
        }
    }
    Ok(())
}

/// Everything a serve call can vary: engine path, arrival timing, and
/// the two policies. [`ServeConfig::new`] gives the defaults (untimed,
/// FIFO, unbounded) that reproduce the pre-split behavior.
pub struct ServeConfig<'a> {
    /// Decode on the KV-resident incremental path instead of the
    /// literal-resident full-recompute path.
    pub use_kv: bool,
    /// Arrival-gate requests on this virtual-clock schedule (None =
    /// whole stream present at entry, wall-clock telemetry).
    pub schedule: Option<&'a Schedule>,
    /// Which ready request fills a freed slot.
    pub scheduler: &'a dyn Scheduler,
    /// Enqueue / shed / expire decisions.
    pub admission: &'a dyn AdmissionPolicy,
    /// Retry/backoff, circuit-breaker and failover knobs for the
    /// recovery layer (the default retries transient faults and never
    /// opens a breaker — inert unless a backend actually fails).
    pub recovery: RecoveryConfig,
    /// Deterministic fault plans to inject, by registry model name
    /// (`None` targets every lane; the single-model entry points
    /// accept `None` or `Some("default")`). Empty = no injection and
    /// bit-identical behavior to the pre-fault loop.
    pub faults: Vec<super::fault::FaultSpec>,
    /// Opt-in cross-model failover route `(from_model, to_model)`,
    /// resolved against the registry — requests bound for a dead or
    /// breaker-open `from` lane reroute to `to` and complete tagged
    /// degraded. Registry serving only.
    pub fallback: Option<(String, String)>,
    /// Opt-in speculative decoding `DRAFT=VERIFIER:k` (model names,
    /// resolved against the registry): requests routed to the
    /// verifier model are served draft-then-verify with output
    /// bitwise identical to plain verifier-only decode. Registry
    /// serving only.
    pub speculate: Option<SpecConfig>,
    /// Opt-in paged KV memory ([`super::pages`]): each lane's KV
    /// budget becomes fixed-size pages behind a free-list allocator,
    /// with memory-aware admission, preemption on a dry allocator and
    /// sliding-window eviction. `None` (the default) keeps the
    /// monolithic full-`ctx_len` allocation; unconstrained paging
    /// (no budget, no window) is bitwise identical to it. Mutually
    /// exclusive with [`Self::speculate`].
    pub paged: Option<PagedKvConfig>,
}

impl<'a> ServeConfig<'a> {
    /// Defaults: FIFO scheduling, unbounded admission, calibrated
    /// step costs, no chaos, no fallback.
    pub fn new(use_kv: bool) -> ServeConfig<'a> {
        ServeConfig {
            use_kv,
            schedule: None,
            scheduler: &Fifo,
            admission: &Unbounded,
            recovery: RecoveryConfig::default(),
            faults: Vec::new(),
            fallback: None,
            speculate: None,
            paged: None,
        }
    }

    /// Defaults plus a virtual-clock schedule.
    pub fn timed(use_kv: bool, schedule: &'a Schedule)
                 -> ServeConfig<'a> {
        ServeConfig { schedule: Some(schedule),
                      ..ServeConfig::new(use_kv) }
    }
}

/// Run a request stream to completion through the engine's
/// literal-resident path (`logits_last`: full-context recompute per
/// step) with FIFO scheduling and unbounded admission. Requests enter
/// slots in order; each finished slot is refilled from the queue
/// before the next model step. `dp` supplies the sampling knobs
/// (`no_repeat_ngram`); generation budgets come from each request's
/// `max_new_tokens`, not `dp.max_new_tokens`.
pub fn serve(
    engine: &DecodeEngine,
    requests: &[DecodeRequest],
    dp: &DecodeParams,
) -> anyhow::Result<ServeReport> {
    serve_with(engine, requests, dp, &ServeConfig::new(false))
}

/// [`serve`] over the KV-resident incremental path: a slot's cache is
/// populated once per (re)fill by the `prefill` artifact, then every
/// step runs `decode_step` — only `(B,)` token/pos vectors cross the
/// host boundary and per-token model work is O(1) in the context
/// length. Greedy output is bit-identical to [`serve`] and to
/// [`crate::generate::reference::greedy`] (integration-tested,
/// including across slot refills). Errors if the KV artifacts were not
/// compiled.
pub fn serve_kv(
    engine: &DecodeEngine,
    requests: &[DecodeRequest],
    dp: &DecodeParams,
) -> anyhow::Result<ServeReport> {
    serve_with(engine, requests, dp, &ServeConfig::new(true))
}

/// Arrival-gated serving on the virtual clock — the `loadgen`
/// simulation driver — with FIFO scheduling and unbounded admission.
/// Decoded tokens are exactly what [`serve`] / [`serve_kv`] produce
/// for the same prompts; only admission timing and the reported
/// `*_ms` telemetry differ. Deterministic for a given request list +
/// schedule.
pub fn serve_timed(
    engine: &DecodeEngine,
    requests: &[DecodeRequest],
    dp: &DecodeParams,
    use_kv: bool,
    schedule: &Schedule,
) -> anyhow::Result<ServeReport> {
    serve_with(engine, requests, dp,
               &ServeConfig::timed(use_kv, schedule))
}

/// One backend-construction site for every public entry point; the
/// fully explicit form (engine path + schedule + policies).
pub fn serve_with(
    engine: &DecodeEngine,
    requests: &[DecodeRequest],
    dp: &DecodeParams,
    cfg: &ServeConfig,
) -> anyhow::Result<ServeReport> {
    anyhow::ensure!(
        cfg.fallback.is_none(),
        "cross-model failover needs a multi-model registry (this \
         entry point serves a single lane)"
    );
    anyhow::ensure!(
        cfg.speculate.is_none(),
        "speculative decoding needs a multi-model registry (this \
         entry point serves a single lane)"
    );
    let names = [String::from("default")];
    let plans = plans_for_lanes(&cfg.faults, &names)?;
    let lane_of = vec![0usize; requests.len()];
    let costs = [LaneCost::unit()];
    let mut backend = backend_for(engine, cfg.use_kv)?;
    match &plans[0] {
        Some(plan) => {
            let mut faulty = FaultyBackend::new(backend, plan, 0)?;
            run_lanes_spec(&mut [&mut faulty], &names, &lane_of,
                           requests, dp, cfg.schedule, cfg.scheduler,
                           cfg.admission, &cfg.recovery, &costs, None,
                           cfg.paged.as_ref())
        }
        None => run_lanes_spec(&mut [backend.as_mut()], &names,
                               &lane_of, requests, dp, cfg.schedule,
                               cfg.scheduler, cfg.admission,
                               &cfg.recovery, &costs, None,
                               cfg.paged.as_ref()),
    }
}

/// Build the per-engine backend for one serve lane: the
/// literal-resident full-recompute path, or the KV-resident
/// incremental path over a fresh [`SessionState`] (errors if the KV
/// artifacts were not compiled). Shared by [`serve_with`] and
/// [`super::registry::ModelRegistry`], which builds one backend per
/// registered model.
pub(crate) fn backend_for<'e>(
    engine: &'e DecodeEngine<'_>,
    use_kv: bool,
) -> anyhow::Result<Box<dyn LogitsBackend + 'e>> {
    if use_kv {
        Ok(Box::new(KvBackend {
            engine,
            state: engine.kv_state()?,
            next_tok: vec![0i32; engine.decode_batch()],
        }))
    } else {
        Ok(Box::new(LiteralBackend { engine }))
    }
}

/// [`run_loop_with`] under the default policies (FIFO, unbounded) —
/// the pre-split entry point, kept for the mock-backed unit tests.
#[cfg(test)]
pub(crate) fn run_loop(
    backend: &mut dyn LogitsBackend,
    requests: &[DecodeRequest],
    dp: &DecodeParams,
    schedule: Option<&Schedule>,
) -> anyhow::Result<ServeReport> {
    run_loop_with(backend, requests, dp, schedule, &Fifo, &Unbounded)
}

/// [`run_lanes_with`] specialized to one anonymous lane under the
/// default recovery config — the single-engine state machine behind
/// the mock-backed unit tests (the public entry points go through
/// [`serve_with`], which also wires fault injection).
/// `DecodeRequest::model` is not consulted here: the one engine
/// serves every request (model routing is
/// [`super::registry::ModelRegistry`]'s job).
pub(crate) fn run_loop_with(
    backend: &mut dyn LogitsBackend,
    requests: &[DecodeRequest],
    dp: &DecodeParams,
    schedule: Option<&Schedule>,
    scheduler: &dyn Scheduler,
    admission: &dyn AdmissionPolicy,
) -> anyhow::Result<ServeReport> {
    let names = [String::from("default")];
    let lane_of = vec![0usize; requests.len()];
    run_lanes_with(&mut [backend], &names, &lane_of, requests, dp,
                   schedule, scheduler, admission,
                   &RecoveryConfig::default())
}

/// Per-lane serving state: one model's fixed decode geometry, its
/// token/pos buffers, batch slots and step counters. The registry's
/// "(model, slot)" pairs are exactly (lane index, slot index) here.
struct Lane {
    b: usize,
    t: usize,
    vocab: usize,
    tokens: Vec<i32>,
    pos: Vec<i32>,
    slots: Vec<Option<Slot>>,
    /// Admitted requests for this lane awaiting one of its slots,
    /// ordered by (arrival, index) — the scheduler picks from here.
    ready: Vec<usize>,
    needs_prefill: bool,
    refill: Vec<f32>,
    any_refill: bool,
    engine_steps: u64,
    slot_steps: u64,
    prefill_steps: u64,
    /// Recovery state: the lane is skipped while `now` is before
    /// `retry_at` (backoff after a transient failure) or `open_until`
    /// (circuit-breaker cooldown; +inf once the lane is `dead`).
    retry_at: f64,
    /// Consecutive failed attempts on the *current* in-flight work —
    /// reset on success and when the retry budget fails the slots.
    attempt: u32,
    /// Consecutive failed attempts feeding the circuit breaker —
    /// reset on success and when the breaker opens.
    consec_fail: u32,
    open_until: f64,
    dead: bool,
    /// Retries scheduled on this lane (ends up in `ServeStats`).
    retries: u64,
    /// Paged-KV state when serving under [`ServeConfig::paged`]: the
    /// free-list allocator, per-slot page tables and page counters.
    /// `None` (the default) is the monolithic full-`ctx_len`
    /// allocation discipline.
    pager: Option<LanePager>,
}

/// One slot-refill state machine for every decode path — and, since
/// the registry refactor, for any number of models at once: lane `l`
/// wraps `backends[l]` (its own geometry, slots and KV state), and
/// `lane_of[i]` routes request `i` to its model's lane. The host-side
/// bookkeeping (token buffers, positions, EOS/length-cap edges,
/// refill order, admission, telemetry) is identical across backends
/// and lanes; the paths differ only in how a step's logits are
/// produced, so any divergence between them is a model-side bug by
/// construction. With a single lane this is bit-for-bit the
/// pre-registry loop (pinned by the unit tests below and the
/// integration suite).
///
/// Per iteration: (1) arrivals up to `now` are admitted into their
/// lane's ready set or shed — admission decisions are model-aware
/// (the waiting count a policy sees is the request's own lane's
/// queue) — and queued requests past the admission deadline expire;
/// shed/expired requests still release their closed-loop successors;
/// (2) every free slot of every lane is filled with the scheduler's
/// pick from **that lane's** ready set (a freed `s75` slot only seats
/// `s75`-ready requests; zero-budget requests complete the moment
/// they are picked and never occupy a slot); (3) each lane with
/// occupied slots runs one model step — steps execute lane-by-lane on
/// the shared clock, modeling one accelerator multiplexing N resident
/// models — and finished requests leave with
/// [`RequestOutcome::Completed`].
///
/// Step errors are contained to their lane by the `recovery` layer: a
/// transient failure schedules a retry with capped backoff (occupied
/// rows re-prefill from tokens-so-far, so resumed decodes stay
/// bitwise identical), an exhausted retry budget fails only the
/// lane's in-flight slots ([`RequestOutcome::Failed`]), a permanently
/// dead backend drains its lane through the failover route (requests
/// restart on the fallback lane tagged degraded) or as `Failed`, and
/// N consecutive failed attempts open a per-lane circuit breaker for
/// a cooldown. A fault-free run is bit-identical to the pre-recovery
/// loop under every config.
///
/// Public (with [`mock`]) so the serve-invariant property suite in
/// `rust/tests/` can drive random traces × policies × lane counts
/// without compiled artifacts.
///
/// Every lane pays the [`Schedule`]'s full (dense) step cost here;
/// [`run_lanes_with_costs`] is the same machine with heterogeneous
/// per-lane [`LaneCost`] multipliers.
#[allow(clippy::too_many_arguments)]
pub fn run_lanes_with(
    backends: &mut [&mut dyn LogitsBackend],
    names: &[String],
    lane_of: &[usize],
    requests: &[DecodeRequest],
    dp: &DecodeParams,
    schedule: Option<&Schedule>,
    scheduler: &dyn Scheduler,
    admission: &dyn AdmissionPolicy,
    recovery: &RecoveryConfig,
) -> anyhow::Result<ServeReport> {
    let costs = vec![LaneCost::unit(); backends.len()];
    run_lanes_with_costs(backends, names, lane_of, requests, dp,
                         schedule, scheduler, admission, recovery,
                         &costs)
}

/// [`run_lanes_with`] with heterogeneous per-lane step costs: lane
/// `l`'s model invocations advance the virtual clock by
/// `lane_costs[l].step_scale × Schedule::step_ms` (and likewise for
/// prefill), so a lane serving a sparse checkpoint steps cheaper than
/// a dense one in proportion to its realized density — the
/// sparsity→capacity win on the virtual timeline. Costs shape *time
/// only*: admitted requests decode exactly the same tokens under any
/// cost vector (what changes is which requests are concurrently
/// in-flight when admission or deadlines bite, and the reported
/// `*_ms` telemetry). At unit costs this is bit-for-bit
/// [`run_lanes_with`]. `lane_costs` must supply one finite positive
/// scale pair per lane.
#[allow(clippy::too_many_arguments)]
pub fn run_lanes_with_costs(
    backends: &mut [&mut dyn LogitsBackend],
    names: &[String],
    lane_of: &[usize],
    requests: &[DecodeRequest],
    dp: &DecodeParams,
    schedule: Option<&Schedule>,
    scheduler: &dyn Scheduler,
    admission: &dyn AdmissionPolicy,
    recovery: &RecoveryConfig,
    lane_costs: &[LaneCost],
) -> anyhow::Result<ServeReport> {
    run_lanes_spec(backends, names, lane_of, requests, dp, schedule,
                   scheduler, admission, recovery, lane_costs, None,
                   None)
}

/// [`run_lanes_with_costs`] plus an optional speculative-decoding
/// plan. With `spec = Some(plan)`, every request seated on
/// `plan.verifier_lane` is served draft-then-verify:
///
///  * **draft** — before the per-lane step round, each verifier slot
///    with no pending drafts leases a *free* row on the draft lane
///    (re-prefilled from its committed tokens) and the draft lane
///    runs up to `k` greedy microsteps, each at the draft lane's
///    [`LaneCost`]; the draft lane's own residents keep decoding
///    normally through those microsteps (their tokens are unaffected
///    — rows are independent).
///  * **verify** — the verifier lane's one step scores every pending
///    draft at once: the slot's own row reads the last committed
///    position and each leased free verifier row replicates the row's
///    tokens at one draft offset, so row `i` yields the dense pick
///    for committed position `m + i`. Costs one verifier-scale step.
///  * **commit** — the longest agreeing draft prefix plus the
///    verifier's next pick (first correction, or the bonus token when
///    everything matched) commit through the same sequential
///    EOS/ctx/budget edges as plain decode, so every verify commits
///    ≥ 1 pick and output is bitwise the dense greedy stream. With
///    fewer free rows than drafts the unchecked tail is retained for
///    the next verify (progress never deadlocks on lease starvation).
///
/// Degradation is built in: when the draft lane is dead, backing off,
/// breaker-open, or out of free rows, verifier slots simply step as
/// plain dense decode that round — a draft-lane fault can never fail
/// (or even stall) a verifier-lane request. With `spec = None` this
/// is bit-for-bit [`run_lanes_with_costs`].
///
/// With `paged = Some(cfg)`, every lane's KV memory is served from a
/// fixed-size-page free list ([`super::pages`]): seating allocates
/// the request's reservation (requeueing it when pages are short),
/// decode grows page tables one page at a time (preempting the
/// youngest-seated slot when the allocator runs dry — its
/// decoded-so-far tokens are dropped as lost and it requeues), a
/// sliding window evicts oldest pages so rows run past `ctx_len`, and
/// memory-aware admission policies can shed on page pressure.
/// Unconstrained paging (no budget, no window) makes exactly the
/// monolithic loop's decisions and is bitwise identical to
/// `paged = None`. Speculative decoding and paging are mutually
/// exclusive (draft-row leases bypass the page accounting).
#[allow(clippy::too_many_arguments)]
pub fn run_lanes_spec(
    backends: &mut [&mut dyn LogitsBackend],
    names: &[String],
    lane_of: &[usize],
    requests: &[DecodeRequest],
    dp: &DecodeParams,
    schedule: Option<&Schedule>,
    scheduler: &dyn Scheduler,
    admission: &dyn AdmissionPolicy,
    recovery: &RecoveryConfig,
    lane_costs: &[LaneCost],
    spec: Option<&SpecPlan>,
    paged: Option<&PagedKvConfig>,
) -> anyhow::Result<ServeReport> {
    let n_lanes = backends.len();
    anyhow::ensure!(lane_costs.len() == n_lanes,
                    "{} lane costs for {} lanes", lane_costs.len(),
                    n_lanes);
    for (l, c) in lane_costs.iter().enumerate() {
        c.validate().map_err(|e| e.context(format!(
            "lane {l} ({})", names.get(l).map(|s| s.as_str())
                .unwrap_or("?"))))?;
    }
    anyhow::ensure!(n_lanes > 0, "serve loop needs at least one lane");
    anyhow::ensure!(names.len() == n_lanes,
                    "{} lane names for {} lanes", names.len(), n_lanes);
    anyhow::ensure!(lane_of.len() == requests.len(),
                    "{} lane assignments for {} requests",
                    lane_of.len(), requests.len());
    let mut lanes: Vec<Lane> = backends
        .iter()
        .map(|be| {
            let (b, t, vocab) = be.dims();
            Lane {
                b,
                t,
                vocab,
                tokens: vec![0i32; b * t],
                pos: vec![0i32; b],
                slots: (0..b).map(|_| None).collect(),
                ready: Vec::new(),
                needs_prefill: be.needs_prefill(),
                refill: vec![0f32; b],
                any_refill: false,
                engine_steps: 0,
                slot_steps: 0,
                prefill_steps: 0,
                retry_at: 0.0,
                attempt: 0,
                consec_fail: 0,
                open_until: 0.0,
                dead: false,
                retries: 0,
                pager: None,
            }
        })
        .collect();
    for (i, (r, &l)) in requests.iter().zip(lane_of).enumerate() {
        anyhow::ensure!(l < n_lanes,
                        "request {i} routed to lane {l} of {n_lanes}");
        anyhow::ensure!(!r.prompt.is_empty(),
                        "empty prompt in decode request stream");
        anyhow::ensure!(
            r.prompt.len() < lanes[l].t,
            "prompt longer than ctx_len - 1 ({}) for model {} in \
             decode request stream — pre-truncate (keeping the tail) \
             with coordinator::prompt_tokens",
            lanes[l].t - 1, names[l]
        );
    }
    if let Some(s) = schedule {
        s.validate(requests.len())?;
    }
    recovery.validate(n_lanes)?;
    if let Some(plan) = spec {
        plan.validate(n_lanes)?;
    }
    anyhow::ensure!(
        spec.is_none() || paged.is_none(),
        "speculative decoding and paged KV are mutually exclusive \
         (draft-row leases bypass the page accounting)"
    );
    if let Some(cfg) = paged {
        for (l, lane) in lanes.iter_mut().enumerate() {
            lane.pager = Some(
                LanePager::new(cfg, lane.b, lane.t).map_err(|e| {
                    e.context(format!("lane {l} ({})", names[l]))
                })?,
            );
        }
    }
    let deadline = admission.deadline_ms();
    if let Some(d) = deadline {
        anyhow::ensure!(d.is_finite() && d > 0.0,
                        "queue deadline must be positive and finite \
                         (got {d})");
    }

    let mut clock = Clock::new(schedule);
    let mut pending = ArrivalQueue::new(requests.len(), schedule);
    // (lane, result) pairs — the lane tag feeds the per-model stats
    // split after the loop and never reaches the caller.
    let mut results: Vec<(usize, RequestResult)> =
        Vec::with_capacity(requests.len());
    // Live routing table: starts as the caller's lane_of and diverges
    // only when the recovery layer fails a request over. Per-model
    // offered counts and result lane tags both follow `route`, so a
    // model's block describes the traffic it actually served.
    let mut route: Vec<usize> = lane_of.to_vec();
    let mut degraded: Vec<bool> = vec![false; requests.len()];
    // Per-request dropped-work counter: tokens a lane decoded for the
    // request that will never be delivered (fault-failed partials,
    // failover restarts, paged preemptions). Rides into every result
    // so the throughput/goodput split stays honest.
    let mut lost: Vec<u64> = vec![0u64; requests.len()];

    loop {
        let now = clock.now_ms();

        // Admission: arrivals up to `now` are enqueued or shed;
        // queued requests past the deadline expire. Loop to a
        // fixpoint — shedding/expiring a closed-loop predecessor can
        // release a successor that is itself already due.
        loop {
            let mut moved = false;
            let free: Vec<usize> = lanes
                .iter()
                .map(|ln| ln.slots.iter().filter(|s| s.is_none())
                    .count())
                .collect();
            while let Some(i) = pending.pop_ready(now) {
                moved = true;
                let mut l = route[i];
                let arrival = pending.arrival_of(i);
                // recovery routing: an arrival bound for a dead or
                // breaker-open lane fails over when a usable fallback
                // is configured; without one, dead-lane arrivals fail
                // at arrival (mirroring shed telemetry) and open-lane
                // arrivals queue out the cooldown
                if lanes[l].dead || now < lanes[l].open_until {
                    let fb = recovery.fallback.get(l).copied()
                        .flatten()
                        .filter(|&f| !lanes[f].dead
                            && requests[i].prompt.len() < lanes[f].t);
                    match fb {
                        Some(f) => {
                            route[i] = f;
                            degraded[i] = true;
                            l = f;
                        }
                        None if lanes[l].dead => {
                            results.push((l, RequestResult {
                                id: requests[i].id,
                                tokens: Vec::new(),
                                lost_tokens: lost[i],
                                queue_steps: 0,
                                decode_steps: 0,
                                arrival_ms: arrival,
                                queue_ms: 0.0,
                                ttft_ms: 0.0,
                                latency_ms: 0.0,
                                outcome: RequestOutcome::Failed,
                                degraded: false,
                                spec: SpecCounters::default(),
                            }));
                            pending.on_complete(i, arrival);
                            continue;
                        }
                        None => {}
                    }
                }
                // a request that will seat immediately never consults
                // the policy — only genuine waiters can be shed; the
                // waiting count is the request's OWN lane's queue.
                // Under paged KV the memory-aware axis is consulted
                // too: the pages this prompt needs against the lane's
                // free pages (policies default to accepting).
                let page_ok = match lanes[l].pager.as_ref() {
                    Some(pg) => admission.admit_pages(
                        pg.seat_need(requests[i].prompt.len()),
                        pg.free_pages()),
                    None => true,
                };
                if page_ok
                    && (lanes[l].ready.len() < free[l]
                        || admission
                            .admit(lanes[l].ready.len() - free[l]))
                {
                    // keep each ready set sorted by (arrival, index):
                    // pops arrive in that order already EXCEPT a
                    // closed-loop successor released by a failure,
                    // whose back-dated arrival can predate entries
                    // admitted earlier in this fixpoint — it must
                    // queue ahead of them, not behind
                    pending.insert_ready(&mut lanes[l].ready, i);
                } else {
                    if !page_ok {
                        if let Some(pg) = lanes[l].pager.as_mut() {
                            pg.note_shed();
                        }
                    }
                    results.push((l, RequestResult {
                        id: requests[i].id,
                        tokens: Vec::new(),
                        lost_tokens: lost[i],
                        queue_steps: 0,
                        decode_steps: 0,
                        arrival_ms: arrival,
                        queue_ms: 0.0,
                        ttft_ms: 0.0,
                        latency_ms: 0.0,
                        outcome: RequestOutcome::Shed,
                        degraded: degraded[i],
                        spec: SpecCounters::default(),
                    }));
                    // rejection happens AT arrival (the telemetry
                    // above says so); the closed-loop successor is
                    // released from that instant, not from the lazy
                    // step-boundary discovery — mirroring the
                    // back-dated expiry release below
                    pending.on_complete(i, arrival);
                }
            }
            if let Some(d) = deadline {
                for (l, lane) in lanes.iter_mut().enumerate() {
                    let mut k = 0;
                    while k < lane.ready.len() {
                        let i = lane.ready[k];
                        let arrival = pending.arrival_of(i);
                        if now - arrival > d {
                            lane.ready.remove(k);
                            moved = true;
                            // the caller gave up at arrival + d; lazy
                            // discovery must not inflate the reported
                            // wait
                            results.push((l, RequestResult {
                                id: requests[i].id,
                                tokens: Vec::new(),
                                lost_tokens: lost[i],
                                queue_steps: 0,
                                decode_steps: 0,
                                arrival_ms: arrival,
                                queue_ms: d,
                                ttft_ms: d,
                                latency_ms: d,
                                outcome: RequestOutcome::Expired,
                                degraded: degraded[i],
                                spec: SpecCounters::default(),
                            }));
                            pending.on_complete(i, arrival + d);
                        } else {
                            k += 1;
                        }
                    }
                }
            }
            if !moved {
                break;
            }
        }

        // Scheduling: fill every free slot of every lane with the
        // policy's pick from that lane's ready set. Zero-budget
        // requests complete the moment they are picked (greedy with
        // `max_new_tokens == 0` decodes nothing) and never occupy a
        // slot.
        for (l, lane) in lanes.iter_mut().enumerate() {
            // a dead lane's queue was drained at death; an open
            // breaker holds seating until the cooldown passes
            if lane.dead || now < lane.open_until {
                continue;
            }
            'slots: for s in 0..lane.b {
                if lane.slots[s].is_some() {
                    continue;
                }
                while !lane.ready.is_empty() {
                    let k = scheduler.pick(&lane.ready, requests);
                    anyhow::ensure!(k < lane.ready.len(),
                                    "scheduler {} picked {k} from a \
                                     ready set of {}", scheduler.name(),
                                    lane.ready.len());
                    let i = lane.ready.remove(k);
                    let arrival = pending.arrival_of(i);
                    if requests[i].max_new_tokens == 0 {
                        results.push((l, RequestResult {
                            id: requests[i].id,
                            tokens: Vec::new(),
                            lost_tokens: lost[i],
                            queue_steps: lane.engine_steps,
                            decode_steps: 0,
                            arrival_ms: arrival,
                            queue_ms: now - arrival,
                            ttft_ms: now - arrival,
                            latency_ms: now - arrival,
                            outcome: RequestOutcome::Completed,
                            degraded: degraded[i],
                            spec: SpecCounters::default(),
                        }));
                        pending.on_complete(i, now);
                        continue;
                    }
                    // paged seating: the request's page reservation
                    // must allocate before the slot fills; when pages
                    // are short it requeues at its original
                    // (arrival, index) rank and this lane stops
                    // seating — head-of-line blocking keeps the
                    // scheduler's order instead of letting a smaller
                    // prompt jump the starved pick
                    let seated = match lane.pager.as_mut() {
                        Some(pg) =>
                            pg.try_seat(s, requests[i].prompt.len()),
                        None => true,
                    };
                    if !seated {
                        pending.insert_ready(&mut lane.ready, i);
                        break 'slots;
                    }
                    fill_slot(&mut lane.tokens, &mut lane.pos, lane.t,
                              s, &requests[i].prompt);
                    if lane.needs_prefill {
                        lane.refill[s] = 1.0;
                        lane.any_refill = true;
                    }
                    lane.slots[s] = Some(Slot {
                        req: i,
                        out: Vec::new(),
                        entered_step: lane.engine_steps,
                        admit_ms: now,
                        first_tok_ms: None,
                        spec: SpecCounters::default(),
                        spec_pending: Vec::new(),
                    });
                    break;
                }
            }
            let occupied =
                lane.slots.iter().filter(|s| s.is_some()).count();
            if let Some(pg) = lane.pager.as_mut() {
                // peak concurrently-seated requests — the bench paged
                // leg's max-concurrency-at-fixed-memory datapoint
                pg.note_seated(occupied);
            }
        }

        if lanes.iter()
            .all(|ln| ln.slots.iter().all(|s| s.is_none()))
            && lanes.iter().all(|ln| ln.ready.is_empty())
        {
            // the fill stage drains every live lane's ready set
            // whenever a slot is free (a breaker-open lane keeps its
            // queue and is handled by the wake computation below), so
            // only future or gated arrivals can remain here
            if pending.is_empty() {
                break;
            }
            match pending.next_arrival() {
                // idle: nothing decoding, next arrival in the future
                Some(next) => {
                    clock.jump_to(next);
                    continue;
                }
                None => anyhow::bail!(
                    "request queue deadlocked: gated requests remain \
                     but nothing will release them"
                ),
            }
        }

        // One model step per lane with work, in lane order on the
        // shared clock — each lane's invocation advances the virtual
        // clock, so an N-model registry pays N step costs per round
        // (one accelerator, N resident models served in turn). A
        // failed attempt is contained to its lane: the error never
        // propagates out of the loop (regression-tested — a transient
        // mid-run fault used to abort the whole run).
        let mut stepped = false;
        // (request, fallback lane, failure instant) — applied after
        // the lane loop, since rerouting pushes into *another* lane's
        // ready set while this loop holds all lanes mutably.
        let mut reroutes: Vec<(usize, usize, f64)> = Vec::new();

        // Speculative draft phase: before the per-lane step round,
        // each verifier slot with no pending drafts leases a free
        // draft-lane row (seeded with its committed tokens, KV
        // re-prefilled) and the draft lane runs up to k greedy
        // microsteps ahead, each at the draft lane's cost. Skipped —
        // degrading those slots to plain dense decode this round —
        // when the draft lane is dead, backing off, cooling a
        // breaker, or out of free rows.
        let mut drafted_lane: Option<usize> = None;
        if let Some(plan) = spec {
            let (d, v) = (plan.draft_lane, plan.verifier_lane);
            let now = clock.now_ms();
            let draft_usable = !lanes[d].dead
                && now >= lanes[d].retry_at
                && now >= lanes[d].open_until;
            let verifier_live =
                !lanes[v].dead && now >= lanes[v].open_until;
            // (verifier slot, committed tokens, m, draft depth)
            let mut jobs: Vec<(usize, Vec<i32>, usize, usize)> =
                Vec::new();
            if draft_usable && verifier_live {
                let t_d = lanes[d].t;
                let vl = &lanes[v];
                for s in 0..vl.b {
                    let Some(slot) = vl.slots[s].as_ref() else {
                        continue;
                    };
                    if !slot.spec_pending.is_empty() {
                        // still holding proposals for the next verify
                        continue;
                    }
                    let m = vl.pos[s] as usize + 1;
                    let budget = requests[slot.req].max_new_tokens
                        .saturating_sub(slot.out.len());
                    // depth capped by the remaining budget, the draft
                    // row's context (committed tokens seat at 0..m-1;
                    // microstep i writes position m-1+i) and the
                    // verifier's committable positions (m..t-1)
                    let want = plan.k.min(budget)
                        .min(t_d.saturating_sub(m))
                        .min((vl.t - 1).saturating_sub(m));
                    if want == 0 {
                        continue; // degrade: plain dense this round
                    }
                    jobs.push((s,
                               vl.tokens[s * vl.t..s * vl.t + m]
                                   .to_vec(),
                               m, want));
                }
            }
            if !jobs.is_empty() {
                let lane = &mut lanes[d];
                let backend = &mut backends[d];
                let t_d = lane.t;
                // lease free draft rows, lowest index first, to
                // verifier slots in slot order; starved jobs degrade
                let free: Vec<usize> = (0..lane.b)
                    .filter(|&r| lane.slots[r].is_none())
                    .collect();
                // (verifier slot, draft row, depth, proposals, live)
                let mut leases: Vec<(usize, usize, usize, Vec<u32>,
                                     bool)> = Vec::new();
                for ((vslot, prefix, m, want), &r) in
                    jobs.into_iter().zip(free.iter())
                {
                    let row =
                        &mut lane.tokens[r * t_d..(r + 1) * t_d];
                    row.fill(0);
                    row[..prefix.len()].copy_from_slice(&prefix);
                    lane.pos[r] = m as i32 - 1;
                    if lane.needs_prefill {
                        lane.refill[r] = 1.0;
                        lane.any_refill = true;
                    }
                    leases.push((vslot, r, want, Vec::new(), true));
                }
                let rounds = leases.iter()
                    .map(|&(_, _, want, _, _)| want)
                    .max().unwrap_or(0);
                let occupied = lane.slots.iter()
                    .filter(|s| s.is_some()).count();
                for _ in 0..rounds {
                    if !leases.iter().any(|&(.., live)| live) {
                        break;
                    }
                    let mut attempt_err = None;
                    if lane.needs_prefill && lane.any_refill {
                        match backend.prefill(&lane.tokens, &lane.pos,
                                              &lane.refill) {
                            Ok(()) => {
                                lane.prefill_steps += 1;
                                lane.refill.fill(0.0);
                                lane.any_refill = false;
                                clock.on_prefill(
                                    lane_costs[d].prefill_scale);
                            }
                            Err(e) => attempt_err = Some(e),
                        }
                    }
                    let mut lv = Vec::new();
                    if attempt_err.is_none() {
                        match backend.step(&lane.tokens, &lane.pos) {
                            Ok(x) => lv = x,
                            Err(e) => attempt_err = Some(e),
                        }
                    }
                    stepped = true;
                    drafted_lane = Some(d);
                    clock.on_step(lane_costs[d].step_scale);
                    if attempt_err.is_some() {
                        // the draft lane fails like any lane (its own
                        // residents retry / reroute / fail);
                        // proposals so far stay valid and are handed
                        // to the verifier below — a draft fault never
                        // touches a verifier-lane request
                        let now = clock.now_ms();
                        handle_step_failure(d, lane,
                                            backend.healthy(), now,
                                            requests, recovery,
                                            &degraded, &mut lost,
                                            &mut pending,
                                            &mut results,
                                            &mut reroutes)?;
                        break;
                    }
                    lane.attempt = 0;
                    lane.consec_fail = 0;
                    lane.engine_steps += 1;
                    let live = leases.iter()
                        .filter(|&&(.., l)| l).count();
                    lane.slot_steps += (occupied + live) as u64;
                    let spike = backend.take_spike_ms();
                    if spike > 0.0 {
                        clock.advance(spike);
                    }
                    let now = clock.now_ms();
                    // the draft lane's own residents advance one
                    // token per microstep, exactly as a plain round
                    for s in 0..lane.b {
                        if lane.slots[s].is_none() {
                            continue;
                        }
                        if commit_slot(lane, s, &[], &lv, dp,
                                       requests, now, false)
                        {
                            finish_slot(lane, s, now, requests,
                                        &route, &degraded, &lost,
                                        &mut pending, &mut results)?;
                        }
                    }
                    // extend each live lease by one greedy proposal
                    for (_, r, want, got, live) in leases.iter_mut() {
                        if !*live {
                            continue;
                        }
                        let row = &lv[*r * lane.vocab
                                      ..(*r + 1) * lane.vocab];
                        let cur = lane.pos[*r] as usize;
                        let ctx: Vec<u32> = if dp.no_repeat_ngram > 0
                        {
                            (0..=cur)
                                .map(|j| lane.tokens[*r * t_d + j]
                                     as u32)
                                .collect()
                        } else {
                            Vec::new()
                        };
                        let next = topk::pick_next(
                            row, &ctx, dp.no_repeat_ngram);
                        got.push(next);
                        let new_pos = cur + 1;
                        if next == EOS || new_pos >= t_d {
                            // can't extend past EOS (or the row);
                            // the verifier decides what commits
                            *live = false;
                        } else {
                            lane.tokens[*r * t_d + new_pos] =
                                next as i32;
                            lane.pos[*r] = new_pos as i32;
                        }
                        if got.len() >= *want {
                            *live = false;
                        }
                    }
                }
                // hand the proposals to their verifier slots
                for (vslot, _, _, got, _) in leases {
                    if got.is_empty() {
                        continue;
                    }
                    if let Some(slot) = lanes[v].slots[vslot].as_mut()
                    {
                        slot.spec.drafted += got.len() as u64;
                        slot.spec_pending = got;
                    }
                }
            }
        }

        for (l, (lane, backend)) in
            lanes.iter_mut().zip(backends.iter_mut()).enumerate()
        {
            if drafted_lane == Some(l) {
                // the draft lane already ran its microsteps (and its
                // residents their commits) this iteration
                continue;
            }
            let occupied =
                lane.slots.iter().filter(|s| s.is_some()).count();
            if occupied == 0 || lane.dead {
                continue;
            }
            let lane_now = clock.now_ms();
            if lane_now < lane.retry_at || lane_now < lane.open_until {
                // backing off after a transient failure, or cooling
                // down an open breaker
                continue;
            }
            // Sliding-window eviction (paged KV): before the step,
            // any slot holding more resident tokens than the window
            // frees its oldest page and the token row shifts left by
            // one page (the KV cache re-prefills from the shifted
            // row), so `pos` stays below the `ctx_len` cap edge
            // forever and generation runs past it on a bounded cache.
            if lane.pager.is_some() {
                let Lane { pager, tokens, pos, slots, refill,
                           any_refill, needs_prefill, t, .. } = lane;
                // invariant: guarded by the `is_some` check above
                let pg = pager.as_mut()
                    .expect("pager present inside paged block");
                let ps = pg.page_size();
                for s in 0..slots.len() {
                    if slots[s].is_none() {
                        continue;
                    }
                    while pg.should_evict(s) {
                        let used = pos[s] as usize + 1;
                        pg.evict_front(s)?;
                        let row = &mut tokens[s * *t..(s + 1) * *t];
                        row.copy_within(ps..used, 0);
                        row[used - ps..].fill(0);
                        pos[s] = (used - ps) as i32 - 1;
                        if *needs_prefill {
                            refill[s] = 1.0;
                            *any_refill = true;
                        }
                    }
                }
            }
            // Speculative verify staging: write each slot's pending
            // drafts into its own row past the committed position
            // (junk beyond `pos` is harmless to every backend) and
            // lease free rows — one replica per checkable draft
            // offset, shared pool in slot order — so this one step
            // scores every proposed position at once. Leased rows
            // re-prefill from their replicated tokens on the KV path.
            let spec_on =
                spec.map_or(false, |p| p.verifier_lane == l);
            let mut slot_leases: Vec<Vec<usize>> = Vec::new();
            let mut lease_count = 0usize;
            if spec_on {
                slot_leases = vec![Vec::new(); lane.b];
                let free: Vec<usize> = (0..lane.b)
                    .filter(|&r| lane.slots[r].is_none())
                    .collect();
                let mut free_rows = free.into_iter();
                let t = lane.t;
                for s in 0..lane.b {
                    let pending_toks = match lane.slots[s].as_ref() {
                        Some(slot) if !slot.spec_pending.is_empty() =>
                            slot.spec_pending.clone(),
                        _ => continue,
                    };
                    let m = lane.pos[s] as usize + 1;
                    // positions m..t-1 are the only committable ones
                    // (committing t-1 terminates), and the own row
                    // already covers position m — so at most t-1-m
                    // drafts are worth staging, and no leased row
                    // ever steps at a position plain decode wouldn't
                    let n_stage = pending_toks.len()
                        .min((t - 1).saturating_sub(m));
                    for (i, &d) in
                        pending_toks.iter().take(n_stage).enumerate()
                    {
                        lane.tokens[s * t + m + i] = d as i32;
                    }
                    let row: Vec<i32> =
                        lane.tokens[s * t..(s + 1) * t].to_vec();
                    for i in 1..=n_stage {
                        let Some(r) = free_rows.next() else {
                            break;
                        };
                        lane.tokens[r * t..(r + 1) * t]
                            .copy_from_slice(&row);
                        lane.pos[r] = (m - 1 + i) as i32;
                        if lane.needs_prefill {
                            lane.refill[r] = 1.0;
                            lane.any_refill = true;
                        }
                        slot_leases[s].push(r);
                        lease_count += 1;
                    }
                }
            }
            // run the attempt (prefill if pending, then one step)
            // with the error contained instead of propagated
            let mut attempt_err = None;
            if lane.needs_prefill && lane.any_refill {
                // populate the marked rows' caches (positions up to
                // and including `pos`) from their prompt rows; other
                // rows pass through untouched
                match backend.prefill(&lane.tokens, &lane.pos,
                                      &lane.refill) {
                    Ok(()) => {
                        lane.prefill_steps += 1;
                        lane.refill.fill(0.0);
                        lane.any_refill = false;
                        clock.on_prefill(lane_costs[l].prefill_scale);
                    }
                    Err(e) => attempt_err = Some(e),
                }
            }
            let mut lv = Vec::new();
            if attempt_err.is_none() {
                match backend.step(&lane.tokens, &lane.pos) {
                    Ok(v) => lv = v,
                    Err(e) => attempt_err = Some(e),
                }
            }
            stepped = true;
            // a failed attempt burns a step's worth of time too —
            // containment must not make failure cheaper than success
            clock.on_step(lane_costs[l].step_scale);

            if attempt_err.is_some() {
                // pending drafts survive a failed verify attempt —
                // the committed prefix is unchanged, so they stay
                // valid proposals for the retried step
                let now = clock.now_ms();
                handle_step_failure(l, lane, backend.healthy(), now,
                                    requests, recovery, &degraded,
                                    &mut lost, &mut pending,
                                    &mut results, &mut reroutes)?;
                continue;
            }
            lane.attempt = 0;
            lane.consec_fail = 0;
            lane.engine_steps += 1;
            // leased verify replicas occupy real batch rows for the
            // step, so they count toward slot-steps and occupancy
            lane.slot_steps += (occupied + lease_count) as u64;
            // injected latency spikes ride on top of the fixed step
            // cost (tokens are unaffected; only the clock moves)
            let spike = backend.take_spike_ms();
            if spike > 0.0 {
                clock.advance(spike);
            }
            let now = clock.now_ms();

            for s in 0..lane.b {
                if lane.slots[s].is_none() {
                    continue;
                }
                let leased: &[usize] = if spec_on {
                    &slot_leases[s]
                } else {
                    &[]
                };
                if commit_slot(lane, s, leased, &lv, dp, requests,
                               now, spec_on)
                {
                    finish_slot(lane, s, now, requests, &route,
                                &degraded, &lost, &mut pending,
                                &mut results)?;
                    // the freed slot refills from its lane's queue at
                    // the top of the next iteration, before the next
                    // model step
                }
            }
            // Paged growth: the surviving slots' page tables must
            // cover the tokens this step committed; a dry allocator
            // preempts the youngest-seated other slot per the paging
            // contract (its decoded-so-far tokens are dropped as
            // lost and it requeues at its original arrival).
            grow_paged(lane, &mut pending, &mut lost)?;
        }

        // Apply deferred failovers: restart each affected request
        // from scratch on its fallback lane (generated-so-far is
        // dropped — the fallback model would decode a different
        // continuation anyway), queued by original arrival. If the
        // fallback itself is unusable by now, the request fails at
        // the instant its own lane did.
        for (i, f, t_fail) in reroutes {
            if lanes[f].dead || requests[i].prompt.len() >= lanes[f].t
            {
                let arrival = pending.arrival_of(i);
                results.push((route[i], RequestResult {
                    id: requests[i].id,
                    tokens: Vec::new(),
                    lost_tokens: lost[i],
                    queue_steps: 0,
                    decode_steps: 0,
                    arrival_ms: arrival,
                    queue_ms: t_fail - arrival,
                    ttft_ms: t_fail - arrival,
                    latency_ms: t_fail - arrival,
                    outcome: RequestOutcome::Failed,
                    degraded: degraded[i],
                    spec: SpecCounters::default(),
                }));
                pending.on_complete(i, t_fail);
            } else {
                route[i] = f;
                degraded[i] = true;
                pending.insert_ready(&mut lanes[f].ready, i);
            }
        }

        if !stepped {
            // nothing could step: every lane with work is waiting out
            // a retry backoff or breaker cooldown. Advance to the
            // earliest wake-up (or next arrival) instead of spinning
            // — on the virtual clock this loop would otherwise never
            // move time forward again.
            let mut wake = f64::INFINITY;
            for lane in &lanes {
                if lane.dead {
                    continue;
                }
                if lane.slots.iter().any(|s| s.is_some())
                    || !lane.ready.is_empty()
                {
                    wake = wake.min(lane.retry_at.max(lane.open_until));
                }
            }
            if let Some(next) = pending.next_arrival() {
                wake = wake.min(next);
            }
            anyhow::ensure!(
                wake.is_finite(),
                "request queue deadlocked: requests remain but every \
                 lane able to serve them is dead"
            );
            clock.wait_until(wake);
        }
    }

    results.sort_by_key(|(_, r)| r.id);
    let wall_secs = clock.wall_secs();
    let sim_ms = clock.now_ms();

    let total_batch: usize = lanes.iter().map(|ln| ln.b).sum();
    let engine_steps: u64 =
        lanes.iter().map(|ln| ln.engine_steps).sum();
    let prefill_steps: u64 =
        lanes.iter().map(|ln| ln.prefill_steps).sum();
    let slot_steps: u64 = lanes.iter().map(|ln| ln.slot_steps).sum();
    // capacity in slot-steps: each lane only offers its own batch
    // during its own steps, so heterogeneous lanes cannot use the
    // aggregate `engine_steps * decode_batch` product (for one lane
    // the two are the same expression)
    let capacity: u64 =
        lanes.iter().map(|ln| ln.engine_steps * ln.b as u64).sum();

    let retries: u64 = lanes.iter().map(|ln| ln.retries).sum();

    // Page-counter snapshots after the loop drained, so leaked_pages
    // (pages still owned) is meaningful — it must be 0.
    let lane_pages: Vec<PageCounters> = lanes
        .iter()
        .map(|ln| ln.pager.as_ref().map(|p| p.counters())
            .unwrap_or_default())
        .collect();
    let mut agg_pages = PageCounters::default();
    for c in &lane_pages {
        agg_pages.absorb(c);
    }

    let all_refs: Vec<&RequestResult> =
        results.iter().map(|(_, r)| r).collect();
    let mut stats = ServeStats::from_results(
        &all_refs, requests.len(), total_batch, engine_steps,
        prefill_steps, slot_steps, wall_secs, sim_ms, retries);
    stats.occupancy = if capacity == 0 {
        0.0
    } else {
        slot_steps as f64 / capacity as f64
    };
    stats.pages = agg_pages;

    // a single lane's block is just the aggregate; the multi-lane
    // split aggregates through references — decoded token buffers are
    // never copied for telemetry
    let per_model: Vec<ModelStats> = if n_lanes == 1 {
        vec![ModelStats { model: names[0].clone(),
                          stats: stats.clone() }]
    } else {
        names
            .iter()
            .enumerate()
            .map(|(l, name)| {
                let lane_refs: Vec<&RequestResult> = results
                    .iter()
                    .filter(|(rl, _)| *rl == l)
                    .map(|(_, r)| r)
                    .collect();
                // offered follows the live route: a failed-over
                // request counts against the lane that served (or
                // finally failed) it, keeping each block's outcome
                // buckets conserved against its own offered count
                let offered =
                    route.iter().filter(|&&x| x == l).count();
                let ln = &lanes[l];
                let mut st = ServeStats::from_results(
                    &lane_refs, offered, ln.b, ln.engine_steps,
                    ln.prefill_steps, ln.slot_steps, wall_secs,
                    sim_ms, ln.retries);
                // wall time is shared by every lane, so dividing it
                // by one lane's steps would inflate the per-step cost
                // ~N x; report the call-wide mean instead
                st.mean_step_ms = stats.mean_step_ms;
                st.pages = lane_pages[l];
                ModelStats { model: name.clone(), stats: st }
            })
            .collect()
    };

    let results: Vec<RequestResult> =
        results.into_iter().map(|(_, r)| r).collect();
    Ok(ServeReport { results, stats, per_model })
}

pub mod mock {
    //! Deterministic artifact-free backends for queueing/clock/policy
    //! tests (also used by `generate::loadgen` unit tests and the
    //! serve-invariant property suite in `rust/tests/`, which is why
    //! this module is compiled unconditionally — it has no runtime
    //! dependencies and is never on a hot path).

    use super::LogitsBackend;

    /// Emits logits whose argmax is always `tok` (never EOS), so
    /// generation length is exactly each request's budget; counts
    /// prefill passes when `kv` is set.
    pub struct MockBackend {
        pub b: usize,
        pub t: usize,
        pub vocab: usize,
        pub tok: usize,
        pub kv: bool,
        pub prefills: u64,
    }

    impl MockBackend {
        /// A `b`-slot, `t`-context mock emitting token 5 every step.
        pub fn new(b: usize, t: usize, kv: bool) -> MockBackend {
            MockBackend { b, t, vocab: 16, tok: 5, kv, prefills: 0 }
        }
    }

    impl LogitsBackend for MockBackend {
        fn dims(&self) -> (usize, usize, usize) {
            (self.b, self.t, self.vocab)
        }

        fn needs_prefill(&self) -> bool {
            self.kv
        }

        fn prefill(&mut self, _tokens: &[i32], _pos: &[i32],
                   _refill: &[f32]) -> anyhow::Result<()> {
            self.prefills += 1;
            Ok(())
        }

        fn step(&mut self, _tokens: &[i32], _pos: &[i32])
                -> anyhow::Result<Vec<f32>> {
            let mut lv = vec![0.0f32; self.b * self.vocab];
            for s in 0..self.b {
                lv[s * self.vocab + self.tok] = 1.0;
            }
            Ok(lv)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::admission::{self, Bounded, MaxQueueDepth,
                                  QueueDeadline};
    use super::super::policy::{self, PriorityClass,
                               ShortestPromptFirst,
                               SmallestBudgetFirst};
    use super::mock::MockBackend;
    use super::*;

    fn reqs(budgets: &[usize]) -> Vec<DecodeRequest> {
        budgets.iter().enumerate()
            .map(|(i, &m)| DecodeRequest::new(i as u64, vec![1, 9, 3],
                                              m))
            .collect()
    }

    fn sched(arrivals: &[f64], step_ms: f64) -> Schedule {
        Schedule::open(arrivals.to_vec(), step_ms, step_ms)
    }

    fn run_policies(
        requests: &[DecodeRequest],
        s: &Schedule,
        scheduler: &dyn Scheduler,
        adm: &dyn AdmissionPolicy,
    ) -> ServeReport {
        let mut be = MockBackend::new(1, 16, false);
        run_loop_with(&mut be, requests, &DecodeParams::default(),
                      Some(s), scheduler, adm)
            .unwrap()
    }

    #[test]
    fn fill_slot_clears_previous_occupant() {
        let t = 8;
        let mut tokens = vec![7i32; 2 * t];
        let mut pos = vec![5i32; 2];
        fill_slot(&mut tokens, &mut pos, t, 1, &[9, 10]);
        assert_eq!(pos[1], 1);
        assert_eq!(&tokens[t..], &[9, 10, 0, 0, 0, 0, 0, 0]);
        // row 0 untouched
        assert!(tokens[..t].iter().all(|&x| x == 7));
    }

    #[test]
    fn fill_slot_max_length_prompt_fits() {
        // longest prompt serve() admits: t - 1 tokens, pos on the last
        let t = 4;
        let mut tokens = vec![0i32; t];
        let mut pos = vec![0i32; 1];
        fill_slot(&mut tokens, &mut pos, t, 0, &[1, 2, 3]);
        assert_eq!(pos[0], 2);
        assert_eq!(tokens, vec![1, 2, 3, 0]);
    }

    #[test]
    fn untimed_mock_serve_fifo_and_occupancy() {
        // 5 requests through 2 slots: FIFO assignment, full stats
        let mut be = MockBackend::new(2, 16, false);
        let requests = reqs(&[3, 3, 2, 2, 1]);
        let report = run_loop(&mut be, &requests,
                              &DecodeParams::default(), None).unwrap();
        assert_eq!(report.results.len(), 5);
        for (i, r) in report.results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.tokens.len(), requests[i].max_new_tokens);
            assert!(r.tokens.iter().all(|&t| t == 5));
            assert!(r.outcome.is_completed());
        }
        let st = &report.stats;
        // steps: slots run [3,3] then [2,2] then [1] → 6 engine steps,
        // slot_steps = 3+3+2+2+1 = 11
        assert_eq!(st.engine_steps, 6);
        assert_eq!(st.slot_steps, 11);
        assert_eq!(st.generated_tokens, 11);
        assert!((st.occupancy - 11.0 / 12.0).abs() < 1e-12);
        // later requests queued
        assert_eq!(report.results[4].queue_steps, 5);
        // unbounded FIFO never sheds
        assert_eq!((st.completed, st.shed, st.expired), (5, 0, 0));
        assert_eq!(st.shed_rate, 0.0);
        assert_eq!(st.tokens_per_sec, st.goodput_tokens_per_sec);
    }

    #[test]
    fn timed_serve_waits_for_arrivals_and_jumps_idle_gaps() {
        let mut be = MockBackend::new(2, 16, false);
        let requests = reqs(&[3, 3, 3, 3]);
        let s = sched(&[0.0, 0.0, 10.0, 10.0], 1.0);
        let report = run_loop(&mut be, &requests,
                              &DecodeParams::default(), Some(&s))
            .unwrap();
        let r = &report.results;
        // first wave: admit at 0, one token per 1ms step, done at 3
        assert_eq!(r[0].queue_ms, 0.0);
        assert_eq!(r[0].ttft_ms, 1.0);
        assert_eq!(r[0].latency_ms, 3.0);
        // second wave: clock jumps the idle gap to t=10
        assert_eq!(r[2].arrival_ms, 10.0);
        assert_eq!(r[2].queue_ms, 0.0);
        assert_eq!(r[2].latency_ms, 3.0);
        assert_eq!(report.stats.engine_steps, 6);
        assert_eq!(report.stats.sim_ms, 13.0);
        // no slot idled while work was pending
        assert!((report.stats.occupancy - 1.0).abs() < 1e-12);
    }

    #[test]
    fn timed_serve_records_queue_wait_under_saturation() {
        // one slot, three simultaneous arrivals: head-of-line blocking
        let mut be = MockBackend::new(1, 16, false);
        let requests = reqs(&[2, 2, 2]);
        let s = sched(&[0.0, 0.0, 0.0], 1.0);
        let report = run_loop(&mut be, &requests,
                              &DecodeParams::default(), Some(&s))
            .unwrap();
        let r = &report.results;
        assert_eq!(
            r.iter().map(|x| x.queue_ms).collect::<Vec<_>>(),
            vec![0.0, 2.0, 4.0]
        );
        assert_eq!(
            r.iter().map(|x| x.latency_ms).collect::<Vec<_>>(),
            vec![2.0, 4.0, 6.0]
        );
        assert_eq!(
            r.iter().map(|x| x.queue_steps).collect::<Vec<_>>(),
            vec![0, 2, 4]
        );
        assert_eq!(report.stats.latency_ms.p50, 4.0);
    }

    #[test]
    fn timed_serve_closed_loop_releases_successor() {
        let mut be = MockBackend::new(1, 16, false);
        let requests = reqs(&[1, 1]);
        let s = Schedule {
            arrivals: vec![0.0, f64::INFINITY],
            release: vec![Some((1, 5.0)), None],
            step_ms: 1.0,
            prefill_ms: 1.0,
        };
        let report = run_loop(&mut be, &requests,
                              &DecodeParams::default(), Some(&s))
            .unwrap();
        let r = &report.results;
        // request 0 completes at t=1; successor arrives at 1 + 5
        assert_eq!(r[1].arrival_ms, 6.0);
        assert_eq!(r[1].queue_ms, 0.0);
        assert_eq!(r[1].latency_ms, 1.0);
        assert_eq!(report.stats.sim_ms, 7.0);
    }

    #[test]
    fn timed_serve_zero_budget_completes_at_arrival() {
        let mut be = MockBackend::new(1, 16, false);
        let requests = reqs(&[2, 0]);
        let s = sched(&[0.0, 5.0], 1.0);
        let report = run_loop(&mut be, &requests,
                              &DecodeParams::default(), Some(&s))
            .unwrap();
        let r = &report.results;
        assert_eq!(r[0].latency_ms, 2.0);
        assert!(r[1].tokens.is_empty());
        assert_eq!(r[1].arrival_ms, 5.0);
        assert_eq!(r[1].latency_ms, 0.0);
        assert_eq!(r[1].decode_steps, 0);
        assert!(r[1].outcome.is_completed());
    }

    #[test]
    fn timed_serve_kv_prefill_costs_virtual_time() {
        let mut be = MockBackend::new(2, 16, true);
        let requests = reqs(&[2, 2, 2]);
        let s = sched(&[0.0, 0.0, 0.0], 1.0);
        let report = run_loop(&mut be, &requests,
                              &DecodeParams::default(), Some(&s))
            .unwrap();
        // initial fill: one prefill; request 2's refill: another
        assert_eq!(be.prefills, 2);
        assert_eq!(report.stats.prefill_steps, 2);
        let r = &report.results;
        // wave 1: prefill(1) + step(2) + step(3) → done at 3
        assert_eq!(r[0].latency_ms, 3.0);
        // request 2 admitted at 3, prefill(4) + step(5) + step(6)
        assert_eq!(r[2].queue_ms, 3.0);
        assert_eq!(r[2].latency_ms, 6.0);
    }

    #[test]
    fn timed_serve_is_deterministic() {
        let requests = reqs(&[3, 1, 4, 2, 2, 3, 1]);
        let s = sched(&[0.0, 0.5, 0.5, 2.0, 2.25, 7.0, 7.0], 0.75);
        let run = || {
            let mut be = MockBackend::new(2, 16, false);
            run_loop(&mut be, &requests, &DecodeParams::default(),
                     Some(&s)).unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.results.len(), b.results.len());
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(
                (x.arrival_ms, x.queue_ms, x.ttft_ms, x.latency_ms),
                (y.arrival_ms, y.queue_ms, y.ttft_ms, y.latency_ms)
            );
        }
        assert_eq!(a.stats.engine_steps, b.stats.engine_steps);
        assert_eq!(a.stats.sim_ms, b.stats.sim_ms);
        assert_eq!(a.stats.latency_ms, b.stats.latency_ms);
    }

    #[test]
    fn schedule_validation_rejects_bad_shapes() {
        let requests = reqs(&[1, 1]);
        let mut be = MockBackend::new(1, 16, false);
        // wrong arrival count
        let s = Schedule::open(vec![0.0], 1.0, 1.0);
        assert!(run_loop(&mut be, &requests, &DecodeParams::default(),
                         Some(&s)).is_err());
        // gated request that nothing releases
        let s = Schedule {
            arrivals: vec![0.0, f64::INFINITY],
            release: vec![None, None],
            step_ms: 1.0,
            prefill_ms: 1.0,
        };
        assert!(run_loop(&mut be, &requests, &DecodeParams::default(),
                         Some(&s)).is_err());
        // double release
        let s = Schedule {
            arrivals: vec![0.0, 0.0, f64::INFINITY],
            release: vec![Some((2, 0.0)), Some((2, 0.0)), None],
            step_ms: 1.0,
            prefill_ms: 1.0,
        };
        assert!(run_loop(&mut be, &reqs(&[1, 1, 1]),
                         &DecodeParams::default(), Some(&s)).is_err());
        // -inf arrival: would be admitted immediately AND re-queued
        // by its release (decoded twice) — must be rejected
        let s = Schedule {
            arrivals: vec![0.0, f64::NEG_INFINITY],
            release: vec![Some((1, 5.0)), None],
            step_ms: 1.0,
            prefill_ms: 1.0,
        };
        assert!(run_loop(&mut be, &requests, &DecodeParams::default(),
                         Some(&s)).is_err());
        // NaN arrival rejected too (the sort itself is total_cmp and
        // cannot panic first — see clock::tests::arrival_sort_is_nan_safe)
        let s = Schedule::open(vec![0.0, f64::NAN], 1.0, 1.0);
        assert!(run_loop(&mut be, &requests, &DecodeParams::default(),
                         Some(&s)).is_err());
    }

    #[test]
    fn bad_deadline_rejected_up_front() {
        let mut be = MockBackend::new(1, 16, false);
        let requests = reqs(&[1]);
        for d in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let adm = QueueDeadline(d);
            assert!(run_loop_with(&mut be, &requests,
                                  &DecodeParams::default(), None,
                                  &Fifo, &adm)
                        .is_err(),
                    "deadline {d} should be rejected");
        }
    }

    #[test]
    fn shortest_prompt_first_reorders_queue() {
        // one slot, simultaneous arrivals with prompt lengths 5/3/4:
        // service order must be 1, 2, 0 (FIFO would be 0, 1, 2)
        let requests = vec![
            DecodeRequest::new(0, vec![1, 2, 3, 4, 5], 2),
            DecodeRequest::new(1, vec![1, 2, 3], 2),
            DecodeRequest::new(2, vec![1, 2, 3, 4], 2),
        ];
        let s = sched(&[0.0, 0.0, 0.0], 1.0);
        let report = run_policies(&requests, &s, &ShortestPromptFirst,
                                  &admission::Unbounded);
        let lat: Vec<f64> =
            report.results.iter().map(|r| r.latency_ms).collect();
        assert_eq!(lat, vec![6.0, 2.0, 4.0]);
        // reordering changes who waits, never what anyone decodes
        for r in &report.results {
            assert_eq!(r.tokens, vec![5, 5]);
        }
    }

    #[test]
    fn smallest_budget_first_reorders_queue() {
        // budgets 5/1/2 through one slot: service order 1, 2, 0
        let requests = reqs(&[5, 1, 2]);
        let s = sched(&[0.0, 0.0, 0.0], 1.0);
        let report = run_policies(&requests, &s, &SmallestBudgetFirst,
                                  &admission::Unbounded);
        let lat: Vec<f64> =
            report.results.iter().map(|r| r.latency_ms).collect();
        assert_eq!(lat, vec![8.0, 1.0, 3.0]);
    }

    #[test]
    fn smallest_budget_first_completes_zero_budget_first() {
        let requests = vec![
            DecodeRequest::new(0, vec![1, 2], 3),
            DecodeRequest::new(1, vec![1, 2], 0),
        ];
        let s = sched(&[0.0, 0.0], 1.0);
        let report = run_policies(&requests, &s, &SmallestBudgetFirst,
                                  &admission::Unbounded);
        assert_eq!(report.results[1].latency_ms, 0.0);
        assert!(report.results[1].outcome.is_completed());
        assert_eq!(report.results[0].latency_ms, 3.0);
    }

    #[test]
    fn priority_class_jumps_the_queue() {
        // priorities 0/0/7 through one slot: request 2 is served
        // first, then FIFO among the zeros
        let requests: Vec<DecodeRequest> = reqs(&[2, 2, 2])
            .into_iter()
            .map(|r| {
                let p = if r.id == 2 { 7 } else { 0 };
                r.with_priority(p)
            })
            .collect();
        let s = sched(&[0.0, 0.0, 0.0], 1.0);
        let report = run_policies(&requests, &s, &PriorityClass,
                                  &admission::Unbounded);
        let lat: Vec<f64> =
            report.results.iter().map(|r| r.latency_ms).collect();
        assert_eq!(lat, vec![4.0, 6.0, 2.0]);
    }

    #[test]
    fn max_queue_sheds_on_arrival_with_pinned_telemetry() {
        // one slot, depth cap 1: request 0 seats, request 1 waits,
        // request 2 is shed the instant it arrives
        let requests = reqs(&[2, 2, 2]);
        let s = sched(&[0.0, 0.0, 0.0], 1.0);
        let report = run_policies(&requests, &s, &Fifo,
                                  &MaxQueueDepth(1));
        let r = &report.results;
        assert_eq!(r[0].latency_ms, 2.0);
        assert!(r[0].outcome.is_completed());
        assert_eq!(r[1].queue_ms, 2.0);
        assert_eq!(r[1].latency_ms, 4.0);
        assert_eq!(r[2].outcome, RequestOutcome::Shed);
        assert!(r[2].tokens.is_empty());
        assert_eq!(r[2].latency_ms, 0.0);
        assert_eq!(r[2].decode_steps, 0);
        let st = &report.stats;
        assert_eq!((st.completed, st.shed, st.expired), (2, 1, 0));
        assert!((st.shed_rate - 1.0 / 3.0).abs() < 1e-12);
        // percentiles cover completed requests only
        assert_eq!(st.latency_ms.n, 2);
        assert_eq!(st.latency_ms.min, 2.0);
        assert_eq!(st.sim_ms, 4.0);
    }

    #[test]
    fn depth_zero_sheds_all_waiters_but_seats_free_slots() {
        // a cold server with a free slot must never shed the request
        // that would seat immediately
        let requests = reqs(&[2, 2, 2]);
        let s = sched(&[0.0, 0.0, 0.0], 1.0);
        let report = run_policies(&requests, &s, &Fifo,
                                  &MaxQueueDepth(0));
        let st = &report.stats;
        assert_eq!((st.completed, st.shed), (1, 2));
        assert!(report.results[0].outcome.is_completed());
    }

    #[test]
    fn queue_deadline_expires_waiters_at_their_deadline() {
        // one slot, 3ms deadline: request 2 would wait 4ms, so it
        // expires — reported at the instant the caller gave up
        // (arrival + 3ms), not at lazy-discovery time
        let requests = reqs(&[2, 2, 2]);
        let s = sched(&[0.0, 0.0, 0.0], 1.0);
        let report = run_policies(&requests, &s, &Fifo,
                                  &QueueDeadline(3.0));
        let r = &report.results;
        assert_eq!(r[0].latency_ms, 2.0);
        // request 1 seats at exactly its 2ms wait (< deadline)
        assert_eq!(r[1].queue_ms, 2.0);
        assert_eq!(r[1].latency_ms, 4.0);
        assert_eq!(r[2].outcome, RequestOutcome::Expired);
        assert_eq!(r[2].queue_ms, 3.0);
        assert_eq!(r[2].latency_ms, 3.0);
        assert!(r[2].tokens.is_empty());
        let st = &report.stats;
        assert_eq!((st.completed, st.shed, st.expired), (2, 0, 1));
        assert_eq!(st.sim_ms, 4.0);
    }

    #[test]
    fn deadline_exactly_met_still_seats() {
        // expiry is strict (> deadline): a request picked at exactly
        // its deadline wait still decodes
        let requests = reqs(&[2, 2]);
        let s = sched(&[0.0, 0.0], 1.0);
        let report = run_policies(&requests, &s, &Fifo,
                                  &QueueDeadline(2.0));
        assert!(report.results[1].outcome.is_completed());
        assert_eq!(report.results[1].queue_ms, 2.0);
    }

    #[test]
    fn backdated_release_keeps_arrival_order() {
        // an expiry discovered late releases its successor with a
        // back-dated arrival (predecessor arrival + deadline +
        // think); the successor must queue AHEAD of ready requests
        // that arrived after that instant, preserving FIFO-by-arrival
        let requests = reqs(&[5, 1, 1, 1]);
        let s = Schedule {
            arrivals: vec![0.0, 0.0, f64::INFINITY, 3.5],
            release: vec![None, Some((2, 0.0)), None, None],
            step_ms: 1.0,
            prefill_ms: 1.0,
        };
        let report = run_policies(&requests, &s, &Fifo,
                                  &QueueDeadline(3.0));
        let r = &report.results;
        assert!(r[0].outcome.is_completed());
        assert_eq!(r[0].latency_ms, 5.0);
        // request 1 waited past the 3ms deadline (slot busy to t=5)
        assert_eq!(r[1].outcome, RequestOutcome::Expired);
        assert_eq!(r[1].queue_ms, 3.0);
        // successor released at 0 + 3 + 0 = 3, BEFORE request 3's
        // 3.5ms arrival — despite being discovered after request 3
        // was already admitted, it is served first
        assert_eq!(r[2].arrival_ms, 3.0);
        assert!(r[2].outcome.is_completed());
        assert_eq!(r[2].queue_ms, 2.0);
        assert_eq!(r[2].latency_ms, 3.0);
        assert_eq!(r[3].queue_ms, 2.5);
        assert_eq!(r[3].latency_ms, 3.5);
        assert_eq!(report.stats.sim_ms, 7.0);
    }

    #[test]
    fn shed_and_expired_release_closed_loop_successors() {
        // depth 0 on one slot: request 1 is shed at t=0, yet its
        // closed-loop successor (request 2) must still be released —
        // the simulated client retries after a failure
        let requests = reqs(&[2, 2, 2]);
        let s = Schedule {
            arrivals: vec![0.0, 0.0, f64::INFINITY],
            release: vec![None, Some((2, 1.0)), None],
            step_ms: 1.0,
            prefill_ms: 1.0,
        };
        let report = run_policies(&requests, &s, &Fifo,
                                  &MaxQueueDepth(0));
        let r = &report.results;
        assert!(r[0].outcome.is_completed());
        assert_eq!(r[1].outcome, RequestOutcome::Shed);
        // released at shed(0) + think(1) = 1, slot busy until 2 →
        // request 2 is itself shed on arrival (depth 0, no free slot)
        assert_eq!(r[2].arrival_ms, 1.0);
        assert_eq!(r[2].outcome, RequestOutcome::Shed);
        // no deadlock: all three requests accounted for
        assert_eq!(report.stats.requests, 3);
        assert_eq!(report.stats.completed + report.stats.shed, 3);
    }

    #[test]
    fn shed_release_is_backdated_to_the_arrival_instant() {
        // a request arriving between step boundaries is shed AT its
        // arrival (its telemetry says latency 0); its closed-loop
        // successor is released from that instant too, not from the
        // step-boundary where the loop discovered the arrival
        let requests = reqs(&[3, 1, 1]);
        let s = Schedule {
            arrivals: vec![0.0, 0.5, f64::INFINITY],
            release: vec![None, Some((2, 0.0)), None],
            step_ms: 1.0,
            prefill_ms: 1.0,
        };
        let report = run_policies(&requests, &s, &Fifo,
                                  &MaxQueueDepth(0));
        let r = &report.results;
        assert_eq!(r[1].outcome, RequestOutcome::Shed);
        assert_eq!(r[1].arrival_ms, 0.5);
        // released at 0.5 + 0 think — not at the 1.0 discovery step
        assert_eq!(r[2].arrival_ms, 0.5);
        assert_eq!(r[2].outcome, RequestOutcome::Shed);
    }

    #[test]
    fn bounded_queue_caps_p95_under_overload() {
        // the acceptance shape: past saturation, bounding the queue
        // trades a nonzero shed rate for a bounded tail latency
        let requests = reqs(&[3, 3, 3, 3, 3, 3]);
        let s = sched(&[0.0; 6], 1.0);
        let unbounded = run_policies(&requests, &s, &Fifo,
                                     &admission::Unbounded);
        let bounded = run_policies(&requests, &s, &Fifo,
                                   &MaxQueueDepth(1));
        assert_eq!(unbounded.stats.shed_rate, 0.0);
        assert!(bounded.stats.shed_rate > 0.0);
        assert!(bounded.stats.latency_ms.p95
                    < unbounded.stats.latency_ms.p95,
                "bounded p95 {} !< unbounded p95 {}",
                bounded.stats.latency_ms.p95,
                unbounded.stats.latency_ms.p95);
        // pinned: completed latencies 3, 6 vs 3, 6, 9, 12, 15, 18
        assert_eq!(bounded.stats.completed, 2);
        assert_eq!(bounded.stats.latency_ms.max, 6.0);
        assert_eq!(unbounded.stats.latency_ms.max, 18.0);
    }

    #[test]
    fn every_scheduler_and_admission_combination_is_sound() {
        // 4 schedulers x 4 admission policies over an oversubscribed
        // timed trace: every combination must terminate, account for
        // every request exactly once, produce only budget-shaped
        // outputs, and be deterministic run-to-run
        let requests: Vec<DecodeRequest> = (0..10)
            .map(|i| {
                DecodeRequest::new(
                    i as u64,
                    vec![1; 2 + (i % 4)],
                    1 + (i % 4),
                )
                .with_priority((i % 3) as u8)
            })
            .collect();
        let s = sched(&[0.0, 0.0, 1.0, 1.0, 2.0, 2.0, 3.0, 3.0, 9.0,
                        9.0], 1.0);
        let schedulers: Vec<Box<dyn Scheduler>> =
            vec![Box::new(Fifo), Box::new(ShortestPromptFirst),
                 Box::new(SmallestBudgetFirst),
                 Box::new(PriorityClass)];
        let admissions: Vec<Box<dyn AdmissionPolicy>> =
            vec![Box::new(admission::Unbounded),
                 Box::new(MaxQueueDepth(2)),
                 Box::new(QueueDeadline(2.5)),
                 Box::new(Bounded { max_queue: 2,
                                    deadline_ms: 2.5 })];
        for sch in &schedulers {
            for adm in &admissions {
                let run = || {
                    let mut be = MockBackend::new(2, 16, false);
                    run_loop_with(&mut be, &requests,
                                  &DecodeParams::default(), Some(&s),
                                  sch.as_ref(), adm.as_ref())
                        .unwrap()
                };
                let label =
                    format!("{}/{}", sch.name(), adm.name());
                let (a, b) = (run(), run());
                let st = &a.stats;
                assert_eq!(a.results.len(), 10, "{label}");
                assert_eq!(st.completed + st.shed + st.expired, 10,
                           "{label}");
                for (i, r) in a.results.iter().enumerate() {
                    assert_eq!(r.id, i as u64, "{label}");
                    match r.outcome {
                        RequestOutcome::Completed => assert_eq!(
                            r.tokens.len(),
                            requests[i].max_new_tokens, "{label}"),
                        _ => assert!(r.tokens.is_empty(), "{label}"),
                    }
                }
                if adm.name() == "unbounded" {
                    assert_eq!(st.shed_rate, 0.0, "{label}");
                    assert_eq!(st.completed, 10, "{label}");
                }
                // determinism across runs, policies included
                assert_eq!(a.results.len(), b.results.len());
                for (x, y) in a.results.iter().zip(&b.results) {
                    assert_eq!(x.tokens, y.tokens, "{label}");
                    assert_eq!(
                        (x.queue_ms, x.latency_ms, x.outcome),
                        (y.queue_ms, y.latency_ms, y.outcome),
                        "{label}"
                    );
                }
                assert_eq!(a.stats.sim_ms, b.stats.sim_ms, "{label}");
            }
        }
    }

    #[test]
    fn single_lane_per_model_block_mirrors_aggregate() {
        // the legacy single-engine entry points report one "default"
        // per-model block that is exactly the aggregate stats
        let requests = reqs(&[3, 1, 4, 2]);
        let s = sched(&[0.0, 0.5, 2.0, 2.0], 1.0);
        let mut be = MockBackend::new(2, 16, false);
        let report = run_loop(&mut be, &requests,
                              &DecodeParams::default(), Some(&s))
            .unwrap();
        assert_eq!(report.per_model.len(), 1);
        let m = &report.per_model[0];
        assert_eq!(m.model, "default");
        assert_eq!(m.stats.to_json().to_string(),
                   report.stats.to_json().to_string());
    }

    #[test]
    fn multi_lane_routes_requests_and_sums_to_aggregate() {
        // two models with one slot each, two requests per model, all
        // arriving at t=0 with budget 2: lanes step in order on the
        // shared clock, each lane serves only its own queue, and the
        // per-model blocks partition the aggregate
        let requests = reqs(&[2, 2, 2, 2]);
        let lane_of = [0usize, 0, 1, 1];
        let names = [String::from("a"), String::from("b")];
        let s = sched(&[0.0; 4], 1.0);
        let mut a = MockBackend::new(1, 16, false);
        let mut b = MockBackend::new(1, 16, false);
        let mut lanes: [&mut dyn LogitsBackend; 2] =
            [&mut a, &mut b];
        let report = run_lanes_with(
            &mut lanes, &names, &lane_of, &requests,
            &DecodeParams::default(), Some(&s), &Fifo, &Unbounded,
            &RecoveryConfig::default())
            .unwrap();
        let r = &report.results;
        // lane a steps before lane b each round: a's requests finish
        // at odd instants, b's one step later
        assert_eq!(
            r.iter().map(|x| x.latency_ms).collect::<Vec<_>>(),
            vec![3.0, 7.0, 4.0, 8.0]
        );
        assert_eq!(r[1].queue_ms, 4.0);
        assert_eq!(r[3].queue_ms, 4.0);
        for x in r {
            assert_eq!(x.tokens, vec![5, 5]);
            assert!(x.outcome.is_completed());
        }
        let st = &report.stats;
        assert_eq!(st.sim_ms, 8.0);
        assert_eq!(st.engine_steps, 8);
        assert_eq!(st.slot_steps, 8);
        assert_eq!(st.decode_batch, 2);
        assert!((st.occupancy - 1.0).abs() < 1e-12);
        // per-model partition: counts sum to the aggregate
        assert_eq!(report.per_model.len(), 2);
        let (ma, mb) = (&report.per_model[0].stats,
                        &report.per_model[1].stats);
        assert_eq!(report.per_model[0].model, "a");
        assert_eq!((ma.requests, ma.completed), (2, 2));
        assert_eq!((mb.requests, mb.completed), (2, 2));
        assert_eq!(ma.engine_steps + mb.engine_steps,
                   st.engine_steps);
        assert_eq!(ma.generated_tokens + mb.generated_tokens,
                   st.generated_tokens);
        assert_eq!(ma.slot_steps + mb.slot_steps, st.slot_steps);
        // each lane fully occupied during its own steps
        assert!((ma.occupancy - 1.0).abs() < 1e-12);
        // per-request steps are denominated in the lane's own model
        // steps (4 per lane), not the 8 aggregate steps
        assert_eq!(r[1].queue_steps, 2);
        assert_eq!(r[1].decode_steps, 2);
    }

    #[test]
    fn multi_lane_admission_sees_per_model_queues() {
        // depth-0 admission with two one-slot lanes: each lane's
        // first request seats (a free slot never sheds), each lane's
        // second is shed against ITS OWN queue — lane b's free slot
        // must not save lane a's waiter or vice versa
        let requests = reqs(&[2, 2, 2]);
        let lane_of = [0usize, 0, 1];
        let names = [String::from("a"), String::from("b")];
        let s = sched(&[0.0; 3], 1.0);
        let mut a = MockBackend::new(1, 16, false);
        let mut b = MockBackend::new(1, 16, false);
        let mut lanes: [&mut dyn LogitsBackend; 2] =
            [&mut a, &mut b];
        let report = run_lanes_with(
            &mut lanes, &names, &lane_of, &requests,
            &DecodeParams::default(), Some(&s), &Fifo,
            &MaxQueueDepth(0), &RecoveryConfig::default())
            .unwrap();
        let r = &report.results;
        assert!(r[0].outcome.is_completed());
        assert_eq!(r[1].outcome, RequestOutcome::Shed);
        assert!(r[2].outcome.is_completed());
        assert_eq!(report.per_model[0].stats.shed, 1);
        assert_eq!(report.per_model[1].stats.shed, 0);
    }

    #[test]
    fn multi_lane_rejects_bad_routing_and_oversize_prompts() {
        let names = [String::from("a"), String::from("b")];
        let run = |lane: usize, requests: &[DecodeRequest]| {
            let mut a = MockBackend::new(1, 16, false);
            let mut b = MockBackend::new(1, 8, false);
            let mut lanes: [&mut dyn LogitsBackend; 2] =
                [&mut a, &mut b];
            run_lanes_with(&mut lanes, &names, &[lane], requests,
                           &DecodeParams::default(), None, &Fifo,
                           &Unbounded, &RecoveryConfig::default())
        };
        // lane index out of range
        assert!(run(2, &reqs(&[1])).is_err());
        // prompt fits lane a (t=16) but not lane b (t=8)
        let long = vec![DecodeRequest::new(0, vec![1; 10], 2)];
        assert!(run(0, &long).is_ok());
        let err = run(1, &long).unwrap_err();
        assert!(err.to_string().contains("model b"), "{err}");
    }

    #[test]
    fn explicit_fifo_unbounded_is_bit_identical_to_default() {
        // the tentpole invariant at the mock level: threading the
        // default policies through run_loop_with changes nothing
        let requests = reqs(&[3, 1, 4, 2, 2, 3, 1]);
        let s = sched(&[0.0, 0.5, 0.5, 2.0, 2.25, 7.0, 7.0], 0.75);
        let mut be_a = MockBackend::new(2, 16, false);
        let a = run_loop(&mut be_a, &requests,
                         &DecodeParams::default(), Some(&s)).unwrap();
        let mut be_b = MockBackend::new(2, 16, false);
        let b = run_loop_with(&mut be_b, &requests,
                              &DecodeParams::default(), Some(&s),
                              &policy::Fifo, &admission::Unbounded)
            .unwrap();
        assert_eq!(a.results.len(), b.results.len());
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(
                (x.arrival_ms, x.queue_ms, x.ttft_ms, x.latency_ms,
                 x.queue_steps, x.decode_steps),
                (y.arrival_ms, y.queue_ms, y.ttft_ms, y.latency_ms,
                 y.queue_steps, y.decode_steps)
            );
        }
        assert_eq!(a.stats.engine_steps, b.stats.engine_steps);
        assert_eq!(a.stats.slot_steps, b.stats.slot_steps);
        assert_eq!(a.stats.sim_ms, b.stats.sim_ms);
        assert_eq!(a.stats.latency_ms, b.stats.latency_ms);
        assert_eq!(a.stats.queue_ms, b.stats.queue_ms);
        assert_eq!(a.stats.ttft_ms, b.stats.ttft_ms);
    }

    // ---- recovery-layer tests (fault containment, retry/backoff,
    // circuit breaker, failover) -------------------------------------

    use super::super::fault::{FaultPlan, RetryPolicy};

    /// Mock failing scripted step-attempt indices (and optionally
    /// dying permanently at one), for pinned recovery-path timing.
    struct ScriptedBackend {
        inner: MockBackend,
        fail: Vec<u64>,
        die_at: Option<u64>,
        attempts: u64,
    }

    impl ScriptedBackend {
        fn new(inner: MockBackend, fail: &[u64], die_at: Option<u64>)
               -> ScriptedBackend {
            ScriptedBackend { inner, fail: fail.to_vec(), die_at,
                              attempts: 0 }
        }
    }

    impl LogitsBackend for ScriptedBackend {
        fn dims(&self) -> (usize, usize, usize) {
            self.inner.dims()
        }

        fn needs_prefill(&self) -> bool {
            self.inner.needs_prefill()
        }

        fn prefill(&mut self, tokens: &[i32], pos: &[i32],
                   refill: &[f32]) -> anyhow::Result<()> {
            self.inner.prefill(tokens, pos, refill)
        }

        fn step(&mut self, tokens: &[i32], pos: &[i32])
                -> anyhow::Result<Vec<f32>> {
            let a = self.attempts;
            self.attempts += 1;
            if self.die_at.is_some_and(|k| a >= k) {
                anyhow::bail!("scripted permanent death at attempt \
                               {a}");
            }
            if self.fail.contains(&a) {
                anyhow::bail!("scripted transient failure at attempt \
                               {a}");
            }
            self.inner.step(tokens, pos)
        }

        fn healthy(&self) -> bool {
            !self.die_at.is_some_and(|k| self.attempts > k)
        }
    }

    fn recovery_with(retry: RetryPolicy) -> RecoveryConfig {
        RecoveryConfig { retry, ..RecoveryConfig::default() }
    }

    fn run_recovery(
        backend: &mut dyn LogitsBackend,
        requests: &[DecodeRequest],
        s: &Schedule,
        recovery: &RecoveryConfig,
    ) -> anyhow::Result<ServeReport> {
        let names = [String::from("default")];
        let lane_of = vec![0usize; requests.len()];
        run_lanes_with(&mut [backend], &names, &lane_of, requests,
                       &DecodeParams::default(), Some(s), &Fifo,
                       &Unbounded, recovery)
    }

    #[test]
    fn transient_mid_run_failure_no_longer_aborts_the_run() {
        // regression on the PR 5 behavior: a single failed step used
        // to propagate out of run_lanes_with and kill every in-flight
        // request on every lane. Now the lane retries with backoff
        // (default policy: 1ms base, doubling) and the request
        // completes with its token stream intact.
        let requests = reqs(&[3]);
        let s = sched(&[0.0], 1.0);
        let mut be =
            ScriptedBackend::new(MockBackend::new(1, 16, false),
                                 &[1], None);
        let report = run_recovery(&mut be, &requests, &s,
                                  &RecoveryConfig::default())
            .expect("transient fault must not abort the run");
        let r = &report.results[0];
        assert!(r.outcome.is_completed());
        assert!(!r.degraded);
        assert_eq!(r.tokens, vec![5, 5, 5], "tokens survive bitwise");
        // t=1: token 1; t=2: failed attempt; backoff to t=3; tokens
        // at t=4 and t=5
        assert_eq!(r.ttft_ms, 1.0);
        assert_eq!(r.latency_ms, 5.0);
        assert_eq!(report.stats.retries, 1);
        assert_eq!(report.stats.engine_steps, 3,
                   "failed attempts are not engine steps");
        assert_eq!(report.stats.sim_ms, 5.0);
        assert_eq!(report.stats.failed, 0);
    }

    #[test]
    fn retry_recovery_reprefills_from_tokens_so_far_on_kv() {
        // on the KV path a retried lane re-marks its occupied rows:
        // the row buffer already holds prompt + generated-so-far, so
        // the existing prefill path rebuilds the cache and decode
        // resumes bitwise — observable here as exactly one extra
        // prefill pass
        let requests = reqs(&[3]);
        let s = sched(&[0.0], 1.0);
        let mut be =
            ScriptedBackend::new(MockBackend::new(1, 16, true),
                                 &[1], None);
        let report = run_recovery(&mut be, &requests, &s,
                                  &RecoveryConfig::default())
            .unwrap();
        let r = &report.results[0];
        assert_eq!(r.tokens, vec![5, 5, 5]);
        assert_eq!(be.inner.prefills, 2,
                   "seat prefill + recovery re-prefill");
        assert_eq!(report.stats.prefill_steps, 2);
        assert_eq!(report.stats.retries, 1);
        // seat prefill t=1, first token t=2, fail t=3, backoff to
        // t=4, re-prefill t=5, tokens t=6 and t=7
        assert_eq!(r.latency_ms, 7.0);
    }

    #[test]
    fn exhausted_retry_budget_fails_only_inflight_slots() {
        // a lane that fails every attempt burns its retry budget and
        // fails the seated request — but the run keeps going and the
        // next request gets its own fresh budget
        let requests = reqs(&[2, 2]);
        let s = sched(&[0.0, 0.0], 1.0);
        let mut be =
            ScriptedBackend::new(MockBackend::new(1, 16, false),
                                 &(0..64).collect::<Vec<u64>>(),
                                 None);
        let recovery = recovery_with(RetryPolicy {
            max_retries: 1,
            base_ms: 1.0,
            multiplier: 2.0,
            cap_ms: 32.0,
        });
        let report =
            run_recovery(&mut be, &requests, &s, &recovery).unwrap();
        assert_eq!(report.results.len(), 2);
        for r in &report.results {
            assert_eq!(r.outcome, RequestOutcome::Failed);
            assert!(r.tokens.is_empty(),
                    "failed requests deliver no partial output");
        }
        let st = &report.stats;
        assert_eq!((st.completed, st.failed), (0, 2));
        assert_eq!(st.completed + st.shed + st.expired + st.failed,
                   st.requests, "conservation includes failed");
        assert_eq!(st.engine_steps, 0);
        assert_eq!(st.generated_tokens, 0);
        // each request: first attempt + 1 retry
        assert_eq!(st.retries, 2);
    }

    #[test]
    fn lane_death_without_fallback_drains_slots_and_queue() {
        // permanent death fails the in-flight slot and the lane's
        // queue at the failure instant, and later arrivals for the
        // dead lane fail at arrival — no slot leaks, the loop exits
        let requests = reqs(&[2, 2, 2]);
        let s = sched(&[0.0, 0.0, 5.0], 1.0);
        let mut be =
            ScriptedBackend::new(MockBackend::new(1, 16, false),
                                 &[], Some(0));
        let report = run_recovery(&mut be, &requests, &s,
                                  &RecoveryConfig::default())
            .unwrap();
        let r = &report.results;
        assert_eq!(r.len(), 3);
        assert!(r.iter().all(|x| {
            x.outcome == RequestOutcome::Failed && x.tokens.is_empty()
        }));
        // seated + queued fail when the lane dies (t=1); the late
        // arrival fails at its arrival (t=5, latency 0)
        assert_eq!(r[0].latency_ms, 1.0);
        assert_eq!(r[1].latency_ms, 1.0);
        assert_eq!((r[2].arrival_ms, r[2].latency_ms), (5.0, 0.0));
        assert_eq!(report.stats.failed, 3);
        assert_eq!(report.stats.engine_steps, 0);
    }

    #[test]
    fn lane_death_with_fallback_rerouted_and_tagged_degraded() {
        // lane a dies on its first attempt; its requests restart from
        // scratch on lane b and complete tagged degraded, while lane
        // b's own traffic is unaffected
        let requests = reqs(&[2, 2, 2]);
        let lane_of = [0usize, 0, 1];
        let names = [String::from("a"), String::from("b")];
        let s = sched(&[0.0; 3], 1.0);
        let mut a =
            ScriptedBackend::new(MockBackend::new(1, 16, false),
                                 &[], Some(0));
        let mut b = MockBackend::new(1, 16, false);
        let mut lanes: [&mut dyn LogitsBackend; 2] = [&mut a, &mut b];
        let recovery = RecoveryConfig {
            fallback: vec![Some(1), None],
            ..RecoveryConfig::default()
        };
        let report = run_lanes_with(
            &mut lanes, &names, &lane_of, &requests,
            &DecodeParams::default(), Some(&s), &Fifo, &Unbounded,
            &recovery)
            .unwrap();
        let r = &report.results;
        assert!(r.iter().all(|x| x.outcome.is_completed()));
        assert!(r.iter().all(|x| x.tokens == vec![5, 5]));
        assert!(r[0].degraded && r[1].degraded,
                "failed-over requests are tagged degraded");
        assert!(!r[2].degraded, "lane b's own request is not");
        // lane a dies at t=1; lane b serves its own request first
        // (done t=3), then the failovers queued by original arrival
        assert_eq!(r[2].latency_ms, 3.0);
        assert_eq!(r[0].latency_ms, 5.0);
        assert_eq!(r[1].latency_ms, 7.0);
        let st = &report.stats;
        assert_eq!((st.completed, st.failed, st.degraded), (3, 0, 2));
        // offered counts follow the live route: every request ends up
        // served by lane b, and each block conserves its own outcomes
        assert_eq!(report.per_model[0].stats.requests, 0);
        assert_eq!(report.per_model[1].stats.requests, 3);
        assert_eq!(report.per_model[1].stats.degraded, 2);
        assert_eq!(report.per_model[0].stats.engine_steps, 0);
        assert_eq!(report.per_model[1].stats.engine_steps, 6);
    }

    #[test]
    fn breaker_opens_after_threshold_and_lane_recovers() {
        // two consecutive failed attempts open the breaker (threshold
        // 2); the lane sits out the 10ms cooldown, then the retry
        // succeeds and the request completes with its tokens intact
        let requests = reqs(&[2]);
        let s = sched(&[0.0], 1.0);
        let mut be =
            ScriptedBackend::new(MockBackend::new(1, 16, false),
                                 &[0, 1], None);
        let recovery = RecoveryConfig {
            retry: RetryPolicy {
                max_retries: 5,
                base_ms: 1.0,
                multiplier: 2.0,
                cap_ms: 32.0,
            },
            breaker_threshold: 2,
            breaker_cooldown_ms: 10.0,
            fallback: Vec::new(),
        };
        let report =
            run_recovery(&mut be, &requests, &s, &recovery).unwrap();
        let r = &report.results[0];
        assert!(r.outcome.is_completed());
        assert_eq!(r.tokens, vec![5, 5]);
        // fails at t=1 (backoff to 2) and t=3 (breaker opens until
        // 13); success at t=14 and t=15
        assert_eq!(r.latency_ms, 15.0);
        assert_eq!(report.stats.retries, 2);
        assert_eq!(report.stats.engine_steps, 2);
    }

    #[test]
    fn injected_spikes_move_the_clock_but_not_the_tokens() {
        // FaultyBackend spikes stretch latency deterministically and
        // leave the decoded stream untouched
        let requests = reqs(&[2]);
        let s = sched(&[0.0], 1.0);
        let mut plan = FaultPlan::new(3);
        plan.spike_p = 1.0;
        plan.spike_ms = 2.0;
        let mut be =
            FaultyBackend::new(MockBackend::new(1, 16, false), &plan,
                               0)
                .unwrap();
        let report = run_recovery(&mut be, &requests, &s,
                                  &RecoveryConfig::default())
            .unwrap();
        let r = &report.results[0];
        assert!(r.outcome.is_completed());
        assert_eq!(r.tokens, vec![5, 5]);
        // each step costs 1ms + a 2ms spike
        assert_eq!(r.latency_ms, 6.0);
        assert_eq!(report.stats.sim_ms, 6.0);
        assert_eq!(report.stats.retries, 0);
        assert_eq!(report.stats.failed, 0);
    }

    #[test]
    fn noop_fault_config_is_bit_identical_to_plain_run() {
        // chaos plumbing engaged but injecting nothing: stats and
        // results serialize byte-identically to the plain loop
        let requests = reqs(&[3, 1, 4, 2]);
        let s = sched(&[0.0, 0.5, 2.0, 2.0], 1.0);
        let mut plain = MockBackend::new(2, 16, false);
        let a = run_loop(&mut plain, &requests,
                         &DecodeParams::default(), Some(&s)).unwrap();
        let mut faulty =
            FaultyBackend::new(MockBackend::new(2, 16, false),
                               &FaultPlan::new(7), 0)
                .unwrap();
        let b = run_recovery(&mut faulty, &requests, &s,
                             &RecoveryConfig::default())
            .unwrap();
        assert_eq!(a.stats_json().to_string(),
                   b.stats_json().to_string());
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.to_json().to_string(),
                       y.to_json().to_string());
        }
    }

    #[test]
    fn unit_lane_costs_are_bit_identical_to_run_lanes_with() {
        // run_lanes_with delegates at unit costs; an explicit unit
        // vector through run_lanes_with_costs must serialize
        // byte-identically — the costs layer is inert until a lane
        // actually scales
        let requests: Vec<DecodeRequest> = (0..4)
            .map(|i| DecodeRequest::new(i, vec![1, 9, 3],
                                        2 + (i as usize % 2)))
            .collect();
        let s = sched(&[0.0, 0.0, 1.0, 1.0], 1.0);
        let names = [String::from("a"), String::from("b")];
        let lane_of = vec![0, 1, 0, 1];
        let mut a0 = MockBackend::new(1, 16, false);
        let mut a1 = MockBackend::new(1, 16, false);
        let a = run_lanes_with(
            &mut [&mut a0, &mut a1], &names, &lane_of, &requests,
            &DecodeParams::default(), Some(&s), &Fifo, &Unbounded,
            &RecoveryConfig::default()).unwrap();
        let mut b0 = MockBackend::new(1, 16, false);
        let mut b1 = MockBackend::new(1, 16, false);
        let b = run_lanes_with_costs(
            &mut [&mut b0, &mut b1], &names, &lane_of, &requests,
            &DecodeParams::default(), Some(&s), &Fifo, &Unbounded,
            &RecoveryConfig::default(),
            &[LaneCost::unit(), LaneCost::unit()]).unwrap();
        assert_eq!(a.stats_json().to_string(),
                   b.stats_json().to_string());
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.to_json().to_string(),
                       y.to_json().to_string());
        }
    }

    #[test]
    fn hetero_lane_costs_change_time_but_not_tokens() {
        // two busy lanes on the shared clock: the s75 lane's steps
        // cost a quarter of dense, so the virtual makespan shrinks
        // while every decoded stream stays bitwise identical
        let requests: Vec<DecodeRequest> = (0..4)
            .map(|i| DecodeRequest::new(i, vec![1, 9, 3], 3))
            .collect();
        let s = sched(&[0.0, 0.0, 0.0, 0.0], 1.0);
        let names = [String::from("dense"), String::from("s75")];
        let lane_of = vec![0, 0, 1, 1];
        let run = |costs: &[LaneCost]| {
            let mut b0 = MockBackend::new(1, 16, false);
            let mut b1 = MockBackend::new(1, 16, false);
            run_lanes_with_costs(
                &mut [&mut b0, &mut b1], &names, &lane_of, &requests,
                &DecodeParams::default(), Some(&s), &Fifo, &Unbounded,
                &RecoveryConfig::default(), costs).unwrap()
        };
        let unit = run(&[LaneCost::unit(), LaneCost::unit()]);
        let hetero =
            run(&[LaneCost::unit(), LaneCost::from_sparsity(0.75)]);
        for (x, y) in unit.results.iter().zip(&hetero.results) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.tokens, y.tokens);
            assert!(x.outcome.is_completed()
                    && y.outcome.is_completed());
        }
        // each round both lanes step: 2.0ms at unit costs,
        // 1.0 + 0.25 = 1.25ms with the calibrated s75 lane. Six
        // rounds drain the queues: 12ms vs 7.5ms makespan.
        assert_eq!(unit.stats.sim_ms, 12.0);
        assert_eq!(hetero.stats.sim_ms, 7.5);
        assert_eq!(unit.stats.generated_tokens,
                   hetero.stats.generated_tokens);
    }

    #[test]
    fn cheaper_lane_clears_deadlined_queue_with_fewer_expiries() {
        // cross-lane golden at the mock level: the same stream routed
        // to a dense-cost lane vs an s75-cost lane under a queue
        // deadline. Survivors decode bitwise-identical streams, and
        // the cheaper lane completes at least as many requests.
        let requests = reqs(&[2, 2, 2, 2]);
        let s = sched(&[0.0, 0.0, 0.0, 0.0], 1.0);
        let names = [String::from("m")];
        let lane_of = vec![0, 0, 0, 0];
        let run = |cost: LaneCost| {
            let mut be = MockBackend::new(1, 16, false);
            run_lanes_with_costs(
                &mut [&mut be], &names, &lane_of, &requests,
                &DecodeParams::default(), Some(&s), &Fifo,
                &QueueDeadline(4.5), &RecoveryConfig::default(),
                &[cost]).unwrap()
        };
        let dense = run(LaneCost::unit());
        let s75 = run(LaneCost::from_sparsity(0.75));
        // dense: completions at t=2/4/6 — the last request expires at
        // 4.5ms of queue wait. s75: steps cost 0.25ms, the whole
        // queue drains by t=2.0 and nothing expires.
        assert_eq!((dense.stats.completed, dense.stats.expired),
                   (3, 1));
        assert_eq!((s75.stats.completed, s75.stats.expired), (4, 0));
        assert!(s75.stats.completed >= dense.stats.completed);
        // survivors of the dense run decode the same streams bitwise
        for d in dense.results.iter()
            .filter(|r| r.outcome.is_completed())
        {
            let v = s75.results.iter().find(|r| r.id == d.id).unwrap();
            assert_eq!(d.tokens, v.tokens);
        }
        assert!(s75.stats.sim_ms < dense.stats.sim_ms);
    }

    fn run_spec_mock(draft_tok: usize, spec: Option<&SpecPlan>)
                     -> ServeReport {
        // two residents on a 2-slot verifier, a 2-row draft lane at
        // s75 cost; MockBackend's fixed pick makes acceptance total
        // (draft_tok == 5) or zero (anything else)
        let requests = vec![
            DecodeRequest::new(0, vec![1, 9, 3], 5),
            DecodeRequest::new(1, vec![1, 9, 3], 3),
        ];
        let s = sched(&[0.0, 0.0], 1.0);
        let names = [String::from("dense"), String::from("s75")];
        let mut dense = MockBackend::new(2, 16, false);
        let mut draft = MockBackend::new(2, 12, false);
        draft.tok = draft_tok;
        run_lanes_spec(
            &mut [&mut dense, &mut draft], &names, &[0, 0], &requests,
            &DecodeParams::default(), Some(&s), &Fifo, &Unbounded,
            &RecoveryConfig::default(),
            &[LaneCost::unit(), LaneCost::from_sparsity(0.75)],
            spec, None).unwrap()
    }

    #[test]
    fn speculative_mock_golden_full_acceptance() {
        // pinned round trace with an agreeing draft (both mocks pick
        // 5): with every verifier slot occupied the rounds interleave
        // draft microsteps (0.25ms each) with single-lease-free
        // verifies, and the makespan lands exactly on the plain run's
        let plan = SpecPlan { draft_lane: 1, verifier_lane: 0, k: 2 };
        let report = run_spec_mock(5, Some(&plan));
        let plain = run_spec_mock(5, None);
        let st = &report.stats;
        assert_eq!((st.completed, st.generated_tokens), (2, 8));
        for (r, p) in report.results.iter().zip(&plain.results) {
            assert_eq!((r.id, &r.tokens), (p.id, &p.tokens));
            assert!(r.tokens.iter().all(|&x| x == 5));
        }
        // r0: 4 drafts all accepted + the bonus pick that finishes
        // the budget; r1 drains its 3 drafts one verify at a time
        let (r0, r1) = (&report.results[0], &report.results[1]);
        assert_eq!((r0.spec.drafted, r0.spec.accepted,
                    r0.spec.corrections, r0.spec.verifies),
                   (4, 4, 1, 4));
        assert_eq!((r1.spec.drafted, r1.spec.accepted,
                    r1.spec.corrections, r1.spec.verifies),
                   (3, 3, 0, 3));
        assert_eq!((st.spec.drafted, st.spec.accepted,
                    st.spec.corrections, st.spec.verifies),
                   (7, 7, 1, 7));
        assert_eq!((st.acceptance_rate, st.wasted_drafts),
                   (1.0, 0));
        assert_eq!(st.tokens_per_verify, 8.0 / 7.0);
        // 4 verifier steps + 4 draft microsteps; the draft lane's
        // leases ride its slot_steps
        assert_eq!((st.engine_steps, st.slot_steps), (8, 15));
        assert_eq!(per_lane(&report, "dense").engine_steps, 4);
        assert_eq!(per_lane(&report, "s75").engine_steps, 4);
        assert_eq!(st.sim_ms, 5.0);
        assert_eq!(plain.stats.sim_ms, 5.0);
        // first token waits for one 0.5ms draft phase + the verify
        assert_eq!(r0.ttft_ms, 1.5);
        assert_eq!((r0.latency_ms, r1.latency_ms), (5.0, 4.0));
    }

    #[test]
    fn speculative_mock_golden_full_rejection() {
        // pinned worst case: the draft always proposes 6, the
        // verifier always picks 5 — every verify commits exactly one
        // correction, output stays the dense stream, and the wasted
        // draft microsteps stretch the makespan past the plain run
        let plan = SpecPlan { draft_lane: 1, verifier_lane: 0, k: 2 };
        let report = run_spec_mock(6, Some(&plan));
        let st = &report.stats;
        assert_eq!((st.completed, st.generated_tokens), (2, 8));
        for r in &report.results {
            assert!(r.tokens.iter().all(|&x| x == 5));
        }
        let (r0, r1) = (&report.results[0], &report.results[1]);
        assert_eq!((r0.spec.drafted, r0.spec.accepted,
                    r0.spec.corrections, r0.spec.verifies),
                   (9, 0, 5, 5));
        assert_eq!((r1.spec.drafted, r1.spec.accepted,
                    r1.spec.corrections, r1.spec.verifies),
                   (5, 0, 3, 3));
        assert_eq!((st.spec.drafted, st.spec.accepted,
                    st.spec.corrections, st.spec.verifies),
                   (14, 0, 8, 8));
        assert_eq!((st.acceptance_rate, st.wasted_drafts),
                   (0.0, 14));
        // the provable floor: the correction keeps every verify at
        // exactly one committed token even with zero acceptance
        assert_eq!(st.tokens_per_verify, 1.0);
        assert_eq!((st.engine_steps, st.slot_steps), (14, 24));
        assert_eq!(st.sim_ms, 7.25);
        assert_eq!((r0.latency_ms, r1.latency_ms), (7.25, 4.5));
    }

    fn per_lane<'a>(rep: &'a ServeReport, name: &str)
                    -> &'a ServeStats {
        &rep.per_model.iter().find(|m| m.model == name)
            .expect("lane name registered in the report")
            .stats
    }

    // -- paged KV memory (pages allocator, preemption, eviction,
    // memory-aware admission) and the throughput/goodput split -------

    use super::super::admission::PagePressure;
    use super::super::pages::PageReserve;

    fn run_paged(
        be: &mut dyn LogitsBackend,
        requests: &[DecodeRequest],
        s: &Schedule,
        adm: &dyn AdmissionPolicy,
        paged: Option<&PagedKvConfig>,
    ) -> ServeReport {
        let names = [String::from("default")];
        let lane_of = vec![0usize; requests.len()];
        run_lanes_spec(&mut [be], &names, &lane_of, requests,
                       &DecodeParams::default(), Some(s), &Fifo, adm,
                       &RecoveryConfig::default(),
                       &[LaneCost::unit()], None, paged)
            .unwrap()
    }

    #[test]
    fn mid_stream_lane_death_splits_goodput_from_throughput() {
        // regression on the PR 6 telemetry: goodput_tokens_per_sec
        // was a copy of tokens_per_sec even when a Failed request
        // dropped partial output. One request completes (2 delivered
        // tokens), the next dies mid-stream with 1 token decoded:
        // throughput must count 3 tokens of engine work, goodput only
        // the 2 delivered.
        let requests = reqs(&[2, 2]);
        let s = sched(&[0.0, 0.0], 1.0);
        let mut be =
            ScriptedBackend::new(MockBackend::new(1, 16, false),
                                 &[], Some(3));
        let report = run_recovery(&mut be, &requests, &s,
                                  &RecoveryConfig::default())
            .unwrap();
        let (r0, r1) = (&report.results[0], &report.results[1]);
        assert!(r0.outcome.is_completed());
        assert_eq!((r0.tokens.as_slice(), r0.lost_tokens),
                   ([5, 5].as_slice(), 0));
        assert_eq!(r1.outcome, RequestOutcome::Failed);
        assert!(r1.tokens.is_empty(),
                "failed requests deliver no partial output");
        assert_eq!(r1.lost_tokens, 1,
                   "the dropped mid-stream token is accounted");
        let st = &report.stats;
        assert_eq!((st.generated_tokens, st.lost_tokens), (2, 1));
        assert!(st.tokens_per_sec > 0.0,
                "three engine steps take nonzero wall time");
        assert!(st.goodput_tokens_per_sec < st.tokens_per_sec,
                "dropped work must not count toward goodput");
        let ratio = st.goodput_tokens_per_sec / st.tokens_per_sec;
        assert!((ratio - 2.0 / 3.0).abs() < 1e-9,
                "goodput/throughput = delivered/(delivered+lost), \
                 got {ratio}");
    }

    #[test]
    fn unconstrained_paged_run_is_bitwise_identical_to_monolithic() {
        // no budget, no window: paging is pure accounting and every
        // decision matches the monolithic loop — results serialize
        // byte-identically and the stats agree on everything except
        // the pages block itself
        let requests = reqs(&[3, 3, 2, 2, 1]);
        let s = sched(&[0.0, 0.0, 1.0, 2.0, 2.0], 1.0);
        let mut plain_be = MockBackend::new(2, 16, false);
        let plain = run_paged(&mut plain_be, &requests, &s,
                              &Unbounded, None);
        let cfg = PagedKvConfig::new(4);
        let mut paged_be = MockBackend::new(2, 16, false);
        let mut paged = run_paged(&mut paged_be, &requests, &s,
                                  &Unbounded, Some(&cfg));
        for (x, y) in plain.results.iter().zip(&paged.results) {
            assert_eq!(x.to_json().to_string(),
                       y.to_json().to_string());
        }
        let pg = paged.stats.pages;
        assert_eq!(pg.page_size, 4);
        assert_eq!(pg.total_pages, 2 * 4, "b × pages_for(ctx_len)");
        assert_eq!((pg.preemptions, pg.page_sheds, pg.evicted_pages),
                   (0, 0, 0),
                   "unconstrained paging never sheds or preempts");
        assert_eq!(pg.leaked_pages, 0);
        assert!(pg.peak_pages >= 2 && pg.peak_seated == 2);
        // zero the pages blocks and the reports serialize
        // byte-identically end to end
        paged.stats.pages = PageCounters::default();
        for m in &mut paged.per_model {
            m.stats.pages = PageCounters::default();
        }
        assert_eq!(plain.stats_json().to_string(),
                   paged.stats_json().to_string());
    }

    #[test]
    fn dry_allocator_preempts_youngest_and_requeues_it() {
        // 4-page budget, two growing residents: when slot 0's table
        // needs a third page the allocator is dry and the
        // youngest-seated other slot (tie → highest index) is
        // preempted — pages freed, decoded-so-far tokens counted
        // lost, request requeued. Everyone still completes with the
        // full budget delivered.
        let requests = reqs(&[8, 8]);
        let s = sched(&[0.0, 0.0], 1.0);
        let cfg = PagedKvConfig::new(4).with_total_pages(4);
        let mut be = MockBackend::new(2, 16, false);
        let report = run_paged(&mut be, &requests, &s, &Unbounded,
                               Some(&cfg));
        assert_eq!(report.stats.completed, 2);
        for r in &report.results {
            assert_eq!(r.tokens, vec![5; 8],
                       "preemption restarts, it does not truncate");
        }
        let pg = report.stats.pages;
        assert_eq!(pg.preemptions, 1);
        assert_eq!(pg.leaked_pages, 0);
        assert_eq!(pg.peak_pages, 4, "budget fully used");
        // slot 1 had decoded 6 tokens when slot 0's growth evicted it
        assert_eq!(report.results[0].lost_tokens, 0);
        assert_eq!(report.results[1].lost_tokens, 6);
        assert_eq!(report.stats.lost_tokens, 6);
        assert!(report.stats.goodput_tokens_per_sec
                < report.stats.tokens_per_sec);
    }

    #[test]
    fn prompt_reserve_seats_more_concurrent_requests_than_full() {
        // the tentpole datapoint at unit-test scale: same 8-page
        // budget, same traffic — full-context reservation (the
        // monolithic discipline in pages) caps concurrency at
        // budget/pages_for(ctx_len) = 2, prompt reservation seats all
        // 4 slots at once
        let requests = reqs(&[2, 2, 2, 2]);
        let s = sched(&[0.0; 4], 1.0);
        let base = PagedKvConfig::new(4).with_total_pages(8);
        let full = base.clone().with_reserve(PageReserve::FullContext);
        let mut be_p = MockBackend::new(4, 16, false);
        let prompt_rep = run_paged(&mut be_p, &requests, &s,
                                   &Unbounded, Some(&base));
        let mut be_f = MockBackend::new(4, 16, false);
        let full_rep = run_paged(&mut be_f, &requests, &s,
                                 &Unbounded, Some(&full));
        assert_eq!(prompt_rep.stats.completed, 4);
        assert_eq!(full_rep.stats.completed, 4);
        for (x, y) in
            prompt_rep.results.iter().zip(&full_rep.results)
        {
            assert_eq!(x.tokens, vec![5, 5]);
            assert_eq!(x.tokens, y.tokens);
        }
        assert_eq!(prompt_rep.stats.pages.peak_seated, 4);
        assert_eq!(full_rep.stats.pages.peak_seated, 2);
        assert!(prompt_rep.stats.pages.peak_seated
                > full_rep.stats.pages.peak_seated,
                "prompt reservation sustains strictly more \
                 concurrent requests at fixed memory");
        assert_eq!(prompt_rep.stats.pages.leaked_pages, 0);
        assert_eq!(full_rep.stats.pages.leaked_pages, 0);
        // seating waits (head-of-line) rather than shedding under the
        // default admission policy
        assert_eq!(full_rep.stats.pages.page_sheds, 0);
        assert!(full_rep.stats.sim_ms > prompt_rep.stats.sim_ms,
                "two seating waves take longer than one");
    }

    #[test]
    fn sliding_window_eviction_decodes_past_ctx_len() {
        // ctx_len 16 caps a monolithic row at 13 generated tokens
        // (prompt 3, cap at pos t-1); an 8-token window keeps freeing
        // the oldest page so the same request delivers its full
        // 20-token budget
        let requests = reqs(&[20]);
        let s = sched(&[0.0], 1.0);
        let mut plain_be = MockBackend::new(1, 16, false);
        let plain = run_paged(&mut plain_be, &requests, &s,
                              &Unbounded, None);
        assert_eq!(plain.results[0].tokens.len(), 13,
                   "monolithic run stops at the ctx_len cap");
        let cfg = PagedKvConfig::new(4).with_window(8);
        let mut be = MockBackend::new(1, 16, false);
        let report = run_paged(&mut be, &requests, &s, &Unbounded,
                               Some(&cfg));
        let r = &report.results[0];
        assert!(r.outcome.is_completed());
        assert_eq!(r.tokens, vec![5; 20],
                   "windowed decode runs past ctx_len");
        let pg = report.stats.pages;
        assert!(pg.evicted_pages >= 2);
        assert_eq!((pg.preemptions, pg.leaked_pages), (0, 0));
    }

    #[test]
    fn page_pressure_sheds_arrival_when_prompt_pages_are_dry() {
        // full-context reservation holds all 4 pages for the seated
        // request; a later arrival under PagePressure sheds at
        // arrival instead of queueing, and the shed is counted on the
        // page telemetry
        let requests = reqs(&[2, 2]);
        let s = sched(&[0.0, 1.0], 1.0);
        let cfg = PagedKvConfig::new(4).with_total_pages(4)
            .with_reserve(PageReserve::FullContext);
        let adm = PagePressure::new();
        let mut be = MockBackend::new(1, 16, false);
        let report = run_paged(&mut be, &requests, &s, &adm,
                               Some(&cfg));
        let (r0, r1) = (&report.results[0], &report.results[1]);
        assert!(r0.outcome.is_completed());
        assert_eq!(r0.tokens, vec![5, 5]);
        assert_eq!(r1.outcome, RequestOutcome::Shed);
        assert_eq!((report.stats.completed, report.stats.shed),
                   (1, 1));
        assert_eq!(report.stats.pages.page_sheds, 1);
        assert_eq!(report.stats.pages.leaked_pages, 0);
    }

    #[test]
    fn speculative_and_paged_are_mutually_exclusive() {
        let requests = reqs(&[2]);
        let s = sched(&[0.0], 1.0);
        let names = [String::from("a"), String::from("b")];
        let plan = SpecPlan { draft_lane: 1, verifier_lane: 0, k: 2 };
        let cfg = PagedKvConfig::new(4);
        let mut b0 = MockBackend::new(1, 16, false);
        let mut b1 = MockBackend::new(1, 16, false);
        let err = run_lanes_spec(
            &mut [&mut b0, &mut b1], &names, &[0], &requests,
            &DecodeParams::default(), Some(&s), &Fifo, &Unbounded,
            &RecoveryConfig::default(),
            &[LaneCost::unit(), LaneCost::unit()], Some(&plan),
            Some(&cfg))
            .unwrap_err();
        assert!(err.to_string().contains("mutually exclusive"));
    }
}
