//! The backend-agnostic slot-refill state machine.
//!
//! The `logits_last` artifact is compiled for a fixed
//! `(decode_batch, ctx_len)` shape, but serving traffic is an arbitrary
//! stream of prompts with wildly different generation lengths. Static
//! chunking (decode `B` prompts, wait for the *slowest*, repeat) burns
//! batch slots as padding the moment one slot finishes early. Here a
//! request queue feeds the batch instead: the moment a slot's request
//! finishes (EOS / length cap), the slot is rewritten with the next
//! queued prompt **mid-flight** — the model step never idles a slot
//! while work is waiting. Causal attention plus the explicit `pos`
//! input make each row independent, so a slot's output is bit-identical
//! to decoding its prompt alone (`tests/integration_runtime.rs` checks
//! this).
//!
//! One state machine, parameterized on three axes:
//!  * **backend** — the per-step logits producer is a
//!    [`LogitsBackend`]: the literal-resident engine path (full
//!    context recompute), the KV-resident incremental path (session
//!    state + per-slot prefill on refill), or a deterministic
//!    in-process mock (so every queueing/clock/policy edge is
//!    unit-testable without compiled artifacts);
//!  * **time** — wall clock, or a deterministic virtual clock under a
//!    [`Schedule`] (the `loadgen` workload driver): requests become
//!    visible as their arrival times pass, every model invocation
//!    advances the clock by a fixed cost, and per-request queue-wait /
//!    TTFT / end-to-end latencies are read off the virtual clock;
//!  * **policy** — a [`Scheduler`] picks which ready request fills a
//!    freed slot and an [`AdmissionPolicy`] decides enqueue / shed /
//!    expire ([`super::policy`], [`super::admission`]). The defaults
//!    (FIFO, unbounded) reproduce the pre-split `batching` behavior
//!    bit-for-bit; policies change *which* request waits or fails,
//!    never *what* an admitted request decodes.
//!
//! Entry points: [`serve`] / [`serve_kv`] (whole stream present at
//! entry, wall-clock latencies), [`serve_timed`] (arrival-gated on the
//! virtual clock), and [`serve_with`] (everything explicit via
//! [`ServeConfig`]).

use std::time::Instant;

use crate::generate::engine::DecodeEngine;
use crate::generate::{topk, DecodeParams};
use crate::runtime::SessionState;
use crate::tokenizer::EOS;

use super::admission::{AdmissionPolicy, Unbounded};
use super::clock::{ArrivalQueue, Clock, Schedule};
use super::policy::{Fifo, Scheduler};
use super::telemetry::{RequestOutcome, RequestResult, ServeReport,
                       ServeStats};
use super::DecodeRequest;

/// The per-step logits producer behind the slot-refill state machine:
/// the literal-resident engine path, the KV-resident path, and
/// deterministic test mocks (so queueing/clock behavior is testable
/// without compiled artifacts).
pub(crate) trait LogitsBackend {
    /// `(decode_batch, ctx_len, vocab)`.
    fn dims(&self) -> (usize, usize, usize);
    /// true → the serve loop maintains per-slot refill marks and calls
    /// [`Self::prefill`] before a step whenever any slot was
    /// (re)written.
    fn needs_prefill(&self) -> bool {
        false
    }
    /// (Re)populate cache rows with `refill[s] > 0` from the token
    /// buffer; other rows pass through untouched.
    fn prefill(&mut self, _tokens: &[i32], _pos: &[i32],
               _refill: &[f32]) -> anyhow::Result<()> {
        Ok(())
    }
    /// Logits for every row read at its `pos` (flat `B * vocab`).
    fn step(&mut self, tokens: &[i32], pos: &[i32])
            -> anyhow::Result<Vec<f32>>;
}

/// Literal-resident backend: full-context recompute per step.
struct LiteralBackend<'e, 'a> {
    engine: &'e DecodeEngine<'a>,
}

impl LogitsBackend for LiteralBackend<'_, '_> {
    fn dims(&self) -> (usize, usize, usize) {
        (self.engine.decode_batch(), self.engine.ctx_len(),
         self.engine.vocab())
    }

    fn step(&mut self, tokens: &[i32], pos: &[i32])
            -> anyhow::Result<Vec<f32>> {
        self.engine.step_logits(tokens, pos)
    }
}

/// KV-resident backend: per-layer caches as session-state literals,
/// advanced by the incremental `decode_step` artifact. Each row steps
/// by its token at `pos` (for a freshly prefilled row that re-derives
/// the prompt tail's K/V — same values — and yields the same logits
/// the prefill already read; uniformity keeps every emitted logit on
/// the incremental program).
struct KvBackend<'e, 'a> {
    engine: &'e DecodeEngine<'a>,
    state: SessionState,
    next_tok: Vec<i32>,
}

impl LogitsBackend for KvBackend<'_, '_> {
    fn dims(&self) -> (usize, usize, usize) {
        (self.engine.decode_batch(), self.engine.ctx_len(),
         self.engine.vocab())
    }

    fn needs_prefill(&self) -> bool {
        true
    }

    fn prefill(&mut self, tokens: &[i32], pos: &[i32], refill: &[f32])
               -> anyhow::Result<()> {
        self.engine.kv_prefill(&mut self.state, tokens, pos, refill)?;
        Ok(())
    }

    fn step(&mut self, tokens: &[i32], pos: &[i32])
            -> anyhow::Result<Vec<f32>> {
        let t = self.engine.ctx_len();
        for (s, nt) in self.next_tok.iter_mut().enumerate() {
            *nt = tokens[s * t + pos[s] as usize];
        }
        self.engine.kv_step(&mut self.state, &self.next_tok, pos)
    }
}

/// A batch slot currently decoding one request. The slot's cursor
/// lives only in the shared `pos` buffer fed to the backend — a
/// slot-local copy would have to be advanced in lockstep and has
/// already caused one logits-read-at-stale-position bug.
struct Slot {
    req: usize, // index into `requests`
    out: Vec<u32>,
    entered_step: u64,
    /// Clock reading at slot entry.
    admit_ms: f64,
    /// Clock reading when the first token was emitted.
    first_tok_ms: Option<f64>,
}

/// Write a request's prompt into row `slot` of the token buffer,
/// clearing stale tokens from the previous occupant first (junk
/// *before* `pos` would leak into the new request's context).
/// `serve` validates up front that the prompt is non-empty and fits
/// the row (`len < t`).
fn fill_slot(
    tokens: &mut [i32],
    pos: &mut [i32],
    t: usize,
    slot: usize,
    prompt: &[u32],
) {
    debug_assert!(!prompt.is_empty() && prompt.len() < t,
                  "serve() validates prompt lengths up front");
    let row = &mut tokens[slot * t..(slot + 1) * t];
    row.fill(0);
    for (j, &tok) in prompt.iter().enumerate() {
        row[j] = tok as i32;
    }
    pos[slot] = prompt.len() as i32 - 1;
}

/// Everything a serve call can vary: engine path, arrival timing, and
/// the two policies. [`ServeConfig::new`] gives the defaults (untimed,
/// FIFO, unbounded) that reproduce the pre-split behavior.
pub struct ServeConfig<'a> {
    /// Decode on the KV-resident incremental path instead of the
    /// literal-resident full-recompute path.
    pub use_kv: bool,
    /// Arrival-gate requests on this virtual-clock schedule (None =
    /// whole stream present at entry, wall-clock telemetry).
    pub schedule: Option<&'a Schedule>,
    /// Which ready request fills a freed slot.
    pub scheduler: &'a dyn Scheduler,
    /// Enqueue / shed / expire decisions.
    pub admission: &'a dyn AdmissionPolicy,
}

impl<'a> ServeConfig<'a> {
    pub fn new(use_kv: bool) -> ServeConfig<'a> {
        ServeConfig {
            use_kv,
            schedule: None,
            scheduler: &Fifo,
            admission: &Unbounded,
        }
    }

    /// Defaults plus a virtual-clock schedule.
    pub fn timed(use_kv: bool, schedule: &'a Schedule)
                 -> ServeConfig<'a> {
        ServeConfig { schedule: Some(schedule),
                      ..ServeConfig::new(use_kv) }
    }
}

/// Run a request stream to completion through the engine's
/// literal-resident path (`logits_last`: full-context recompute per
/// step) with FIFO scheduling and unbounded admission. Requests enter
/// slots in order; each finished slot is refilled from the queue
/// before the next model step. `dp` supplies the sampling knobs
/// (`no_repeat_ngram`); generation budgets come from each request's
/// `max_new_tokens`, not `dp.max_new_tokens`.
pub fn serve(
    engine: &DecodeEngine,
    requests: &[DecodeRequest],
    dp: &DecodeParams,
) -> anyhow::Result<ServeReport> {
    serve_with(engine, requests, dp, &ServeConfig::new(false))
}

/// [`serve`] over the KV-resident incremental path: a slot's cache is
/// populated once per (re)fill by the `prefill` artifact, then every
/// step runs `decode_step` — only `(B,)` token/pos vectors cross the
/// host boundary and per-token model work is O(1) in the context
/// length. Greedy output is bit-identical to [`serve`] and to
/// [`crate::generate::reference::greedy`] (integration-tested,
/// including across slot refills). Errors if the KV artifacts were not
/// compiled.
pub fn serve_kv(
    engine: &DecodeEngine,
    requests: &[DecodeRequest],
    dp: &DecodeParams,
) -> anyhow::Result<ServeReport> {
    serve_with(engine, requests, dp, &ServeConfig::new(true))
}

/// Arrival-gated serving on the virtual clock — the `loadgen`
/// simulation driver — with FIFO scheduling and unbounded admission.
/// Decoded tokens are exactly what [`serve`] / [`serve_kv`] produce
/// for the same prompts; only admission timing and the reported
/// `*_ms` telemetry differ. Deterministic for a given request list +
/// schedule.
pub fn serve_timed(
    engine: &DecodeEngine,
    requests: &[DecodeRequest],
    dp: &DecodeParams,
    use_kv: bool,
    schedule: &Schedule,
) -> anyhow::Result<ServeReport> {
    serve_with(engine, requests, dp,
               &ServeConfig::timed(use_kv, schedule))
}

/// One backend-construction site for every public entry point; the
/// fully explicit form (engine path + schedule + policies).
pub fn serve_with(
    engine: &DecodeEngine,
    requests: &[DecodeRequest],
    dp: &DecodeParams,
    cfg: &ServeConfig,
) -> anyhow::Result<ServeReport> {
    if cfg.use_kv {
        let mut backend = KvBackend {
            engine,
            state: engine.kv_state()?,
            next_tok: vec![0i32; engine.decode_batch()],
        };
        run_loop_with(&mut backend, requests, dp, cfg.schedule,
                      cfg.scheduler, cfg.admission)
    } else {
        let mut backend = LiteralBackend { engine };
        run_loop_with(&mut backend, requests, dp, cfg.schedule,
                      cfg.scheduler, cfg.admission)
    }
}

/// [`run_loop_with`] under the default policies (FIFO, unbounded) —
/// the pre-split entry point, kept for the mock-backed unit tests.
#[cfg(test)]
pub(crate) fn run_loop(
    backend: &mut dyn LogitsBackend,
    requests: &[DecodeRequest],
    dp: &DecodeParams,
    schedule: Option<&Schedule>,
) -> anyhow::Result<ServeReport> {
    run_loop_with(backend, requests, dp, schedule, &Fifo, &Unbounded)
}

/// One slot-refill state machine for every decode path. The host-side
/// bookkeeping (token buffer, positions, EOS/length-cap edges, refill
/// order, admission, telemetry) is identical across backends; the
/// paths differ only in how a step's logits are produced, so any
/// divergence between them is a model-side bug by construction.
///
/// Per iteration: (1) arrivals up to `now` are admitted into the ready
/// set or shed, and queued requests past the admission deadline
/// expire — shed/expired requests still release their closed-loop
/// successors; (2) every free slot is filled with the scheduler's pick
/// from the ready set (zero-budget requests complete the moment they
/// are picked and never occupy a slot); (3) one model step advances
/// every occupied slot, and finished requests leave with
/// [`RequestOutcome::Completed`].
pub(crate) fn run_loop_with(
    backend: &mut dyn LogitsBackend,
    requests: &[DecodeRequest],
    dp: &DecodeParams,
    schedule: Option<&Schedule>,
    scheduler: &dyn Scheduler,
    admission: &dyn AdmissionPolicy,
) -> anyhow::Result<ServeReport> {
    let (b, t, vocab) = backend.dims();
    anyhow::ensure!(requests.iter().all(|r| !r.prompt.is_empty()),
                    "empty prompt in decode request stream");
    anyhow::ensure!(
        requests.iter().all(|r| r.prompt.len() < t),
        "prompt longer than ctx_len - 1 ({}) in decode request \
         stream — pre-truncate (keeping the tail) with \
         coordinator::prompt_tokens",
        t - 1
    );
    if let Some(s) = schedule {
        s.validate(requests.len())?;
    }
    let deadline = admission.deadline_ms();
    if let Some(d) = deadline {
        anyhow::ensure!(d.is_finite() && d > 0.0,
                        "queue deadline must be positive and finite \
                         (got {d})");
    }

    let t0 = Instant::now();
    let mut clock = Clock::new(schedule);
    let mut pending = ArrivalQueue::new(requests.len(), schedule);
    // Admitted requests awaiting a slot, ordered by (arrival, index) —
    // the scheduler picks from this set.
    let mut ready: Vec<usize> = Vec::new();
    let mut tokens = vec![0i32; b * t];
    let mut pos = vec![0i32; b];
    let mut slots: Vec<Option<Slot>> = (0..b).map(|_| None).collect();
    let mut results: Vec<RequestResult> =
        Vec::with_capacity(requests.len());
    let mut engine_steps = 0u64;
    let mut slot_steps = 0u64;
    let mut prefill_steps = 0u64;

    // KV path: `refill` marks rows whose cache must be (re)populated
    // from the token buffer before the next step.
    let needs_prefill = backend.needs_prefill();
    let mut refill = vec![0f32; b];
    let mut any_refill = false;

    loop {
        let now = clock.now_ms(&t0);

        // Admission: arrivals up to `now` are enqueued or shed;
        // queued requests past the deadline expire. Loop to a
        // fixpoint — shedding/expiring a closed-loop predecessor can
        // release a successor that is itself already due.
        loop {
            let mut moved = false;
            let free = slots.iter().filter(|s| s.is_none()).count();
            while let Some(i) = pending.pop_ready(now) {
                moved = true;
                let arrival = pending.arrival_of(i);
                // a request that will seat immediately never consults
                // the policy — only genuine waiters can be shed
                if ready.len() < free
                    || admission.admit(ready.len() - free)
                {
                    // keep the ready set sorted by (arrival, index):
                    // pops arrive in that order already EXCEPT a
                    // closed-loop successor released by a failure,
                    // whose back-dated arrival can predate entries
                    // admitted earlier in this fixpoint — it must
                    // queue ahead of them, not behind
                    pending.insert_ready(&mut ready, i);
                } else {
                    results.push(RequestResult {
                        id: requests[i].id,
                        tokens: Vec::new(),
                        queue_steps: 0,
                        decode_steps: 0,
                        arrival_ms: arrival,
                        queue_ms: 0.0,
                        ttft_ms: 0.0,
                        latency_ms: 0.0,
                        outcome: RequestOutcome::Shed,
                    });
                    // rejection happens AT arrival (the telemetry
                    // above says so); the closed-loop successor is
                    // released from that instant, not from the lazy
                    // step-boundary discovery — mirroring the
                    // back-dated expiry release below
                    pending.on_complete(i, arrival);
                }
            }
            if let Some(d) = deadline {
                let mut k = 0;
                while k < ready.len() {
                    let i = ready[k];
                    let arrival = pending.arrival_of(i);
                    if now - arrival > d {
                        ready.remove(k);
                        moved = true;
                        // the caller gave up at arrival + d; lazy
                        // discovery must not inflate the reported wait
                        results.push(RequestResult {
                            id: requests[i].id,
                            tokens: Vec::new(),
                            queue_steps: 0,
                            decode_steps: 0,
                            arrival_ms: arrival,
                            queue_ms: d,
                            ttft_ms: d,
                            latency_ms: d,
                            outcome: RequestOutcome::Expired,
                        });
                        pending.on_complete(i, arrival + d);
                    } else {
                        k += 1;
                    }
                }
            }
            if !moved {
                break;
            }
        }

        // Scheduling: fill every free slot with the policy's pick
        // from the ready set. Zero-budget requests complete the
        // moment they are picked (greedy with `max_new_tokens == 0`
        // decodes nothing) and never occupy a slot.
        for s in 0..b {
            if slots[s].is_some() {
                continue;
            }
            while !ready.is_empty() {
                let k = scheduler.pick(&ready, requests);
                anyhow::ensure!(k < ready.len(),
                                "scheduler {} picked {k} from a ready \
                                 set of {}", scheduler.name(),
                                ready.len());
                let i = ready.remove(k);
                let arrival = pending.arrival_of(i);
                if requests[i].max_new_tokens == 0 {
                    results.push(RequestResult {
                        id: requests[i].id,
                        tokens: Vec::new(),
                        queue_steps: engine_steps,
                        decode_steps: 0,
                        arrival_ms: arrival,
                        queue_ms: now - arrival,
                        ttft_ms: now - arrival,
                        latency_ms: now - arrival,
                        outcome: RequestOutcome::Completed,
                    });
                    pending.on_complete(i, now);
                    continue;
                }
                fill_slot(&mut tokens, &mut pos, t, s,
                          &requests[i].prompt);
                if needs_prefill {
                    refill[s] = 1.0;
                    any_refill = true;
                }
                slots[s] = Some(Slot {
                    req: i,
                    out: Vec::new(),
                    entered_step: engine_steps,
                    admit_ms: now,
                    first_tok_ms: None,
                });
                break;
            }
        }

        if slots.iter().all(|s| s.is_none()) {
            // the fill stage drains `ready` whenever a slot is free,
            // so only future or gated arrivals can remain
            if pending.is_empty() {
                break;
            }
            match pending.next_arrival() {
                // idle: nothing decoding, next arrival in the future
                Some(next) => {
                    clock.jump_to(next);
                    continue;
                }
                None => anyhow::bail!(
                    "request queue deadlocked: gated requests remain \
                     but nothing will release them"
                ),
            }
        }

        let occupied = slots.iter().filter(|s| s.is_some()).count();
        if needs_prefill && any_refill {
            // populate the marked rows' caches (positions up to and
            // including `pos`) from their prompt rows; other rows
            // pass through untouched
            backend.prefill(&tokens, &pos, &refill)?;
            prefill_steps += 1;
            refill.fill(0.0);
            any_refill = false;
            clock.on_prefill();
        }
        let lv = backend.step(&tokens, &pos)?;
        engine_steps += 1;
        slot_steps += occupied as u64;
        clock.on_step();
        let now = clock.now_ms(&t0);

        for s in 0..b {
            let finished = {
                let Some(slot) = slots[s].as_mut() else { continue };
                let max_new = requests[slot.req].max_new_tokens;
                let row = &lv[s * vocab..(s + 1) * vocab];
                let cur = pos[s] as usize;
                let ctx: Vec<u32> = if dp.no_repeat_ngram > 0 {
                    (0..=cur).map(|j| tokens[s * t + j] as u32)
                        .collect()
                } else {
                    Vec::new()
                };
                let next = topk::pick_next(row, &ctx,
                                           dp.no_repeat_ngram);
                let new_pos = cur + 1;
                let done = if next == EOS || new_pos >= t - 1 {
                    if next != EOS && new_pos < t {
                        slot.out.push(next);
                    }
                    true
                } else {
                    tokens[s * t + new_pos] = next as i32;
                    pos[s] = new_pos as i32;
                    slot.out.push(next);
                    slot.out.len() >= max_new
                };
                if slot.first_tok_ms.is_none() && !slot.out.is_empty() {
                    slot.first_tok_ms = Some(now);
                }
                done
            };
            if finished {
                let slot = slots[s].take().unwrap();
                let arrival = pending.arrival_of(slot.req);
                results.push(RequestResult {
                    id: requests[slot.req].id,
                    queue_steps: slot.entered_step,
                    decode_steps: engine_steps - slot.entered_step,
                    arrival_ms: arrival,
                    queue_ms: slot.admit_ms - arrival,
                    ttft_ms: slot.first_tok_ms.unwrap_or(now)
                        - arrival,
                    latency_ms: now - arrival,
                    tokens: slot.out,
                    outcome: RequestOutcome::Completed,
                });
                pending.on_complete(slot.req, now);
                // the freed slot refills from the queue at the top of
                // the next iteration, before the next model step
            }
        }
    }

    results.sort_by_key(|r| r.id);
    let wall_secs = t0.elapsed().as_secs_f64();
    let sim_ms = clock.now_ms(&t0);
    let stats = ServeStats::from_results(
        &results, requests.len(), b, engine_steps, prefill_steps,
        slot_steps, wall_secs, sim_ms);
    Ok(ServeReport { results, stats })
}

#[cfg(test)]
pub(crate) mod mock {
    //! Deterministic artifact-free backends for queueing/clock/policy
    //! tests (also used by `generate::loadgen` unit tests).

    use super::LogitsBackend;

    /// Emits logits whose argmax is always `tok` (never EOS), so
    /// generation length is exactly each request's budget; counts
    /// prefill passes when `kv` is set.
    pub struct MockBackend {
        pub b: usize,
        pub t: usize,
        pub vocab: usize,
        pub tok: usize,
        pub kv: bool,
        pub prefills: u64,
    }

    impl MockBackend {
        pub fn new(b: usize, t: usize, kv: bool) -> MockBackend {
            MockBackend { b, t, vocab: 16, tok: 5, kv, prefills: 0 }
        }
    }

    impl LogitsBackend for MockBackend {
        fn dims(&self) -> (usize, usize, usize) {
            (self.b, self.t, self.vocab)
        }

        fn needs_prefill(&self) -> bool {
            self.kv
        }

        fn prefill(&mut self, _tokens: &[i32], _pos: &[i32],
                   _refill: &[f32]) -> anyhow::Result<()> {
            self.prefills += 1;
            Ok(())
        }

        fn step(&mut self, _tokens: &[i32], _pos: &[i32])
                -> anyhow::Result<Vec<f32>> {
            let mut lv = vec![0.0f32; self.b * self.vocab];
            for s in 0..self.b {
                lv[s * self.vocab + self.tok] = 1.0;
            }
            Ok(lv)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::admission::{self, Bounded, MaxQueueDepth,
                                  QueueDeadline};
    use super::super::policy::{self, PriorityClass,
                               ShortestPromptFirst,
                               SmallestBudgetFirst};
    use super::mock::MockBackend;
    use super::*;

    fn reqs(budgets: &[usize]) -> Vec<DecodeRequest> {
        budgets.iter().enumerate()
            .map(|(i, &m)| DecodeRequest::new(i as u64, vec![1, 9, 3],
                                              m))
            .collect()
    }

    fn sched(arrivals: &[f64], step_ms: f64) -> Schedule {
        Schedule::open(arrivals.to_vec(), step_ms, step_ms)
    }

    fn run_policies(
        requests: &[DecodeRequest],
        s: &Schedule,
        scheduler: &dyn Scheduler,
        adm: &dyn AdmissionPolicy,
    ) -> ServeReport {
        let mut be = MockBackend::new(1, 16, false);
        run_loop_with(&mut be, requests, &DecodeParams::default(),
                      Some(s), scheduler, adm)
            .unwrap()
    }

    #[test]
    fn fill_slot_clears_previous_occupant() {
        let t = 8;
        let mut tokens = vec![7i32; 2 * t];
        let mut pos = vec![5i32; 2];
        fill_slot(&mut tokens, &mut pos, t, 1, &[9, 10]);
        assert_eq!(pos[1], 1);
        assert_eq!(&tokens[t..], &[9, 10, 0, 0, 0, 0, 0, 0]);
        // row 0 untouched
        assert!(tokens[..t].iter().all(|&x| x == 7));
    }

    #[test]
    fn fill_slot_max_length_prompt_fits() {
        // longest prompt serve() admits: t - 1 tokens, pos on the last
        let t = 4;
        let mut tokens = vec![0i32; t];
        let mut pos = vec![0i32; 1];
        fill_slot(&mut tokens, &mut pos, t, 0, &[1, 2, 3]);
        assert_eq!(pos[0], 2);
        assert_eq!(tokens, vec![1, 2, 3, 0]);
    }

    #[test]
    fn untimed_mock_serve_fifo_and_occupancy() {
        // 5 requests through 2 slots: FIFO assignment, full stats
        let mut be = MockBackend::new(2, 16, false);
        let requests = reqs(&[3, 3, 2, 2, 1]);
        let report = run_loop(&mut be, &requests,
                              &DecodeParams::default(), None).unwrap();
        assert_eq!(report.results.len(), 5);
        for (i, r) in report.results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.tokens.len(), requests[i].max_new_tokens);
            assert!(r.tokens.iter().all(|&t| t == 5));
            assert!(r.outcome.is_completed());
        }
        let st = &report.stats;
        // steps: slots run [3,3] then [2,2] then [1] → 6 engine steps,
        // slot_steps = 3+3+2+2+1 = 11
        assert_eq!(st.engine_steps, 6);
        assert_eq!(st.slot_steps, 11);
        assert_eq!(st.generated_tokens, 11);
        assert!((st.occupancy - 11.0 / 12.0).abs() < 1e-12);
        // later requests queued
        assert_eq!(report.results[4].queue_steps, 5);
        // unbounded FIFO never sheds
        assert_eq!((st.completed, st.shed, st.expired), (5, 0, 0));
        assert_eq!(st.shed_rate, 0.0);
        assert_eq!(st.tokens_per_sec, st.goodput_tokens_per_sec);
    }

    #[test]
    fn timed_serve_waits_for_arrivals_and_jumps_idle_gaps() {
        let mut be = MockBackend::new(2, 16, false);
        let requests = reqs(&[3, 3, 3, 3]);
        let s = sched(&[0.0, 0.0, 10.0, 10.0], 1.0);
        let report = run_loop(&mut be, &requests,
                              &DecodeParams::default(), Some(&s))
            .unwrap();
        let r = &report.results;
        // first wave: admit at 0, one token per 1ms step, done at 3
        assert_eq!(r[0].queue_ms, 0.0);
        assert_eq!(r[0].ttft_ms, 1.0);
        assert_eq!(r[0].latency_ms, 3.0);
        // second wave: clock jumps the idle gap to t=10
        assert_eq!(r[2].arrival_ms, 10.0);
        assert_eq!(r[2].queue_ms, 0.0);
        assert_eq!(r[2].latency_ms, 3.0);
        assert_eq!(report.stats.engine_steps, 6);
        assert_eq!(report.stats.sim_ms, 13.0);
        // no slot idled while work was pending
        assert!((report.stats.occupancy - 1.0).abs() < 1e-12);
    }

    #[test]
    fn timed_serve_records_queue_wait_under_saturation() {
        // one slot, three simultaneous arrivals: head-of-line blocking
        let mut be = MockBackend::new(1, 16, false);
        let requests = reqs(&[2, 2, 2]);
        let s = sched(&[0.0, 0.0, 0.0], 1.0);
        let report = run_loop(&mut be, &requests,
                              &DecodeParams::default(), Some(&s))
            .unwrap();
        let r = &report.results;
        assert_eq!(
            r.iter().map(|x| x.queue_ms).collect::<Vec<_>>(),
            vec![0.0, 2.0, 4.0]
        );
        assert_eq!(
            r.iter().map(|x| x.latency_ms).collect::<Vec<_>>(),
            vec![2.0, 4.0, 6.0]
        );
        assert_eq!(
            r.iter().map(|x| x.queue_steps).collect::<Vec<_>>(),
            vec![0, 2, 4]
        );
        assert_eq!(report.stats.latency_ms.p50, 4.0);
    }

    #[test]
    fn timed_serve_closed_loop_releases_successor() {
        let mut be = MockBackend::new(1, 16, false);
        let requests = reqs(&[1, 1]);
        let s = Schedule {
            arrivals: vec![0.0, f64::INFINITY],
            release: vec![Some((1, 5.0)), None],
            step_ms: 1.0,
            prefill_ms: 1.0,
        };
        let report = run_loop(&mut be, &requests,
                              &DecodeParams::default(), Some(&s))
            .unwrap();
        let r = &report.results;
        // request 0 completes at t=1; successor arrives at 1 + 5
        assert_eq!(r[1].arrival_ms, 6.0);
        assert_eq!(r[1].queue_ms, 0.0);
        assert_eq!(r[1].latency_ms, 1.0);
        assert_eq!(report.stats.sim_ms, 7.0);
    }

    #[test]
    fn timed_serve_zero_budget_completes_at_arrival() {
        let mut be = MockBackend::new(1, 16, false);
        let requests = reqs(&[2, 0]);
        let s = sched(&[0.0, 5.0], 1.0);
        let report = run_loop(&mut be, &requests,
                              &DecodeParams::default(), Some(&s))
            .unwrap();
        let r = &report.results;
        assert_eq!(r[0].latency_ms, 2.0);
        assert!(r[1].tokens.is_empty());
        assert_eq!(r[1].arrival_ms, 5.0);
        assert_eq!(r[1].latency_ms, 0.0);
        assert_eq!(r[1].decode_steps, 0);
        assert!(r[1].outcome.is_completed());
    }

    #[test]
    fn timed_serve_kv_prefill_costs_virtual_time() {
        let mut be = MockBackend::new(2, 16, true);
        let requests = reqs(&[2, 2, 2]);
        let s = sched(&[0.0, 0.0, 0.0], 1.0);
        let report = run_loop(&mut be, &requests,
                              &DecodeParams::default(), Some(&s))
            .unwrap();
        // initial fill: one prefill; request 2's refill: another
        assert_eq!(be.prefills, 2);
        assert_eq!(report.stats.prefill_steps, 2);
        let r = &report.results;
        // wave 1: prefill(1) + step(2) + step(3) → done at 3
        assert_eq!(r[0].latency_ms, 3.0);
        // request 2 admitted at 3, prefill(4) + step(5) + step(6)
        assert_eq!(r[2].queue_ms, 3.0);
        assert_eq!(r[2].latency_ms, 6.0);
    }

    #[test]
    fn timed_serve_is_deterministic() {
        let requests = reqs(&[3, 1, 4, 2, 2, 3, 1]);
        let s = sched(&[0.0, 0.5, 0.5, 2.0, 2.25, 7.0, 7.0], 0.75);
        let run = || {
            let mut be = MockBackend::new(2, 16, false);
            run_loop(&mut be, &requests, &DecodeParams::default(),
                     Some(&s)).unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.results.len(), b.results.len());
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(
                (x.arrival_ms, x.queue_ms, x.ttft_ms, x.latency_ms),
                (y.arrival_ms, y.queue_ms, y.ttft_ms, y.latency_ms)
            );
        }
        assert_eq!(a.stats.engine_steps, b.stats.engine_steps);
        assert_eq!(a.stats.sim_ms, b.stats.sim_ms);
        assert_eq!(a.stats.latency_ms, b.stats.latency_ms);
    }

    #[test]
    fn schedule_validation_rejects_bad_shapes() {
        let requests = reqs(&[1, 1]);
        let mut be = MockBackend::new(1, 16, false);
        // wrong arrival count
        let s = Schedule::open(vec![0.0], 1.0, 1.0);
        assert!(run_loop(&mut be, &requests, &DecodeParams::default(),
                         Some(&s)).is_err());
        // gated request that nothing releases
        let s = Schedule {
            arrivals: vec![0.0, f64::INFINITY],
            release: vec![None, None],
            step_ms: 1.0,
            prefill_ms: 1.0,
        };
        assert!(run_loop(&mut be, &requests, &DecodeParams::default(),
                         Some(&s)).is_err());
        // double release
        let s = Schedule {
            arrivals: vec![0.0, 0.0, f64::INFINITY],
            release: vec![Some((2, 0.0)), Some((2, 0.0)), None],
            step_ms: 1.0,
            prefill_ms: 1.0,
        };
        assert!(run_loop(&mut be, &reqs(&[1, 1, 1]),
                         &DecodeParams::default(), Some(&s)).is_err());
        // -inf arrival: would be admitted immediately AND re-queued
        // by its release (decoded twice) — must be rejected
        let s = Schedule {
            arrivals: vec![0.0, f64::NEG_INFINITY],
            release: vec![Some((1, 5.0)), None],
            step_ms: 1.0,
            prefill_ms: 1.0,
        };
        assert!(run_loop(&mut be, &requests, &DecodeParams::default(),
                         Some(&s)).is_err());
        // NaN arrival rejected too (the sort itself is total_cmp and
        // cannot panic first — see clock::tests::arrival_sort_is_nan_safe)
        let s = Schedule::open(vec![0.0, f64::NAN], 1.0, 1.0);
        assert!(run_loop(&mut be, &requests, &DecodeParams::default(),
                         Some(&s)).is_err());
    }

    #[test]
    fn bad_deadline_rejected_up_front() {
        let mut be = MockBackend::new(1, 16, false);
        let requests = reqs(&[1]);
        for d in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let adm = QueueDeadline(d);
            assert!(run_loop_with(&mut be, &requests,
                                  &DecodeParams::default(), None,
                                  &Fifo, &adm)
                        .is_err(),
                    "deadline {d} should be rejected");
        }
    }

    #[test]
    fn shortest_prompt_first_reorders_queue() {
        // one slot, simultaneous arrivals with prompt lengths 5/3/4:
        // service order must be 1, 2, 0 (FIFO would be 0, 1, 2)
        let requests = vec![
            DecodeRequest::new(0, vec![1, 2, 3, 4, 5], 2),
            DecodeRequest::new(1, vec![1, 2, 3], 2),
            DecodeRequest::new(2, vec![1, 2, 3, 4], 2),
        ];
        let s = sched(&[0.0, 0.0, 0.0], 1.0);
        let report = run_policies(&requests, &s, &ShortestPromptFirst,
                                  &admission::Unbounded);
        let lat: Vec<f64> =
            report.results.iter().map(|r| r.latency_ms).collect();
        assert_eq!(lat, vec![6.0, 2.0, 4.0]);
        // reordering changes who waits, never what anyone decodes
        for r in &report.results {
            assert_eq!(r.tokens, vec![5, 5]);
        }
    }

    #[test]
    fn smallest_budget_first_reorders_queue() {
        // budgets 5/1/2 through one slot: service order 1, 2, 0
        let requests = reqs(&[5, 1, 2]);
        let s = sched(&[0.0, 0.0, 0.0], 1.0);
        let report = run_policies(&requests, &s, &SmallestBudgetFirst,
                                  &admission::Unbounded);
        let lat: Vec<f64> =
            report.results.iter().map(|r| r.latency_ms).collect();
        assert_eq!(lat, vec![8.0, 1.0, 3.0]);
    }

    #[test]
    fn smallest_budget_first_completes_zero_budget_first() {
        let requests = vec![
            DecodeRequest::new(0, vec![1, 2], 3),
            DecodeRequest::new(1, vec![1, 2], 0),
        ];
        let s = sched(&[0.0, 0.0], 1.0);
        let report = run_policies(&requests, &s, &SmallestBudgetFirst,
                                  &admission::Unbounded);
        assert_eq!(report.results[1].latency_ms, 0.0);
        assert!(report.results[1].outcome.is_completed());
        assert_eq!(report.results[0].latency_ms, 3.0);
    }

    #[test]
    fn priority_class_jumps_the_queue() {
        // priorities 0/0/7 through one slot: request 2 is served
        // first, then FIFO among the zeros
        let requests: Vec<DecodeRequest> = reqs(&[2, 2, 2])
            .into_iter()
            .map(|r| {
                let p = if r.id == 2 { 7 } else { 0 };
                r.with_priority(p)
            })
            .collect();
        let s = sched(&[0.0, 0.0, 0.0], 1.0);
        let report = run_policies(&requests, &s, &PriorityClass,
                                  &admission::Unbounded);
        let lat: Vec<f64> =
            report.results.iter().map(|r| r.latency_ms).collect();
        assert_eq!(lat, vec![4.0, 6.0, 2.0]);
    }

    #[test]
    fn max_queue_sheds_on_arrival_with_pinned_telemetry() {
        // one slot, depth cap 1: request 0 seats, request 1 waits,
        // request 2 is shed the instant it arrives
        let requests = reqs(&[2, 2, 2]);
        let s = sched(&[0.0, 0.0, 0.0], 1.0);
        let report = run_policies(&requests, &s, &Fifo,
                                  &MaxQueueDepth(1));
        let r = &report.results;
        assert_eq!(r[0].latency_ms, 2.0);
        assert!(r[0].outcome.is_completed());
        assert_eq!(r[1].queue_ms, 2.0);
        assert_eq!(r[1].latency_ms, 4.0);
        assert_eq!(r[2].outcome, RequestOutcome::Shed);
        assert!(r[2].tokens.is_empty());
        assert_eq!(r[2].latency_ms, 0.0);
        assert_eq!(r[2].decode_steps, 0);
        let st = &report.stats;
        assert_eq!((st.completed, st.shed, st.expired), (2, 1, 0));
        assert!((st.shed_rate - 1.0 / 3.0).abs() < 1e-12);
        // percentiles cover completed requests only
        assert_eq!(st.latency_ms.n, 2);
        assert_eq!(st.latency_ms.min, 2.0);
        assert_eq!(st.sim_ms, 4.0);
    }

    #[test]
    fn depth_zero_sheds_all_waiters_but_seats_free_slots() {
        // a cold server with a free slot must never shed the request
        // that would seat immediately
        let requests = reqs(&[2, 2, 2]);
        let s = sched(&[0.0, 0.0, 0.0], 1.0);
        let report = run_policies(&requests, &s, &Fifo,
                                  &MaxQueueDepth(0));
        let st = &report.stats;
        assert_eq!((st.completed, st.shed), (1, 2));
        assert!(report.results[0].outcome.is_completed());
    }

    #[test]
    fn queue_deadline_expires_waiters_at_their_deadline() {
        // one slot, 3ms deadline: request 2 would wait 4ms, so it
        // expires — reported at the instant the caller gave up
        // (arrival + 3ms), not at lazy-discovery time
        let requests = reqs(&[2, 2, 2]);
        let s = sched(&[0.0, 0.0, 0.0], 1.0);
        let report = run_policies(&requests, &s, &Fifo,
                                  &QueueDeadline(3.0));
        let r = &report.results;
        assert_eq!(r[0].latency_ms, 2.0);
        // request 1 seats at exactly its 2ms wait (< deadline)
        assert_eq!(r[1].queue_ms, 2.0);
        assert_eq!(r[1].latency_ms, 4.0);
        assert_eq!(r[2].outcome, RequestOutcome::Expired);
        assert_eq!(r[2].queue_ms, 3.0);
        assert_eq!(r[2].latency_ms, 3.0);
        assert!(r[2].tokens.is_empty());
        let st = &report.stats;
        assert_eq!((st.completed, st.shed, st.expired), (2, 0, 1));
        assert_eq!(st.sim_ms, 4.0);
    }

    #[test]
    fn deadline_exactly_met_still_seats() {
        // expiry is strict (> deadline): a request picked at exactly
        // its deadline wait still decodes
        let requests = reqs(&[2, 2]);
        let s = sched(&[0.0, 0.0], 1.0);
        let report = run_policies(&requests, &s, &Fifo,
                                  &QueueDeadline(2.0));
        assert!(report.results[1].outcome.is_completed());
        assert_eq!(report.results[1].queue_ms, 2.0);
    }

    #[test]
    fn backdated_release_keeps_arrival_order() {
        // an expiry discovered late releases its successor with a
        // back-dated arrival (predecessor arrival + deadline +
        // think); the successor must queue AHEAD of ready requests
        // that arrived after that instant, preserving FIFO-by-arrival
        let requests = reqs(&[5, 1, 1, 1]);
        let s = Schedule {
            arrivals: vec![0.0, 0.0, f64::INFINITY, 3.5],
            release: vec![None, Some((2, 0.0)), None, None],
            step_ms: 1.0,
            prefill_ms: 1.0,
        };
        let report = run_policies(&requests, &s, &Fifo,
                                  &QueueDeadline(3.0));
        let r = &report.results;
        assert!(r[0].outcome.is_completed());
        assert_eq!(r[0].latency_ms, 5.0);
        // request 1 waited past the 3ms deadline (slot busy to t=5)
        assert_eq!(r[1].outcome, RequestOutcome::Expired);
        assert_eq!(r[1].queue_ms, 3.0);
        // successor released at 0 + 3 + 0 = 3, BEFORE request 3's
        // 3.5ms arrival — despite being discovered after request 3
        // was already admitted, it is served first
        assert_eq!(r[2].arrival_ms, 3.0);
        assert!(r[2].outcome.is_completed());
        assert_eq!(r[2].queue_ms, 2.0);
        assert_eq!(r[2].latency_ms, 3.0);
        assert_eq!(r[3].queue_ms, 2.5);
        assert_eq!(r[3].latency_ms, 3.5);
        assert_eq!(report.stats.sim_ms, 7.0);
    }

    #[test]
    fn shed_and_expired_release_closed_loop_successors() {
        // depth 0 on one slot: request 1 is shed at t=0, yet its
        // closed-loop successor (request 2) must still be released —
        // the simulated client retries after a failure
        let requests = reqs(&[2, 2, 2]);
        let s = Schedule {
            arrivals: vec![0.0, 0.0, f64::INFINITY],
            release: vec![None, Some((2, 1.0)), None],
            step_ms: 1.0,
            prefill_ms: 1.0,
        };
        let report = run_policies(&requests, &s, &Fifo,
                                  &MaxQueueDepth(0));
        let r = &report.results;
        assert!(r[0].outcome.is_completed());
        assert_eq!(r[1].outcome, RequestOutcome::Shed);
        // released at shed(0) + think(1) = 1, slot busy until 2 →
        // request 2 is itself shed on arrival (depth 0, no free slot)
        assert_eq!(r[2].arrival_ms, 1.0);
        assert_eq!(r[2].outcome, RequestOutcome::Shed);
        // no deadlock: all three requests accounted for
        assert_eq!(report.stats.requests, 3);
        assert_eq!(report.stats.completed + report.stats.shed, 3);
    }

    #[test]
    fn shed_release_is_backdated_to_the_arrival_instant() {
        // a request arriving between step boundaries is shed AT its
        // arrival (its telemetry says latency 0); its closed-loop
        // successor is released from that instant too, not from the
        // step-boundary where the loop discovered the arrival
        let requests = reqs(&[3, 1, 1]);
        let s = Schedule {
            arrivals: vec![0.0, 0.5, f64::INFINITY],
            release: vec![None, Some((2, 0.0)), None],
            step_ms: 1.0,
            prefill_ms: 1.0,
        };
        let report = run_policies(&requests, &s, &Fifo,
                                  &MaxQueueDepth(0));
        let r = &report.results;
        assert_eq!(r[1].outcome, RequestOutcome::Shed);
        assert_eq!(r[1].arrival_ms, 0.5);
        // released at 0.5 + 0 think — not at the 1.0 discovery step
        assert_eq!(r[2].arrival_ms, 0.5);
        assert_eq!(r[2].outcome, RequestOutcome::Shed);
    }

    #[test]
    fn bounded_queue_caps_p95_under_overload() {
        // the acceptance shape: past saturation, bounding the queue
        // trades a nonzero shed rate for a bounded tail latency
        let requests = reqs(&[3, 3, 3, 3, 3, 3]);
        let s = sched(&[0.0; 6], 1.0);
        let unbounded = run_policies(&requests, &s, &Fifo,
                                     &admission::Unbounded);
        let bounded = run_policies(&requests, &s, &Fifo,
                                   &MaxQueueDepth(1));
        assert_eq!(unbounded.stats.shed_rate, 0.0);
        assert!(bounded.stats.shed_rate > 0.0);
        assert!(bounded.stats.latency_ms.p95
                    < unbounded.stats.latency_ms.p95,
                "bounded p95 {} !< unbounded p95 {}",
                bounded.stats.latency_ms.p95,
                unbounded.stats.latency_ms.p95);
        // pinned: completed latencies 3, 6 vs 3, 6, 9, 12, 15, 18
        assert_eq!(bounded.stats.completed, 2);
        assert_eq!(bounded.stats.latency_ms.max, 6.0);
        assert_eq!(unbounded.stats.latency_ms.max, 18.0);
    }

    #[test]
    fn every_scheduler_and_admission_combination_is_sound() {
        // 4 schedulers x 4 admission policies over an oversubscribed
        // timed trace: every combination must terminate, account for
        // every request exactly once, produce only budget-shaped
        // outputs, and be deterministic run-to-run
        let requests: Vec<DecodeRequest> = (0..10)
            .map(|i| {
                DecodeRequest::new(
                    i as u64,
                    vec![1; 2 + (i % 4)],
                    1 + (i % 4),
                )
                .with_priority((i % 3) as u8)
            })
            .collect();
        let s = sched(&[0.0, 0.0, 1.0, 1.0, 2.0, 2.0, 3.0, 3.0, 9.0,
                        9.0], 1.0);
        let schedulers: Vec<Box<dyn Scheduler>> =
            vec![Box::new(Fifo), Box::new(ShortestPromptFirst),
                 Box::new(SmallestBudgetFirst),
                 Box::new(PriorityClass)];
        let admissions: Vec<Box<dyn AdmissionPolicy>> =
            vec![Box::new(admission::Unbounded),
                 Box::new(MaxQueueDepth(2)),
                 Box::new(QueueDeadline(2.5)),
                 Box::new(Bounded { max_queue: 2,
                                    deadline_ms: 2.5 })];
        for sch in &schedulers {
            for adm in &admissions {
                let run = || {
                    let mut be = MockBackend::new(2, 16, false);
                    run_loop_with(&mut be, &requests,
                                  &DecodeParams::default(), Some(&s),
                                  sch.as_ref(), adm.as_ref())
                        .unwrap()
                };
                let label =
                    format!("{}/{}", sch.name(), adm.name());
                let (a, b) = (run(), run());
                let st = &a.stats;
                assert_eq!(a.results.len(), 10, "{label}");
                assert_eq!(st.completed + st.shed + st.expired, 10,
                           "{label}");
                for (i, r) in a.results.iter().enumerate() {
                    assert_eq!(r.id, i as u64, "{label}");
                    match r.outcome {
                        RequestOutcome::Completed => assert_eq!(
                            r.tokens.len(),
                            requests[i].max_new_tokens, "{label}"),
                        _ => assert!(r.tokens.is_empty(), "{label}"),
                    }
                }
                if adm.name() == "unbounded" {
                    assert_eq!(st.shed_rate, 0.0, "{label}");
                    assert_eq!(st.completed, 10, "{label}");
                }
                // determinism across runs, policies included
                assert_eq!(a.results.len(), b.results.len());
                for (x, y) in a.results.iter().zip(&b.results) {
                    assert_eq!(x.tokens, y.tokens, "{label}");
                    assert_eq!(
                        (x.queue_ms, x.latency_ms, x.outcome),
                        (y.queue_ms, y.latency_ms, y.outcome),
                        "{label}"
                    );
                }
                assert_eq!(a.stats.sim_ms, b.stats.sim_ms, "{label}");
            }
        }
    }

    #[test]
    fn explicit_fifo_unbounded_is_bit_identical_to_default() {
        // the tentpole invariant at the mock level: threading the
        // default policies through run_loop_with changes nothing
        let requests = reqs(&[3, 1, 4, 2, 2, 3, 1]);
        let s = sched(&[0.0, 0.5, 0.5, 2.0, 2.25, 7.0, 7.0], 0.75);
        let mut be_a = MockBackend::new(2, 16, false);
        let a = run_loop(&mut be_a, &requests,
                         &DecodeParams::default(), Some(&s)).unwrap();
        let mut be_b = MockBackend::new(2, 16, false);
        let b = run_loop_with(&mut be_b, &requests,
                              &DecodeParams::default(), Some(&s),
                              &policy::Fifo, &admission::Unbounded)
            .unwrap();
        assert_eq!(a.results.len(), b.results.len());
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(
                (x.arrival_ms, x.queue_ms, x.ttft_ms, x.latency_ms,
                 x.queue_steps, x.decode_steps),
                (y.arrival_ms, y.queue_ms, y.ttft_ms, y.latency_ms,
                 y.queue_steps, y.decode_steps)
            );
        }
        assert_eq!(a.stats.engine_steps, b.stats.engine_steps);
        assert_eq!(a.stats.slot_steps, b.stats.slot_steps);
        assert_eq!(a.stats.sim_ms, b.stats.sim_ms);
        assert_eq!(a.stats.latency_ms, b.stats.latency_ms);
        assert_eq!(a.stats.queue_ms, b.stats.queue_ms);
        assert_eq!(a.stats.ttft_ms, b.stats.ttft_ms);
    }
}
