//! Multi-model serving registry: one serve loop, N resident models.
//!
//! SPDF's training recipe yields a *family* of checkpoints — the dense
//! baseline plus the sparse-pre-trained/dense-fine-tuned variants at
//! 50%/75% sparsity — and a real deployment serves several of them at
//! once from one process. [`ModelRegistry`] holds N named
//! [`DecodeEngine`]s (each with its own literal-resident parameter
//! cache and, on the KV path, its own session state, typically loaded
//! from separate artifact dirs such as `dense/`, `s50/`, `s75/`) and
//! routes a single request stream across them through the
//! scheduler-driven core: [`DecodeRequest::model`] names the target
//! model (`None` → the default, the first registered entry), slots
//! become (model, slot) pairs with per-model `decode_batch` budgets,
//! and the `Scheduler`/`AdmissionPolicy` decisions stay model-aware —
//! a freed `s75` slot only seats `s75`-ready requests, and the queue
//! depth an admission policy sees is the request's own model's queue.
//!
//! The registry adds routing, never semantics: a registry holding a
//! single model reproduces the plain [`core::serve_timed`] output
//! bit-for-bit on both engine paths (pinned by the integration
//! suite), and per-model [`super::telemetry::ModelStats`] blocks sum
//! to the aggregate [`super::telemetry::ServeStats`]
//! (property-tested in `rust/tests/`).

use crate::generate::engine::DecodeEngine;
use crate::generate::DecodeParams;

use super::clock::Schedule;
use super::core::{self, LogitsBackend, ServeConfig};
use super::fault::{plans_for_lanes, FaultyBackend, RecoveryConfig};
use super::speculative::SpecPlan;
use super::telemetry::ServeReport;
use super::DecodeRequest;

/// N named decode engines behind one serve loop. The first registered
/// entry is the **default model** — the target of requests whose
/// [`DecodeRequest::model`] is `None`.
///
/// ```no_run
/// use spdf::generate::{DecodeParams, DecodeRequest};
/// use spdf::generate::engine::DecodeEngine;
/// use spdf::generate::serve::ModelRegistry;
///
/// fn sweep(dense: &DecodeEngine, s75: &DecodeEngine)
///          -> anyhow::Result<()> {
///     let mut reg = ModelRegistry::new("dense", dense)?;
///     reg.register("s75", s75)?;
///     let reqs = vec![
///         // no tag → the default model ("dense")
///         DecodeRequest::new(0, vec![1, 2, 3], 8),
///         DecodeRequest::new(1, vec![4, 5], 8).with_model("s75"),
///     ];
///     let report = reg.serve(&reqs, &DecodeParams::default())?;
///     assert_eq!(report.stats.completed, 2);
///     Ok(())
/// }
/// ```
pub struct ModelRegistry<'e, 'a> {
    entries: Vec<(String, &'e DecodeEngine<'a>)>,
}

impl<'e, 'a> ModelRegistry<'e, 'a> {
    /// Registry with its default model. More models join via
    /// [`Self::register`].
    pub fn new(default_name: impl Into<String>,
               engine: &'e DecodeEngine<'a>)
               -> anyhow::Result<ModelRegistry<'e, 'a>> {
        let mut r = ModelRegistry { entries: Vec::new() };
        r.register(default_name, engine)?;
        Ok(r)
    }

    /// Add a named model. Names must be unique and non-empty; the
    /// same engine may be registered under several names (useful for
    /// A/B routing and for the cross-engine golden tests).
    pub fn register(&mut self, name: impl Into<String>,
                    engine: &'e DecodeEngine<'a>)
                    -> anyhow::Result<()> {
        let name = name.into();
        anyhow::ensure!(!name.is_empty(),
                        "registry model name must be non-empty");
        anyhow::ensure!(
            self.entries.iter().all(|(n, _)| *n != name),
            "model {name} already registered"
        );
        self.entries.push((name, engine));
        Ok(())
    }

    /// Number of registered models, the default entry included.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Never true — [`Self::new`] always registers the default entry
    /// (kept alongside [`Self::len`] for the usual pairing).
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Registered model names, registration order (default first).
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|(n, _)| n.as_str()).collect()
    }

    /// The default model's name (the first registered entry).
    pub fn default_model(&self) -> &str {
        &self.entries[0].0
    }

    /// Is the KV-resident path available on **every** registered
    /// engine? (The serve loop runs all lanes on one path.)
    pub fn kv_available(&self) -> bool {
        self.entries.iter().all(|(_, e)| e.kv_available())
    }

    /// Lane index for one request's model tag: `None` routes to the
    /// default (index 0), `Some(name)` must match a registered model
    /// exactly.
    pub fn resolve(&self, model: Option<&str>)
                   -> anyhow::Result<usize> {
        match model {
            None => Ok(0),
            Some(m) => self
                .entries
                .iter()
                .position(|(n, _)| n == m)
                .ok_or_else(|| anyhow::anyhow!(
                    "model {m} not in registry (have: {})",
                    self.names().join(", "))),
        }
    }

    /// Per-request lane assignment for a stream — the routing table
    /// the serve loop runs on. Unknown model names error up front,
    /// before any model work.
    pub fn lane_of(&self, requests: &[DecodeRequest])
                   -> anyhow::Result<Vec<usize>> {
        requests
            .iter()
            .map(|r| self.resolve(r.model.as_deref()))
            .collect()
    }

    /// [`core::serve`] across the registry: whole stream present at
    /// entry, literal-resident path, FIFO + unbounded.
    pub fn serve(&self, requests: &[DecodeRequest], dp: &DecodeParams)
                 -> anyhow::Result<ServeReport> {
        self.serve_with(requests, dp, &ServeConfig::new(false))
    }

    /// [`Self::serve`] over the KV-resident incremental path (every
    /// lane gets its own fresh session state).
    pub fn serve_kv(&self, requests: &[DecodeRequest],
                    dp: &DecodeParams) -> anyhow::Result<ServeReport> {
        self.serve_with(requests, dp, &ServeConfig::new(true))
    }

    /// Arrival-gated serving on the virtual clock — one
    /// [`Schedule`]'s stream multiplexed across every registered
    /// model. With a single registered model this is bit-for-bit
    /// [`core::serve_timed`].
    pub fn serve_timed(&self, requests: &[DecodeRequest],
                       dp: &DecodeParams, use_kv: bool,
                       schedule: &Schedule)
                       -> anyhow::Result<ServeReport> {
        self.serve_with(requests, dp,
                        &ServeConfig::timed(use_kv, schedule))
    }

    /// The fully explicit form: engine path + schedule + policies +
    /// fault/recovery config, routed per-request by
    /// [`DecodeRequest::model`]. Fault plans in `cfg.faults` wrap the
    /// named lanes' backends in deterministic injectors,
    /// `cfg.fallback` resolves `(from, to)` model names into the
    /// recovery layer's failover route, `cfg.speculate` resolves
    /// `DRAFT=VERIFIER:k` model names into the self-speculative
    /// [`SpecPlan`] (draft lane proposes, verifier lane commits), and
    /// `cfg.paged` puts every lane's KV memory behind a fixed-size-
    /// page free list ([`super::pages`]).
    pub fn serve_with(&self, requests: &[DecodeRequest],
                      dp: &DecodeParams, cfg: &ServeConfig)
                      -> anyhow::Result<ServeReport> {
        let lane_of = self.lane_of(requests)?;
        let names: Vec<String> =
            self.entries.iter().map(|(n, _)| n.clone()).collect();
        let plans = plans_for_lanes(&cfg.faults, &names)?;
        let mut recovery: RecoveryConfig = cfg.recovery.clone();
        if let Some((from, to)) = &cfg.fallback {
            anyhow::ensure!(
                recovery.fallback.is_empty(),
                "give the failover route either as model names \
                 (fallback) or as resolved lanes (recovery.fallback), \
                 not both"
            );
            let from = self.resolve(Some(from))?;
            let to = self.resolve(Some(to))?;
            anyhow::ensure!(
                from != to,
                "failover route must name two different models \
                 (got {} twice)", names[from]
            );
            let mut table = vec![None; names.len()];
            table[from] = Some(to);
            recovery.fallback = table;
        }
        let mut backends: Vec<Box<dyn LogitsBackend + 'e>> = self
            .entries
            .iter()
            .enumerate()
            .map(|(l, (name, engine))| {
                // *engine copies the full-'e reference out of the
                // entry (a deref-coerced reborrow would be too short
                // for the Box<dyn + 'e> annotation)
                let backend = core::backend_for(*engine, cfg.use_kv)
                    .map_err(|e| {
                        e.context(format!("building {} backend for \
                                           model {name}",
                                          if cfg.use_kv {
                                              "kv"
                                          } else {
                                              "literal"
                                          }))
                    })?;
                match &plans[l] {
                    Some(plan) => Ok(Box::new(FaultyBackend::new(
                        backend, plan, l)?)
                        as Box<dyn LogitsBackend + 'e>),
                    None => Ok(backend),
                }
            })
            .collect::<anyhow::Result<_>>()?;
        let mut refs: Vec<&mut dyn LogitsBackend> =
            backends.iter_mut().map(|b| b.as_mut()).collect();
        // heterogeneous step costs: each lane's virtual step is scaled
        // by its engine's realized density (unit for dense engines),
        // so the s75 lane of a checkpoint-sweep registry steps ~4x
        // cheaper than dense on the shared clock
        let costs = self.lane_costs();
        let spec_plan: Option<SpecPlan> = match &cfg.speculate {
            Some(sc) => {
                sc.validate()?;
                Some(SpecPlan {
                    draft_lane: self.resolve(Some(&sc.draft))?,
                    verifier_lane: self.resolve(Some(&sc.verifier))?,
                    k: sc.k,
                })
            }
            None => None,
        };
        core::run_lanes_spec(&mut refs, &names, &lane_of, requests,
                             dp, cfg.schedule, cfg.scheduler,
                             cfg.admission, &recovery, &costs,
                             spec_plan.as_ref(), cfg.paged.as_ref())
    }

    /// Per-lane virtual step-cost multipliers, registration order:
    /// each engine's [`DecodeEngine::lane_cost`] (unit for dense and
    /// dense-loaded engines, density-scaled for CSR-resident ones).
    pub fn lane_costs(&self) -> Vec<super::clock::LaneCost> {
        self.entries.iter().map(|(_, e)| e.lane_cost()).collect()
    }
}
