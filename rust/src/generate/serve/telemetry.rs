//! Per-request and aggregate serving telemetry, with one JSON style
//! (the shared `util::json::push_num` helpers) across
//! [`RequestResult`], [`ServeStats`] and `util::stats::Summary`.

use crate::util::json::Json;
use crate::util::stats::{summarize, Summary};

use super::pages::PageCounters;

/// How a request left the server.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestOutcome {
    /// Decoded to completion (EOS / budget / context cap).
    Completed,
    /// Rejected at arrival by the admission policy (bounded queue).
    Shed,
    /// Admitted but abandoned after waiting past the queue deadline.
    Expired,
    /// Lost to a lane fault after admission: the lane died (with no
    /// live fallback) or transient step failures exhausted the retry
    /// budget. Failed results deliver no tokens — anything decoded
    /// before the failure is dropped and counted in
    /// [`RequestResult::lost_tokens`].
    Failed,
}

impl RequestOutcome {
    /// Stable lowercase name, as written into telemetry JSON.
    pub fn as_str(&self) -> &'static str {
        match self {
            RequestOutcome::Completed => "completed",
            RequestOutcome::Shed => "shed",
            RequestOutcome::Expired => "expired",
            RequestOutcome::Failed => "failed",
        }
    }

    /// True only for [`RequestOutcome::Completed`] — the goodput
    /// predicate.
    pub fn is_completed(&self) -> bool {
        matches!(self, RequestOutcome::Completed)
    }
}

/// Per-request speculative-decoding bookkeeping (all zero outside
/// speculative mode, and for requests not served on the verifier
/// lane). Conservation: a completed speculatively-served request has
/// `tokens.len() == accepted + corrections` — every committed token
/// was either an accepted draft or a verifier emission
/// (property-tested in `rust/tests/serve_properties.rs`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpecCounters {
    /// Draft tokens proposed for this request (accepted or not).
    pub drafted: u64,
    /// Draft tokens accepted by the verifier and committed.
    pub accepted: u64,
    /// Tokens the verifier emitted itself: rejections' corrections,
    /// all-accepted bonus tokens, and plain dense steps while the
    /// request was degraded (no draft lease that round).
    pub corrections: u64,
    /// Verifier steps this request participated in while served
    /// speculatively (tokens-per-verify denominator).
    pub verifies: u64,
}

impl SpecCounters {
    /// Draft steps wasted: proposed but never committed.
    pub fn wasted(&self) -> u64 {
        self.drafted - self.accepted
    }
}

/// The decoded continuation plus per-request serving telemetry. All
/// `*_ms` fields are wall-clock on the untimed `serve`/`serve_kv` path
/// and virtual-clock under a `serve_timed` schedule.
///
/// Shed requests carry no tokens and zero `queue_ms`/`latency_ms`
/// (they are rejected at arrival); expired requests report the queue
/// deadline as their wait — the instant the caller gave up.
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: u64,
    /// Generated tokens (without the prompt, without EOS).
    pub tokens: Vec<u32>,
    /// Tokens decoded for this request and then *dropped* instead of
    /// delivered: the partial output of a fault-failed slot, work
    /// discarded when a failover restarted the request on another
    /// lane, and decode undone by a paged-KV preemption. The engine
    /// paid for these steps — `tokens` alone under-reports the work —
    /// but no caller ever saw them, which is exactly the
    /// throughput-vs-goodput gap.
    pub lost_tokens: u64,
    /// Engine steps spent queued before a slot freed up.
    pub queue_steps: u64,
    /// Engine steps the request occupied a slot.
    pub decode_steps: u64,
    /// When the request became visible to the server (0.0 when the
    /// whole stream is present at entry).
    pub arrival_ms: f64,
    /// Arrival → slot entry (queue wait).
    pub queue_ms: f64,
    /// Arrival → first generated token; equals `latency_ms` for
    /// requests that produce none (zero budget / immediate EOS).
    pub ttft_ms: f64,
    /// Arrival → completion — what a caller would observe.
    pub latency_ms: f64,
    /// Completed / shed / expired / failed.
    pub outcome: RequestOutcome,
    /// The request was rerouted to a fallback model by the recovery
    /// layer (its lane died or its breaker opened) — the caller got an
    /// answer, but from the degraded-mode substitute, not the model it
    /// asked for.
    pub degraded: bool,
    /// Speculative-decoding bookkeeping (zero outside speculative
    /// mode; failed results drop their counters with their tokens).
    pub spec: SpecCounters,
}

impl RequestResult {
    /// JSON form (per-request dumps and tests).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.push_num("id", self.id)
            .push_num("tokens", self.tokens.len())
            .push_num("lost_tokens", self.lost_tokens)
            .push_num("queue_steps", self.queue_steps)
            .push_num("decode_steps", self.decode_steps)
            .push_num("arrival_ms", self.arrival_ms)
            .push_num("queue_ms", self.queue_ms)
            .push_num("ttft_ms", self.ttft_ms)
            .push_num("latency_ms", self.latency_ms)
            .push_str("outcome", self.outcome.as_str())
            .push_bool("degraded", self.degraded)
            .push_num("drafted", self.spec.drafted)
            .push_num("accepted", self.spec.accepted)
            .push_num("corrections", self.spec.corrections)
            .push_num("verifies", self.spec.verifies);
        j
    }
}

/// Aggregate serving statistics for one serve call. The latency
/// summaries (`queue_ms` / `ttft_ms` / `latency_ms`) cover **completed
/// requests only** — shed and expired requests would otherwise drag
/// the percentiles toward their failure constants; they are counted in
/// `shed` / `expired` / `shed_rate` instead.
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub requests: usize,
    /// Requests decoded to completion.
    pub completed: usize,
    /// Requests rejected at arrival by the admission policy.
    pub shed: usize,
    /// Requests that waited past the queue deadline.
    pub expired: usize,
    /// Requests lost to lane faults after admission (dead lane with no
    /// fallback, or retry budget exhausted).
    pub failed: usize,
    /// `(shed + expired) / requests` — 0.0 under unbounded admission.
    /// Fault losses are deliberately excluded: shed/expired measure
    /// the *admission* policy's pressure response, `failed` measures
    /// the *recovery* layer's losses, and the two knobs are tuned
    /// independently.
    pub shed_rate: f64,
    /// Step attempts re-scheduled by the retry policy after a
    /// transient lane failure (each backoff period counts once).
    pub retries: u64,
    /// Completed/expired requests that ran degraded — rerouted to a
    /// fallback model by the recovery layer.
    pub degraded: usize,
    pub decode_batch: usize,
    /// Model steps executed.
    pub engine_steps: u64,
    /// KV cache-population runs (0 on the literal-resident path). A
    /// prefill fires once per engine step in which at least one slot
    /// was (re)filled, not per request.
    pub prefill_steps: u64,
    /// Occupied slot-steps (out of `engine_steps * decode_batch`).
    pub slot_steps: u64,
    /// `slot_steps / (engine_steps * decode_batch)` — 1.0 means no
    /// slot ever idled; 0.0 (not NaN) when either factor is zero.
    pub occupancy: f64,
    /// Tokens *delivered* in results (every one belongs to a
    /// completed request — failed/preempted work is dropped, not
    /// delivered).
    pub generated_tokens: u64,
    /// Tokens decoded and then dropped instead of delivered (summed
    /// [`RequestResult::lost_tokens`]): fault-failed partial output,
    /// failover restarts, paged-KV preemptions.
    pub lost_tokens: u64,
    /// Real host time spent, always wall-clock (the virtual schedule
    /// does not change how long the model actually runs).
    pub wall_secs: f64,
    /// Raw decode throughput: every token the engine produced —
    /// delivered *or* dropped (`generated_tokens + lost_tokens`) —
    /// per wall second. The engine paid for dropped work, so it
    /// belongs in the throughput numerator.
    pub tokens_per_sec: f64,
    /// Tokens delivered to **completed** requests per wall second —
    /// what callers actually received. Strictly below
    /// `tokens_per_sec` whenever failures or preemptions dropped
    /// partially decoded output (regression-tested with an injected
    /// mid-stream lane death); equal only when nothing was lost.
    pub goodput_tokens_per_sec: f64,
    pub mean_step_ms: f64,
    /// Clock reading when the last request completed: wall ms on the
    /// untimed path, virtual ms under a `Schedule`.
    pub sim_ms: f64,
    /// Speculative-decoding sums over the result set (all zero
    /// outside speculative mode — see [`SpecCounters`]).
    pub spec: SpecCounters,
    /// `accepted / drafted` — the draft model's hit rate against the
    /// dense verifier; 0.0 when nothing was drafted.
    pub acceptance_rate: f64,
    /// Committed tokens per verifier step for speculatively-served
    /// requests, `(accepted + corrections) / verifies` — the per-round
    /// progress a verify buys; 0.0 when nothing was verified.
    pub tokens_per_verify: f64,
    /// Draft steps wasted: `drafted - accepted`.
    pub wasted_drafts: u64,
    /// Paged-KV counters (allocator peaks, evictions, preemptions,
    /// page sheds, leak check). All zero — and omitted from the JSON
    /// — when paging is off (`page_size == 0`), so non-paged stats
    /// keep their byte-identical shape. Filled in by the serve loop
    /// after aggregation, not by `from_results`.
    pub pages: PageCounters,
    /// Per-request queue wait (arrival → slot entry), completed only.
    pub queue_ms: Summary,
    /// Per-request time-to-first-token, completed only.
    pub ttft_ms: Summary,
    /// Per-request end-to-end latency (p50/p95/p99), completed only.
    pub latency_ms: Summary,
}

impl ServeStats {
    /// Fold a finished result set into a stats block. Takes
    /// references so the serve loop's per-model split never copies
    /// decoded token buffers just to aggregate. `results` need not be
    /// sorted; `requests` is the offered count (every request lands
    /// in exactly one outcome bucket).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_results(
        results: &[&RequestResult],
        requests: usize,
        decode_batch: usize,
        engine_steps: u64,
        prefill_steps: u64,
        slot_steps: u64,
        wall_secs: f64,
        sim_ms: f64,
        retries: u64,
    ) -> ServeStats {
        let completed =
            results.iter().filter(|r| r.outcome.is_completed()).count();
        let shed = results.iter()
            .filter(|r| r.outcome == RequestOutcome::Shed).count();
        let expired = results.iter()
            .filter(|r| r.outcome == RequestOutcome::Expired).count();
        let failed = results.iter()
            .filter(|r| r.outcome == RequestOutcome::Failed).count();
        let degraded =
            results.iter().filter(|r| r.degraded).count();
        let generated_tokens: u64 =
            results.iter().map(|r| r.tokens.len() as u64).sum();
        let lost_tokens: u64 =
            results.iter().map(|r| r.lost_tokens).sum();
        // goodput counts only tokens delivered to completed requests
        // — filtered explicitly, so the datapoint stays honest even
        // if a future outcome starts carrying partial output
        let delivered: u64 = results.iter()
            .filter(|r| r.outcome.is_completed())
            .map(|r| r.tokens.len() as u64)
            .sum();
        let collect = |f: fn(&RequestResult) -> f64| -> Summary {
            summarize(&results.iter()
                .filter(|r| r.outcome.is_completed())
                .map(|r| f(r))
                .collect::<Vec<f64>>())
        };
        let spec = results.iter().fold(
            SpecCounters::default(), |acc, r| SpecCounters {
                drafted: acc.drafted + r.spec.drafted,
                accepted: acc.accepted + r.spec.accepted,
                corrections: acc.corrections + r.spec.corrections,
                verifies: acc.verifies + r.spec.verifies,
            });
        let per_sec = |tokens: u64| {
            if wall_secs > 0.0 {
                tokens as f64 / wall_secs
            } else {
                0.0
            }
        };
        ServeStats {
            requests,
            completed,
            shed,
            expired,
            failed,
            shed_rate: if requests == 0 {
                0.0
            } else {
                (shed + expired) as f64 / requests as f64
            },
            retries,
            degraded,
            decode_batch,
            engine_steps,
            prefill_steps,
            slot_steps,
            // guard the whole product: an all-shed trace can hand in
            // zero steps, and a degenerate lane zero batch — either
            // factor alone makes the division NaN/inf
            occupancy: if engine_steps * decode_batch as u64 == 0 {
                0.0
            } else {
                slot_steps as f64
                    / (engine_steps * decode_batch as u64) as f64
            },
            generated_tokens,
            lost_tokens,
            wall_secs,
            // the engine decoded dropped work too — raw throughput
            // charges for it; goodput is delivered-only, so the two
            // split exactly when partial output is lost
            tokens_per_sec: per_sec(generated_tokens + lost_tokens),
            goodput_tokens_per_sec: per_sec(delivered),
            mean_step_ms: if engine_steps == 0 {
                0.0
            } else {
                wall_secs * 1e3 / engine_steps as f64
            },
            sim_ms,
            spec,
            acceptance_rate: if spec.drafted == 0 {
                0.0
            } else {
                spec.accepted as f64 / spec.drafted as f64
            },
            tokens_per_verify: if spec.verifies == 0 {
                0.0
            } else {
                (spec.accepted + spec.corrections) as f64
                    / spec.verifies as f64
            },
            wasted_drafts: spec.wasted(),
            pages: PageCounters::default(),
            queue_ms: collect(|r| r.queue_ms),
            ttft_ms: collect(|r| r.ttft_ms),
            latency_ms: collect(|r| r.latency_ms),
        }
    }

    /// JSON form for `BENCH_decode.json`, `BENCH_serve_load.json` and
    /// `spdf serve --stats-json`.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.push_num("requests", self.requests)
            .push_num("completed", self.completed)
            .push_num("shed", self.shed)
            .push_num("expired", self.expired)
            .push_num("failed", self.failed)
            .push_num("shed_rate", self.shed_rate)
            .push_num("retries", self.retries)
            .push_num("degraded", self.degraded)
            .push_num("decode_batch", self.decode_batch)
            .push_num("engine_steps", self.engine_steps)
            .push_num("prefill_steps", self.prefill_steps)
            .push_num("slot_steps", self.slot_steps)
            .push_num("occupancy", self.occupancy)
            .push_num("generated_tokens", self.generated_tokens)
            .push_num("lost_tokens", self.lost_tokens)
            .push_num("wall_secs", self.wall_secs)
            .push_num("tokens_per_sec", self.tokens_per_sec)
            .push_num("goodput_tokens_per_sec",
                      self.goodput_tokens_per_sec)
            .push_num("mean_step_ms", self.mean_step_ms)
            .push_num("sim_ms", self.sim_ms)
            .push_num("drafted", self.spec.drafted)
            .push_num("accepted", self.spec.accepted)
            .push_num("corrections", self.spec.corrections)
            .push_num("verifies", self.spec.verifies)
            .push_num("acceptance_rate", self.acceptance_rate)
            .push_num("tokens_per_verify", self.tokens_per_verify)
            .push_num("wasted_drafts", self.wasted_drafts)
            .push("queue_ms", self.queue_ms.to_json())
            .push("ttft_ms", self.ttft_ms.to_json())
            .push("latency_ms", self.latency_ms.to_json());
        // pages block only when paging was on: pre-paging consumers
        // (and the byte-identical single-model JSON pin) keep their
        // exact shape
        if self.pages.page_size > 0 {
            let mut p = Json::obj();
            p.push_num("page_size", self.pages.page_size)
                .push_num("total_pages", self.pages.total_pages)
                .push_num("peak_pages", self.pages.peak_pages)
                .push_num("peak_seated", self.pages.peak_seated)
                .push_num("evicted_pages", self.pages.evicted_pages)
                .push_num("preemptions", self.pages.preemptions)
                .push_num("page_sheds", self.pages.page_sheds)
                .push_num("leaked_pages", self.pages.leaked_pages);
            j.push("pages", p);
        }
        j
    }
}

/// One model's share of a (possibly multi-model) serve call.
#[derive(Debug, Clone)]
pub struct ModelStats {
    /// Registry name of the model ("default" for the single-model
    /// entry points that never name one).
    pub model: String,
    /// The same [`ServeStats`] block, restricted to this model's
    /// requests and engine lane. The countable fields (requests,
    /// completed/shed/expired, generated_tokens, engine/prefill/slot
    /// steps) sum to the aggregate block across models; rate fields
    /// share the aggregate's wall/sim denominators so they sum too.
    /// `mean_step_ms` is the exception: wall time is shared across
    /// lanes, so every block reports the call-wide mean step cost
    /// rather than a (meaningless) per-lane division.
    pub stats: ServeStats,
}

/// Results (sorted by request id) + aggregate stats, plus the
/// per-model breakdown (one entry per registry lane; a single entry
/// mirroring the aggregate on the single-model paths).
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub results: Vec<RequestResult>,
    pub stats: ServeStats,
    pub per_model: Vec<ModelStats>,
}

impl ServeReport {
    /// Aggregate stats JSON, with a `"models"` object of per-model
    /// [`ServeStats`] blocks appended when the serve call actually
    /// multiplexed more than one model (the single-model shape stays
    /// byte-identical to the pre-registry emitter).
    pub fn stats_json(&self) -> Json {
        let mut j = self.stats.to_json();
        if self.per_model.len() > 1 {
            let mut models = Json::obj();
            for m in &self.per_model {
                models.push(&m.model, m.stats.to_json());
            }
            j.push("models", models);
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn refs(results: &[RequestResult]) -> Vec<&RequestResult> {
        results.iter().collect()
    }

    fn result(id: u64, tokens: usize, latency: f64,
              outcome: RequestOutcome) -> RequestResult {
        RequestResult {
            id,
            tokens: vec![5; tokens],
            lost_tokens: 0,
            queue_steps: 0,
            decode_steps: tokens as u64,
            arrival_ms: 0.0,
            queue_ms: 0.0,
            ttft_ms: latency,
            latency_ms: latency,
            outcome,
            degraded: false,
            spec: SpecCounters::default(),
        }
    }

    #[test]
    fn from_results_buckets_outcomes_and_skips_failed_latencies() {
        let results = vec![
            result(0, 4, 10.0, RequestOutcome::Completed),
            result(1, 4, 30.0, RequestOutcome::Completed),
            result(2, 0, 0.0, RequestOutcome::Shed),
            result(3, 0, 5.0, RequestOutcome::Expired),
        ];
        let st = ServeStats::from_results(&refs(&results), 4, 2, 8, 0,
                                          14, 0.5, 40.0, 0);
        assert_eq!((st.completed, st.shed, st.expired), (2, 1, 1));
        assert_eq!((st.failed, st.retries, st.degraded), (0, 0, 0));
        assert_eq!(st.shed_rate, 0.5);
        assert_eq!(st.generated_tokens, 8);
        assert_eq!(st.tokens_per_sec, 16.0);
        assert_eq!(st.goodput_tokens_per_sec, 16.0);
        // percentiles over the two completed requests only: the shed
        // request's 0.0 and the expired request's 5.0 must not appear
        assert_eq!(st.latency_ms.n, 2);
        assert_eq!(st.latency_ms.min, 10.0);
        assert_eq!(st.latency_ms.p50, 20.0);
        assert!((st.occupancy - 14.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn from_results_all_completed_matches_unbounded_invariants() {
        let results = vec![
            result(0, 3, 3.0, RequestOutcome::Completed),
            result(1, 2, 5.0, RequestOutcome::Completed),
        ];
        let st = ServeStats::from_results(&refs(&results), 2, 2, 5, 0,
                                          5, 0.25, 5.0, 0);
        assert_eq!(st.shed_rate, 0.0);
        assert_eq!(st.completed, 2);
        assert_eq!(st.tokens_per_sec, st.goodput_tokens_per_sec);
        assert_eq!(st.latency_ms.n, 2);
    }

    #[test]
    fn stats_json_has_core_and_shed_fields() {
        let results = vec![
            result(0, 5, 200.0, RequestOutcome::Completed),
            result(1, 5, 300.0, RequestOutcome::Completed),
            result(2, 5, 450.0, RequestOutcome::Completed),
            result(3, 0, 0.0, RequestOutcome::Shed),
        ];
        let st = ServeStats::from_results(&refs(&results), 4, 2, 10,
                                          2, 17, 0.5, 500.0, 0);
        let j = st.to_json();
        assert_eq!(j.get("tokens_per_sec").unwrap().as_f64(),
                   Some(30.0));
        assert_eq!(j.get("goodput_tokens_per_sec").unwrap().as_f64(),
                   Some(30.0));
        assert_eq!(j.get("engine_steps").unwrap().as_usize(), Some(10));
        assert_eq!(j.get("prefill_steps").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("completed").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("shed").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("expired").unwrap().as_usize(), Some(0));
        assert_eq!(j.get("shed_rate").unwrap().as_f64(), Some(0.25));
        let lat = j.get("latency_ms").unwrap();
        assert_eq!(lat.get("p50").unwrap().as_f64(), Some(300.0));
    }

    #[test]
    fn report_stats_json_nests_per_model_blocks_only_for_registries() {
        let results = vec![
            result(0, 3, 4.0, RequestOutcome::Completed),
            result(1, 2, 6.0, RequestOutcome::Completed),
        ];
        let stats = ServeStats::from_results(&refs(&results), 2, 2, 5,
                                             0, 5, 0.5, 6.0, 0);
        let solo = ServeReport {
            results: results.clone(),
            stats: stats.clone(),
            per_model: vec![ModelStats { model: "default".into(),
                                         stats: stats.clone() }],
        };
        // single-model shape is byte-identical to the plain emitter
        assert_eq!(solo.stats_json().to_string(),
                   stats.to_json().to_string());
        let multi = ServeReport {
            results,
            stats: stats.clone(),
            per_model: vec![
                ModelStats { model: "dense".into(),
                             stats: stats.clone() },
                ModelStats { model: "s75".into(), stats },
            ],
        };
        let j = multi.stats_json();
        let models = j.get("models").unwrap();
        assert!(models.get("dense").is_some());
        assert_eq!(models.get("s75").unwrap().get("completed")
                       .unwrap().as_usize(),
                   Some(2));
    }

    #[test]
    fn request_result_json_carries_outcome() {
        let r = result(7, 2, 12.5, RequestOutcome::Expired);
        let j = r.to_json();
        assert_eq!(j.get("id").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("outcome").unwrap().as_str(), Some("expired"));
        assert_eq!(j.get("latency_ms").unwrap().as_f64(), Some(12.5));
        assert_eq!(j.get("degraded").unwrap().as_bool(), Some(false));
        assert_eq!(RequestOutcome::Completed.as_str(), "completed");
        assert_eq!(RequestOutcome::Shed.as_str(), "shed");
        assert_eq!(RequestOutcome::Failed.as_str(), "failed");
    }

    #[test]
    fn spec_counters_aggregate_and_derive_rates() {
        let mut a = result(0, 4, 3.0, RequestOutcome::Completed);
        a.spec = SpecCounters { drafted: 6, accepted: 3,
                                corrections: 1, verifies: 2 };
        let mut b = result(1, 3, 5.0, RequestOutcome::Completed);
        b.spec = SpecCounters { drafted: 2, accepted: 1,
                                corrections: 2, verifies: 2 };
        let results = vec![a, b];
        let st = ServeStats::from_results(&refs(&results), 2, 2, 4, 0,
                                          6, 0.5, 8.0, 0);
        assert_eq!(st.spec.drafted, 8);
        assert_eq!(st.spec.accepted, 4);
        assert_eq!(st.spec.corrections, 3);
        assert_eq!(st.spec.verifies, 4);
        assert_eq!(st.acceptance_rate, 0.5);
        assert_eq!(st.tokens_per_verify, 7.0 / 4.0);
        assert_eq!(st.wasted_drafts, 4);
        let j = st.to_json();
        assert_eq!(j.get("drafted").unwrap().as_usize(), Some(8));
        assert_eq!(j.get("acceptance_rate").unwrap().as_f64(),
                   Some(0.5));
        assert_eq!(j.get("tokens_per_verify").unwrap().as_f64(),
                   Some(1.75));
        assert_eq!(j.get("wasted_drafts").unwrap().as_usize(), Some(4));
        // non-speculative runs report an all-zero block, not NaNs
        let plain = vec![result(2, 3, 2.0, RequestOutcome::Completed)];
        let st = ServeStats::from_results(&refs(&plain), 1, 1, 3, 0, 3,
                                          0.1, 3.0, 0);
        assert_eq!(st.spec, SpecCounters::default());
        assert_eq!((st.acceptance_rate, st.tokens_per_verify), (0.0,
                                                                0.0));
    }

    #[test]
    fn lost_tokens_split_goodput_below_raw_throughput() {
        // a fault-failed request dropped 3 decoded tokens: raw
        // throughput charges for them, goodput does not — the two
        // datapoints must diverge, not mirror each other
        let mut died = result(1, 0, 6.0, RequestOutcome::Failed);
        died.lost_tokens = 3;
        let results = vec![
            result(0, 5, 10.0, RequestOutcome::Completed),
            died,
        ];
        let st = ServeStats::from_results(&refs(&results), 2, 2, 8, 0,
                                          12, 0.5, 16.0, 0);
        assert_eq!(st.generated_tokens, 5);
        assert_eq!(st.lost_tokens, 3);
        assert_eq!(st.tokens_per_sec, 16.0); // (5 + 3) / 0.5
        assert_eq!(st.goodput_tokens_per_sec, 10.0); // 5 / 0.5
        assert!(st.goodput_tokens_per_sec < st.tokens_per_sec);
        let j = st.to_json();
        assert_eq!(j.get("lost_tokens").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("goodput_tokens_per_sec").unwrap().as_f64(),
                   Some(10.0));
        let rj = results[1].to_json();
        assert_eq!(rj.get("lost_tokens").unwrap().as_usize(), Some(3));
        assert_eq!(rj.get("tokens").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn all_shed_trace_yields_zeros_not_nan() {
        // every request shed at arrival: zero steps, zero wall time,
        // zero batch occupancy — every derived rate must be exactly
        // 0.0, or bench_gate.py comparisons silently poison
        let results = vec![
            result(0, 0, 0.0, RequestOutcome::Shed),
            result(1, 0, 0.0, RequestOutcome::Shed),
        ];
        let st = ServeStats::from_results(&refs(&results), 2, 0, 0, 0,
                                          0, 0.0, 0.0, 0);
        assert_eq!(st.occupancy, 0.0);
        assert_eq!(st.tokens_per_sec, 0.0);
        assert_eq!(st.goodput_tokens_per_sec, 0.0);
        assert_eq!(st.mean_step_ms, 0.0);
        assert_eq!(st.acceptance_rate, 0.0);
        assert_eq!(st.tokens_per_verify, 0.0);
        assert_eq!(st.shed_rate, 1.0);
        for v in [st.occupancy, st.tokens_per_sec,
                  st.goodput_tokens_per_sec, st.mean_step_ms,
                  st.acceptance_rate, st.tokens_per_verify,
                  st.shed_rate] {
            assert!(v.is_finite(), "non-finite stat {v}");
        }
        // zero batch with nonzero steps is the other NaN edge of the
        // occupancy product
        let st = ServeStats::from_results(&refs(&results), 2, 0, 4, 0,
                                          0, 0.0, 0.0, 0);
        assert_eq!(st.occupancy, 0.0);
    }

    #[test]
    fn pages_json_block_only_when_paging_on() {
        let results = vec![result(0, 2, 4.0,
                                  RequestOutcome::Completed)];
        let mut st = ServeStats::from_results(&refs(&results), 1, 1,
                                              2, 0, 2, 0.1, 4.0, 0);
        assert!(st.to_json().get("pages").is_none());
        st.pages = PageCounters { page_size: 4, total_pages: 8,
                                  peak_pages: 5, peak_seated: 2,
                                  evicted_pages: 1, preemptions: 2,
                                  page_sheds: 3, leaked_pages: 0 };
        let j = st.to_json();
        let p = j.get("pages").unwrap();
        assert_eq!(p.get("page_size").unwrap().as_usize(), Some(4));
        assert_eq!(p.get("peak_seated").unwrap().as_usize(), Some(2));
        assert_eq!(p.get("leaked_pages").unwrap().as_usize(), Some(0));
        assert_eq!(p.get("preemptions").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn fault_counters_bucket_failed_and_degraded() {
        let mut rerouted = result(1, 3, 9.0, RequestOutcome::Completed);
        rerouted.degraded = true;
        let results = vec![
            result(0, 4, 10.0, RequestOutcome::Completed),
            rerouted,
            result(2, 0, 6.0, RequestOutcome::Failed),
            result(3, 0, 0.0, RequestOutcome::Shed),
        ];
        let st = ServeStats::from_results(&refs(&results), 4, 2, 9, 0,
                                          15, 0.5, 12.0, 5);
        assert_eq!((st.completed, st.shed, st.expired, st.failed),
                   (2, 1, 0, 1));
        assert_eq!(st.completed + st.shed + st.expired + st.failed,
                   st.requests, "conservation includes failed");
        assert_eq!(st.retries, 5);
        assert_eq!(st.degraded, 1);
        // shed_rate keeps its admission-policy meaning; fault losses
        // are reported separately
        assert_eq!(st.shed_rate, 0.25);
        // latency percentiles still cover completed only
        assert_eq!(st.latency_ms.n, 2);
        let j = st.to_json();
        assert_eq!(j.get("failed").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("retries").unwrap().as_usize(), Some(5));
        assert_eq!(j.get("degraded").unwrap().as_usize(), Some(1));
    }
}
