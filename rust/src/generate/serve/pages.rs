//! Paged KV-cache memory management (vLLM-style).
//!
//! The monolithic loop gives every batch slot a full `ctx_len` KV
//! allocation for its whole residency — at scale, KV memory (not
//! slots) is the binding constraint, and a slot decoding a short
//! request wastes almost all of its reservation. Here a lane's KV
//! budget is broken into fixed-size **pages** handed out by a
//! free-list [`PageAllocator`]: a seated request owns a *page table*
//! (its pages, oldest first) that grows one page at a time as it
//! decodes and is returned in full when the request leaves its slot
//! for any reason.
//!
//! Three policy levers ride on the page accounting:
//!
//!  * **memory-aware admission** — a request is admittable iff the
//!    pages for its prompt exist right now
//!    ([`super::admission::AdmissionPolicy::admit_pages`], the
//!    [`super::admission::PagePressure`] policy); the serve loop sheds
//!    on page pressure and counts it ([`PageCounters::page_sheds`]);
//!  * **preemption** — when a decoding request needs one more page
//!    and the allocator is dry, the youngest-seated other slot is
//!    preempted: its pages are freed, its decoded-so-far tokens are
//!    dropped (counted as [lost] in telemetry) and it requeues at its
//!    original arrival;
//!  * **sliding-window eviction** — with `--kv-window W`, any row
//!    holding more than `W` resident tokens frees its *oldest* page
//!    (the row shifts left by one page), so generation runs past
//!    `ctx_len` on a bounded cache.
//!
//! The allocator is pure bookkeeping over the lane's existing token /
//! KV buffers — pages are never materialized as separate storage, so
//! an **unconstrained** paged run (no page budget, no window) makes
//! exactly the decisions the monolithic loop makes and its output is
//! bitwise identical (pinned by the core unit tests and the property
//! suite). Invariants the property suite enforces: no page is ever
//! leaked (all pages free once the loop drains), no page is ever
//! owned by two slots, and page counts are conserved under
//! memory-pressure shedding.
//!
//! [lost]: super::telemetry::ServeStats::lost_tokens

use std::collections::BTreeSet;

use crate::runtime::PagedSessionState;

/// Pages needed to hold `len` tokens at `page_size` tokens per page.
pub fn pages_for(len: usize, page_size: usize) -> usize {
    len.div_ceil(page_size)
}

/// How many pages a request reserves when it seats.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageReserve {
    /// Reserve only the pages the prompt needs; decode grows the
    /// table one page at a time (preempting a younger slot when the
    /// allocator is dry). The paged default.
    Prompt,
    /// Reserve the full `ctx_len` worth of pages up front — the
    /// monolithic allocation discipline expressed in pages, kept as
    /// the bench comparison arm (`perf_serve_load` paged leg).
    FullContext,
}

/// Paged-KV configuration for one serve call (applied per lane).
#[derive(Debug, Clone)]
pub struct PagedKvConfig {
    /// Tokens per page (`--page-size`; ≥ 1, ≤ `ctx_len`).
    pub page_size: usize,
    /// Page budget per lane (`--kv-pages`). `None` = unconstrained:
    /// every lane gets `decode_batch × pages_for(ctx_len)` pages, so
    /// seating and growth can never fail and the run is bitwise
    /// identical to the monolithic loop.
    pub total_pages: Option<usize>,
    /// Sliding-window eviction threshold in resident tokens
    /// (`--kv-window`; `page_size ≤ W ≤ ctx_len − 2`). Rows holding
    /// more than `W` tokens evict their oldest page before the next
    /// step, so generation runs past `ctx_len`.
    pub window: Option<usize>,
    /// Seating reservation policy.
    pub reserve: PageReserve,
}

impl PagedKvConfig {
    /// Unconstrained paging at `page_size` tokens per page: prompt
    /// reservation, no budget, no eviction window.
    pub fn new(page_size: usize) -> PagedKvConfig {
        PagedKvConfig { page_size, total_pages: None, window: None,
                        reserve: PageReserve::Prompt }
    }

    /// Builder-style per-lane page budget.
    pub fn with_total_pages(mut self, total: usize) -> PagedKvConfig {
        self.total_pages = Some(total);
        self
    }

    /// Builder-style sliding-window eviction threshold.
    pub fn with_window(mut self, window: usize) -> PagedKvConfig {
        self.window = Some(window);
        self
    }

    /// Builder-style seating reservation policy.
    pub fn with_reserve(mut self, reserve: PageReserve)
                        -> PagedKvConfig {
        self.reserve = reserve;
        self
    }
}

/// Free-list page allocator for one lane: fixed `total` pages, each
/// free or owned by exactly one slot. Allocation is all-or-nothing
/// and deterministic (lowest page ids first); freeing verifies
/// ownership, so a double-free or foreign free is an error, never
/// silent corruption.
#[derive(Debug)]
pub struct PageAllocator {
    page_size: usize,
    /// `owner[p]` is the slot holding page `p`, `None` when free.
    owner: Vec<Option<usize>>,
    /// Free page ids; `BTreeSet` so allocation order is the sorted
    /// id order regardless of free order.
    free: BTreeSet<usize>,
    peak_pages: usize,
}

impl PageAllocator {
    /// An allocator over `total` pages of `page_size` tokens each.
    pub fn new(page_size: usize, total: usize)
               -> anyhow::Result<PageAllocator> {
        anyhow::ensure!(page_size >= 1,
                        "page size must be ≥ 1 (got {page_size})");
        anyhow::ensure!(total >= 1,
                        "page budget must be ≥ 1 (got {total})");
        Ok(PageAllocator {
            page_size,
            owner: vec![None; total],
            free: (0..total).collect(),
            peak_pages: 0,
        })
    }

    /// Tokens per page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Total pages in the budget.
    pub fn total_pages(&self) -> usize {
        self.owner.len()
    }

    /// Pages currently free.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Pages currently owned by some slot.
    pub fn in_use(&self) -> usize {
        self.owner.len() - self.free.len()
    }

    /// High-water mark of [`Self::in_use`] over the allocator's life.
    pub fn peak_pages(&self) -> usize {
        self.peak_pages
    }

    /// Pages needed to hold `len` tokens.
    pub fn pages_for(&self, len: usize) -> usize {
        pages_for(len, self.page_size)
    }

    /// Allocate `n` pages to `slot`, all-or-nothing: `None` (and no
    /// state change) when fewer than `n` pages are free. Returned ids
    /// are the lowest free ids, ascending — deterministic for a given
    /// alloc/free history.
    pub fn try_alloc(&mut self, n: usize, slot: usize)
                     -> Option<Vec<usize>> {
        if self.free.len() < n {
            return None;
        }
        let ids: Vec<usize> =
            self.free.iter().take(n).copied().collect();
        for &p in &ids {
            self.free.remove(&p);
            debug_assert!(self.owner[p].is_none(),
                          "free page {p} already has an owner");
            self.owner[p] = Some(slot);
        }
        self.peak_pages = self.peak_pages.max(self.in_use());
        Some(ids)
    }

    /// Return page `p` from `slot` to the free list. Errors on a
    /// double-free or a free by a slot that does not own the page —
    /// the no-double-own invariant made loud.
    pub fn free_page(&mut self, p: usize, slot: usize)
                     -> anyhow::Result<()> {
        anyhow::ensure!(p < self.owner.len(),
                        "freed page {p} out of range ({} pages)",
                        self.owner.len());
        match self.owner[p] {
            Some(s) if s == slot => {
                self.owner[p] = None;
                self.free.insert(p);
                Ok(())
            }
            Some(s) => anyhow::bail!(
                "slot {slot} freed page {p} owned by slot {s}"),
            None => anyhow::bail!(
                "slot {slot} double-freed page {p}"),
        }
    }
}

/// Page telemetry for one serve call (one lane's counters, or the
/// element-wise sum across lanes in the aggregate block). Emitted as
/// the `pages` object of the stats JSON only when paging was on
/// (`page_size > 0`), so non-paged reports keep their byte-identical
/// shape.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PageCounters {
    /// Tokens per page (0 = paging off).
    pub page_size: usize,
    /// Page budget (summed across lanes in the aggregate).
    pub total_pages: usize,
    /// High-water mark of pages in use.
    pub peak_pages: usize,
    /// High-water mark of concurrently seated requests — the "max
    /// concurrent requests at fixed memory" datapoint of the bench
    /// paged leg.
    pub peak_seated: usize,
    /// Oldest pages freed by sliding-window eviction.
    pub evicted_pages: u64,
    /// Seated requests preempted (pages freed, decoded-so-far tokens
    /// dropped and counted as lost, request requeued) so another slot
    /// could grow.
    pub preemptions: u64,
    /// Requests shed at arrival by a memory-aware admission policy
    /// ([`super::admission::AdmissionPolicy::admit_pages`]).
    pub page_sheds: u64,
    /// Pages still owned after the loop drained — always 0 unless the
    /// allocator bookkeeping is broken (asserted by the property
    /// suite and gated by the bench paged leg).
    pub leaked_pages: usize,
}

impl PageCounters {
    /// Element-wise accumulate `other` (page size carries over; both
    /// lanes of a paged run share one configured size).
    pub fn absorb(&mut self, other: &PageCounters) {
        self.page_size = self.page_size.max(other.page_size);
        self.total_pages += other.total_pages;
        self.peak_pages += other.peak_pages;
        self.peak_seated += other.peak_seated;
        self.evicted_pages += other.evicted_pages;
        self.preemptions += other.preemptions;
        self.page_sheds += other.page_sheds;
        self.leaked_pages += other.leaked_pages;
    }
}

/// One lane's paging state: the free-list allocator, the per-slot
/// page tables, the paged session accounting
/// ([`crate::runtime::PagedSessionState`]) and the policy knobs. The
/// serve loop drives it at the five page-lifecycle points — admit,
/// seat, grow (with preemption), evict, release — and reads the
/// counters out at the end.
#[derive(Debug)]
pub struct LanePager {
    alloc: PageAllocator,
    /// `tables[s]` = pages owned by slot `s`, oldest first.
    tables: Vec<Vec<usize>>,
    state: PagedSessionState,
    ctx_len: usize,
    window: Option<usize>,
    reserve: PageReserve,
    peak_seated: usize,
    evicted_pages: u64,
    preemptions: u64,
    page_sheds: u64,
}

impl LanePager {
    /// Build the pager for one lane of geometry `(b, t)`. Validates
    /// the config against the geometry: `1 ≤ page_size ≤ t`; a
    /// window obeys `page_size ≤ W ≤ t − 2` (so an evicted row's next
    /// commit can never trip the `ctx_len` cap edge); a page budget
    /// must fit at least one full-context request
    /// (`total ≥ pages_for(t)`), which is what makes preemption a
    /// progress guarantee rather than a livelock.
    pub fn new(cfg: &PagedKvConfig, b: usize, t: usize)
               -> anyhow::Result<LanePager> {
        anyhow::ensure!(cfg.page_size >= 1 && cfg.page_size <= t,
                        "page size must be in 1..={t} (got {})",
                        cfg.page_size);
        if let Some(w) = cfg.window {
            anyhow::ensure!(
                w >= cfg.page_size && w + 2 <= t,
                "eviction window must be in page_size..=ctx_len-2 \
                 ({}..={}; got {w})",
                cfg.page_size, t - 2
            );
        }
        let full = pages_for(t, cfg.page_size);
        let total = cfg.total_pages.unwrap_or(b * full);
        anyhow::ensure!(
            total >= full,
            "page budget {total} cannot hold one full-context \
             request ({full} pages of {} tokens at ctx_len {t})",
            cfg.page_size
        );
        Ok(LanePager {
            alloc: PageAllocator::new(cfg.page_size, total)?,
            tables: vec![Vec::new(); b],
            state: PagedSessionState::accounting(b, cfg.page_size),
            ctx_len: t,
            window: cfg.window,
            reserve: cfg.reserve,
            peak_seated: 0,
            evicted_pages: 0,
            preemptions: 0,
            page_sheds: 0,
        })
    }

    /// Pages a request with `prompt_len` prompt tokens must be able
    /// to allocate to seat, under the configured reservation policy.
    pub fn seat_need(&self, prompt_len: usize) -> usize {
        match self.reserve {
            PageReserve::Prompt => self.alloc.pages_for(prompt_len),
            PageReserve::FullContext =>
                self.alloc.pages_for(self.ctx_len),
        }
    }

    /// Pages currently free on this lane's allocator.
    pub fn free_pages(&self) -> usize {
        self.alloc.free_pages()
    }

    /// Tokens per page (what the serve loop shifts a row by when it
    /// mirrors an eviction onto the token buffer).
    pub fn page_size(&self) -> usize {
        self.alloc.page_size()
    }

    /// Seat a request with `prompt_len` prompt tokens on `slot`:
    /// allocate its reservation all-or-nothing. `false` leaves the
    /// allocator untouched (the loop requeues the request and waits
    /// for pages to free up).
    pub fn try_seat(&mut self, slot: usize, prompt_len: usize)
                    -> bool {
        let need = self.seat_need(prompt_len);
        match self.alloc.try_alloc(need, slot) {
            Some(ids) => {
                self.tables[slot] = ids;
                self.state.seat(slot, prompt_len);
                true
            }
            None => false,
        }
    }

    /// Record the resident token count of `slot` after a commit (the
    /// loop's `pos + 1`).
    pub fn set_used(&mut self, slot: usize, used: usize) {
        self.state.seat(slot, used);
    }

    /// Grow `slot`'s table until it covers the slot's resident
    /// tokens, one page at a time. `false` = the allocator is dry and
    /// the table still falls short: the loop must preempt a victim
    /// (freeing its pages) and call again.
    pub fn try_cover(&mut self, slot: usize) -> bool {
        let used = self.state.used(slot);
        while self.tables[slot].len() * self.alloc.page_size() < used
        {
            match self.alloc.try_alloc(1, slot) {
                Some(ids) => self.tables[slot].extend(ids),
                None => return false,
            }
        }
        true
    }

    /// Does `slot` hold more resident tokens than the eviction
    /// window allows? (Always false without a window.)
    pub fn should_evict(&self, slot: usize) -> bool {
        self.window
            .map_or(false, |w| self.state.used(slot) > w)
    }

    /// Evict `slot`'s oldest page: free it and drop one page's worth
    /// of resident tokens from the front of the accounting. The loop
    /// mirrors this on the token buffer (shift left by `page_size`)
    /// and re-prefills the row.
    pub fn evict_front(&mut self, slot: usize) -> anyhow::Result<()> {
        anyhow::ensure!(!self.tables[slot].is_empty(),
                        "evict on slot {slot} with no pages");
        let p = self.tables[slot].remove(0);
        self.alloc.free_page(p, slot)?;
        self.state.trim_front(slot)?;
        self.evicted_pages += 1;
        Ok(())
    }

    /// Return every page `slot` owns (request finished, failed, was
    /// preempted or drained) and clear its accounting.
    pub fn release(&mut self, slot: usize) -> anyhow::Result<()> {
        for p in std::mem::take(&mut self.tables[slot]) {
            self.alloc.free_page(p, slot)?;
        }
        self.state.release(slot);
        Ok(())
    }

    /// Record the current number of seated requests (peak feeds the
    /// bench paged leg's max-concurrency datapoint).
    pub fn note_seated(&mut self, occupied: usize) {
        self.peak_seated = self.peak_seated.max(occupied);
    }

    /// Count one admission shed due to page pressure.
    pub fn note_shed(&mut self) {
        self.page_sheds += 1;
    }

    /// Count one preemption (the loop does the release + requeue).
    pub fn note_preempted(&mut self) {
        self.preemptions += 1;
    }

    /// Snapshot the counters; call after the loop drains so
    /// `leaked_pages` ([`PageAllocator::in_use`] at that point) is
    /// meaningful.
    pub fn counters(&self) -> PageCounters {
        PageCounters {
            page_size: self.alloc.page_size(),
            total_pages: self.alloc.total_pages(),
            peak_pages: self.alloc.peak_pages(),
            peak_seated: self.peak_seated,
            evicted_pages: self.evicted_pages,
            preemptions: self.preemptions,
            page_sheds: self.page_sheds,
            leaked_pages: self.alloc.in_use(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pages_for_rounds_up() {
        assert_eq!(pages_for(0, 4), 0);
        assert_eq!(pages_for(1, 4), 1);
        assert_eq!(pages_for(4, 4), 1);
        assert_eq!(pages_for(5, 4), 2);
        assert_eq!(pages_for(16, 4), 4);
    }

    #[test]
    fn allocator_hands_out_lowest_ids_all_or_nothing() {
        let mut a = PageAllocator::new(4, 4).unwrap();
        assert_eq!(a.try_alloc(2, 0), Some(vec![0, 1]));
        assert_eq!(a.try_alloc(1, 1), Some(vec![2]));
        // all-or-nothing: 2 wanted, 1 free — no state change
        assert_eq!(a.try_alloc(2, 1), None);
        assert_eq!(a.free_pages(), 1);
        a.free_page(1, 0).unwrap();
        // freed id 1 comes back before the never-used id 3
        assert_eq!(a.try_alloc(2, 2), Some(vec![1, 3]));
        assert_eq!((a.free_pages(), a.in_use(), a.peak_pages()),
                   (0, 4, 4));
    }

    #[test]
    fn allocator_rejects_double_free_and_foreign_free() {
        let mut a = PageAllocator::new(2, 2).unwrap();
        assert_eq!(a.try_alloc(1, 0), Some(vec![0]));
        assert!(a.free_page(0, 1).is_err()); // slot 1 never owned 0
        a.free_page(0, 0).unwrap();
        assert!(a.free_page(0, 0).is_err()); // double free
        assert!(a.free_page(7, 0).is_err()); // out of range
        assert_eq!(a.free_pages(), 2);
    }

    #[test]
    fn pager_validates_geometry_window_and_budget() {
        let cfg = PagedKvConfig::new(0);
        assert!(LanePager::new(&cfg, 2, 16).is_err());
        let cfg = PagedKvConfig::new(4).with_window(2);
        assert!(LanePager::new(&cfg, 2, 16).is_err()); // w < page
        let cfg = PagedKvConfig::new(4).with_window(15);
        assert!(LanePager::new(&cfg, 2, 16).is_err()); // w > t-2
        let cfg = PagedKvConfig::new(4).with_total_pages(3);
        assert!(LanePager::new(&cfg, 2, 16).is_err()); // < full ctx
        let cfg = PagedKvConfig::new(4).with_window(8)
            .with_total_pages(4);
        assert!(LanePager::new(&cfg, 2, 16).is_ok());
    }

    #[test]
    fn unconstrained_pager_never_fails_to_seat_or_grow() {
        let (b, t) = (3, 16);
        let cfg = PagedKvConfig::new(4);
        let mut p = LanePager::new(&cfg, b, t).unwrap();
        for s in 0..b {
            assert!(p.try_seat(s, t - 1));
            p.set_used(s, t - 1);
            assert!(p.try_cover(s));
        }
        assert_eq!(p.free_pages(), 0); // b * pages_for(t) exactly
        for s in 0..b {
            p.release(s).unwrap();
        }
        assert_eq!(p.counters().leaked_pages, 0);
    }

    #[test]
    fn prompt_reserve_grows_and_full_context_reserves_up_front() {
        let cfg = PagedKvConfig::new(4).with_total_pages(8);
        let mut p = LanePager::new(&cfg, 2, 16).unwrap();
        assert_eq!(p.seat_need(3), 1);
        assert!(p.try_seat(0, 3));
        assert_eq!(p.free_pages(), 7);
        p.set_used(0, 5); // crossed a page boundary
        assert!(p.try_cover(0));
        assert_eq!(p.free_pages(), 6);

        let cfg = cfg.with_reserve(PageReserve::FullContext);
        let mut p = LanePager::new(&cfg, 2, 16).unwrap();
        assert_eq!(p.seat_need(3), 4); // pages_for(ctx_len)
        assert!(p.try_seat(0, 3));
        assert!(p.try_seat(1, 3));
        assert_eq!(p.free_pages(), 0);
        // a third seat must wait for pages, not over-commit
        assert!(!p.try_seat(0, 3) || p.free_pages() > 0);
    }

    #[test]
    fn eviction_frees_oldest_page_and_trims_accounting() {
        let cfg = PagedKvConfig::new(4).with_window(8);
        let mut p = LanePager::new(&cfg, 1, 16).unwrap();
        assert!(p.try_seat(0, 7));
        assert!(!p.should_evict(0));
        p.set_used(0, 9);
        assert!(p.try_cover(0));
        assert!(p.should_evict(0));
        p.evict_front(0).unwrap();
        assert!(!p.should_evict(0)); // 9 - 4 = 5 ≤ 8
        let c = p.counters();
        assert_eq!(c.evicted_pages, 1);
        p.release(0).unwrap();
        assert_eq!(p.counters().leaked_pages, 0);
    }

    #[test]
    fn counters_absorb_sums_and_keeps_page_size() {
        let mut a = PageCounters { page_size: 4, total_pages: 8,
                                   peak_pages: 5, peak_seated: 2,
                                   evicted_pages: 1, preemptions: 2,
                                   page_sheds: 3, leaked_pages: 0 };
        let b = PageCounters { page_size: 4, total_pages: 4,
                               peak_pages: 1, peak_seated: 1,
                               evicted_pages: 0, preemptions: 1,
                               page_sheds: 0, leaked_pages: 0 };
        a.absorb(&b);
        assert_eq!(a.page_size, 4);
        assert_eq!(a.total_pages, 12);
        assert_eq!(a.peak_pages, 6);
        assert_eq!(a.peak_seated, 3);
        assert_eq!((a.evicted_pages, a.preemptions, a.page_sheds),
                   (1, 3, 3));
    }
}
