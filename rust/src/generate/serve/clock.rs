//! The serve loop's notion of time: the wall/virtual [`Clock`], the
//! timed-arrival [`Schedule`], per-lane step-cost multipliers
//! ([`LaneCost`]), and the [`ArrivalQueue`] that feeds requests to the
//! admission stage as their arrival times pass.
//!
//! A [`Schedule`] carries the *dense* per-step virtual cost; sparse
//! lanes scale it down through their [`LaneCost`] (calibrated from
//! realized weight sparsity via `sparse_compute::theoretical_speedup`),
//! which is how the sparsity→capacity win of the SPDF checkpoint sweep
//! becomes visible on the virtual clock.

use std::time::Instant;

/// Per-lane multiplier on the [`Schedule`]'s virtual step costs: a
/// lane serving a sparse checkpoint advances the clock by
/// `step_scale × Schedule::step_ms` per engine step instead of the
/// full dense cost.
///
/// Scales are calibrated from realized weight sparsity `S` as
/// `1 / theoretical_speedup(S) = 1 − S` (the paper's FLOPs model: an
/// s75 lane steps at a quarter of the dense cost). Scales only shape
/// the virtual timeline — token streams are computed by the same
/// engines either way, so survivors stay bitwise identical to a run
/// at unit costs.
///
/// ```
/// use spdf::generate::serve::LaneCost;
///
/// let dense = LaneCost::unit();
/// let s75 = LaneCost::from_sparsity(0.75);
/// assert_eq!(dense.step_scale, 1.0);
/// assert_eq!(s75.step_scale, 0.25);
/// assert_eq!(s75.prefill_scale, 0.25);
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LaneCost {
    /// Multiplier on `Schedule::step_ms` for one engine step.
    pub step_scale: f64,
    /// Multiplier on `Schedule::prefill_ms` for one KV prefill pass.
    pub prefill_scale: f64,
}

impl LaneCost {
    /// Dense-lane cost: the schedule's step costs unscaled. This is
    /// the behavior of every serve path before lanes had
    /// heterogeneous costs, and the delegation default of
    /// `run_lanes_with`.
    pub fn unit() -> LaneCost {
        LaneCost { step_scale: 1.0, prefill_scale: 1.0 }
    }

    /// Calibrate from realized weight sparsity: scale =
    /// `1 / sparse_compute::theoretical_speedup(S)` = `1 − S`, the
    /// dense-FLOPs fraction a sparse step actually executes. Sparsity
    /// is clamped to `[0, 1)` so a (degenerate) all-zero checkpoint
    /// still costs a sliver of virtual time rather than zero.
    pub fn from_sparsity(sparsity: f64) -> LaneCost {
        let s = if sparsity.is_finite() { sparsity } else { 0.0 };
        let s = s.clamp(0.0, 1.0 - 1e-6);
        let scale = 1.0 / crate::sparse_compute::theoretical_speedup(s);
        LaneCost { step_scale: scale, prefill_scale: scale }
    }

    /// Virtual cost of one full speculative round, in units of the
    /// schedule's dense `step_ms`: `k` draft microsteps at the draft
    /// lane's scale plus one batched verify at the verifier's scale —
    /// `k·(1−s) + 1` for an s-sparse draft against a unit-cost dense
    /// verifier. The measurable per-round speedup is
    /// `committed_len / spec_round_scale`, so speculation wins
    /// whenever mean acceptance exceeds `k·(1−s)` (commit `a+1` ≥
    /// round cost). The `perf_serve_load` speculative leg gates on
    /// exactly this threshold.
    ///
    /// ```
    /// use spdf::generate::serve::LaneCost;
    /// let draft = LaneCost::from_sparsity(0.75); // step_scale 0.25
    /// let dense = LaneCost::unit();
    /// assert!((draft.spec_round_scale(&dense, 4) - 2.0).abs()
    ///         < 1e-12);
    /// ```
    pub fn spec_round_scale(&self, verifier: &LaneCost, k: usize)
                            -> f64 {
        k as f64 * self.step_scale + verifier.step_scale
    }

    pub(crate) fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.step_scale.is_finite() && self.step_scale > 0.0
                && self.prefill_scale.is_finite()
                && self.prefill_scale > 0.0,
            "lane cost scales must be finite and positive \
             (step {}, prefill {})",
            self.step_scale, self.prefill_scale
        );
        Ok(())
    }
}

/// Timed-arrival schedule for `serve_timed`: the virtual clock and
/// when each request joins the queue. Built by `generate::loadgen`.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Admission time per request, virtual ms, aligned with the
    /// request slice. `f64::INFINITY` marks a closed-loop successor
    /// that is released by its predecessor's completion (see
    /// `release`).
    pub arrivals: Vec<f64>,
    /// `release[i] = Some((j, think_ms))`: completing request `i`
    /// releases request `j` at `completion(i) + think_ms` (closed-loop
    /// client chains). Empty or all-`None` for open-loop traces.
    pub release: Vec<Option<(usize, f64)>>,
    /// Virtual cost of one engine step, ms.
    pub step_ms: f64,
    /// Virtual cost of one KV prefill pass, ms (unused on the literal
    /// path).
    pub prefill_ms: f64,
}

impl Schedule {
    /// Open-loop schedule: explicit arrival times, no release chains.
    pub fn open(arrivals: Vec<f64>, step_ms: f64, prefill_ms: f64)
                -> Schedule {
        let n = arrivals.len();
        Schedule { arrivals, release: vec![None; n], step_ms,
                   prefill_ms }
    }

    pub(crate) fn validate(&self, n: usize) -> anyhow::Result<()> {
        anyhow::ensure!(self.arrivals.len() == n,
                        "schedule has {} arrivals for {} requests",
                        self.arrivals.len(), n);
        anyhow::ensure!(self.release.len() == n,
                        "schedule has {} release entries for {} \
                         requests", self.release.len(), n);
        anyhow::ensure!(
            self.step_ms >= 0.0 && self.prefill_ms >= 0.0
                && self.step_ms.is_finite()
                && self.prefill_ms.is_finite(),
            "schedule step costs must be finite and non-negative"
        );
        let mut released = vec![false; n];
        for (i, r) in self.release.iter().enumerate() {
            if let Some((j, think)) = r {
                anyhow::ensure!(*j < n && *j != i,
                                "release target {j} out of range (from \
                                 request {i})");
                anyhow::ensure!(!released[*j],
                                "request {j} released twice");
                anyhow::ensure!(self.arrivals[*j] == f64::INFINITY,
                                "release target {j} must be gated at \
                                 +infinity");
                anyhow::ensure!(think.is_finite() && *think >= 0.0,
                                "bad think time for release of {j}");
                released[*j] = true;
            }
        }
        for (i, a) in self.arrivals.iter().enumerate() {
            if *a == f64::INFINITY {
                anyhow::ensure!(released[i],
                                "request {i} is gated (infinite \
                                 arrival) but nothing releases it");
            } else {
                // NaN and -inf both fail here: a negative-infinity
                // arrival would be admitted immediately AND look
                // "gated" to on_complete, decoding the request twice
                anyhow::ensure!(a.is_finite() && *a >= 0.0,
                                "bad arrival time for request {i}");
            }
        }
        Ok(())
    }
}

/// The serve loop's notion of time: real on the untimed path, a
/// deterministic per-invocation accumulator under a [`Schedule`].
///
/// The wall epoch lives here, not in the serve loop: this module is
/// the one sanctioned place the serve tree reads wall time (it is on
/// the `analysis::lint` wall-clock allowlist), so `core.rs` can stay
/// `Instant`-free and every timestamp flows through one abstraction.
pub(crate) struct Clock {
    /// Wall epoch of the serve call. Virtual runs never read it for
    /// timestamps, but [`Clock::wall_secs`] still reports the real
    /// compute time of the simulation for telemetry.
    t0: Instant,
    mode: Mode,
}

enum Mode {
    Wall,
    Virtual { now_ms: f64, step_ms: f64, prefill_ms: f64 },
}

impl Clock {
    pub(crate) fn new(schedule: Option<&Schedule>) -> Clock {
        let mode = match schedule {
            Some(s) => Mode::Virtual {
                now_ms: 0.0,
                step_ms: s.step_ms,
                prefill_ms: s.prefill_ms,
            },
            None => Mode::Wall,
        };
        Clock { t0: Instant::now(), mode }
    }

    pub(crate) fn now_ms(&self) -> f64 {
        match &self.mode {
            Mode::Wall => self.t0.elapsed().as_secs_f64() * 1e3,
            Mode::Virtual { now_ms, .. } => *now_ms,
        }
    }

    /// Real seconds since the serve call started, on both paths —
    /// telemetry's tokens-per-wall-second denominator.
    pub(crate) fn wall_secs(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// One engine step elapsed on a lane whose [`LaneCost`] step
    /// multiplier is `scale` (1.0 for a dense lane).
    pub(crate) fn on_step(&mut self, scale: f64) {
        if let Mode::Virtual { now_ms, step_ms, .. } = &mut self.mode {
            *now_ms += *step_ms * scale;
        }
    }

    /// One KV prefill pass elapsed, scaled like [`Clock::on_step`].
    pub(crate) fn on_prefill(&mut self, scale: f64) {
        if let Mode::Virtual { now_ms, prefill_ms, .. } = &mut self.mode
        {
            *now_ms += *prefill_ms * scale;
        }
    }

    /// Idle jump: nothing is decoding and nothing has arrived yet.
    pub(crate) fn jump_to(&mut self, t: f64) {
        if let Mode::Virtual { now_ms, .. } = &mut self.mode {
            *now_ms = now_ms.max(t);
        }
    }

    /// Extra elapsed time beyond the fixed step cost — an injected
    /// latency spike, attributed after the step that carried it. Wall
    /// clock ignores it (real time already passed, or didn't).
    pub(crate) fn advance(&mut self, ms: f64) {
        if let Mode::Virtual { now_ms, .. } = &mut self.mode {
            *now_ms += ms;
        }
    }

    /// Block until `t`: every lane with work is waiting out a retry
    /// backoff or breaker cooldown, so time must pass without a model
    /// step. Virtual → jump; Wall → sleep off the remainder.
    pub(crate) fn wait_until(&mut self, t: f64) {
        match &self.mode {
            Mode::Virtual { .. } => self.jump_to(t),
            Mode::Wall => {
                let now = self.t0.elapsed().as_secs_f64() * 1e3;
                if t > now {
                    std::thread::sleep(
                        std::time::Duration::from_secs_f64(
                            (t - now) / 1e3));
                }
            }
        }
    }
}

/// Pending-arrival queue: request indices ordered by (arrival, index),
/// with closed-loop successors gated at infinity until their
/// predecessor's completion releases them. Requests popped here flow
/// into the admission stage; this queue knows nothing about policies.
pub(crate) struct ArrivalQueue {
    arrivals: Vec<f64>,
    release: Vec<Option<(usize, f64)>>,
    /// Not-yet-admitted request indices, sorted by (arrival, index);
    /// gated (infinite-arrival) entries sit at the tail.
    waiting: Vec<usize>,
}

impl ArrivalQueue {
    pub(crate) fn new(n: usize, schedule: Option<&Schedule>)
                      -> ArrivalQueue {
        let (arrivals, release) = match schedule {
            Some(s) => (s.arrivals.clone(), s.release.clone()),
            None => (vec![0.0; n], vec![None; n]),
        };
        let mut waiting: Vec<usize> = (0..n).collect();
        // total_cmp, not partial_cmp().unwrap(): arrivals are
        // validated finite-or-+inf before the loop runs, but the sort
        // itself must never be the thing that panics on a NaN that
        // slipped past a future caller (NaN orders after +inf, i.e.
        // onto the gated tail, and the validation error still fires)
        waiting.sort_by(|&a, &b| {
            arrivals[a].total_cmp(&arrivals[b]).then(a.cmp(&b))
        });
        ArrivalQueue { arrivals, release, waiting }
    }

    pub(crate) fn arrival_of(&self, i: usize) -> f64 {
        self.arrivals[i]
    }

    /// Head of the queue if it has arrived by `now`.
    pub(crate) fn pop_ready(&mut self, now: f64) -> Option<usize> {
        let ready = matches!(self.waiting.first(),
                             Some(&i) if self.arrivals[i] <= now);
        if ready {
            Some(self.waiting.remove(0))
        } else {
            None
        }
    }

    /// Earliest pending arrival, if any is finite (i.e. not gated).
    pub(crate) fn next_arrival(&self) -> Option<f64> {
        self.waiting.first()
            .map(|&i| self.arrivals[i])
            .filter(|a| a.is_finite())
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.waiting.is_empty()
    }

    /// Completion hook: release request `i`'s closed-loop successor.
    /// Shed and expired requests release theirs too — the simulated
    /// client issues its next request after a failure just the same
    /// (`now` is then the failure instant: arrival for a shed,
    /// arrival + deadline for an expiry).
    pub(crate) fn on_complete(&mut self, i: usize, now: f64) {
        if let Some((j, think)) = self.release[i] {
            debug_assert!(self.arrivals[j] == f64::INFINITY,
                          "successor released twice");
            self.arrivals[j] = now + think;
            // reposition j from the gated tail to its sorted slot
            self.waiting.retain(|&w| w != j);
            insert_by_arrival(&self.arrivals, &mut self.waiting, j);
        }
    }

    /// [`insert_by_arrival`] against this queue's arrival times — the
    /// serve loop's ready set shares the ordering invariant.
    pub(crate) fn insert_ready(&self, list: &mut Vec<usize>,
                               i: usize) {
        insert_by_arrival(&self.arrivals, list, i);
    }
}

/// Insert request index `i` into `list` keeping it sorted by
/// (arrival, index) — the one definition of the FIFO-by-arrival
/// ordering shared by [`ArrivalQueue::on_complete`] (repositioning a
/// released successor) and the serve loop's ready set (where a
/// back-dated release must queue ahead of later arrivals).
pub(crate) fn insert_by_arrival(arrivals: &[f64],
                                list: &mut Vec<usize>, i: usize) {
    let ai = arrivals[i];
    let at = list.iter()
        .position(|&w| {
            let aw = arrivals[w];
            aw > ai || (aw == ai && w > i)
        })
        .unwrap_or(list.len());
    list.insert(at, i);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_queue_pops_in_arrival_then_index_order() {
        let s = Schedule::open(vec![5.0, 0.0, 5.0, 1.0], 1.0, 1.0);
        let mut q = ArrivalQueue::new(4, Some(&s));
        assert_eq!(q.pop_ready(10.0), Some(1));
        assert_eq!(q.pop_ready(10.0), Some(3));
        assert_eq!(q.pop_ready(10.0), Some(0)); // ties break by index
        assert_eq!(q.pop_ready(10.0), Some(2));
        assert_eq!(q.pop_ready(10.0), None);
        assert!(q.is_empty());
    }

    #[test]
    fn arrival_queue_gates_future_and_infinite_arrivals() {
        let s = Schedule {
            arrivals: vec![0.0, 4.0, f64::INFINITY],
            release: vec![Some((2, 1.0)), None, None],
            step_ms: 1.0,
            prefill_ms: 1.0,
        };
        let mut q = ArrivalQueue::new(3, Some(&s));
        assert_eq!(q.pop_ready(0.0), Some(0));
        assert_eq!(q.pop_ready(0.0), None);
        assert_eq!(q.next_arrival(), Some(4.0));
        // releasing the gated successor schedules it at now + think
        q.on_complete(0, 2.0);
        assert_eq!(q.arrival_of(2), 3.0);
        assert_eq!(q.pop_ready(3.5), Some(2));
        assert_eq!(q.pop_ready(4.0), Some(1));
    }

    #[test]
    fn arrival_sort_is_nan_safe() {
        // regression (ISSUE 4 satellite): the arrival sort used
        // partial_cmp().unwrap() and panicked on NaN before the
        // validation error could fire. total_cmp must order NaN onto
        // the gated tail without panicking; run_loop's validation
        // still rejects the schedule (covered in core::tests).
        let s = Schedule::open(vec![2.0, f64::NAN, 0.0], 1.0, 1.0);
        let mut q = ArrivalQueue::new(3, Some(&s));
        assert_eq!(q.pop_ready(5.0), Some(2));
        assert_eq!(q.pop_ready(5.0), Some(0));
        // the NaN entry never reads as "arrived"
        assert_eq!(q.pop_ready(f64::MAX), None);
        assert!(!q.is_empty());
        assert_eq!(q.next_arrival(), None);
    }

    #[test]
    fn insert_by_arrival_orders_by_arrival_then_index() {
        let arrivals = [5.0, 1.0, 3.0, 3.0, 0.5];
        let mut list = Vec::new();
        for i in [0, 1, 3] {
            insert_by_arrival(&arrivals, &mut list, i);
        }
        assert_eq!(list, vec![1, 3, 0]);
        // same arrival as 3 but smaller index: queues ahead of it
        insert_by_arrival(&arrivals, &mut list, 2);
        assert_eq!(list, vec![1, 2, 3, 0]);
        // earliest arrival goes to the front
        insert_by_arrival(&arrivals, &mut list, 4);
        assert_eq!(list, vec![4, 1, 2, 3, 0]);
    }

    #[test]
    fn schedule_validate_rejects_nan_and_negative_arrivals() {
        let s = Schedule::open(vec![0.0, f64::NAN], 1.0, 1.0);
        assert!(s.validate(2).is_err());
        let s = Schedule::open(vec![0.0, -1.0], 1.0, 1.0);
        assert!(s.validate(2).is_err());
        let s = Schedule::open(vec![0.0, 1.0], 1.0, 1.0);
        assert!(s.validate(2).is_ok());
    }

    #[test]
    fn virtual_clock_accumulates_and_jumps() {
        let s = Schedule::open(vec![0.0], 2.0, 3.0);
        let mut c = Clock::new(Some(&s));
        assert_eq!(c.now_ms(), 0.0);
        c.on_step(1.0);
        c.on_prefill(1.0);
        assert_eq!(c.now_ms(), 5.0);
        c.jump_to(10.0);
        assert_eq!(c.now_ms(), 10.0);
        c.jump_to(4.0); // never rewinds
        assert_eq!(c.now_ms(), 10.0);
        // spikes add on top of wherever the clock is
        c.advance(2.5);
        assert_eq!(c.now_ms(), 12.5);
        // wait_until is a jump on the virtual clock, max-only
        c.wait_until(20.0);
        assert_eq!(c.now_ms(), 20.0);
        c.wait_until(1.0);
        assert_eq!(c.now_ms(), 20.0);
        // the virtual timeline is decoupled from the wall epoch, but
        // wall_secs still reports (tiny) real elapsed compute time
        assert!(c.wall_secs() >= 0.0 && c.wall_secs() < 60.0);
    }

    #[test]
    fn lane_cost_scales_virtual_step_costs() {
        let s = Schedule::open(vec![0.0], 4.0, 8.0);
        let mut c = Clock::new(Some(&s));
        // an s75 lane steps at a quarter of the dense cost
        let s75 = LaneCost::from_sparsity(0.75);
        assert_eq!(s75.step_scale, 0.25);
        c.on_step(s75.step_scale);
        assert_eq!(c.now_ms(), 1.0);
        c.on_prefill(s75.prefill_scale);
        assert_eq!(c.now_ms(), 3.0);
        // a dense lane on the same clock pays full price
        c.on_step(LaneCost::unit().step_scale);
        assert_eq!(c.now_ms(), 7.0);
    }

    #[test]
    fn lane_cost_calibration_and_validation() {
        assert_eq!(LaneCost::unit(), LaneCost::from_sparsity(0.0));
        assert_eq!(LaneCost::from_sparsity(0.5).step_scale, 0.5);
        // degenerate inputs clamp instead of producing zero/negative
        // or non-finite scales
        assert!(LaneCost::from_sparsity(1.0).validate().is_ok());
        assert!(LaneCost::from_sparsity(-3.0).step_scale == 1.0);
        assert!(LaneCost::from_sparsity(f64::NAN).validate().is_ok());
        let bad = LaneCost { step_scale: 0.0, prefill_scale: 1.0 };
        assert!(bad.validate().is_err());
        let bad = LaneCost { step_scale: 1.0, prefill_scale: f64::NAN };
        assert!(bad.validate().is_err());
    }
}
