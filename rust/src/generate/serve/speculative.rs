//! Self-speculative decoding: a cheap sparse checkpoint drafts, the
//! dense checkpoint verifies, output stays bitwise dense.
//!
//! SPDF's sparse-pre-trained checkpoints compute a fraction of the
//! dense FLOPs while staying close to the dense model's distribution —
//! exactly the profile of a good *draft* model. In speculative mode a
//! request routed to the verifier lane transiently holds rows on two
//! lanes per round:
//!
//!  1. **draft** — a leased row on the draft lane (the s75 checkpoint,
//!     ~4× cheaper per step under [`super::clock::LaneCost`]) is
//!     re-prefilled from the committed tokens and runs up to `k`
//!     greedy microsteps ahead, proposing `d_1..d_k`;
//!  2. **verify** — the verifier lane scores all proposals in **one**
//!     batched step: the request's own row reads the committed
//!     position and each free verifier row is leased to replicate the
//!     row at one draft offset, so a single step yields the dense
//!     picks `v_0..v_u` for every proposed position at once;
//!  3. **accept** — the engine commits the longest agreeing prefix
//!     ([`accept_len`]) plus the verifier's first correction (or the
//!     bonus token when every draft matched), so every verify step
//!     commits ≥ 1 pick and the committed stream is provably the
//!     dense greedy stream: each committed token is a dense pick made
//!     from an already-validated dense context.
//!
//! Faults compose instead of cascading: a dead / backing-off /
//! breaker-open draft lane (or simple lease starvation) degrades the
//! request to plain dense decode for the round — never `Failed` — and
//! a verifier-lane fault follows the ordinary recovery path with the
//! pending drafts retained for the retried verify.
//!
//! The per-round virtual-time cost is `k · (1 − s) + 1` dense steps
//! ([`super::clock::LaneCost::spec_round_scale`]), so the measurable
//! speedup is `accepted_len / (k·(1−s) + 1)` — the acceptance-rate
//! telemetry in [`super::telemetry::ServeStats`] makes the win (or its
//! absence) a first-class datapoint.

/// User-facing speculative-decoding knob: registry model **names**
/// plus the draft depth, as given on the CLI
/// (`--speculate DRAFT=VERIFIER:k`).
///
/// ```
/// use spdf::generate::serve::SpecConfig;
/// let c = SpecConfig::parse("s75=dense:4").unwrap();
/// assert_eq!((c.draft.as_str(), c.verifier.as_str(), c.k),
///            ("s75", "dense", 4));
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpecConfig {
    /// Model that drafts ahead (the cheap sparse checkpoint).
    pub draft: String,
    /// Model whose output the caller receives, bitwise (dense).
    pub verifier: String,
    /// Draft depth: greedy tokens proposed per round (≥ 1).
    pub k: usize,
}

impl SpecConfig {
    /// A validated config from its three parts.
    pub fn new(draft: impl Into<String>, verifier: impl Into<String>,
               k: usize) -> anyhow::Result<SpecConfig> {
        let cfg = SpecConfig { draft: draft.into(),
                               verifier: verifier.into(), k };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse the CLI form `DRAFT=VERIFIER:k` (mirroring
    /// `--fallback FROM=TO`), e.g. `s75=dense:4`.
    pub fn parse(spec: &str) -> anyhow::Result<SpecConfig> {
        let (draft, rest) = spec.split_once('=').ok_or_else(|| {
            anyhow::anyhow!(
                "--speculate wants DRAFT=VERIFIER:k (got {spec:?})")
        })?;
        let (verifier, k) = rest.split_once(':').ok_or_else(|| {
            anyhow::anyhow!(
                "--speculate wants DRAFT=VERIFIER:k (got {spec:?})")
        })?;
        let k: usize = k.parse().map_err(|_| {
            anyhow::anyhow!("--speculate draft depth must be an \
                             integer (got {k:?})")
        })?;
        SpecConfig::new(draft, verifier, k)
    }

    /// Structural checks that need no registry: non-empty distinct
    /// model names, draft depth ≥ 1.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.draft.is_empty()
                            && !self.verifier.is_empty(),
                        "speculative config needs non-empty draft and \
                         verifier model names");
        anyhow::ensure!(self.draft != self.verifier,
                        "speculative draft and verifier must be \
                         different models (got {} twice)", self.draft);
        anyhow::ensure!(self.k >= 1,
                        "speculative draft depth k must be >= 1");
        Ok(())
    }
}

/// [`SpecConfig`] resolved against a registry: lane indices instead of
/// model names. Built by `ModelRegistry::serve_with`; the serve core
/// takes it by reference and stays name-agnostic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpecPlan {
    /// Lane that drafts (leased rows only — its own residents keep
    /// decoding normally, one token per draft microstep).
    pub draft_lane: usize,
    /// Lane whose residents are served speculatively.
    pub verifier_lane: usize,
    /// Draft depth per round.
    pub k: usize,
}

impl SpecPlan {
    /// Lane-level checks: distinct in-range lanes, depth ≥ 1.
    pub fn validate(&self, n_lanes: usize) -> anyhow::Result<()> {
        anyhow::ensure!(self.draft_lane < n_lanes
                            && self.verifier_lane < n_lanes,
                        "speculative lanes ({}, {}) out of range for \
                         {n_lanes} lanes",
                        self.draft_lane, self.verifier_lane);
        anyhow::ensure!(self.draft_lane != self.verifier_lane,
                        "speculative draft and verifier must be \
                         different lanes (got {} twice)",
                        self.draft_lane);
        anyhow::ensure!(self.k >= 1,
                        "speculative draft depth k must be >= 1");
        Ok(())
    }
}

/// Longest agreeing prefix: how many leading draft tokens match the
/// verifier's picks for the same positions. `drafts[i]` proposes the
/// token for committed position `m + i`; `verified[i]` is the dense
/// pick for that position given the prefix `drafts[..i]` — so the
/// prefix of length `accept_len` is exactly the dense greedy stream.
///
/// ```
/// use spdf::generate::serve::speculative::accept_len;
/// assert_eq!(accept_len(&[7, 8, 9], &[7, 8, 2]), 2);
/// assert_eq!(accept_len(&[7, 8, 9], &[7, 8, 9]), 3);
/// assert_eq!(accept_len(&[1], &[2]), 0);
/// ```
pub fn accept_len(drafts: &[u32], verified: &[u32]) -> usize {
    drafts
        .iter()
        .zip(verified)
        .take_while(|(d, v)| d == v)
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_the_cli_form() {
        let c = SpecConfig::parse("s75=dense:3").unwrap();
        assert_eq!(c, SpecConfig { draft: "s75".into(),
                                   verifier: "dense".into(), k: 3 });
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in ["s75", "s75=dense", "s75:dense=3", "s75=dense:x",
                    "s75=dense:0", "=dense:3", "s75=:3",
                    "dense=dense:3"] {
            assert!(SpecConfig::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn plan_validation_needs_two_distinct_lanes() {
        let ok = SpecPlan { draft_lane: 1, verifier_lane: 0, k: 4 };
        ok.validate(2).unwrap();
        assert!(ok.validate(1).is_err(), "lane out of range");
        let same = SpecPlan { draft_lane: 0, verifier_lane: 0, k: 4 };
        assert!(same.validate(2).is_err(), "same lane twice");
        let k0 = SpecPlan { draft_lane: 1, verifier_lane: 0, k: 0 };
        assert!(k0.validate(2).is_err(), "k = 0");
    }

    #[test]
    fn accept_len_is_the_longest_agreeing_prefix() {
        assert_eq!(accept_len(&[], &[]), 0);
        assert_eq!(accept_len(&[5], &[]), 0);
        assert_eq!(accept_len(&[5, 6], &[5, 6, 7]), 2);
        assert_eq!(accept_len(&[5, 9, 6], &[5, 6, 6]), 1);
        assert_eq!(accept_len(&[3, 3, 3], &[3, 3, 3]), 3);
    }
}
