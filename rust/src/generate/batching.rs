//! Continuous slot-refill batching over the fixed decode geometry.
//!
//! The `logits_last` artifact is compiled for a fixed
//! `(decode_batch, ctx_len)` shape, but serving traffic is an arbitrary
//! stream of prompts with wildly different generation lengths. Static
//! chunking (decode `B` prompts, wait for the *slowest*, repeat) burns
//! batch slots as padding the moment one slot finishes early. Here a
//! request queue feeds the batch instead: the moment a slot's request
//! finishes (EOS / length cap), the slot is rewritten with the next
//! queued prompt **mid-flight** — the model step never idles a slot
//! while work is waiting. Causal attention plus the explicit `pos`
//! input make each row independent, so a slot's output is bit-identical
//! to decoding its prompt alone (`tests/integration_runtime.rs` checks
//! this).
//!
//! Two logits backends share one state machine: [`serve`] recomputes
//! the full context per step (`logits_last`), [`serve_kv`] holds
//! per-layer K/V caches as runtime session state and advances with the
//! incremental `decode_step` artifact, re-populating a slot's cache
//! rows via the `prefill` artifact whenever the slot is rewritten.
//!
//! Per-request latency and batch-occupancy stats feed
//! `coordinator::report::serve_table` and `benches/perf_decode`.

use std::time::Instant;

use crate::tokenizer::EOS;
use crate::util::json::Json;
use crate::util::stats::summarize;

use super::engine::DecodeEngine;
use super::{topk, DecodeParams};

/// One queued decode request.
#[derive(Debug, Clone)]
pub struct DecodeRequest {
    /// Caller-chosen id, echoed in the result (results are returned
    /// sorted by id).
    pub id: u64,
    /// Prompt token ids (unpadded, non-empty).
    pub prompt: Vec<u32>,
    /// Per-request generation budget.
    pub max_new_tokens: usize,
}

impl DecodeRequest {
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize)
               -> DecodeRequest {
        DecodeRequest { id, prompt, max_new_tokens }
    }
}

/// The decoded continuation plus per-request serving telemetry.
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: u64,
    /// Generated tokens (without the prompt, without EOS).
    pub tokens: Vec<u32>,
    /// Engine steps spent queued before a slot freed up.
    pub queue_steps: u64,
    /// Engine steps the request occupied a slot.
    pub decode_steps: u64,
    /// Wall time from `serve` entry to request completion (queue wait
    /// included — this is what a caller would observe).
    pub latency_ms: f64,
}

/// Aggregate serving statistics for one `serve` call.
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub requests: usize,
    pub decode_batch: usize,
    /// Model steps executed.
    pub engine_steps: u64,
    /// KV cache-population runs (0 on the literal-resident path). A
    /// prefill fires once per engine step in which at least one slot
    /// was (re)filled, not per request.
    pub prefill_steps: u64,
    /// Occupied slot-steps (out of `engine_steps * decode_batch`).
    pub slot_steps: u64,
    /// `slot_steps / (engine_steps * decode_batch)` — 1.0 means no
    /// slot ever idled.
    pub occupancy: f64,
    pub generated_tokens: u64,
    pub wall_secs: f64,
    pub tokens_per_sec: f64,
    pub mean_step_ms: f64,
    pub latency_ms_p50: f64,
    pub latency_ms_p95: f64,
}

impl ServeStats {
    /// JSON form for `BENCH_decode.json` and `spdf serve --stats-json`.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.push("requests", Json::Num(self.requests as f64))
            .push("decode_batch", Json::Num(self.decode_batch as f64))
            .push("engine_steps", Json::Num(self.engine_steps as f64))
            .push("prefill_steps", Json::Num(self.prefill_steps as f64))
            .push("slot_steps", Json::Num(self.slot_steps as f64))
            .push("occupancy", Json::Num(self.occupancy))
            .push("generated_tokens",
                  Json::Num(self.generated_tokens as f64))
            .push("wall_secs", Json::Num(self.wall_secs))
            .push("tokens_per_sec", Json::Num(self.tokens_per_sec))
            .push("mean_step_ms", Json::Num(self.mean_step_ms))
            .push("latency_ms_p50", Json::Num(self.latency_ms_p50))
            .push("latency_ms_p95", Json::Num(self.latency_ms_p95));
        j
    }
}

/// Results (sorted by request id) + aggregate stats.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub results: Vec<RequestResult>,
    pub stats: ServeStats,
}

/// A batch slot currently decoding one request. The slot's cursor
/// lives only in the shared `pos` buffer fed to `step_logits` — a
/// slot-local copy would have to be advanced in lockstep and has
/// already caused one logits-read-at-stale-position bug.
struct Slot {
    req: usize, // index into `requests`
    out: Vec<u32>,
    entered_step: u64,
}

/// Write a request's prompt into row `slot` of the token buffer,
/// clearing stale tokens from the previous occupant first (junk
/// *before* `pos` would leak into the new request's context).
/// `serve` validates up front that the prompt is non-empty and fits
/// the row (`len < t`).
fn fill_slot(
    tokens: &mut [i32],
    pos: &mut [i32],
    t: usize,
    slot: usize,
    prompt: &[u32],
) {
    debug_assert!(!prompt.is_empty() && prompt.len() < t,
                  "serve() validates prompt lengths up front");
    let row = &mut tokens[slot * t..(slot + 1) * t];
    row.fill(0);
    for (j, &tok) in prompt.iter().enumerate() {
        row[j] = tok as i32;
    }
    pos[slot] = prompt.len() as i32 - 1;
}

/// Complete zero-budget requests immediately (greedy with
/// `max_new_tokens == 0` decodes nothing) so they never occupy a slot.
fn drain_zero_budget(
    requests: &[DecodeRequest],
    next_req: &mut usize,
    results: &mut Vec<RequestResult>,
    engine_steps: u64,
    latency_ms: f64,
) {
    while *next_req < requests.len()
        && requests[*next_req].max_new_tokens == 0
    {
        results.push(RequestResult {
            id: requests[*next_req].id,
            tokens: Vec::new(),
            queue_steps: engine_steps,
            decode_steps: 0,
            latency_ms,
        });
        *next_req += 1;
    }
}

/// Run a request stream to completion through the engine's
/// literal-resident path (`logits_last`: full-context recompute per
/// step). Requests enter slots in order; each finished slot is
/// refilled from the queue before the next model step. `dp` supplies
/// the sampling knobs (`no_repeat_ngram`); generation budgets come
/// from each request's `max_new_tokens`, not `dp.max_new_tokens`.
pub fn serve(
    engine: &DecodeEngine,
    requests: &[DecodeRequest],
    dp: &DecodeParams,
) -> anyhow::Result<ServeReport> {
    serve_impl(engine, requests, dp, false)
}

/// [`serve`] over the KV-resident incremental path: a slot's cache is
/// populated once per (re)fill by the `prefill` artifact, then every
/// step runs `decode_step` — only `(B,)` token/pos vectors cross the
/// host boundary and per-token model work is O(1) in the context
/// length. Greedy output is bit-identical to [`serve`] and to
/// [`super::reference::greedy`] (integration-tested, including across
/// slot refills). Errors if the KV artifacts were not compiled.
pub fn serve_kv(
    engine: &DecodeEngine,
    requests: &[DecodeRequest],
    dp: &DecodeParams,
) -> anyhow::Result<ServeReport> {
    serve_impl(engine, requests, dp, true)
}

/// One slot-refill state machine for both decode paths. The host-side
/// bookkeeping (token buffer, positions, EOS/length-cap edges, refill
/// order, telemetry) is identical; the paths differ only in how a
/// step's logits are produced, so any divergence between them is a
/// model-side bug by construction.
fn serve_impl(
    engine: &DecodeEngine,
    requests: &[DecodeRequest],
    dp: &DecodeParams,
    use_kv: bool,
) -> anyhow::Result<ServeReport> {
    let b = engine.decode_batch();
    let t = engine.ctx_len();
    let vocab = engine.vocab();
    anyhow::ensure!(requests.iter().all(|r| !r.prompt.is_empty()),
                    "empty prompt in decode request stream");
    anyhow::ensure!(
        requests.iter().all(|r| r.prompt.len() < t),
        "prompt longer than ctx_len - 1 ({}) in decode request \
         stream — pre-truncate (keeping the tail) with \
         coordinator::prompt_tokens",
        t - 1
    );

    let t0 = Instant::now();
    let mut tokens = vec![0i32; b * t];
    let mut pos = vec![0i32; b];
    let mut slots: Vec<Option<Slot>> = (0..b).map(|_| None).collect();
    let mut next_req = 0usize;
    let mut results: Vec<RequestResult> =
        Vec::with_capacity(requests.len());
    let mut engine_steps = 0u64;
    let mut slot_steps = 0u64;
    let mut prefill_steps = 0u64;

    // KV session state: the cache literals round-trip output→input
    // across steps; `refill` marks rows whose cache must be
    // (re)populated from the token buffer before the next step.
    let mut kv_state = if use_kv { Some(engine.kv_state()?) } else {
        None
    };
    let mut refill = vec![0f32; b];
    let mut any_refill = false;
    let mut next_tok = vec![0i32; b];

    // initial fill
    for s in 0..b {
        drain_zero_budget(requests, &mut next_req, &mut results, 0,
                          0.0);
        if next_req >= requests.len() {
            break;
        }
        fill_slot(&mut tokens, &mut pos, t, s,
                  &requests[next_req].prompt);
        refill[s] = 1.0;
        any_refill = true;
        slots[s] = Some(Slot {
            req: next_req,
            out: Vec::new(),
            entered_step: 0,
        });
        next_req += 1;
    }

    while slots.iter().any(|s| s.is_some()) {
        let occupied = slots.iter().filter(|s| s.is_some()).count();
        let lv = if let Some(state) = kv_state.as_mut() {
            if any_refill {
                // populate the marked rows' caches (positions up to
                // and including `pos`) from their prompt rows; other
                // rows pass through untouched
                engine.kv_prefill(state, &tokens, &pos, &refill)?;
                prefill_steps += 1;
                refill.fill(0.0);
                any_refill = false;
            }
            // each row advances by its token at `pos` (for a freshly
            // prefilled row that re-derives the prompt tail's K/V —
            // same values — and yields the same logits the prefill
            // already read; uniformity keeps every emitted logit on
            // the incremental program)
            for s in 0..b {
                next_tok[s] = tokens[s * t + pos[s] as usize];
            }
            engine.kv_step(state, &next_tok, &pos)?
        } else {
            engine.step_logits(&tokens, &pos)?
        };
        engine_steps += 1;
        slot_steps += occupied as u64;

        for s in 0..b {
            let finished = {
                let Some(slot) = slots[s].as_mut() else { continue };
                let max_new = requests[slot.req].max_new_tokens;
                let row = &lv[s * vocab..(s + 1) * vocab];
                let cur = pos[s] as usize;
                let ctx: Vec<u32> = if dp.no_repeat_ngram > 0 {
                    (0..=cur).map(|j| tokens[s * t + j] as u32)
                        .collect()
                } else {
                    Vec::new()
                };
                let next = topk::pick_next(row, &ctx,
                                           dp.no_repeat_ngram);
                let new_pos = cur + 1;
                if next == EOS || new_pos >= t - 1 {
                    if next != EOS && new_pos < t {
                        slot.out.push(next);
                    }
                    true
                } else {
                    tokens[s * t + new_pos] = next as i32;
                    pos[s] = new_pos as i32;
                    slot.out.push(next);
                    slot.out.len() >= max_new
                }
            };
            if finished {
                let slot = slots[s].take().unwrap();
                results.push(RequestResult {
                    id: requests[slot.req].id,
                    tokens: slot.out,
                    queue_steps: slot.entered_step,
                    decode_steps: engine_steps - slot.entered_step,
                    latency_ms: t0.elapsed().as_secs_f64() * 1e3,
                });
                // refill mid-flight: the freed slot decodes the next
                // queued request starting with the following step
                drain_zero_budget(requests, &mut next_req,
                                  &mut results, engine_steps,
                                  t0.elapsed().as_secs_f64() * 1e3);
                if next_req < requests.len() {
                    fill_slot(&mut tokens, &mut pos, t, s,
                              &requests[next_req].prompt);
                    // KV path: the freed slot's cache still holds the
                    // previous occupant — mark it for re-population
                    // before the next step
                    refill[s] = 1.0;
                    any_refill = true;
                    slots[s] = Some(Slot {
                        req: next_req,
                        out: Vec::new(),
                        entered_step: engine_steps,
                    });
                    next_req += 1;
                }
            }
        }
    }

    results.sort_by_key(|r| r.id);
    let wall_secs = t0.elapsed().as_secs_f64();
    let generated_tokens: u64 =
        results.iter().map(|r| r.tokens.len() as u64).sum();
    let latencies: Vec<f64> =
        results.iter().map(|r| r.latency_ms).collect();
    let (p50, p95) = if latencies.is_empty() {
        (0.0, 0.0)
    } else {
        let s = summarize(&latencies);
        (s.p50, s.p95)
    };
    let stats = ServeStats {
        requests: requests.len(),
        decode_batch: b,
        engine_steps,
        prefill_steps,
        slot_steps,
        occupancy: if engine_steps == 0 {
            0.0
        } else {
            slot_steps as f64 / (engine_steps * b as u64) as f64
        },
        generated_tokens,
        wall_secs,
        tokens_per_sec: if wall_secs > 0.0 {
            generated_tokens as f64 / wall_secs
        } else {
            0.0
        },
        mean_step_ms: if engine_steps == 0 {
            0.0
        } else {
            wall_secs * 1e3 / engine_steps as f64
        },
        latency_ms_p50: p50,
        latency_ms_p95: p95,
    };
    Ok(ServeReport { results, stats })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_slot_clears_previous_occupant() {
        let t = 8;
        let mut tokens = vec![7i32; 2 * t];
        let mut pos = vec![5i32; 2];
        fill_slot(&mut tokens, &mut pos, t, 1, &[9, 10]);
        assert_eq!(pos[1], 1);
        assert_eq!(&tokens[t..], &[9, 10, 0, 0, 0, 0, 0, 0]);
        // row 0 untouched
        assert!(tokens[..t].iter().all(|&x| x == 7));
    }

    #[test]
    fn fill_slot_max_length_prompt_fits() {
        // longest prompt serve() admits: t - 1 tokens, pos on the last
        let t = 4;
        let mut tokens = vec![0i32; t];
        let mut pos = vec![0i32; 1];
        fill_slot(&mut tokens, &mut pos, t, 0, &[1, 2, 3]);
        assert_eq!(pos[0], 2);
        assert_eq!(tokens, vec![1, 2, 3, 0]);
    }

    #[test]
    fn stats_json_has_core_fields() {
        let stats = ServeStats {
            requests: 3,
            decode_batch: 2,
            engine_steps: 10,
            prefill_steps: 2,
            slot_steps: 17,
            occupancy: 0.85,
            generated_tokens: 15,
            wall_secs: 0.5,
            tokens_per_sec: 30.0,
            mean_step_ms: 50.0,
            latency_ms_p50: 200.0,
            latency_ms_p95: 450.0,
        };
        let j = stats.to_json();
        assert_eq!(j.get("tokens_per_sec").unwrap().as_f64(),
                   Some(30.0));
        assert_eq!(j.get("occupancy").unwrap().as_f64(), Some(0.85));
        assert_eq!(j.get("engine_steps").unwrap().as_usize(), Some(10));
        assert_eq!(j.get("prefill_steps").unwrap().as_usize(), Some(2));
    }
}
