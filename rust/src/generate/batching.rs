//! Continuous slot-refill batching over the fixed decode geometry.
//!
//! The `logits_last` artifact is compiled for a fixed
//! `(decode_batch, ctx_len)` shape, but serving traffic is an arbitrary
//! stream of prompts with wildly different generation lengths. Static
//! chunking (decode `B` prompts, wait for the *slowest*, repeat) burns
//! batch slots as padding the moment one slot finishes early. Here a
//! request queue feeds the batch instead: the moment a slot's request
//! finishes (EOS / length cap), the slot is rewritten with the next
//! queued prompt **mid-flight** — the model step never idles a slot
//! while work is waiting. Causal attention plus the explicit `pos`
//! input make each row independent, so a slot's output is bit-identical
//! to decoding its prompt alone (`tests/integration_runtime.rs` checks
//! this).
//!
//! One state machine, three entry points:
//!  * [`serve`] — the literal-resident path (`logits_last`, full
//!    context recompute per step), whole request stream present at
//!    entry, wall-clock latencies;
//!  * [`serve_kv`] — same queueing over the KV-resident incremental
//!    path (`prefill` + `decode_step` session state);
//!  * [`serve_timed`] — arrival-gated admission on a **virtual
//!    clock** (the `loadgen` workload driver): each request becomes
//!    visible only once the simulated clock passes its
//!    [`Schedule::arrivals`] entry, every model invocation advances
//!    the clock by a fixed cost, and per-request queue-wait / TTFT /
//!    end-to-end latencies are read off the virtual clock — fully
//!    deterministic for a given trace and step costs.
//!
//! The logits producer behind the loop is a [`LogitsBackend`]: the two
//! engine paths plus deterministic in-process mocks, so every queueing
//! and clock edge case is unit-testable without compiled artifacts.
//!
//! Per-request latency and batch-occupancy stats feed
//! `coordinator::report::{serve_table, load_table}` and the
//! `perf_decode` / `perf_serve_load` benches.

use std::time::Instant;

use crate::runtime::SessionState;
use crate::tokenizer::EOS;
use crate::util::json::Json;
use crate::util::stats::{summarize, Summary};

use super::engine::DecodeEngine;
use super::{topk, DecodeParams};

/// One queued decode request.
#[derive(Debug, Clone)]
pub struct DecodeRequest {
    /// Caller-chosen id, echoed in the result (results are returned
    /// sorted by id).
    pub id: u64,
    /// Prompt token ids (unpadded, non-empty).
    pub prompt: Vec<u32>,
    /// Per-request generation budget.
    pub max_new_tokens: usize,
}

impl DecodeRequest {
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize)
               -> DecodeRequest {
        DecodeRequest { id, prompt, max_new_tokens }
    }
}

/// The decoded continuation plus per-request serving telemetry. All
/// `*_ms` fields are wall-clock on the [`serve`]/[`serve_kv`] path and
/// virtual-clock under a [`serve_timed`] schedule.
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: u64,
    /// Generated tokens (without the prompt, without EOS).
    pub tokens: Vec<u32>,
    /// Engine steps spent queued before a slot freed up.
    pub queue_steps: u64,
    /// Engine steps the request occupied a slot.
    pub decode_steps: u64,
    /// When the request became visible to the server (0.0 when the
    /// whole stream is present at entry).
    pub arrival_ms: f64,
    /// Arrival → slot entry (queue wait).
    pub queue_ms: f64,
    /// Arrival → first generated token; equals `latency_ms` for
    /// requests that produce none (zero budget / immediate EOS).
    pub ttft_ms: f64,
    /// Arrival → completion — what a caller would observe.
    pub latency_ms: f64,
}

/// Aggregate serving statistics for one serve call.
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub requests: usize,
    pub decode_batch: usize,
    /// Model steps executed.
    pub engine_steps: u64,
    /// KV cache-population runs (0 on the literal-resident path). A
    /// prefill fires once per engine step in which at least one slot
    /// was (re)filled, not per request.
    pub prefill_steps: u64,
    /// Occupied slot-steps (out of `engine_steps * decode_batch`).
    pub slot_steps: u64,
    /// `slot_steps / (engine_steps * decode_batch)` — 1.0 means no
    /// slot ever idled.
    pub occupancy: f64,
    pub generated_tokens: u64,
    /// Real host time spent, always wall-clock (the virtual schedule
    /// does not change how long the model actually runs).
    pub wall_secs: f64,
    pub tokens_per_sec: f64,
    pub mean_step_ms: f64,
    /// Clock reading when the last request completed: wall ms on the
    /// untimed path, virtual ms under a [`Schedule`].
    pub sim_ms: f64,
    /// Per-request queue wait (arrival → slot entry).
    pub queue_ms: Summary,
    /// Per-request time-to-first-token.
    pub ttft_ms: Summary,
    /// Per-request end-to-end latency (p50/p95/p99 et al).
    pub latency_ms: Summary,
}

impl ServeStats {
    /// JSON form for `BENCH_decode.json`, `BENCH_serve_load.json` and
    /// `spdf serve --stats-json`.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.push("requests", Json::Num(self.requests as f64))
            .push("decode_batch", Json::Num(self.decode_batch as f64))
            .push("engine_steps", Json::Num(self.engine_steps as f64))
            .push("prefill_steps", Json::Num(self.prefill_steps as f64))
            .push("slot_steps", Json::Num(self.slot_steps as f64))
            .push("occupancy", Json::Num(self.occupancy))
            .push("generated_tokens",
                  Json::Num(self.generated_tokens as f64))
            .push("wall_secs", Json::Num(self.wall_secs))
            .push("tokens_per_sec", Json::Num(self.tokens_per_sec))
            .push("mean_step_ms", Json::Num(self.mean_step_ms))
            .push("sim_ms", Json::Num(self.sim_ms))
            .push("queue_ms", self.queue_ms.to_json())
            .push("ttft_ms", self.ttft_ms.to_json())
            .push("latency_ms", self.latency_ms.to_json());
        j
    }
}

/// Results (sorted by request id) + aggregate stats.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub results: Vec<RequestResult>,
    pub stats: ServeStats,
}

/// Timed-arrival schedule for [`serve_timed`]: the virtual clock and
/// when each request joins the queue. Built by `generate::loadgen`.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Admission time per request, virtual ms, aligned with the
    /// request slice. `f64::INFINITY` marks a closed-loop successor
    /// that is released by its predecessor's completion (see
    /// `release`).
    pub arrivals: Vec<f64>,
    /// `release[i] = Some((j, think_ms))`: completing request `i`
    /// releases request `j` at `completion(i) + think_ms` (closed-loop
    /// client chains). Empty or all-`None` for open-loop traces.
    pub release: Vec<Option<(usize, f64)>>,
    /// Virtual cost of one engine step, ms.
    pub step_ms: f64,
    /// Virtual cost of one KV prefill pass, ms (unused on the literal
    /// path).
    pub prefill_ms: f64,
}

impl Schedule {
    /// Open-loop schedule: explicit arrival times, no release chains.
    pub fn open(arrivals: Vec<f64>, step_ms: f64, prefill_ms: f64)
                -> Schedule {
        let n = arrivals.len();
        Schedule { arrivals, release: vec![None; n], step_ms,
                   prefill_ms }
    }

    fn validate(&self, n: usize) -> anyhow::Result<()> {
        anyhow::ensure!(self.arrivals.len() == n,
                        "schedule has {} arrivals for {} requests",
                        self.arrivals.len(), n);
        anyhow::ensure!(self.release.len() == n,
                        "schedule has {} release entries for {} \
                         requests", self.release.len(), n);
        anyhow::ensure!(
            self.step_ms >= 0.0 && self.prefill_ms >= 0.0
                && self.step_ms.is_finite()
                && self.prefill_ms.is_finite(),
            "schedule step costs must be finite and non-negative"
        );
        let mut released = vec![false; n];
        for (i, r) in self.release.iter().enumerate() {
            if let Some((j, think)) = r {
                anyhow::ensure!(*j < n && *j != i,
                                "release target {j} out of range (from \
                                 request {i})");
                anyhow::ensure!(!released[*j],
                                "request {j} released twice");
                anyhow::ensure!(self.arrivals[*j] == f64::INFINITY,
                                "release target {j} must be gated at \
                                 +infinity");
                anyhow::ensure!(think.is_finite() && *think >= 0.0,
                                "bad think time for release of {j}");
                released[*j] = true;
            }
        }
        for (i, a) in self.arrivals.iter().enumerate() {
            if *a == f64::INFINITY {
                anyhow::ensure!(released[i],
                                "request {i} is gated (infinite \
                                 arrival) but nothing releases it");
            } else {
                // NaN and -inf both fail here: a negative-infinity
                // arrival would be admitted immediately AND look
                // "gated" to on_complete, decoding the request twice
                anyhow::ensure!(a.is_finite() && *a >= 0.0,
                                "bad arrival time for request {i}");
            }
        }
        Ok(())
    }
}

/// The per-step logits producer behind the slot-refill state machine:
/// the literal-resident engine path, the KV-resident path, and
/// deterministic test mocks (so queueing/clock behavior is testable
/// without compiled artifacts).
pub(crate) trait LogitsBackend {
    /// `(decode_batch, ctx_len, vocab)`.
    fn dims(&self) -> (usize, usize, usize);
    /// true → the serve loop maintains per-slot refill marks and calls
    /// [`Self::prefill`] before a step whenever any slot was
    /// (re)written.
    fn needs_prefill(&self) -> bool {
        false
    }
    /// (Re)populate cache rows with `refill[s] > 0` from the token
    /// buffer; other rows pass through untouched.
    fn prefill(&mut self, _tokens: &[i32], _pos: &[i32],
               _refill: &[f32]) -> anyhow::Result<()> {
        Ok(())
    }
    /// Logits for every row read at its `pos` (flat `B * vocab`).
    fn step(&mut self, tokens: &[i32], pos: &[i32])
            -> anyhow::Result<Vec<f32>>;
}

/// Literal-resident backend: full-context recompute per step.
struct LiteralBackend<'e, 'a> {
    engine: &'e DecodeEngine<'a>,
}

impl LogitsBackend for LiteralBackend<'_, '_> {
    fn dims(&self) -> (usize, usize, usize) {
        (self.engine.decode_batch(), self.engine.ctx_len(),
         self.engine.vocab())
    }

    fn step(&mut self, tokens: &[i32], pos: &[i32])
            -> anyhow::Result<Vec<f32>> {
        self.engine.step_logits(tokens, pos)
    }
}

/// KV-resident backend: per-layer caches as session-state literals,
/// advanced by the incremental `decode_step` artifact. Each row steps
/// by its token at `pos` (for a freshly prefilled row that re-derives
/// the prompt tail's K/V — same values — and yields the same logits
/// the prefill already read; uniformity keeps every emitted logit on
/// the incremental program).
struct KvBackend<'e, 'a> {
    engine: &'e DecodeEngine<'a>,
    state: SessionState,
    next_tok: Vec<i32>,
}

impl LogitsBackend for KvBackend<'_, '_> {
    fn dims(&self) -> (usize, usize, usize) {
        (self.engine.decode_batch(), self.engine.ctx_len(),
         self.engine.vocab())
    }

    fn needs_prefill(&self) -> bool {
        true
    }

    fn prefill(&mut self, tokens: &[i32], pos: &[i32], refill: &[f32])
               -> anyhow::Result<()> {
        self.engine.kv_prefill(&mut self.state, tokens, pos, refill)?;
        Ok(())
    }

    fn step(&mut self, tokens: &[i32], pos: &[i32])
            -> anyhow::Result<Vec<f32>> {
        let t = self.engine.ctx_len();
        for (s, nt) in self.next_tok.iter_mut().enumerate() {
            *nt = tokens[s * t + pos[s] as usize];
        }
        self.engine.kv_step(&mut self.state, &self.next_tok, pos)
    }
}

/// The serve loop's notion of time: real on the untimed path, a
/// deterministic per-invocation accumulator under a [`Schedule`].
enum Clock {
    Wall,
    Virtual { now_ms: f64, step_ms: f64, prefill_ms: f64 },
}

impl Clock {
    fn now_ms(&self, t0: &Instant) -> f64 {
        match self {
            Clock::Wall => t0.elapsed().as_secs_f64() * 1e3,
            Clock::Virtual { now_ms, .. } => *now_ms,
        }
    }

    fn on_step(&mut self) {
        if let Clock::Virtual { now_ms, step_ms, .. } = self {
            *now_ms += *step_ms;
        }
    }

    fn on_prefill(&mut self) {
        if let Clock::Virtual { now_ms, prefill_ms, .. } = self {
            *now_ms += *prefill_ms;
        }
    }

    /// Idle jump: nothing is decoding and nothing has arrived yet.
    fn jump_to(&mut self, t: f64) {
        if let Clock::Virtual { now_ms, .. } = self {
            *now_ms = now_ms.max(t);
        }
    }
}

/// Admission queue: request indices ordered by (arrival, index), with
/// closed-loop successors gated at infinity until their predecessor's
/// completion releases them.
struct ArrivalQueue {
    arrivals: Vec<f64>,
    release: Vec<Option<(usize, f64)>>,
    /// Not-yet-admitted request indices, sorted by (arrival, index);
    /// gated (infinite-arrival) entries sit at the tail.
    waiting: Vec<usize>,
}

impl ArrivalQueue {
    fn new(n: usize, schedule: Option<&Schedule>) -> ArrivalQueue {
        let (arrivals, release) = match schedule {
            Some(s) => (s.arrivals.clone(), s.release.clone()),
            None => (vec![0.0; n], vec![None; n]),
        };
        let mut waiting: Vec<usize> = (0..n).collect();
        waiting.sort_by(|&a, &b| {
            arrivals[a].partial_cmp(&arrivals[b]).unwrap()
                .then(a.cmp(&b))
        });
        ArrivalQueue { arrivals, release, waiting }
    }

    fn arrival_of(&self, i: usize) -> f64 {
        self.arrivals[i]
    }

    /// Head of the queue if it has arrived by `now`.
    fn pop_ready(&mut self, now: f64) -> Option<usize> {
        let ready = matches!(self.waiting.first(),
                             Some(&i) if self.arrivals[i] <= now);
        if ready {
            Some(self.waiting.remove(0))
        } else {
            None
        }
    }

    /// Earliest pending arrival, if any is finite (i.e. not gated).
    fn next_arrival(&self) -> Option<f64> {
        self.waiting.first()
            .map(|&i| self.arrivals[i])
            .filter(|a| a.is_finite())
    }

    fn is_empty(&self) -> bool {
        self.waiting.is_empty()
    }

    /// Completion hook: release request `i`'s closed-loop successor.
    fn on_complete(&mut self, i: usize, now: f64) {
        if let Some((j, think)) = self.release[i] {
            debug_assert!(self.arrivals[j] == f64::INFINITY,
                          "successor released twice");
            let at = now + think;
            self.arrivals[j] = at;
            // reposition j from the gated tail to its sorted slot
            self.waiting.retain(|&w| w != j);
            let idx = self.waiting
                .iter()
                .position(|&w| {
                    let (aw, ai) = (self.arrivals[w], self.arrivals[j]);
                    aw > ai || (aw == ai && w > j)
                })
                .unwrap_or(self.waiting.len());
            self.waiting.insert(idx, j);
        }
    }
}

/// A batch slot currently decoding one request. The slot's cursor
/// lives only in the shared `pos` buffer fed to the backend — a
/// slot-local copy would have to be advanced in lockstep and has
/// already caused one logits-read-at-stale-position bug.
struct Slot {
    req: usize, // index into `requests`
    out: Vec<u32>,
    entered_step: u64,
    /// Clock reading at slot entry.
    admit_ms: f64,
    /// Clock reading when the first token was emitted.
    first_tok_ms: Option<f64>,
}

/// Write a request's prompt into row `slot` of the token buffer,
/// clearing stale tokens from the previous occupant first (junk
/// *before* `pos` would leak into the new request's context).
/// `serve` validates up front that the prompt is non-empty and fits
/// the row (`len < t`).
fn fill_slot(
    tokens: &mut [i32],
    pos: &mut [i32],
    t: usize,
    slot: usize,
    prompt: &[u32],
) {
    debug_assert!(!prompt.is_empty() && prompt.len() < t,
                  "serve() validates prompt lengths up front");
    let row = &mut tokens[slot * t..(slot + 1) * t];
    row.fill(0);
    for (j, &tok) in prompt.iter().enumerate() {
        row[j] = tok as i32;
    }
    pos[slot] = prompt.len() as i32 - 1;
}

/// Run a request stream to completion through the engine's
/// literal-resident path (`logits_last`: full-context recompute per
/// step). Requests enter slots in order; each finished slot is
/// refilled from the queue before the next model step. `dp` supplies
/// the sampling knobs (`no_repeat_ngram`); generation budgets come
/// from each request's `max_new_tokens`, not `dp.max_new_tokens`.
pub fn serve(
    engine: &DecodeEngine,
    requests: &[DecodeRequest],
    dp: &DecodeParams,
) -> anyhow::Result<ServeReport> {
    serve_with(engine, requests, dp, false, None)
}

/// [`serve`] over the KV-resident incremental path: a slot's cache is
/// populated once per (re)fill by the `prefill` artifact, then every
/// step runs `decode_step` — only `(B,)` token/pos vectors cross the
/// host boundary and per-token model work is O(1) in the context
/// length. Greedy output is bit-identical to [`serve`] and to
/// [`super::reference::greedy`] (integration-tested, including across
/// slot refills). Errors if the KV artifacts were not compiled.
pub fn serve_kv(
    engine: &DecodeEngine,
    requests: &[DecodeRequest],
    dp: &DecodeParams,
) -> anyhow::Result<ServeReport> {
    serve_with(engine, requests, dp, true, None)
}

/// Arrival-gated serving on the virtual clock — the `loadgen`
/// simulation driver. Decoded tokens are exactly what [`serve`] /
/// [`serve_kv`] produce for the same prompts; only admission timing
/// and the reported `*_ms` telemetry differ. Deterministic for a
/// given request list + schedule.
pub fn serve_timed(
    engine: &DecodeEngine,
    requests: &[DecodeRequest],
    dp: &DecodeParams,
    use_kv: bool,
    schedule: &Schedule,
) -> anyhow::Result<ServeReport> {
    serve_with(engine, requests, dp, use_kv, Some(schedule))
}

/// One backend-construction site for every public entry point.
fn serve_with(
    engine: &DecodeEngine,
    requests: &[DecodeRequest],
    dp: &DecodeParams,
    use_kv: bool,
    schedule: Option<&Schedule>,
) -> anyhow::Result<ServeReport> {
    if use_kv {
        let mut backend = KvBackend {
            engine,
            state: engine.kv_state()?,
            next_tok: vec![0i32; engine.decode_batch()],
        };
        run_loop(&mut backend, requests, dp, schedule)
    } else {
        let mut backend = LiteralBackend { engine };
        run_loop(&mut backend, requests, dp, schedule)
    }
}

/// One slot-refill state machine for every decode path. The host-side
/// bookkeeping (token buffer, positions, EOS/length-cap edges, refill
/// order, admission, telemetry) is identical across backends; the
/// paths differ only in how a step's logits are produced, so any
/// divergence between them is a model-side bug by construction.
pub(crate) fn run_loop(
    backend: &mut dyn LogitsBackend,
    requests: &[DecodeRequest],
    dp: &DecodeParams,
    schedule: Option<&Schedule>,
) -> anyhow::Result<ServeReport> {
    let (b, t, vocab) = backend.dims();
    anyhow::ensure!(requests.iter().all(|r| !r.prompt.is_empty()),
                    "empty prompt in decode request stream");
    anyhow::ensure!(
        requests.iter().all(|r| r.prompt.len() < t),
        "prompt longer than ctx_len - 1 ({}) in decode request \
         stream — pre-truncate (keeping the tail) with \
         coordinator::prompt_tokens",
        t - 1
    );
    if let Some(s) = schedule {
        s.validate(requests.len())?;
    }

    let t0 = Instant::now();
    let mut clock = match schedule {
        Some(s) => Clock::Virtual {
            now_ms: 0.0,
            step_ms: s.step_ms,
            prefill_ms: s.prefill_ms,
        },
        None => Clock::Wall,
    };
    let mut queue = ArrivalQueue::new(requests.len(), schedule);
    let mut tokens = vec![0i32; b * t];
    let mut pos = vec![0i32; b];
    let mut slots: Vec<Option<Slot>> = (0..b).map(|_| None).collect();
    let mut results: Vec<RequestResult> =
        Vec::with_capacity(requests.len());
    let mut engine_steps = 0u64;
    let mut slot_steps = 0u64;
    let mut prefill_steps = 0u64;

    // KV path: `refill` marks rows whose cache must be (re)populated
    // from the token buffer before the next step.
    let needs_prefill = backend.needs_prefill();
    let mut refill = vec![0f32; b];
    let mut any_refill = false;

    loop {
        // Admission: fill every free slot from the ready queue.
        // Zero-budget requests complete the moment they reach the
        // queue head (greedy with `max_new_tokens == 0` decodes
        // nothing) and never occupy a slot.
        let now = clock.now_ms(&t0);
        for s in 0..b {
            if slots[s].is_some() {
                continue;
            }
            while let Some(i) = queue.pop_ready(now) {
                if requests[i].max_new_tokens == 0 {
                    let arrival = queue.arrival_of(i);
                    results.push(RequestResult {
                        id: requests[i].id,
                        tokens: Vec::new(),
                        queue_steps: engine_steps,
                        decode_steps: 0,
                        arrival_ms: arrival,
                        queue_ms: now - arrival,
                        ttft_ms: now - arrival,
                        latency_ms: now - arrival,
                    });
                    queue.on_complete(i, now);
                    continue;
                }
                fill_slot(&mut tokens, &mut pos, t, s,
                          &requests[i].prompt);
                if needs_prefill {
                    refill[s] = 1.0;
                    any_refill = true;
                }
                slots[s] = Some(Slot {
                    req: i,
                    out: Vec::new(),
                    entered_step: engine_steps,
                    admit_ms: now,
                    first_tok_ms: None,
                });
                break;
            }
        }

        if slots.iter().all(|s| s.is_none()) {
            if queue.is_empty() {
                break;
            }
            match queue.next_arrival() {
                // idle: nothing decoding, next arrival in the future
                Some(next) => {
                    clock.jump_to(next);
                    continue;
                }
                None => anyhow::bail!(
                    "request queue deadlocked: gated requests remain \
                     but nothing will release them"
                ),
            }
        }

        let occupied = slots.iter().filter(|s| s.is_some()).count();
        if needs_prefill && any_refill {
            // populate the marked rows' caches (positions up to and
            // including `pos`) from their prompt rows; other rows
            // pass through untouched
            backend.prefill(&tokens, &pos, &refill)?;
            prefill_steps += 1;
            refill.fill(0.0);
            any_refill = false;
            clock.on_prefill();
        }
        let lv = backend.step(&tokens, &pos)?;
        engine_steps += 1;
        slot_steps += occupied as u64;
        clock.on_step();
        let now = clock.now_ms(&t0);

        for s in 0..b {
            let finished = {
                let Some(slot) = slots[s].as_mut() else { continue };
                let max_new = requests[slot.req].max_new_tokens;
                let row = &lv[s * vocab..(s + 1) * vocab];
                let cur = pos[s] as usize;
                let ctx: Vec<u32> = if dp.no_repeat_ngram > 0 {
                    (0..=cur).map(|j| tokens[s * t + j] as u32)
                        .collect()
                } else {
                    Vec::new()
                };
                let next = topk::pick_next(row, &ctx,
                                           dp.no_repeat_ngram);
                let new_pos = cur + 1;
                let done = if next == EOS || new_pos >= t - 1 {
                    if next != EOS && new_pos < t {
                        slot.out.push(next);
                    }
                    true
                } else {
                    tokens[s * t + new_pos] = next as i32;
                    pos[s] = new_pos as i32;
                    slot.out.push(next);
                    slot.out.len() >= max_new
                };
                if slot.first_tok_ms.is_none() && !slot.out.is_empty() {
                    slot.first_tok_ms = Some(now);
                }
                done
            };
            if finished {
                let slot = slots[s].take().unwrap();
                let arrival = queue.arrival_of(slot.req);
                results.push(RequestResult {
                    id: requests[slot.req].id,
                    queue_steps: slot.entered_step,
                    decode_steps: engine_steps - slot.entered_step,
                    arrival_ms: arrival,
                    queue_ms: slot.admit_ms - arrival,
                    ttft_ms: slot.first_tok_ms.unwrap_or(now)
                        - arrival,
                    latency_ms: now - arrival,
                    tokens: slot.out,
                });
                queue.on_complete(slot.req, now);
                // the freed slot refills from the queue at the top of
                // the next iteration, before the next model step
            }
        }
    }

    results.sort_by_key(|r| r.id);
    let wall_secs = t0.elapsed().as_secs_f64();
    let sim_ms = clock.now_ms(&t0);
    let generated_tokens: u64 =
        results.iter().map(|r| r.tokens.len() as u64).sum();
    let collect = |f: fn(&RequestResult) -> f64| -> Summary {
        summarize(&results.iter().map(f).collect::<Vec<f64>>())
    };
    let stats = ServeStats {
        requests: requests.len(),
        decode_batch: b,
        engine_steps,
        prefill_steps,
        slot_steps,
        occupancy: if engine_steps == 0 {
            0.0
        } else {
            slot_steps as f64 / (engine_steps * b as u64) as f64
        },
        generated_tokens,
        wall_secs,
        tokens_per_sec: if wall_secs > 0.0 {
            generated_tokens as f64 / wall_secs
        } else {
            0.0
        },
        mean_step_ms: if engine_steps == 0 {
            0.0
        } else {
            wall_secs * 1e3 / engine_steps as f64
        },
        sim_ms,
        queue_ms: collect(|r| r.queue_ms),
        ttft_ms: collect(|r| r.ttft_ms),
        latency_ms: collect(|r| r.latency_ms),
    };
    Ok(ServeReport { results, stats })
}

#[cfg(test)]
pub(crate) mod mock {
    //! Deterministic artifact-free backends for queueing/clock tests
    //! (also used by `generate::loadgen` unit tests).

    use super::LogitsBackend;

    /// Emits logits whose argmax is always `tok` (never EOS), so
    /// generation length is exactly each request's budget; counts
    /// prefill passes when `kv` is set.
    pub struct MockBackend {
        pub b: usize,
        pub t: usize,
        pub vocab: usize,
        pub tok: usize,
        pub kv: bool,
        pub prefills: u64,
    }

    impl MockBackend {
        pub fn new(b: usize, t: usize, kv: bool) -> MockBackend {
            MockBackend { b, t, vocab: 16, tok: 5, kv, prefills: 0 }
        }
    }

    impl LogitsBackend for MockBackend {
        fn dims(&self) -> (usize, usize, usize) {
            (self.b, self.t, self.vocab)
        }

        fn needs_prefill(&self) -> bool {
            self.kv
        }

        fn prefill(&mut self, _tokens: &[i32], _pos: &[i32],
                   _refill: &[f32]) -> anyhow::Result<()> {
            self.prefills += 1;
            Ok(())
        }

        fn step(&mut self, _tokens: &[i32], _pos: &[i32])
                -> anyhow::Result<Vec<f32>> {
            let mut lv = vec![0.0f32; self.b * self.vocab];
            for s in 0..self.b {
                lv[s * self.vocab + self.tok] = 1.0;
            }
            Ok(lv)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::mock::MockBackend;
    use super::*;

    fn reqs(budgets: &[usize]) -> Vec<DecodeRequest> {
        budgets.iter().enumerate()
            .map(|(i, &m)| DecodeRequest::new(i as u64, vec![1, 9, 3],
                                              m))
            .collect()
    }

    fn sched(arrivals: &[f64], step_ms: f64) -> Schedule {
        Schedule::open(arrivals.to_vec(), step_ms, step_ms)
    }

    #[test]
    fn fill_slot_clears_previous_occupant() {
        let t = 8;
        let mut tokens = vec![7i32; 2 * t];
        let mut pos = vec![5i32; 2];
        fill_slot(&mut tokens, &mut pos, t, 1, &[9, 10]);
        assert_eq!(pos[1], 1);
        assert_eq!(&tokens[t..], &[9, 10, 0, 0, 0, 0, 0, 0]);
        // row 0 untouched
        assert!(tokens[..t].iter().all(|&x| x == 7));
    }

    #[test]
    fn fill_slot_max_length_prompt_fits() {
        // longest prompt serve() admits: t - 1 tokens, pos on the last
        let t = 4;
        let mut tokens = vec![0i32; t];
        let mut pos = vec![0i32; 1];
        fill_slot(&mut tokens, &mut pos, t, 0, &[1, 2, 3]);
        assert_eq!(pos[0], 2);
        assert_eq!(tokens, vec![1, 2, 3, 0]);
    }

    #[test]
    fn stats_json_has_core_fields() {
        let mut stats = ServeStats {
            requests: 3,
            decode_batch: 2,
            engine_steps: 10,
            prefill_steps: 2,
            slot_steps: 17,
            occupancy: 0.85,
            generated_tokens: 15,
            wall_secs: 0.5,
            tokens_per_sec: 30.0,
            mean_step_ms: 50.0,
            sim_ms: 500.0,
            queue_ms: Summary::zero(),
            ttft_ms: Summary::zero(),
            latency_ms: summarize(&[200.0, 300.0, 450.0]),
        };
        stats.latency_ms.p95 = 440.0;
        let j = stats.to_json();
        assert_eq!(j.get("tokens_per_sec").unwrap().as_f64(),
                   Some(30.0));
        assert_eq!(j.get("occupancy").unwrap().as_f64(), Some(0.85));
        assert_eq!(j.get("engine_steps").unwrap().as_usize(), Some(10));
        assert_eq!(j.get("prefill_steps").unwrap().as_usize(), Some(2));
        let lat = j.get("latency_ms").unwrap();
        assert_eq!(lat.get("p95").unwrap().as_f64(), Some(440.0));
        assert_eq!(lat.get("p50").unwrap().as_f64(), Some(300.0));
    }

    #[test]
    fn untimed_mock_serve_fifo_and_occupancy() {
        // 5 requests through 2 slots: FIFO assignment, full stats
        let mut be = MockBackend::new(2, 16, false);
        let requests = reqs(&[3, 3, 2, 2, 1]);
        let report = run_loop(&mut be, &requests,
                              &DecodeParams::default(), None).unwrap();
        assert_eq!(report.results.len(), 5);
        for (i, r) in report.results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.tokens.len(), requests[i].max_new_tokens);
            assert!(r.tokens.iter().all(|&t| t == 5));
        }
        let st = &report.stats;
        // steps: slots run [3,3] then [2,2] then [1] → 6 engine steps,
        // slot_steps = 3+3+2+2+1 = 11
        assert_eq!(st.engine_steps, 6);
        assert_eq!(st.slot_steps, 11);
        assert_eq!(st.generated_tokens, 11);
        assert!((st.occupancy - 11.0 / 12.0).abs() < 1e-12);
        // later requests queued
        assert_eq!(report.results[4].queue_steps, 5);
    }

    #[test]
    fn timed_serve_waits_for_arrivals_and_jumps_idle_gaps() {
        let mut be = MockBackend::new(2, 16, false);
        let requests = reqs(&[3, 3, 3, 3]);
        let s = sched(&[0.0, 0.0, 10.0, 10.0], 1.0);
        let report = run_loop(&mut be, &requests,
                              &DecodeParams::default(), Some(&s))
            .unwrap();
        let r = &report.results;
        // first wave: admit at 0, one token per 1ms step, done at 3
        assert_eq!(r[0].queue_ms, 0.0);
        assert_eq!(r[0].ttft_ms, 1.0);
        assert_eq!(r[0].latency_ms, 3.0);
        // second wave: clock jumps the idle gap to t=10
        assert_eq!(r[2].arrival_ms, 10.0);
        assert_eq!(r[2].queue_ms, 0.0);
        assert_eq!(r[2].latency_ms, 3.0);
        assert_eq!(report.stats.engine_steps, 6);
        assert_eq!(report.stats.sim_ms, 13.0);
        // no slot idled while work was pending
        assert!((report.stats.occupancy - 1.0).abs() < 1e-12);
    }

    #[test]
    fn timed_serve_records_queue_wait_under_saturation() {
        // one slot, three simultaneous arrivals: head-of-line blocking
        let mut be = MockBackend::new(1, 16, false);
        let requests = reqs(&[2, 2, 2]);
        let s = sched(&[0.0, 0.0, 0.0], 1.0);
        let report = run_loop(&mut be, &requests,
                              &DecodeParams::default(), Some(&s))
            .unwrap();
        let r = &report.results;
        assert_eq!(
            r.iter().map(|x| x.queue_ms).collect::<Vec<_>>(),
            vec![0.0, 2.0, 4.0]
        );
        assert_eq!(
            r.iter().map(|x| x.latency_ms).collect::<Vec<_>>(),
            vec![2.0, 4.0, 6.0]
        );
        assert_eq!(
            r.iter().map(|x| x.queue_steps).collect::<Vec<_>>(),
            vec![0, 2, 4]
        );
        assert_eq!(report.stats.latency_ms.p50, 4.0);
    }

    #[test]
    fn timed_serve_closed_loop_releases_successor() {
        let mut be = MockBackend::new(1, 16, false);
        let requests = reqs(&[1, 1]);
        let s = Schedule {
            arrivals: vec![0.0, f64::INFINITY],
            release: vec![Some((1, 5.0)), None],
            step_ms: 1.0,
            prefill_ms: 1.0,
        };
        let report = run_loop(&mut be, &requests,
                              &DecodeParams::default(), Some(&s))
            .unwrap();
        let r = &report.results;
        // request 0 completes at t=1; successor arrives at 1 + 5
        assert_eq!(r[1].arrival_ms, 6.0);
        assert_eq!(r[1].queue_ms, 0.0);
        assert_eq!(r[1].latency_ms, 1.0);
        assert_eq!(report.stats.sim_ms, 7.0);
    }

    #[test]
    fn timed_serve_zero_budget_completes_at_arrival() {
        let mut be = MockBackend::new(1, 16, false);
        let requests = reqs(&[2, 0]);
        let s = sched(&[0.0, 5.0], 1.0);
        let report = run_loop(&mut be, &requests,
                              &DecodeParams::default(), Some(&s))
            .unwrap();
        let r = &report.results;
        assert_eq!(r[0].latency_ms, 2.0);
        assert!(r[1].tokens.is_empty());
        assert_eq!(r[1].arrival_ms, 5.0);
        assert_eq!(r[1].latency_ms, 0.0);
        assert_eq!(r[1].decode_steps, 0);
    }

    #[test]
    fn timed_serve_kv_prefill_costs_virtual_time() {
        let mut be = MockBackend::new(2, 16, true);
        let requests = reqs(&[2, 2, 2]);
        let s = sched(&[0.0, 0.0, 0.0], 1.0);
        let report = run_loop(&mut be, &requests,
                              &DecodeParams::default(), Some(&s))
            .unwrap();
        // initial fill: one prefill; request 2's refill: another
        assert_eq!(be.prefills, 2);
        assert_eq!(report.stats.prefill_steps, 2);
        let r = &report.results;
        // wave 1: prefill(1) + step(2) + step(3) → done at 3
        assert_eq!(r[0].latency_ms, 3.0);
        // request 2 admitted at 3, prefill(4) + step(5) + step(6)
        assert_eq!(r[2].queue_ms, 3.0);
        assert_eq!(r[2].latency_ms, 6.0);
    }

    #[test]
    fn timed_serve_is_deterministic() {
        let requests = reqs(&[3, 1, 4, 2, 2, 3, 1]);
        let s = sched(&[0.0, 0.5, 0.5, 2.0, 2.25, 7.0, 7.0], 0.75);
        let run = || {
            let mut be = MockBackend::new(2, 16, false);
            run_loop(&mut be, &requests, &DecodeParams::default(),
                     Some(&s)).unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.results.len(), b.results.len());
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(
                (x.arrival_ms, x.queue_ms, x.ttft_ms, x.latency_ms),
                (y.arrival_ms, y.queue_ms, y.ttft_ms, y.latency_ms)
            );
        }
        assert_eq!(a.stats.engine_steps, b.stats.engine_steps);
        assert_eq!(a.stats.sim_ms, b.stats.sim_ms);
        assert_eq!(a.stats.latency_ms, b.stats.latency_ms);
    }

    #[test]
    fn schedule_validation_rejects_bad_shapes() {
        let requests = reqs(&[1, 1]);
        let mut be = MockBackend::new(1, 16, false);
        // wrong arrival count
        let s = Schedule::open(vec![0.0], 1.0, 1.0);
        assert!(run_loop(&mut be, &requests, &DecodeParams::default(),
                         Some(&s)).is_err());
        // gated request that nothing releases
        let s = Schedule {
            arrivals: vec![0.0, f64::INFINITY],
            release: vec![None, None],
            step_ms: 1.0,
            prefill_ms: 1.0,
        };
        assert!(run_loop(&mut be, &requests, &DecodeParams::default(),
                         Some(&s)).is_err());
        // double release
        let s = Schedule {
            arrivals: vec![0.0, 0.0, f64::INFINITY],
            release: vec![Some((2, 0.0)), Some((2, 0.0)), None],
            step_ms: 1.0,
            prefill_ms: 1.0,
        };
        assert!(run_loop(&mut be, &reqs(&[1, 1, 1]),
                         &DecodeParams::default(), Some(&s)).is_err());
        // -inf arrival: would be admitted immediately AND re-queued
        // by its release (decoded twice) — must be rejected
        let s = Schedule {
            arrivals: vec![0.0, f64::NEG_INFINITY],
            release: vec![Some((1, 5.0)), None],
            step_ms: 1.0,
            prefill_ms: 1.0,
        };
        assert!(run_loop(&mut be, &requests, &DecodeParams::default(),
                         Some(&s)).is_err());
        // NaN arrival rejected too
        let s = Schedule::open(vec![0.0, f64::NAN], 1.0, 1.0);
        assert!(run_loop(&mut be, &requests, &DecodeParams::default(),
                         Some(&s)).is_err());
    }
}
