//! Compatibility shim: continuous slot-refill batching now lives in
//! the [`super::serve`] module tree (`core` — the backend-agnostic
//! state machine, `policy` — scheduling, `admission` — load shedding,
//! `clock`, `telemetry`). This module re-exports the pre-split names
//! so existing call sites (`main.rs`, benches, tests, downstream
//! users of `generate::batching::*`) compile unchanged.
//!
//! New code should import from [`super::serve`] directly — in
//! particular the policy-aware entry point
//! [`serve_with`]/[`ServeConfig`], which this shim forwards too.

pub use super::serve::clock::Schedule;
pub use super::serve::core::{serve, serve_kv, serve_timed, serve_with,
                             ServeConfig};
pub use super::serve::telemetry::{RequestOutcome, RequestResult,
                                  ServeReport, ServeStats};
pub use super::serve::DecodeRequest;
