//! Partial top-k selection over a logit row.
//!
//! The decode hot loop previously full-sorted the vocabulary
//! (O(V log V)) per batch slot per step just to read off the argmax or
//! the 2k beam candidates. This module provides the O(V + k log k)
//! replacement: `select_nth_unstable_by` partitions the top k in linear
//! time, then only those k entries are sorted.
//!
//! Ordering contract: entries are ranked by (logit descending, index
//! ascending). A *stable* descending sort over the full vocab — what
//! `generate::reference` does — produces exactly this order, because
//! stability preserves the ascending index order of tied values. So
//! `top_k(row, k)` is bit-identical to the first k entries of the old
//! full sort, ties included, and `argmax` to its first entry.

use std::cmp::Ordering;

/// Descending-by-value, ascending-by-index total order. Logits are
/// finite by construction; a NaN means the model diverged and we panic
/// exactly like the old `partial_cmp(..).unwrap()` sort did.
#[inline]
fn cmp_desc(row: &[f32], a: u32, b: u32) -> Ordering {
    // lint:allow(float-sort) must keep the frozen oracle's exact tie
    // semantics (±0.0 compare Equal, index breaks the tie); invariant:
    // logits are finite by construction, NaN panics by contract
    row[b as usize]
        .partial_cmp(&row[a as usize])
        .expect("NaN logit in decode")
        .then(a.cmp(&b))
}

/// Indices of the k largest logits, ordered (value desc, index asc).
/// Equals the length-k prefix of a stable full descending sort.
pub fn top_k(row: &[f32], k: usize) -> Vec<u32> {
    let v = row.len();
    let k = k.min(v);
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<u32> = (0..v as u32).collect();
    if k < v {
        idx.select_nth_unstable_by(k - 1, |&a, &b| cmp_desc(row, a, b));
        idx.truncate(k);
    }
    idx.sort_unstable_by(|&a, &b| cmp_desc(row, a, b));
    idx
}

/// Index of the largest logit (smallest index wins ties) — the k=1
/// special case, done in one linear scan with no allocation.
pub fn argmax(row: &[f32]) -> u32 {
    debug_assert!(!row.is_empty());
    let mut best = 0u32;
    for (i, &x) in row.iter().enumerate().skip(1) {
        // lint:allow(float-sort) same tie/panic contract as cmp_desc;
        // invariant: logits are finite by construction
        if x.partial_cmp(&row[best as usize])
            .expect("NaN logit in decode")
            == Ordering::Greater
        {
            best = i as u32;
        }
    }
    best
}

/// How many candidates greedy decode tries before falling through the
/// full order (the historical "top-8" window).
pub const GREEDY_BLOCK_WINDOW: usize = 8;

/// Greedy next-token choice under n-gram blocking: the first of the
/// top-`GREEDY_BLOCK_WINDOW` candidates that does not repeat an n-gram;
/// if all of them are blocked, fall through the *full* candidate order
/// (this used to silently return the blocked argmax). If every token in
/// the vocabulary is blocked, the argmax is returned — emitting the
/// least-bad token beats emitting an arbitrary one.
pub fn pick_next(
    row: &[f32],
    ctx: &[u32],
    no_repeat_ngram: usize,
) -> u32 {
    if no_repeat_ngram == 0 {
        return argmax(row);
    }
    let head = top_k(row, GREEDY_BLOCK_WINDOW);
    for &cand in &head {
        if !super::repeats_ngram(ctx, cand, no_repeat_ngram) {
            return cand;
        }
    }
    let full = top_k(row, row.len());
    for &cand in &full[head.len()..] {
        if !super::repeats_ngram(ctx, cand, no_repeat_ngram) {
            return cand;
        }
    }
    full[0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The oracle: the old full stable descending sort.
    fn full_sort_desc(row: &[f32]) -> Vec<u32> {
        let mut order: Vec<u32> = (0..row.len() as u32).collect();
        order.sort_by(|&a, &b| {
            row[b as usize].partial_cmp(&row[a as usize]).unwrap()
        });
        order
    }

    #[test]
    fn matches_full_sort_on_simple_row() {
        let row = [0.1f32, 3.0, -1.0, 3.0, 2.0];
        // ties at 3.0: stable sort keeps index order 1 before 3
        assert_eq!(top_k(&row, 3), vec![1, 3, 4]);
        assert_eq!(top_k(&row, 5), full_sort_desc(&row));
        assert_eq!(argmax(&row), 1);
    }

    #[test]
    fn k_zero_and_k_beyond_len() {
        let row = [1.0f32, 2.0];
        assert!(top_k(&row, 0).is_empty());
        assert_eq!(top_k(&row, 10), vec![1, 0]);
    }

    #[test]
    fn property_topk_is_full_sort_prefix() {
        // random logits, including heavy ties (values snapped to a
        // small grid), across k = 1..V
        crate::util::proptest::check(
            7, 64, 40,
            |rng: &mut Rng, size: usize| {
                let v = 2 + rng.below(size.max(2) * 8);
                let snap = rng.below(2) == 0;
                let row: Vec<f32> = (0..v)
                    .map(|_| {
                        let x = rng.normal_f32(0.0, 1.0);
                        if snap { (x * 4.0).round() / 4.0 } else { x }
                    })
                    .collect();
                let k = 1 + rng.below(v);
                (row, k)
            },
            |(row, k)| {
                let oracle = full_sort_desc(row);
                top_k(row, *k) == oracle[..*k]
                    && argmax(row) == oracle[0]
            },
        );
    }

    #[test]
    fn property_beam_expansion_candidates_match() {
        // beam search takes the first 2k of the full sort; top_k must
        // reproduce that window exactly
        crate::util::proptest::check(
            11, 48, 32,
            |rng: &mut Rng, size: usize| {
                let v = 4 + rng.below(size.max(4) * 8);
                let row: Vec<f32> = (0..v)
                    .map(|_| ((rng.below(9) as f32) - 4.0) * 0.5)
                    .collect();
                let k = 1 + rng.below(4);
                (row, k)
            },
            |(row, k)| {
                let want: Vec<u32> = full_sort_desc(row)
                    .into_iter()
                    .take(2 * k)
                    .collect();
                top_k(row, 2 * k) == want
            },
        );
    }

    #[test]
    fn blocked_window_falls_through_full_order() {
        // 16-token vocab, logits strictly descending by index, and the
        // context blocks (n=1) every one of the top-8 candidates: the
        // fixed fallback must yield token 8, not the blocked argmax 0.
        let row: Vec<f32> = (0..16).map(|i| 16.0 - i as f32).collect();
        let ctx: Vec<u32> = (0..8).collect();
        assert_eq!(pick_next(&row, &ctx, 1), 8);
        // unblocked head: argmax wins as before
        assert_eq!(pick_next(&row, &[12, 13], 1), 0);
        // blocking off: pure argmax
        assert_eq!(pick_next(&row, &ctx, 0), 0);
    }

    #[test]
    fn fully_blocked_vocab_returns_argmax() {
        let row: Vec<f32> = (0..4).map(|i| 4.0 - i as f32).collect();
        let ctx: Vec<u32> = vec![0, 1, 2, 3];
        assert_eq!(pick_next(&row, &ctx, 1), 0);
    }
}
