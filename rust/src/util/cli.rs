//! Declarative CLI flag parser (no `clap` offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! args, and generates usage text. Each binary declares its flags up
//! front; unknown flags are hard errors so typos don't silently run the
//! wrong experiment.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub takes_value: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

pub struct Cli {
    pub name: &'static str,
    pub about: &'static str,
    flags: Vec<FlagSpec>,
}

impl Cli {
    pub fn new(name: &'static str, about: &'static str) -> Cli {
        Cli { name, about, flags: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, default: &'static str,
                help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name, help, default: Some(default), takes_value: true,
        });
        self
    }

    pub fn flag_req(mut self, name: &'static str, help: &'static str)
                    -> Self {
        self.flags.push(FlagSpec {
            name, help, default: None, takes_value: true,
        });
        self
    }

    pub fn switch(mut self, name: &'static str, help: &'static str)
                  -> Self {
        self.flags.push(FlagSpec {
            name, help, default: None, takes_value: false,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nflags:\n", self.name, self.about);
        for f in &self.flags {
            let v = if f.takes_value { "=<v>" } else { "" };
            let d = f.default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{}{v:<8} {}{d}\n", f.name, f.help));
        }
        s
    }

    /// Parse a raw arg list (excluding argv[0]).
    pub fn parse(&self, raw: &[String]) -> anyhow::Result<Args> {
        let mut args = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                println!("{}", self.usage());
                std::process::exit(0);
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self.flags.iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| anyhow::anyhow!(
                        "unknown flag --{name}\n\n{}", self.usage()))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => it.next()
                            .ok_or_else(|| anyhow::anyhow!(
                                "--{name} needs a value"))?
                            .clone(),
                    };
                    args.values.insert(name, v);
                } else {
                    args.bools.insert(name, true);
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        // defaults
        for f in &self.flags {
            if f.takes_value && !args.values.contains_key(f.name) {
                if let Some(d) = f.default {
                    args.values.insert(f.name.to_string(), d.to_string());
                } else {
                    anyhow::bail!("missing required flag --{}\n\n{}",
                                  f.name, self.usage());
                }
            }
        }
        Ok(args)
    }

    /// Parse std::env::args() (skipping the binary name).
    pub fn parse_env(&self) -> anyhow::Result<Args> {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        self.parse(&raw)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or_else(|| {
            panic!("flag --{name} not declared");
        })
    }

    pub fn get_usize(&self, name: &str) -> anyhow::Result<usize> {
        self.get(name).parse()
            .map_err(|_| anyhow::anyhow!("--{name} must be an integer"))
    }

    pub fn get_u64(&self, name: &str) -> anyhow::Result<u64> {
        self.get(name).parse()
            .map_err(|_| anyhow::anyhow!("--{name} must be an integer"))
    }

    pub fn get_f64(&self, name: &str) -> anyhow::Result<f64> {
        self.get(name).parse()
            .map_err(|_| anyhow::anyhow!("--{name} must be a number"))
    }

    pub fn get_f32(&self, name: &str) -> anyhow::Result<f32> {
        Ok(self.get_f64(name)? as f32)
    }

    pub fn is_set(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }

    /// Comma-separated list flag.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        self.get(name).split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().to_string())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .flag("model", "gpt-nano", "model name")
            .flag("steps", "100", "steps")
            .flag_req("out", "output path")
            .switch("verbose", "log more")
    }

    fn s(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cli().parse(&s(&["--out", "/tmp/x", "--steps=250"]))
            .unwrap();
        assert_eq!(a.get("model"), "gpt-nano");
        assert_eq!(a.get_usize("steps").unwrap(), 250);
        assert_eq!(a.get("out"), "/tmp/x");
        assert!(!a.is_set("verbose"));
    }

    #[test]
    fn switch_and_positional() {
        let a = cli()
            .parse(&s(&["--out=o", "--verbose", "pos1", "pos2"]))
            .unwrap();
        assert!(a.is_set("verbose"));
        assert_eq!(a.positional, s(&["pos1", "pos2"]));
    }

    #[test]
    fn missing_required_fails() {
        assert!(cli().parse(&s(&["--model", "x"])).is_err());
    }

    #[test]
    fn unknown_flag_fails() {
        assert!(cli().parse(&s(&["--out=o", "--bogus"])).is_err());
    }

    #[test]
    fn list_flag() {
        let a = cli().parse(&s(&["--out=o", "--model", "a, b,c"]))
            .unwrap();
        assert_eq!(a.get_list("model"), s(&["a", "b", "c"]));
    }
}
