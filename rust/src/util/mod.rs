//! Substrate utilities built from scratch for the offline environment:
//! JSON, RNG, CLI parsing, statistics, thread pool and a tiny
//! property-testing driver (DESIGN.md §2 "Offline-environment
//! substitutions").

pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threads;

use std::time::Instant;

/// Wall-clock timer for coarse phase logging.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Timer {
        Timer(Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// Human-readable FLOP counts (paper tables use x10^18 "exaFLOPs").
pub fn fmt_flops(x: f64) -> String {
    if x >= 1e18 {
        format!("{:.2}e18", x / 1e18)
    } else if x >= 1e12 {
        format!("{:.2}e12", x / 1e12)
    } else if x >= 1e9 {
        format!("{:.2}e9", x / 1e9)
    } else {
        format!("{x:.3e}")
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn fmt_flops_scales() {
        assert_eq!(super::fmt_flops(2.48e18), "2.48e18");
        assert_eq!(super::fmt_flops(1.99e12), "1.99e12");
    }
}
