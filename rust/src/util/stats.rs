//! Small statistics toolkit: summary stats for benches and the
//! mean±std reporting the paper's tables use, plus Pearson/Spearman
//! correlation for the H2/H3 hypothesis checks.

use crate::util::json::Json;

/// Summary of a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// The empty-sample summary (`n == 0`, every statistic 0.0) —
    /// what `summarize(&[])` returns, so latency tables over an empty
    /// request stream render zeros instead of panicking.
    pub fn zero() -> Summary {
        Summary {
            n: 0,
            mean: 0.0,
            std: 0.0,
            min: 0.0,
            max: 0.0,
            p50: 0.0,
            p95: 0.0,
            p99: 0.0,
        }
    }

    /// JSON form used by the serve/loadgen stats blocks
    /// (`BENCH_decode.json`, `BENCH_serve_load.json`).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.push_num("n", self.n)
            .push_num("mean", self.mean)
            .push_num("min", self.min)
            .push_num("max", self.max)
            .push_num("p50", self.p50)
            .push_num("p95", self.p95)
            .push_num("p99", self.p99);
        j
    }
}

/// Summary statistics of a sample. An empty sample yields
/// [`Summary::zero`] rather than panicking (serving stats legitimately
/// aggregate zero requests).
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::zero();
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let mut sorted = xs.to_vec();
    // total_cmp: a NaN sample (e.g. a 0/0 rate from an empty bucket)
    // sorts to the tail instead of panicking mid-report
    sorted.sort_by(|a, b| a.total_cmp(b));
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile(&sorted, 0.50),
        p95: percentile(&sorted, 0.95),
        p99: percentile(&sorted, 0.99),
    }
}

/// Percentile by linear interpolation over a pre-sorted slice; `q` is
/// clamped to [0, 1]. An empty slice yields 0.0 (matching
/// [`summarize`]'s empty-sample convention).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (pos - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx).powi(2);
        vy += (y - my).powi(2);
    }
    if vx == 0.0 || vy == 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Spearman rank correlation.
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    pearson(&ranks(xs), &ranks(ys))
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
    let mut r = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        // average ties
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            r[idx[k]] = avg;
        }
        i = j + 1;
    }
    r
}

/// Format the paper's `mean ± std` cell.
pub fn pm(mean: f64, std: f64, digits: usize) -> String {
    format!("{mean:.d$} ±{std:.d$}", d = digits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert!((s.std - 1.5811).abs() < 1e-3);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.5), 5.0);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 10.0);
    }

    #[test]
    fn summarize_empty_is_zero() {
        let s = summarize(&[]);
        assert_eq!(s, Summary::zero());
        assert_eq!(s.n, 0);
        assert_eq!(percentile(&[], 0.95), 0.0);
    }

    #[test]
    fn summarize_single_element() {
        let s = summarize(&[7.5]);
        assert_eq!(s.n, 1);
        assert_eq!(s.mean, 7.5);
        assert_eq!(s.std, 0.0);
        assert_eq!((s.min, s.max), (7.5, 7.5));
        assert_eq!((s.p50, s.p95, s.p99), (7.5, 7.5, 7.5));
    }

    #[test]
    fn summarize_duplicate_heavy() {
        // 99 copies of 1.0 and one 100.0: the duplicates pin every
        // percentile up to p98; p99 interpolates into the outlier
        let mut xs = vec![1.0; 99];
        xs.push(100.0);
        let s = summarize(&xs);
        assert_eq!(s.p50, 1.0);
        assert_eq!(s.p95, 1.0);
        assert!(s.p99 > 1.0 && s.p99 < 100.0, "p99={}", s.p99);
        assert_eq!(s.max, 100.0);
        // all-identical sample: zero spread, every percentile equal
        let t = summarize(&vec![3.0; 40]);
        assert_eq!(t.std, 0.0);
        assert_eq!((t.p50, t.p95, t.p99), (3.0, 3.0, 3.0));
    }

    #[test]
    fn percentile_clamps_q() {
        let xs = [1.0, 2.0, 3.0];
        assert_eq!(percentile(&xs, -0.5), 1.0);
        assert_eq!(percentile(&xs, 1.5), 3.0);
    }

    #[test]
    fn summary_json_has_percentiles() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        let j = s.to_json();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("p50").unwrap().as_f64(), Some(2.5));
        assert!(j.get("p99").unwrap().as_f64().unwrap() > 3.9);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [3.0, 2.0, 1.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [1.0, 8.0, 27.0, 64.0];
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_ties() {
        let xs = [1.0, 1.0, 2.0];
        let r = ranks(&xs);
        assert_eq!(r, vec![1.5, 1.5, 3.0]);
    }

    #[test]
    fn summarize_nan_does_not_panic() {
        // regression (ISSUE 7): the percentile sort used
        // partial_cmp().unwrap() and panicked on a NaN sample (a 0/0
        // rate from an empty bucket); total_cmp orders NaN to the tail
        let s = summarize(&[2.0, f64::NAN, 1.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert!(s.max.is_nan());
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn ranks_nan_does_not_panic() {
        // same regression for the Spearman rank sort: NaN ranks last
        let r = ranks(&[3.0, f64::NAN, 1.0]);
        assert_eq!(r[2], 1.0);
        assert_eq!(r[0], 2.0);
        assert_eq!(r[1], 3.0);
    }

    #[test]
    fn pm_format() {
        assert_eq!(pm(67.49, 0.6, 2), "67.49 ±0.60");
    }
}
