//! Thread-pool substrate (no `tokio` offline).
//!
//! The coordinator's hot loop is synchronous compute (PJRT execute), so
//! async isn't load-bearing here; what we need is data-parallel helpers
//! for corpus generation, metric evaluation and the CSR matmul engine.
//! `parallel_map` fans work over `std::thread::scope` workers with a
//! shared atomic work queue (dynamic load balancing).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

/// Number of workers to use: respects SPDF_THREADS, else available
/// cores. Resolved **once per process** — the CSR matmul calls this per
/// chunk-size computation, and a getenv + parse on every matmul is
/// measurable noise. Set SPDF_THREADS before the first parallel call;
/// later changes to the variable are ignored (see rust/README.md).
pub fn worker_count() -> usize {
    static WORKERS: OnceLock<usize> = OnceLock::new();
    *WORKERS.get_or_init(|| {
        if let Ok(v) = std::env::var("SPDF_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
    })
}

/// Apply `f` to every index in [0, n) on a worker pool; results returned
/// in index order. `f` must be Sync (called concurrently).
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    parallel_map_workers(n, worker_count(), f)
}

pub fn parallel_map_workers<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.min(n).max(1);
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<T>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().unwrap())
        .collect()
}

/// Parallel chunked for-each over a mutable slice: each worker owns a
/// disjoint chunk (no locking on the data path). Used by the CSR matmul.
pub fn parallel_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() {
        return;
    }
    let chunk = chunk.max(1);
    std::thread::scope(|scope| {
        for (ci, part) in data.chunks_mut(chunk).enumerate() {
            let f = &f;
            scope.spawn(move || f(ci * chunk, part));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_count_positive_and_stable() {
        let a = worker_count();
        assert!(a >= 1);
        assert_eq!(a, worker_count());
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(1000, |i| i * 2);
        assert_eq!(out, (0..1000).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty_and_single() {
        assert!(parallel_map(0, |i| i).is_empty());
        assert_eq!(parallel_map(1, |i| i + 5), vec![5]);
    }

    #[test]
    fn parallel_map_worker_counts() {
        for w in [1, 2, 7, 64] {
            let out = parallel_map_workers(37, w, |i| i * i);
            assert_eq!(out, (0..37).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn chunks_mut_disjoint_writes() {
        let mut data = vec![0usize; 100];
        parallel_chunks_mut(&mut data, 7, |start, part| {
            for (k, x) in part.iter_mut().enumerate() {
                *x = start + k;
            }
        });
        assert_eq!(data, (0..100).collect::<Vec<_>>());
    }
}
