//! Tiny property-testing driver (no `proptest` crate offline).
//!
//! `check(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop` on each; on failure it performs a simple
//! halving-shrink over the generator's size parameter and reports the
//! smallest failing case's debug form. Used by the L3 invariant tests
//! (routing/batching/sparsity/metrics).

use super::rng::Rng;

/// A generator draws a value of size <= `size` from the rng.
pub trait Gen<T> {
    fn gen(&self, rng: &mut Rng, size: usize) -> T;
}

impl<T, F: Fn(&mut Rng, usize) -> T> Gen<T> for F {
    fn gen(&self, rng: &mut Rng, size: usize) -> T {
        self(rng, size)
    }
}

/// Run a property over `cases` random inputs. Panics with the smallest
/// failing input found (by shrinking the size parameter).
pub fn check<T: std::fmt::Debug, G: Gen<T>>(
    seed: u64,
    cases: usize,
    max_size: usize,
    gen: G,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        // ramp the size up over the run like proptest does
        let size = 1 + (max_size - 1) * case / cases.max(1);
        let input = gen.gen(&mut rng, size.max(1));
        if !prop(&input) {
            // shrink: re-draw at smaller sizes from a forked stream
            let mut smallest = input;
            let mut s = size;
            while s > 1 {
                s /= 2;
                // lint:allow(rng-discipline) not a feature
                // side-stream: the derivation is data-dependent
                // (size, case), which a named *_SALT constant cannot
                // express; shrink draws never feed a pinned trace
                let mut r2 = Rng::new(seed ^ (s as u64) << 32 | case as u64);
                for _ in 0..20 {
                    let candidate = gen.gen(&mut r2, s);
                    if !prop(&candidate) {
                        smallest = candidate;
                        break;
                    }
                }
            }
            panic!(
                "property failed (seed={seed}, case={case}); \
                 smallest failing input:\n{smallest:#?}"
            );
        }
    }
}

/// Common generator: vector of f64 in [-scale, scale].
pub fn vec_f64(scale: f64) -> impl Gen<Vec<f64>> {
    move |rng: &mut Rng, size: usize| {
        let n = 1 + rng.below(size);
        (0..n).map(|_| (rng.uniform() * 2.0 - 1.0) * scale).collect()
    }
}

/// Common generator: vector of u32 tokens below `vocab`.
pub fn vec_tokens(vocab: u32) -> impl Gen<Vec<u32>> {
    move |rng: &mut Rng, size: usize| {
        let n = 1 + rng.below(size);
        (0..n).map(|_| rng.below(vocab as usize) as u32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs() {
        check(0, 100, 50, vec_f64(1.0), |xs| {
            xs.iter().all(|x| x.abs() <= 1.0)
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(0, 100, 50, vec_f64(1.0), |xs| xs.len() < 3);
    }

    #[test]
    fn token_gen_in_vocab() {
        check(1, 50, 64, vec_tokens(512), |ts| {
            ts.iter().all(|&t| t < 512)
        });
    }
}
