//! Deterministic RNG substrate (no `rand` crate offline).
//!
//! PCG64-style generator (xsl-rr output on a 128-bit LCG state) with
//! SplitMix64 seeding, plus the distributions the stack needs: uniform,
//! normal (Box–Muller), integer ranges, shuffles, choices and Bernoulli
//! masks. Every experiment takes an explicit `seed`, and every run is
//! exactly reproducible from it.

/// PCG-XSL-RR-128/64.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u128,
    inc: u128,
    /// cached second normal from Box–Muller
    spare_normal: Option<f64>,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // SplitMix64 expands the u64 seed into state + stream.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let state = ((next() as u128) << 64) | next() as u128;
        let inc = (((next() as u128) << 64) | next() as u128) | 1;
        let mut rng = Rng { state, inc, spare_normal: None };
        rng.next_u64(); // warm up
        rng
    }

    /// Derive an independent child stream (for parallel workers / named
    /// sub-experiments) without correlating with the parent.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire-style rejection-free-enough for our sizes.
        (self.uniform() * n as f64) as usize % n
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + self.below((hi - lo) as usize) as i64
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = (self.uniform().max(1e-300), self.uniform());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// k distinct indices from [0, n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher–Yates: first k positions
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Weighted choice: index i with probability w[i]/sum(w).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let a: Vec<u64> = (0..8).map({
            let mut r = Rng::new(42);
            move |_| r.next_u64()
        }).collect();
        let b: Vec<u64> = (0..8).map({
            let mut r = Rng::new(42);
            move |_| r.next_u64()
        }).collect();
        assert_eq!(a, b);
        let c: Vec<u64> = (0..8).map({
            let mut r = Rng::new(43);
            move |_| r.next_u64()
        }).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(1);
        let n = 100_000;
        let mut mean = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            mean += u;
        }
        mean /= n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 200_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m += z;
            v += z * z;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean={m}");
        assert!((v - 1.0).abs() < 0.03, "var={v}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
        // all values hit
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), 20);
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let f2 = counts[2] as f64 / 30_000.0;
        assert!((f2 - 0.7).abs() < 0.02, "{counts:?}");
    }

    #[test]
    fn fork_decorrelates() {
        let mut parent = Rng::new(0);
        let mut a = parent.fork(1);
        let mut b = parent.fork(2);
        let xs: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
