//! Minimal JSON parser + serializer.
//!
//! `serde`/`serde_json` are unavailable in this offline environment, so the
//! AOT manifest, configs, checkpoints metadata and experiment reports go
//! through this hand-rolled implementation. It supports the full JSON
//! grammar (objects, arrays, strings with escapes, numbers, bools, null);
//! object key order is preserved on parse and emit so reports diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Key-ordered object (insertion order preserved).
    Obj(Vec<(String, Json)>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

/// Number-like values accepted by [`Json::push_num`]. JSON numbers are
/// f64, so every integer type funnels through one lossy-above-2^53
/// cast — the same cast the emitters previously wrote by hand.
pub trait JsonNum {
    fn json_f64(&self) -> f64;
}

impl JsonNum for f64 {
    fn json_f64(&self) -> f64 {
        *self
    }
}

macro_rules! impl_json_num {
    ($($t:ty),*) => {$(
        impl JsonNum for $t {
            fn json_f64(&self) -> f64 {
                *self as f64
            }
        }
    )*};
}

impl_json_num!(f32, usize, u64, u32, u8, i64, i32);

impl Json {
    // ---- constructors -------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn push(&mut self, key: &str, value: Json) -> &mut Self {
        if let Json::Obj(entries) = self {
            entries.push((key.to_string(), value));
        } else {
            panic!("push on non-object json value");
        }
        self
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// `push` a numeric field. One helper for every stats/telemetry
    /// emitter (`Summary`, `ServeStats`, `RequestResult`, `LoadPoint`)
    /// so the `Json::Num(x as f64)` boilerplate lives in one place.
    pub fn push_num(&mut self, key: &str, value: impl JsonNum)
                    -> &mut Self {
        self.push(key, Json::Num(value.json_f64()))
    }

    /// `push` a string field.
    pub fn push_str(&mut self, key: &str, value: &str) -> &mut Self {
        self.push(key, Json::Str(value.to_string()))
    }

    /// `push` a boolean field.
    pub fn push_bool(&mut self, key: &str, value: bool) -> &mut Self {
        self.push(key, Json::Bool(value))
    }

    // ---- accessors -----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(entries) => {
                entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest loading wants this.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key: {key}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// Object entries as a map (for lookup-heavy callers).
    pub fn to_map(&self) -> BTreeMap<String, Json> {
        match self {
            Json::Obj(v) => v.iter().cloned().collect(),
            _ => BTreeMap::new(),
        }
    }

    // ---- parsing -------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // ---- serialization ---------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(entries) => {
                out.push('{');
                for (i, (k, v)) in entries.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !entries.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..(w * depth) {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() && x == x.trunc() && x.abs() < 1e15 {
        fmt::Write::write_fmt(out, format_args!("{}", x as i64)).unwrap();
    } else if x.is_finite() {
        fmt::Write::write_fmt(out, format_args!("{x}")).unwrap();
    } else {
        // JSON has no inf/nan; emit null like python's json with allow_nan=False off
        out.push_str("null");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32))
                    .unwrap();
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut entries = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(entries));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            entries.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(entries));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // surrogate pairs
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                let combined = 0x10000
                                    + ((cp - 0xD800) << 10)
                                    + (lo - 0xDC00);
                                char::from_u32(combined)
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| {
                                self.err("invalid unicode escape")
                            })?);
                            self.pos -= 1; // compensate the += 1 below
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("short unicode escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16)
            .map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(),
                   Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#)
            .unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b").unwrap().as_str().unwrap(),
            "c"
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(),
                   Json::Str("é".into()));
        // surrogate pair: U+1F600
        assert_eq!(Json::parse(r#""😀""#).unwrap(),
                   Json::Str("😀".into()));
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(Json::parse("\"héllo wörld\"").unwrap(),
                   Json::Str("héllo wörld".into()));
    }

    #[test]
    fn round_trip() {
        let src = r#"{"model":{"layers":24,"lr":0.0002,"tags":["a","b"],"ok":true}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn pretty_round_trip() {
        let v = Json::parse(r#"{"a":[1,2],"b":{"c":3}}"#).unwrap();
        let v2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn key_order_preserved() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter()
            .map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn parses_python_json_dump() {
        // the exact style aot.py emits (indent=1, sort_keys=True)
        let src = "{\n \"a\": [\n  1,\n  2\n ],\n \"b\": 1e-08\n}";
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("b").unwrap().as_f64().unwrap(), 1e-8);
    }

    #[test]
    fn push_helpers_build_objects() {
        let mut j = Json::obj();
        j.push_num("a", 3usize)
            .push_num("b", 0.5f64)
            .push_num("c", 7u64)
            .push_str("s", "x")
            .push_bool("ok", true);
        assert_eq!(j.get("a").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("b").unwrap().as_f64(), Some(0.5));
        assert_eq!(j.get("c").unwrap().as_f64(), Some(7.0));
        assert_eq!(j.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn num_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(0.25).to_string(), "0.25");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }
}
