//! Analyses over the repro itself.
//!
//! Two kinds live here: [`subspace`] measures the *model* (parameter
//! subspace distances, paper §3.4 Figures 3–4), and [`lint`] measures
//! the *source tree* — the determinism and panic-safety conventions
//! every pinned result rests on, enforced as a machine-checked gate
//! (`spdf lint`, wired into `scripts/check.sh` and CI).

pub mod lint;
pub mod subspace;

pub use subspace::{cosine_distance, mean_distance, parse_module,
                   subspace_distances, MODULES};
