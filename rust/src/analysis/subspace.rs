//! Parameter-subspace analysis (paper §3.4, Figures 3–4): the angular
//! (cosine) distance between pre-trained and fine-tuned weights, per
//! module type per layer.

use std::collections::BTreeMap;

use crate::train::ParamMap;

/// The six module types the paper inspects.
pub const MODULES: [&str; 6] = ["wq", "wk", "wv", "wd", "wi", "wo"];

/// Cosine distance 1 - cos(a, b) in [0, 2].
pub fn cosine_distance(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += (x as f64) * (x as f64);
        nb += (y as f64) * (y as f64);
    }
    if na == 0.0 || nb == 0.0 {
        return if na == nb { 0.0 } else { 1.0 };
    }
    1.0 - dot / (na.sqrt() * nb.sqrt())
}

/// Map a parameter name like "h3.attn.wq" / "h0.mlp.wi" to (layer,
/// module) if it is one of the six tracked matrices.
pub fn parse_module(name: &str) -> Option<(usize, &'static str)> {
    let rest = name.strip_prefix('h')?;
    let (layer_s, tail) = rest.split_once('.')?;
    let layer: usize = layer_s.parse().ok()?;
    let module = match tail {
        "attn.wq" => "wq",
        "attn.wk" => "wk",
        "attn.wv" => "wv",
        "attn.wd" => "wd",
        "mlp.wi" => "wi",
        "mlp.wo" => "wo",
        _ => return None,
    };
    Some((layer, module))
}

/// Figures 3–4 data: module -> per-layer cosine distances between the
/// pre-trained and fine-tuned parameter sets.
pub fn subspace_distances(
    pretrained: &ParamMap,
    finetuned: &ParamMap,
) -> BTreeMap<&'static str, Vec<f64>> {
    let mut layers_by_module: BTreeMap<&'static str, Vec<(usize, f64)>> =
        BTreeMap::new();
    for (name, pre) in pretrained {
        if let Some((layer, module)) = parse_module(name) {
            let fine = match finetuned.get(name) {
                Some(f) => f,
                None => continue,
            };
            layers_by_module
                .entry(module)
                .or_default()
                .push((layer, cosine_distance(pre, fine)));
        }
    }
    layers_by_module
        .into_iter()
        .map(|(m, mut v)| {
            v.sort_by_key(|(l, _)| *l);
            (m, v.into_iter().map(|(_, d)| d).collect())
        })
        .collect()
}

/// Mean distance across all tracked modules (scalar summary used by the
/// H3 comparison: larger models should move less).
pub fn mean_distance(pretrained: &ParamMap, finetuned: &ParamMap) -> f64 {
    let d = subspace_distances(pretrained, finetuned);
    let all: Vec<f64> = d.values().flatten().copied().collect();
    if all.is_empty() {
        return 0.0;
    }
    all.iter().sum::<f64>() / all.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_identical_is_zero() {
        let a = vec![1.0, 2.0, 3.0];
        assert!(cosine_distance(&a, &a).abs() < 1e-12);
    }

    #[test]
    fn cosine_orthogonal_is_one() {
        let a = vec![1.0, 0.0];
        let b = vec![0.0, 1.0];
        assert!((cosine_distance(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_opposite_is_two() {
        let a = vec![1.0, -2.0];
        let b = vec![-1.0, 2.0];
        assert!((cosine_distance(&a, &b) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_scale_invariant() {
        let a = vec![1.0, 2.0, 3.0];
        let b = vec![2.0, 4.0, 6.0];
        assert!(cosine_distance(&a, &b).abs() < 1e-12);
    }

    #[test]
    fn parse_module_names() {
        assert_eq!(parse_module("h0.attn.wq"), Some((0, "wq")));
        assert_eq!(parse_module("h11.mlp.wo"), Some((11, "wo")));
        assert_eq!(parse_module("h2.attn.bq"), None);
        assert_eq!(parse_module("wte"), None);
        assert_eq!(parse_module("h0.ln1.g"), None);
    }

    #[test]
    fn subspace_collects_per_layer_in_order() {
        let mut pre = ParamMap::new();
        let mut fin = ParamMap::new();
        for l in 0..3 {
            pre.insert(format!("h{l}.attn.wq"), vec![1.0, 0.0]);
            // layer l rotated progressively further
            let theta = 0.3 * l as f32;
            fin.insert(format!("h{l}.attn.wq"),
                       vec![theta.cos(), theta.sin()]);
        }
        pre.insert("wte".into(), vec![1.0]);
        fin.insert("wte".into(), vec![-1.0]);
        let d = subspace_distances(&pre, &fin);
        let wq = &d["wq"];
        assert_eq!(wq.len(), 3);
        assert!(wq[0] < wq[1] && wq[1] < wq[2]);
        assert!(!d.contains_key("wk"));
    }
}
