//! The six determinism & panic-safety & doc-coverage rules, applied
//! to one scanned source file at a time.
//!
//! Every rule reads the blanked `code` channel (so literals and
//! comments can't trigger it) and every rule can be silenced at a
//! specific site with a justified marker comment on the same line or
//! up to [`MARKER_WINDOW`] lines above:
//!
//! ```text
//! // lint:allow(<rule>) <reason>
//! ```
//!
//! where `<rule>` is one of [`RULES`]. Rule 4 additionally accepts an
//! adjacent `invariant:` comment, the repo's convention for "this
//! panic is a contract, not a bug". Markers that never match a
//! checked site are themselves findings (`stale-allow`) so silenced
//! sites can't outlive the code they excused.

use super::scanner::{self, Line};

/// A marker excuses a site on its own line or up to this many lines
/// below it (justification blocks span a few lines above their code).
pub const MARKER_WINDOW: usize = 5;

pub const RULE_FLOAT_SORT: &str = "float-sort";
pub const RULE_UNORDERED: &str = "unordered";
pub const RULE_WALL_CLOCK: &str = "wall-clock";
pub const RULE_PANIC_SAFETY: &str = "panic-safety";
pub const RULE_RNG: &str = "rng-discipline";
pub const RULE_DOC_COVERAGE: &str = "doc-coverage";
pub const RULE_STALE_ALLOW: &str = "stale-allow";
pub const RULE_STALE_ALLOWLIST: &str = "stale-allowlist";

/// The site-checkable rules (the two `stale-*` rules are meta-checks
/// and cannot be allowed).
pub const RULES: [&str; 6] = [
    RULE_FLOAT_SORT,
    RULE_UNORDERED,
    RULE_WALL_CLOCK,
    RULE_PANIC_SAFETY,
    RULE_RNG,
    RULE_DOC_COVERAGE,
];

/// One lint violation.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Path relative to the scanned root, `/`-separated.
    pub file: String,
    /// 1-based source line; 0 for file-level findings.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

/// Where each rule applies. Module entries are path prefixes relative
/// to the scan root; file entries are exact relative paths.
pub struct LintConfig {
    /// Rule 2: modules whose map iteration feeds pinned output — no
    /// `HashMap`/`HashSet` without a justification marker.
    pub ordered_modules: Vec<&'static str>,
    /// Rule 4: hot-path modules where `.unwrap()`/`.expect(` needs an
    /// adjacent `invariant:` comment.
    pub panic_modules: Vec<&'static str>,
    /// Rule 3: the only files allowed to read the wall clock.
    pub wall_clock_allow: Vec<&'static str>,
    /// Rule 5: files exempt from seed-derivation discipline (the rng
    /// implementation itself).
    pub rng_exempt: Vec<&'static str>,
    /// Rule 6: public-surface modules where every `pub fn` /
    /// `pub struct` must carry a doc comment (the serving stack and
    /// the sparse-compute kernels are the documented API
    /// `docs/ARCHITECTURE.md` routes readers into).
    pub doc_modules: Vec<&'static str>,
}

impl LintConfig {
    /// The shipped tree's policy.
    pub fn repo_default() -> LintConfig {
        LintConfig {
            ordered_modules: vec![
                "generate/",
                "eval/",
                "tokenizer/",
                "coordinator/",
            ],
            panic_modules: vec!["generate/", "runtime/"],
            wall_clock_allow: vec![
                "bench_support/mod.rs",
                "util/mod.rs",
                "runtime/engine.rs",
                "train/session.rs",
                "generate/serve/clock.rs",
            ],
            rng_exempt: vec!["util/rng.rs"],
            doc_modules: vec!["generate/serve/", "sparse_compute/"],
        }
    }
}

/// Run all rules over one file's text. `file` is the root-relative
/// path the config's module prefixes are matched against.
pub fn scan_source(
    file: &str,
    text: &str,
    cfg: &LintConfig,
) -> Vec<Finding> {
    let lines = scanner::scan(text);
    let present = present_markers(&lines);
    let mut used = vec![false; present.len()];
    let mut out: Vec<Finding> = Vec::new();

    let ordered = in_module(file, &cfg.ordered_modules);
    let panic_mod = in_module(file, &cfg.panic_modules);
    let wall_ok = cfg.wall_clock_allow.iter().any(|a| *a == file);
    let rng_ok = cfg.rng_exempt.iter().any(|a| *a == file);
    let doc_mod = in_module(file, &cfg.doc_modules);

    // ---- line-local rules (2, 3, 4) ---------------------------------
    for (i, l) in lines.iter().enumerate() {
        if l.in_test {
            continue;
        }
        if ordered {
            for pat in ["HashMap", "HashSet"] {
                if l.code.contains(pat) {
                    if !allow(i, RULE_UNORDERED, &present, &mut used) {
                        out.push(finding(
                            file,
                            i,
                            RULE_UNORDERED,
                            format!(
                                "{pat} in an order-sensitive module; \
                                 use BTreeMap/BTreeSet or justify"
                            ),
                        ));
                    }
                    break;
                }
            }
        }
        if !wall_ok {
            for pat in ["Instant::now", "SystemTime"] {
                if l.code.contains(pat) {
                    if !allow(i, RULE_WALL_CLOCK, &present, &mut used) {
                        out.push(finding(
                            file,
                            i,
                            RULE_WALL_CLOCK,
                            format!(
                                "{pat} outside the wall-clock \
                                 allowlist"
                            ),
                        ));
                    }
                    break;
                }
            }
        }
        if panic_mod
            && (l.code.contains(".unwrap()")
                || l.code.contains(".expect("))
            && !has_invariant(&lines, i)
            && !allow(i, RULE_PANIC_SAFETY, &present, &mut used)
        {
            out.push(finding(
                file,
                i,
                RULE_PANIC_SAFETY,
                "hot-path unwrap/expect without an adjacent \
                 invariant: justification"
                    .to_string(),
            ));
        }
        if doc_mod {
            if let Some(kind) = pub_item(&l.code) {
                if !has_doc(&lines, i)
                    && !allow(i, RULE_DOC_COVERAGE, &present, &mut used)
                {
                    out.push(finding(
                        file,
                        i,
                        RULE_DOC_COVERAGE,
                        format!(
                            "pub {kind} in a documented-API module \
                             without a doc comment"
                        ),
                    ));
                }
            }
        }
    }

    // ---- expression rules over joined code (1, 5) -------------------
    let joined = lines
        .iter()
        .map(|l| l.code.as_str())
        .collect::<Vec<_>>()
        .join("\n");
    let mut starts = vec![0usize];
    for l in &lines {
        let last = *starts.last().unwrap_or(&0);
        starts.push(last + l.code.len() + 1);
    }
    let line_of =
        |off: usize| starts.partition_point(|&s| s <= off) - 1;

    // rule 1: float comparators must not panic on NaN
    let needle = "partial_cmp";
    let mut pos = 0usize;
    while let Some(rel) = joined[pos..].find(needle) {
        let at = pos + rel;
        pos = at + needle.len();
        let li = line_of(at);
        if lines[li].in_test {
            continue;
        }
        if let Some((_, rest)) = split_call(&joined[at + needle.len()..])
        {
            let t = rest.trim_start();
            if (t.starts_with(".unwrap()") || t.starts_with(".expect("))
                && !allow(li, RULE_FLOAT_SORT, &present, &mut used)
            {
                out.push(finding(
                    file,
                    li,
                    RULE_FLOAT_SORT,
                    "partial_cmp().unwrap()/.expect() comparator \
                     panics on NaN; use total_cmp"
                        .to_string(),
                ));
            }
        }
    }

    // rule 5: seed derivations outside util/rng must go through a
    // named *_SALT constant or fork, so side-streams are auditable
    let needle = "Rng::new";
    let mut pos = 0usize;
    while let Some(rel) = joined[pos..].find(needle) {
        let at = pos + rel;
        pos = at + needle.len();
        let li = line_of(at);
        if lines[li].in_test || rng_ok {
            continue;
        }
        if let Some((arg, _)) = split_call(&joined[at + needle.len()..])
        {
            if arg.contains('^')
                && !arg.contains("_SALT")
                && !arg.contains("fork")
                && !allow(li, RULE_RNG, &present, &mut used)
            {
                out.push(finding(
                    file,
                    li,
                    RULE_RNG,
                    "seed derivation without a named *_SALT \
                     constant"
                        .to_string(),
                ));
            }
        }
    }

    // ---- stale markers ----------------------------------------------
    for (k, (m, r)) in present.iter().enumerate() {
        if !used[k] {
            out.push(finding(
                file,
                *m,
                RULE_STALE_ALLOW,
                format!("allow marker for `{r}` never matched a \
                         checked site"),
            ));
        }
    }

    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

fn finding(
    file: &str,
    line_idx: usize,
    rule: &'static str,
    message: String,
) -> Finding {
    Finding { file: file.to_string(), line: line_idx + 1, rule, message }
}

fn in_module(file: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| file.starts_with(p))
}

/// All allow markers in non-test comments: (line index, rule). The
/// rule name between the parens must match [`RULES`] exactly —
/// anything else (prose, placeholders) is ignored.
fn present_markers(lines: &[Line]) -> Vec<(usize, &'static str)> {
    let opener = "lint:allow(";
    let mut v = Vec::new();
    for (i, l) in lines.iter().enumerate() {
        if l.in_test {
            continue;
        }
        let mut c = l.comment.as_str();
        while let Some(p) = c.find(opener) {
            let rest = &c[p + opener.len()..];
            let Some(end) = rest.find(')') else { break };
            if let Some(r) = RULES.iter().find(|r| **r == rest[..end]) {
                v.push((i, *r));
            }
            c = &rest[end + 1..];
        }
    }
    v
}

/// Is a marker for `rule` in scope at line `idx`? Marks every marker
/// it consumes as used.
fn allow(
    idx: usize,
    rule: &'static str,
    present: &[(usize, &'static str)],
    used: &mut [bool],
) -> bool {
    let lo = idx.saturating_sub(MARKER_WINDOW);
    let mut hit = false;
    for (k, (m, r)) in present.iter().enumerate() {
        if *r == rule && *m >= lo && *m <= idx {
            used[k] = true;
            hit = true;
        }
    }
    hit
}

fn has_invariant(lines: &[Line], idx: usize) -> bool {
    let lo = idx.saturating_sub(MARKER_WINDOW);
    lines[lo..=idx]
        .iter()
        .any(|l| l.comment.contains("invariant:"))
}

/// Does the blanked code line declare a public item rule 6 covers?
/// Returns the item kind (`fn` / `struct`) for the finding message.
/// `pub(crate)`/`pub(super)` items are not public API and are skipped.
fn pub_item(code: &str) -> Option<&'static str> {
    let t = code.trim_start();
    let rest = t.strip_prefix("pub ")?;
    // qualifiers that may sit between `pub` and the item keyword
    let rest = ["const ", "unsafe ", "async ", "extern "]
        .iter()
        .fold(rest, |r, q| r.strip_prefix(q).unwrap_or(r));
    if rest.starts_with("fn ") {
        Some("fn")
    } else if rest.starts_with("struct ") {
        Some("struct")
    } else {
        None
    }
}

/// Is the `pub` item at `idx` documented? Walks upward through
/// attribute lines (`#[...]`, including multi-line ones, whose
/// continuation lines end in `)]`) and comment-only lines, looking
/// for a `///` doc comment; the first other code line ends the walk.
/// A `//!` module header does not document an item.
fn has_doc(lines: &[Line], idx: usize) -> bool {
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        let code = l.code.trim();
        let comment = l.comment.trim_start();
        if comment.starts_with("///") {
            return true;
        }
        let attr_line = code.starts_with("#[") || code.ends_with(")]");
        let comment_only = code.is_empty() && !comment.is_empty();
        if !(attr_line || comment_only) {
            return false;
        }
    }
    false
}

/// Split text that (after whitespace) starts with `(` into the
/// balanced argument text and the remainder after the close paren.
fn split_call(s: &str) -> Option<(&str, &str)> {
    let s = s.trim_start();
    if !s.starts_with('(') {
        return None;
    }
    let mut depth = 0i32;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '(' => {
                depth += 1;
                if depth == 1 {
                    start = i + 1;
                }
            }
            ')' => {
                depth -= 1;
                if depth == 0 {
                    return Some((&s[start..i], &s[i + 1..]));
                }
            }
            _ => {}
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bare() -> LintConfig {
        LintConfig {
            ordered_modules: vec![],
            panic_modules: vec![],
            wall_clock_allow: vec![],
            rng_exempt: vec![],
            doc_modules: vec![],
        }
    }

    fn rules_of(fs: &[Finding]) -> Vec<&'static str> {
        fs.iter().map(|f| f.rule).collect()
    }

    // ---- rule 1: float-sort -----------------------------------------

    #[test]
    fn float_sort_flags_unwrapped_partial_cmp() {
        let src = "fn f(xs: &mut Vec<f64>) {\n\
                   xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
        let fs = scan_source("serve/x.rs", src, &bare());
        assert_eq!(rules_of(&fs), vec![RULE_FLOAT_SORT]);
        assert_eq!(fs[0].line, 2);
    }

    #[test]
    fn float_sort_flags_multiline_expect_chain() {
        let src = "fn f(a: f32, b: f32) -> std::cmp::Ordering {\n\
                   a.partial_cmp(&b)\n\
                   .expect(\"nan\")\n}\n";
        let fs = scan_source("serve/x.rs", src, &bare());
        assert_eq!(rules_of(&fs), vec![RULE_FLOAT_SORT]);
        assert_eq!(fs[0].line, 2);
    }

    #[test]
    fn float_sort_ignores_total_cmp_and_unwrap_or() {
        let src = "fn f(xs: &mut Vec<f64>) {\n\
                   xs.sort_by(|a, b| a.total_cmp(b));\n\
                   let o = (1.0f64).partial_cmp(&2.0)\
                   .unwrap_or(std::cmp::Ordering::Equal);\n}\n";
        assert!(scan_source("serve/x.rs", src, &bare()).is_empty());
    }

    #[test]
    fn float_sort_ignores_comments_and_strings() {
        let src = "// a.partial_cmp(b).unwrap() was here\n\
                   fn f() -> &'static str {\n\
                   \"a.partial_cmp(b).unwrap()\"\n}\n";
        assert!(scan_source("serve/x.rs", src, &bare()).is_empty());
    }

    #[test]
    fn float_sort_allow_marker_is_honored_and_used() {
        let src = "fn f(xs: &mut Vec<f32>) {\n\
                   // lint:allow(float-sort) frozen comparator\n\
                   xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
        assert!(scan_source("serve/x.rs", src, &bare()).is_empty());
    }

    // ---- rule 2: unordered ------------------------------------------

    #[test]
    fn unordered_flags_hashmap_in_ordered_module_only() {
        let cfg = LintConfig {
            ordered_modules: vec!["eval/"],
            ..bare()
        };
        let src = "use std::collections::HashMap;\n";
        let fs = scan_source("eval/x.rs", src, &cfg);
        assert_eq!(rules_of(&fs), vec![RULE_UNORDERED]);
        assert!(scan_source("serve/x.rs", src, &cfg).is_empty());
    }

    #[test]
    fn unordered_allow_marker_is_honored() {
        let cfg = LintConfig {
            ordered_modules: vec!["eval/"],
            ..bare()
        };
        let src = "// lint:allow(unordered) lookup-only map\n\
                   use std::collections::HashMap;\n";
        assert!(scan_source("eval/x.rs", src, &cfg).is_empty());
    }

    // ---- rule 3: wall-clock -----------------------------------------

    #[test]
    fn wall_clock_flags_instant_now_outside_allowlist() {
        let cfg = LintConfig {
            wall_clock_allow: vec!["util/timer.rs"],
            ..bare()
        };
        let src = "fn t() { let t0 = Instant::now(); }\n";
        let fs = scan_source("serve/x.rs", src, &cfg);
        assert_eq!(rules_of(&fs), vec![RULE_WALL_CLOCK]);
        assert!(scan_source("util/timer.rs", src, &cfg).is_empty());
    }

    #[test]
    fn wall_clock_ignores_commented_out_code() {
        let src = "// let t0 = Instant::now();\nfn t() {}\n";
        assert!(scan_source("serve/x.rs", src, &bare()).is_empty());
    }

    // ---- rule 4: panic-safety ---------------------------------------

    #[test]
    fn panic_safety_requires_invariant_in_hot_modules() {
        let cfg = LintConfig {
            panic_modules: vec!["serve/"],
            ..bare()
        };
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let fs = scan_source("serve/x.rs", src, &cfg);
        assert_eq!(rules_of(&fs), vec![RULE_PANIC_SAFETY]);
        assert!(scan_source("other/x.rs", src, &cfg).is_empty());
    }

    #[test]
    fn panic_safety_accepts_adjacent_invariant_comment() {
        let cfg = LintConfig {
            panic_modules: vec!["serve/"],
            ..bare()
        };
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   // invariant: caller checked is_some\n\
                   x.unwrap()\n}\n";
        assert!(scan_source("serve/x.rs", src, &cfg).is_empty());
    }

    #[test]
    fn panic_safety_ignores_unwrap_or_variants() {
        let cfg = LintConfig {
            panic_modules: vec!["serve/"],
            ..bare()
        };
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   x.unwrap_or_default()\n}\n";
        assert!(scan_source("serve/x.rs", src, &cfg).is_empty());
    }

    // ---- rule 5: rng-discipline -------------------------------------

    #[test]
    fn rng_flags_unsalted_xor_derivation() {
        let src = "fn f(seed: u64) -> Rng {\n\
                   Rng::new(seed ^ 0x1234)\n}\n";
        let fs = scan_source("serve/x.rs", src, &bare());
        assert_eq!(rules_of(&fs), vec![RULE_RNG]);
    }

    #[test]
    fn rng_accepts_salt_fork_and_plain_seed() {
        let src = "fn f(seed: u64, r: &mut Rng) {\n\
                   let a = Rng::new(seed ^ FAULT_SALT);\n\
                   let b = Rng::new(seed);\n\
                   let c = Rng::new(seed ^ r.fork());\n}\n";
        assert!(scan_source("serve/x.rs", src, &bare()).is_empty());
    }

    #[test]
    fn rng_exempt_file_is_skipped() {
        let cfg = LintConfig {
            rng_exempt: vec!["util/rng.rs"],
            ..bare()
        };
        let src = "fn f(seed: u64) -> Rng { Rng::new(seed ^ 1) }\n";
        assert!(scan_source("util/rng.rs", src, &cfg).is_empty());
    }

    // ---- rule 6: doc-coverage ---------------------------------------

    fn doc_cfg() -> LintConfig {
        LintConfig { doc_modules: vec!["serve/"], ..bare() }
    }

    #[test]
    fn doc_coverage_flags_undocumented_pub_items() {
        let src = "pub fn f() {}\npub struct S;\n";
        let fs = scan_source("serve/x.rs", src, &doc_cfg());
        assert_eq!(
            rules_of(&fs),
            vec![RULE_DOC_COVERAGE, RULE_DOC_COVERAGE]
        );
        assert_eq!(fs[0].line, 1);
        // the rule only applies inside the configured modules
        assert!(scan_source("other/x.rs", src, &doc_cfg()).is_empty());
    }

    #[test]
    fn doc_coverage_accepts_doc_comments_through_attributes() {
        let src = "/// Documented.\n\
                   pub fn f() {}\n\
                   /// Also documented, behind attributes.\n\
                   #[derive(Debug, Clone)]\n\
                   #[allow(dead_code)]\n\
                   pub struct S;\n";
        assert!(scan_source("serve/x.rs", src, &doc_cfg()).is_empty());
    }

    #[test]
    fn doc_coverage_skips_crate_private_and_qualified_items() {
        // pub(crate)/pub(super) are not public API; qualified pub
        // items (const/unsafe/async) are still checked
        let src = "pub(crate) fn hidden() {}\n\
                   pub(super) struct Inner;\n\
                   pub const fn k() {}\n";
        let fs = scan_source("serve/x.rs", src, &doc_cfg());
        assert_eq!(rules_of(&fs), vec![RULE_DOC_COVERAGE]);
        assert_eq!(fs[0].line, 3);
    }

    #[test]
    fn doc_coverage_module_header_does_not_document_items() {
        // a `//!` header documents the module, not the first item
        let src = "//! Module header.\npub fn f() {}\n";
        let fs = scan_source("serve/x.rs", src, &doc_cfg());
        assert_eq!(rules_of(&fs), vec![RULE_DOC_COVERAGE]);
    }

    #[test]
    fn doc_coverage_allow_marker_and_tests_are_exempt() {
        let src = "// lint:allow(doc-coverage) generated shim\n\
                   pub fn raw() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   pub fn helper() {}\n\
                   }\n";
        assert!(scan_source("serve/x.rs", src, &doc_cfg()).is_empty());
    }

    // ---- cfg(test) and markers --------------------------------------

    #[test]
    fn cfg_test_code_is_exempt_from_every_rule() {
        let cfg = LintConfig {
            ordered_modules: vec!["eval/"],
            panic_modules: vec!["eval/"],
            ..bare()
        };
        let src = "fn live() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   use std::collections::HashMap;\n\
                   fn t(x: Option<f64>, y: f64) {\n\
                   let t0 = Instant::now();\n\
                   let r = Rng::new(1u64 ^ 2);\n\
                   let o = x.unwrap().partial_cmp(&y).unwrap();\n\
                   }\n}\n";
        assert!(scan_source("eval/x.rs", src, &cfg).is_empty());
    }

    #[test]
    fn stale_allow_marker_is_reported() {
        let src = "// lint:allow(float-sort) nothing here anymore\n\
                   fn f() {}\n";
        let fs = scan_source("serve/x.rs", src, &bare());
        assert_eq!(rules_of(&fs), vec![RULE_STALE_ALLOW]);
        assert_eq!(fs[0].line, 1);
    }

    #[test]
    fn marker_with_unknown_rule_name_is_ignored() {
        let src = "// lint:allow(<rule>) doc placeholder\nfn f() {}\n";
        assert!(scan_source("serve/x.rs", src, &bare()).is_empty());
    }

    #[test]
    fn marker_outside_window_does_not_excuse() {
        let mut src = String::from(
            "// lint:allow(wall-clock) too far away\n",
        );
        for _ in 0..MARKER_WINDOW + 1 {
            src.push_str("fn pad() {}\n");
        }
        src.push_str("fn t() { let t0 = Instant::now(); }\n");
        let fs = scan_source("serve/x.rs", &src, &bare());
        assert_eq!(
            rules_of(&fs),
            vec![RULE_STALE_ALLOW, RULE_WALL_CLOCK]
        );
    }
}
