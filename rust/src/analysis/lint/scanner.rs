//! Comment/string-aware Rust source scanner — the lexical substrate
//! the lint rules run on.
//!
//! This is deliberately *not* a Rust parser. Rules match substrings,
//! so all the scanner must guarantee is that (1) text inside
//! comments, string/char literals never looks like code, (2) comment
//! text is preserved separately so `lint:allow(...)`-style markers
//! and `invariant:` justifications can be found, and (3) items under
//! `#[cfg(test)]` are labeled, because every rule applies to shipped
//! code only. The same no-deps, hand-rolled idiom as `util::json`.

/// One scanned source line.
#[derive(Debug, Clone)]
pub struct Line {
    /// Code with comment text and literal *contents* blanked to
    /// spaces (delimiters kept), so byte offsets match the original.
    pub code: String,
    /// Comment text on this line (line, block and doc comments).
    pub comment: String,
    /// Inside a `#[cfg(test)]` item — rules skip these lines.
    pub in_test: bool,
}

#[derive(PartialEq)]
enum State {
    Normal,
    LineComment,
    /// Nested block comments: the u32 is the nesting depth.
    BlockComment(u32),
    Str,
    /// Raw string with `n` hashes: closes on `"` + n `#`s.
    RawStr(u32),
    CharLit,
}

/// Scan source text into labeled lines.
pub fn scan(text: &str) -> Vec<Line> {
    let mut lines = Vec::new();
    let mut state = State::Normal;

    // #[cfg(test)] region tracking, over the code channel only
    let mut depth: i64 = 0;
    let mut pending_attr = false;
    let mut test_close_depth: Option<i64> = None;

    for raw in text.lines() {
        let mut code = String::with_capacity(raw.len());
        let mut comment = String::new();
        let was_test = test_close_depth.is_some();

        let b: Vec<char> = raw.chars().collect();
        let mut i = 0;
        while i < b.len() {
            let c = b[i];
            let next = b.get(i + 1).copied();
            match state {
                State::Normal => match c {
                    '/' if next == Some('/') => {
                        comment.push_str(&raw[raw
                            .char_indices()
                            .nth(i)
                            .map(|(o, _)| o)
                            .unwrap_or(raw.len())..]);
                        for _ in i..b.len() {
                            code.push(' ');
                        }
                        state = State::LineComment;
                        i = b.len();
                        continue;
                    }
                    '/' if next == Some('*') => {
                        code.push_str("  ");
                        state = State::BlockComment(1);
                        i += 2;
                        continue;
                    }
                    '"' => {
                        // raw-string prefix? look back over r / br
                        let hashes = raw_hashes_before(&b, i);
                        match hashes {
                            Some(n) => state = State::RawStr(n),
                            None => state = State::Str,
                        }
                        code.push('"');
                    }
                    '\'' => {
                        // char literal vs lifetime: 'x' or '\...'
                        let is_char = next == Some('\\')
                            || (b.get(i + 2).copied() == Some('\'')
                                && next != Some('\''));
                        if is_char {
                            code.push('\'');
                            state = State::CharLit;
                        } else {
                            code.push('\'');
                        }
                    }
                    _ => code.push(c),
                },
                State::LineComment => unreachable!("line-scoped"),
                State::BlockComment(d) => {
                    if c == '*' && next == Some('/') {
                        code.push_str("  ");
                        i += 2;
                        state = if d > 1 {
                            State::BlockComment(d - 1)
                        } else {
                            State::Normal
                        };
                        continue;
                    } else if c == '/' && next == Some('*') {
                        code.push_str("  ");
                        comment.push_str("  ");
                        i += 2;
                        state = State::BlockComment(d + 1);
                        continue;
                    } else {
                        code.push(' ');
                        comment.push(c);
                    }
                }
                State::Str => match c {
                    '\\' => {
                        code.push_str("  ");
                        i += 2;
                        continue;
                    }
                    '"' => {
                        code.push('"');
                        state = State::Normal;
                    }
                    _ => code.push(' '),
                },
                State::RawStr(n) => {
                    if c == '"' && closes_raw(&b, i, n) {
                        code.push('"');
                        for _ in 0..n {
                            code.push('#');
                        }
                        i += 1 + n as usize;
                        state = State::Normal;
                        continue;
                    }
                    code.push(' ');
                }
                State::CharLit => match c {
                    '\\' => {
                        code.push_str("  ");
                        i += 2;
                        continue;
                    }
                    '\'' => {
                        code.push('\'');
                        state = State::Normal;
                    }
                    _ => code.push(' '),
                },
            }
            i += 1;
        }

        // line comments end at EOL, and a char literal never spans
        // lines (so an open one here is a misread lifetime — recover);
        // strings (plain with \-continuations, raw) and block
        // comments carry over
        match state {
            State::LineComment | State::CharLit => {
                state = State::Normal;
            }
            _ => {}
        }

        // second pass over the blanked code: brace depth and
        // #[cfg(test)] region tracking (must run on `code`, not the
        // raw line, so braces inside literals/comments don't count)
        let attr_pos = code.find("#[cfg(test)]");
        let mut armed = pending_attr;
        for (ci, c) in code.char_indices() {
            if attr_pos == Some(ci) {
                armed = true;
            }
            match c {
                '{' => {
                    depth += 1;
                    if armed && test_close_depth.is_none() {
                        test_close_depth = Some(depth - 1);
                    }
                    armed = false;
                }
                '}' => {
                    depth -= 1;
                    if test_close_depth == Some(depth) {
                        test_close_depth = None;
                    }
                }
                // an attribute consumed by a braceless item
                ';' => armed = false,
                _ => {}
            }
        }
        pending_attr = armed;

        let in_test =
            was_test || test_close_depth.is_some() || pending_attr;
        lines.push(Line { code, comment, in_test });
    }
    lines
}

/// Is the `"` at `i` preceded by `r`/`br` + exactly the hashes of a
/// raw-string opener? Returns the hash count if so.
fn raw_hashes_before(b: &[char], i: usize) -> Option<u32> {
    let mut j = i;
    let mut hashes = 0u32;
    while j > 0 && b[j - 1] == '#' {
        j -= 1;
        hashes += 1;
    }
    if j == 0 {
        return None;
    }
    let p = b[j - 1];
    let is_raw = p == 'r'
        && (j < 2 || !b[j - 2].is_alphanumeric() || b[j - 2] == 'b');
    if is_raw {
        Some(hashes)
    } else {
        None
    }
}

/// Does the `"` at `i` close a raw string with `n` hashes?
fn closes_raw(b: &[char], i: usize, n: u32) -> bool {
    (1..=n as usize).all(|k| b.get(i + k).copied() == Some('#'))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> Vec<String> {
        scan(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn line_comments_are_blanked_but_kept_as_comment() {
        let ls = scan("let x = 1; // HashMap here\nlet y = 2;");
        assert!(!ls[0].code.contains("HashMap"));
        assert!(ls[0].comment.contains("HashMap"));
        assert!(ls[0].code.contains("let x = 1;"));
    }

    #[test]
    fn string_literals_are_blanked() {
        let ls = code_of(r#"let s = "Instant::now() inside";"#);
        assert!(!ls[0].contains("Instant::now"));
        assert!(ls[0].starts_with("let s = \""));
    }

    #[test]
    fn escaped_quotes_do_not_end_strings() {
        let ls = code_of(r#"let s = "a \" HashMap b"; let h = 1;"#);
        assert!(!ls[0].contains("HashMap"));
        assert!(ls[0].contains("let h = 1;"));
    }

    #[test]
    fn plain_strings_span_lines_via_continuation() {
        // a `\`-continued string: its later lines are still literal
        // text, and braces inside must not move the scope depth
        let src = "fn f() -> &'static str {\n\
                   \"fixture {\\\n\
                   Rng::new(1 ^ 2) }\\\n\
                   done\"\n}\nfn g() {}";
        let ls = scan(src);
        assert!(!ls[2].code.contains("Rng::new"));
        assert!(ls[4].code.contains('}'));
        assert!(ls[5].code.contains("fn g() {}"));
    }

    #[test]
    fn raw_strings_span_lines() {
        let src = "let s = r#\"line one HashMap\nline two \
                   SystemTime\"#;\nlet x = 3;";
        let ls = code_of(src);
        assert!(!ls[0].contains("HashMap"));
        assert!(!ls[1].contains("SystemTime"));
        assert!(ls[2].contains("let x = 3;"));
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let src = "a(); /* outer /* inner HashMap */ still out \
                   */\nb(); /* open\nSystemTime\n*/ c();";
        let ls = scan(src);
        assert!(!ls[0].code.contains("HashMap"));
        assert!(ls[0].code.contains("a();"));
        assert!(!ls[2].code.contains("SystemTime"));
        assert!(ls[2].comment.contains("SystemTime"));
        assert!(ls[3].code.contains("c();"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let ls = code_of(
            "let q = '\"'; let s = \"HashMap\"; fn f<'a>(x: &'a u8) {}",
        );
        // the char literal's quote must not open a string
        assert!(!ls[0].contains("HashMap"));
        assert!(ls[0].contains("fn f<'a>(x: &'a u8) {}"));
    }

    #[test]
    fn cfg_test_regions_are_labeled() {
        let src = "fn live() { a(); }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       fn t() { b(); }\n\
                   }\n\
                   fn live2() { c(); }";
        let ls = scan(src);
        assert!(!ls[0].in_test);
        assert!(ls[1].in_test);
        assert!(ls[2].in_test);
        assert!(ls[3].in_test);
        assert!(ls[4].in_test);
        assert!(!ls[5].in_test);
    }

    #[test]
    fn cfg_test_on_single_fn() {
        let src = "#[cfg(test)]\n\
                   pub(crate) fn helper(x: usize) -> usize {\n\
                       x + 1\n\
                   }\n\
                   fn live() {}";
        let ls = scan(src);
        assert!(ls[1].in_test && ls[2].in_test && ls[3].in_test);
        assert!(!ls[4].in_test);
    }

    #[test]
    fn cfg_test_attr_consumed_by_braceless_item() {
        let src = "#[cfg(test)]\nuse foo::bar;\nfn live() { x(); }";
        let ls = scan(src);
        assert!(!ls[2].in_test);
    }
}
