//! `spdf lint` — a determinism & panic-safety static-analysis pass
//! over this source tree.
//!
//! Every pinned artifact in the repo (the reference-oracle traces, KV
//! equivalence checks, chaos-schedule determinism, eval JSON) rests
//! on conventions no compiler enforces: float comparators must not
//! panic on NaN, map iteration feeding output must be ordered, the
//! wall clock stays behind a small allowlist, hot-path panics carry a
//! written invariant, RNG side-streams derive through named salts,
//! and the documented API surface (`generate/serve`,
//! `sparse_compute`) keeps a doc comment on every `pub fn` /
//! `pub struct`. This module makes those conventions machine-checked: a
//! comment/string-aware scanner ([`scanner`]), the rules themselves
//! ([`rules`]), and here the tree walker plus human/JSON reporting.
//! Wired into `scripts/check.sh` and CI; `spdf lint` exits nonzero on
//! any finding.

pub mod rules;
pub mod scanner;

pub use rules::{scan_source, Finding, LintConfig};

use std::fs;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Result of linting a tree.
pub struct LintReport {
    /// All findings, ordered by file then line.
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

/// Lint every `.rs` file under `root` (sorted walk, so output order
/// is stable across machines).
pub fn run(root: &Path, cfg: &LintConfig) -> anyhow::Result<LintReport> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();

    let mut findings = Vec::new();
    let mut allow_live = vec![false; cfg.wall_clock_allow.len()];
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let text = fs::read_to_string(path)?;
        if let Some(k) =
            cfg.wall_clock_allow.iter().position(|a| *a == rel)
        {
            allow_live[k] = reads_wall_clock(&text);
        }
        findings.extend(rules::scan_source(&rel, &text, cfg));
    }

    // an allowlist entry for a file that no longer exists (or no
    // longer reads the clock) is a hole waiting to be abused
    for (k, entry) in cfg.wall_clock_allow.iter().enumerate() {
        if !allow_live[k] {
            findings.push(Finding {
                file: entry.to_string(),
                line: 0,
                rule: rules::RULE_STALE_ALLOWLIST,
                message: "wall-clock allowlist entry is missing or \
                          no longer reads the clock"
                    .to_string(),
            });
        }
    }

    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule)
            .cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(LintReport { findings, files_scanned: files.len() })
}

/// Does any non-test code line actually read the wall clock?
fn reads_wall_clock(text: &str) -> bool {
    scanner::scan(text).iter().any(|l| {
        !l.in_test
            && (l.code.contains("Instant::now")
                || l.code.contains("SystemTime"))
    })
}

fn collect_rs(
    dir: &Path,
    out: &mut Vec<PathBuf>,
) -> anyhow::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Aligned human-readable table, one finding per row.
    pub fn render(&self) -> String {
        if self.findings.is_empty() {
            return format!(
                "lint: clean ({} files scanned)\n",
                self.files_scanned
            );
        }
        let locs: Vec<String> = self
            .findings
            .iter()
            .map(|f| format!("{}:{}", f.file, f.line))
            .collect();
        let w_loc = locs.iter().map(|l| l.len()).max().unwrap_or(0);
        let w_rule = self
            .findings
            .iter()
            .map(|f| f.rule.len())
            .max()
            .unwrap_or(0);
        let mut s = String::new();
        for (loc, f) in locs.iter().zip(&self.findings) {
            s.push_str(&format!(
                "{loc:<w_loc$}  {rule:<w_rule$}  {msg}\n",
                rule = f.rule,
                msg = f.message,
            ));
        }
        s.push_str(&format!(
            "\nlint: {} finding(s) in {} files scanned\n",
            self.findings.len(),
            self.files_scanned
        ));
        s
    }

    /// Machine-readable report for CI artifacts.
    pub fn to_json(&self) -> Json {
        let items: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                let mut o = Json::obj();
                o.push_str("file", &f.file)
                    .push_num("line", f.line)
                    .push_str("rule", f.rule)
                    .push_str("message", &f.message);
                o
            })
            .collect();
        let mut j = Json::obj();
        j.push_num("files_scanned", self.files_scanned)
            .push_num("findings", self.findings.len())
            .push("violations", Json::Arr(items));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The gate itself: the shipped tree must be clean under the
    /// shipped policy. If this fails, either fix the violation or
    /// justify it where it lives — do not touch the policy first.
    #[test]
    fn shipped_tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let rep = run(&root, &LintConfig::repo_default()).unwrap();
        assert!(rep.is_clean(), "\n{}", rep.render());
        assert!(rep.files_scanned > 30, "walker missed most of src/");
    }

    #[test]
    fn stale_allowlist_entry_is_reported() {
        let dir = std::env::temp_dir()
            .join(format!("spdf_lint_{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        fs::write(dir.join("a.rs"), "fn f() {}\n").unwrap();
        let cfg = LintConfig {
            ordered_modules: vec![],
            panic_modules: vec![],
            wall_clock_allow: vec!["gone.rs", "a.rs"],
            rng_exempt: vec![],
            doc_modules: vec![],
        };
        let rep = run(&dir, &cfg).unwrap();
        fs::remove_dir_all(&dir).unwrap();
        let rules: Vec<&str> =
            rep.findings.iter().map(|f| f.rule).collect();
        assert_eq!(
            rules,
            vec![
                rules::RULE_STALE_ALLOWLIST,
                rules::RULE_STALE_ALLOWLIST
            ],
            "both the missing file and the clock-free file are stale"
        );
    }

    #[test]
    fn report_renders_and_serializes() {
        let rep = LintReport {
            findings: vec![Finding {
                file: "a.rs".to_string(),
                line: 3,
                rule: rules::RULE_WALL_CLOCK,
                message: "m".to_string(),
            }],
            files_scanned: 1,
        };
        let table = rep.render();
        assert!(table.contains("a.rs:3"));
        assert!(table.contains("1 finding(s)"));
        let j = rep.to_json().to_string_pretty();
        let back = Json::parse(&j).unwrap();
        assert_eq!(
            back.get("findings").and_then(|v| v.as_f64()),
            Some(1.0)
        );
    }
}
