//! Byte-level BPE tokenizer (trainable), the vocabulary substrate shared
//! by pre-training and every downstream task.
//!
//! Layout: ids 0..4 are specials (PAD, BOS, EOS, SEP), 4..260 the raw
//! bytes, and the rest learned merges — the GPT-2 scheme scaled to the
//! simulation vocab (512). Words are whitespace-delimited with a leading
//! space marker byte, like GPT-2's 'Ġ'.

// lint:allow(unordered) both HashMap uses below are order-blind:
// merge_map is lookup-only, pair counts resolve by a total tie-break
use std::collections::{BTreeMap, HashMap};

use crate::util::json::Json;

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
pub const SEP: u32 = 3;
pub const N_SPECIAL: u32 = 4;
const BYTE_BASE: u32 = N_SPECIAL;
/// Space marker prepended to each non-initial word (GPT-2 'Ġ').
const SPACE: u8 = 0x20;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    pub vocab_size: usize,
    /// merge rules in training order: (left, right) -> new id
    merges: Vec<(u32, u32)>,
    /// lookup: pair -> (rank, merged id)
    // lint:allow(unordered) lookup-only: never iterated, so its order
    // cannot reach encode output
    merge_map: HashMap<(u32, u32), (usize, u32)>,
}

impl Tokenizer {
    /// Train BPE on a corpus to the target vocab size.
    pub fn train(corpus: &str, vocab_size: usize) -> Tokenizer {
        assert!(vocab_size > (BYTE_BASE + 256) as usize,
                "vocab must exceed specials+bytes");
        // word frequency table; each word is a Vec of current token ids
        let mut word_freq: BTreeMap<Vec<u8>, u64> = BTreeMap::new();
        for (i, w) in corpus.split_whitespace().enumerate() {
            let mut bytes = Vec::with_capacity(w.len() + 1);
            if i > 0 {
                bytes.push(SPACE);
            }
            bytes.extend_from_slice(w.as_bytes());
            *word_freq.entry(bytes).or_insert(0) += 1;
        }
        let mut words: Vec<(Vec<u32>, u64)> = word_freq
            .into_iter()
            .map(|(bytes, f)| {
                (bytes.iter().map(|&b| BYTE_BASE + b as u32).collect(), f)
            })
            .collect();

        let mut merges = Vec::new();
        let mut next_id = BYTE_BASE + 256;
        while (next_id as usize) < vocab_size {
            // count all adjacent pairs
            // lint:allow(unordered) iterated only via the max_by_key
            // below, whose (count, pair-id) key is a total order — the
            // argmax is the same under any iteration order
            let mut counts: HashMap<(u32, u32), u64> = HashMap::new();
            for (toks, f) in &words {
                for win in toks.windows(2) {
                    *counts.entry((win[0], win[1])).or_insert(0) += f;
                }
            }
            // deterministic argmax: highest count, then lowest pair ids
            let best = counts.iter().max_by_key(|(&(a, b), &c)| {
                (c, std::cmp::Reverse(a), std::cmp::Reverse(b))
            });
            let (&pair, &count) = match best {
                Some(kv) => kv,
                None => break,
            };
            if count < 2 {
                break; // no productive merges left
            }
            merges.push(pair);
            for (toks, _) in &mut words {
                merge_in_place(toks, pair, next_id);
            }
            next_id += 1;
        }
        Tokenizer::from_merges(vocab_size, merges)
    }

    pub fn from_merges(vocab_size: usize, merges: Vec<(u32, u32)>)
                       -> Tokenizer {
        let merge_map = merges
            .iter()
            .enumerate()
            .map(|(rank, &(a, b))| {
                ((a, b), (rank, BYTE_BASE + 256 + rank as u32))
            })
            .collect();
        Tokenizer { vocab_size, merges, merge_map }
    }

    pub fn n_merges(&self) -> usize {
        self.merges.len()
    }

    /// Encode text to token ids (no specials added).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::new();
        for (i, w) in text.split_whitespace().enumerate() {
            let mut toks: Vec<u32> = Vec::with_capacity(w.len() + 1);
            if i > 0 {
                toks.push(BYTE_BASE + SPACE as u32);
            }
            toks.extend(w.as_bytes().iter()
                        .map(|&b| BYTE_BASE + b as u32));
            // repeatedly apply the lowest-rank applicable merge
            loop {
                let mut best: Option<(usize, usize, u32)> = None; // (rank, pos, id)
                for (pos, win) in toks.windows(2).enumerate() {
                    if let Some(&(rank, id)) =
                        self.merge_map.get(&(win[0], win[1]))
                    {
                        if best.map_or(true, |(br, _, _)| rank < br) {
                            best = Some((rank, pos, id));
                        }
                    }
                }
                match best {
                    Some((_, pos, id)) => {
                        toks[pos] = id;
                        toks.remove(pos + 1);
                    }
                    None => break,
                }
            }
            out.extend(toks);
        }
        out
    }

    /// Decode ids back to text (specials are dropped).
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            self.push_bytes(id, &mut bytes);
        }
        let s = String::from_utf8_lossy(&bytes).into_owned();
        s.trim_start().to_string()
    }

    fn push_bytes(&self, id: u32, out: &mut Vec<u8>) {
        if id < N_SPECIAL {
            return;
        }
        if id < BYTE_BASE + 256 {
            out.push((id - BYTE_BASE) as u8);
            return;
        }
        let (a, b) = self.merges[(id - BYTE_BASE - 256) as usize];
        self.push_bytes(a, out);
        self.push_bytes(b, out);
    }

    // ---- persistence ---------------------------------------------------
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("vocab_size", Json::Num(self.vocab_size as f64));
        o.push("merges", Json::Arr(
            self.merges.iter()
                .map(|&(a, b)| Json::Arr(vec![
                    Json::Num(a as f64), Json::Num(b as f64)]))
                .collect()));
        o
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Tokenizer> {
        let vocab_size = j.req("vocab_size")?.as_usize()
            .ok_or_else(|| anyhow::anyhow!("vocab_size"))?;
        let merges = j.req("merges")?.as_arr()
            .ok_or_else(|| anyhow::anyhow!("merges"))?
            .iter()
            .map(|p| {
                let a = p.as_arr().unwrap();
                (a[0].as_usize().unwrap() as u32,
                 a[1].as_usize().unwrap() as u32)
            })
            .collect();
        Ok(Tokenizer::from_merges(vocab_size, merges))
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Tokenizer> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("tokenizer json: {e}"))?;
        Tokenizer::from_json(&j)
    }
}

fn merge_in_place(toks: &mut Vec<u32>, pair: (u32, u32), id: u32) {
    let mut i = 0;
    while i + 1 < toks.len() {
        if toks[i] == pair.0 && toks[i + 1] == pair.1 {
            toks[i] = id;
            toks.remove(i + 1);
        } else {
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CORPUS: &str = "the cat sat on the mat . the dog sat on the \
        log . the cat and the dog sat together on the mat near the log .";

    #[test]
    fn round_trip_exact() {
        let tok = Tokenizer::train(CORPUS, 300);
        for text in [
            "the cat sat",
            "a dog on the mat",
            "unseen words tokenize too",
            "punctuation , and . marks",
        ] {
            assert_eq!(tok.decode(&tok.encode(text)), text);
        }
    }

    #[test]
    fn merges_compress_frequent_words() {
        let tok = Tokenizer::train(CORPUS, 300);
        assert!(tok.n_merges() > 0);
        // "the" is the most frequent word: must encode shorter than bytes
        let ids = tok.encode("the the the");
        assert!(ids.len() < 9, "ids={ids:?}");
    }

    #[test]
    fn ids_stay_in_vocab() {
        let tok = Tokenizer::train(CORPUS, 300);
        for id in tok.encode("the quick brown fox . zzz") {
            assert!((id as usize) < 300);
        }
    }

    #[test]
    fn unseen_bytes_fall_back_to_byte_tokens() {
        let tok = Tokenizer::train(CORPUS, 300);
        let ids = tok.encode("héllo");
        assert_eq!(tok.decode(&ids), "héllo");
    }

    #[test]
    fn specials_are_skipped_in_decode() {
        let tok = Tokenizer::train(CORPUS, 300);
        let mut ids = vec![BOS];
        ids.extend(tok.encode("the cat"));
        ids.push(EOS);
        ids.push(PAD);
        assert_eq!(tok.decode(&ids), "the cat");
    }

    #[test]
    fn json_round_trip_preserves_encoding() {
        let tok = Tokenizer::train(CORPUS, 300);
        let tok2 = Tokenizer::from_json(&tok.to_json()).unwrap();
        let text = "the dog sat on the mat";
        assert_eq!(tok.encode(text), tok2.encode(text));
    }

    #[test]
    fn training_is_deterministic() {
        let a = Tokenizer::train(CORPUS, 290);
        let b = Tokenizer::train(CORPUS, 290);
        assert_eq!(a.encode("the cat sat"), b.encode("the cat sat"));
    }

    #[test]
    fn property_round_trip_ascii() {
        let tok = Tokenizer::train(CORPUS, 300);
        crate::util::proptest::check(
            5, 40, 30,
            |rng: &mut crate::util::rng::Rng, size: usize| {
                let words = ["the", "cat", "dog", "xyzzy", "42", ".,!"];
                (0..1 + rng.below(size))
                    .map(|_| *rng.choice(&words))
                    .collect::<Vec<_>>()
                    .join(" ")
            },
            |text| tok.decode(&tok.encode(text)) == *text,
        );
    }
}
