//! Sparse compute engine: CSR matrices + sparse/dense matmul kernels.
//!
//! This is the Appendix-C substrate: the paper shows *measured* speedup
//! of a 12k×12k GPT-3-layer matmul on the Cerebras CS-2 versus the
//! theoretical 1/(1-S) bound. Our hardware is a CPU, so we build the
//! honest CPU analogue — a parallel CSR sparse-times-dense matmul — and
//! measure its realized speedup against an equally-optimized dense
//! kernel across the same sparsity sweep (`benches/appc_sparse_speedup`).

use crate::util::rng::Rng;
use crate::util::threads;

/// Compressed Sparse Row matrix (f32).
///
/// `from_dense` drops only exact zeros, so `to_dense()` is an exact
/// round-trip — and [`Csr::spmm`] matches [`dense_matmul`] **bitwise**
/// (not approximately): both accumulate k-major in the same order, and
/// the dense kernel explicitly skips zero operands the same way the
/// sparse one structurally does. That bitwise pin is what lets the
/// serve path hold sparse checkpoints CSR-resident without perturbing
/// a single logit.
///
/// ```
/// use spdf::sparse_compute::Csr;
///
/// let dense = vec![1.0, 0.0, 2.0,
///                  0.0, 0.0, 3.0];
/// let csr = Csr::from_dense(&dense, 2, 3);
/// assert_eq!(csr.nnz(), 3);
/// assert_eq!(csr.to_dense(), dense);          // exact round-trip
/// assert_eq!(csr.density(), 0.5);
/// // multiply by a dense (cols × n) B, here n = 1
/// let b = vec![10.0, 20.0, 30.0];
/// assert_eq!(csr.spmm(&b, 1), vec![70.0, 90.0]);
/// ```
#[derive(Debug, Clone)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    /// Build from a dense row-major matrix, dropping exact zeros.
    pub fn from_dense(dense: &[f32], rows: usize, cols: usize) -> Csr {
        assert_eq!(dense.len(), rows * cols);
        // exact nnz in one streaming pass: large mask matrices would
        // otherwise realloc col_idx/values ~log2(nnz) times
        let nnz = dense.iter().filter(|&&v| v != 0.0).count();
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        row_ptr.push(0);
        for r in 0..rows {
            for c in 0..cols {
                let v = dense[r * cols + c];
                if v != 0.0 {
                    col_idx.push(c as u32);
                    values.push(v);
                }
            }
            row_ptr.push(col_idx.len());
        }
        Csr { rows, cols, row_ptr, col_idx, values }
    }

    /// Random matrix at the target sparsity (Bernoulli per element —
    /// representative of an unstructured random mask).
    pub fn random(rows: usize, cols: usize, sparsity: f64, rng: &mut Rng)
                  -> Csr {
        // expected nnz + 2% Bernoulli headroom, capped at the dense size
        let expect = ((rows * cols) as f64 * (1.0 - sparsity) * 1.02)
            .ceil() as usize;
        let expect = (expect + 16).min(rows * cols);
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::with_capacity(expect);
        let mut values = Vec::with_capacity(expect);
        row_ptr.push(0);
        for _ in 0..rows {
            for c in 0..cols {
                if !rng.bernoulli(sparsity) {
                    col_idx.push(c as u32);
                    values.push(rng.normal_f32(0.0, 1.0));
                }
            }
            row_ptr.push(col_idx.len());
        }
        Csr { rows, cols, row_ptr, col_idx, values }
    }

    /// Stored (nonzero) element count.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fraction of elements stored: `nnz / (rows × cols)`.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Materialize the dense row-major matrix. Exact inverse of
    /// [`Csr::from_dense`] (zeros dropped there come back as `+0.0`).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.rows * self.cols];
        for r in 0..self.rows {
            for k in self.row_ptr[r]..self.row_ptr[r + 1] {
                out[r * self.cols + self.col_idx[k] as usize] =
                    self.values[k];
            }
        }
        out
    }

    /// y = A x (sparse matrix-vector).
    pub fn spmv(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0f32; self.rows];
        for r in 0..self.rows {
            let mut acc = 0.0f32;
            for k in self.row_ptr[r]..self.row_ptr[r + 1] {
                acc += self.values[k]
                    * x[self.col_idx[k] as usize];
            }
            y[r] = acc;
        }
        y
    }

    /// C = A · B where B is dense (cols × n), row-parallel.
    /// Inner loop is laid out for streaming access over B's rows.
    pub fn spmm(&self, b: &[f32], n: usize) -> Vec<f32> {
        assert_eq!(b.len(), self.cols * n);
        let mut c = vec![0.0f32; self.rows * n];
        let rows_per_chunk =
            (self.rows / (4 * threads::worker_count())).max(8);
        threads::parallel_chunks_mut(
            &mut c,
            rows_per_chunk * n,
            |start_elem, chunk| {
                let row0 = start_elem / n;
                for (ri, crow) in chunk.chunks_mut(n).enumerate() {
                    let r = row0 + ri;
                    for k in self.row_ptr[r]..self.row_ptr[r + 1] {
                        let v = self.values[k];
                        let brow = &b[self.col_idx[k] as usize * n..]
                            [..n];
                        for (cv, bv) in crow.iter_mut().zip(brow) {
                            *cv += v * bv;
                        }
                    }
                }
            },
        );
        c
    }
}

/// Equally-optimized dense baseline: row-parallel, k-major inner loop
/// (same memory pattern as spmm with a fully-dense A).
pub fn dense_matmul(
    a: &[f32], b: &[f32], m: usize, k: usize, n: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    let rows_per_chunk = (m / (4 * threads::worker_count())).max(8);
    threads::parallel_chunks_mut(
        &mut c,
        rows_per_chunk * n,
        |start_elem, chunk| {
            let row0 = start_elem / n;
            for (ri, crow) in chunk.chunks_mut(n).enumerate() {
                let r = row0 + ri;
                let arow = &a[r * k..(r + 1) * k];
                for (kk, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue; // branch mirrors spmm's skip
                    }
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
        },
    );
    c
}

/// Theoretical speedup of sparsity S over dense: 1 / (1 - S)
/// (the dashed line in App. C Figure 1).
pub fn theoretical_speedup(sparsity: f64) -> f64 {
    1.0 / (1.0 - sparsity)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense_ref(a: &[f32], b: &[f32], m: usize, k: usize, n: usize)
                 -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    fn close(a: &[f32], b: &[f32]) -> bool {
        a.iter().zip(b).all(|(x, y)| (x - y).abs() < 1e-3)
    }

    #[test]
    fn csr_round_trip() {
        let dense = vec![1.0, 0.0, 2.0, 0.0, 0.0, 3.0];
        let csr = Csr::from_dense(&dense, 2, 3);
        assert_eq!(csr.nnz(), 3);
        assert_eq!(csr.to_dense(), dense);
    }

    #[test]
    fn spmv_matches_dense() {
        let mut rng = Rng::new(0);
        let csr = Csr::random(33, 17, 0.7, &mut rng);
        let dense = csr.to_dense();
        let x: Vec<f32> = (0..17).map(|i| (i as f32) * 0.1 - 0.5)
            .collect();
        let want = dense_ref(&dense, &x, 33, 17, 1);
        assert!(close(&csr.spmv(&x), &want));
    }

    #[test]
    fn spmm_matches_dense_ref_various_shapes() {
        let mut rng = Rng::new(1);
        for (m, k, n, s) in [(16, 16, 8, 0.5), (64, 48, 32, 0.75),
                             (100, 37, 19, 0.9), (8, 8, 8, 0.0)] {
            let csr = Csr::random(m, k, s, &mut rng);
            let dense = csr.to_dense();
            let b: Vec<f32> = (0..k * n)
                .map(|i| ((i % 13) as f32) * 0.3 - 1.0)
                .collect();
            let want = dense_ref(&dense, &b, m, k, n);
            assert!(close(&csr.spmm(&b, n), &want), "shape {m}x{k}x{n}");
            assert!(close(&dense_matmul(&dense, &b, m, k, n), &want));
        }
    }

    #[test]
    fn random_density_tracks_target() {
        let mut rng = Rng::new(2);
        let csr = Csr::random(200, 200, 0.75, &mut rng);
        assert!((csr.density() - 0.25).abs() < 0.02,
                "density={}", csr.density());
    }

    #[test]
    fn theoretical_speedup_values() {
        assert_eq!(theoretical_speedup(0.5), 2.0);
        assert_eq!(theoretical_speedup(0.75), 4.0);
        assert!((theoretical_speedup(0.9983) - 588.0).abs() < 10.0);
    }

    /// Bitwise equality — the serve-path pin, not a tolerance check.
    fn bitwise(a: &[f32], b: &[f32]) -> bool {
        a.len() == b.len()
            && a.iter().zip(b)
                .all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn spmm_matches_dense_matmul_bitwise_at_edge_shapes() {
        // the decode path feeds spmm shapes the tolerance tests above
        // never exercised: single-row A, n=1 activations, empty rows,
        // and a fully-dense matrix. The pin is exact: spmm(csr, x)
        // must equal dense_matmul(to_dense(csr), x) bit for bit,
        // because both accumulate k-major per row and the dense
        // kernel's zero-skip mirrors the CSR structure.
        let mut rng = Rng::new(11);
        let shapes: [(usize, usize, usize, f64); 6] = [
            (1, 16, 8, 0.75),  // 1-row A
            (16, 16, 1, 0.75), // 1-column activations
            (1, 8, 1, 0.5),    // both degenerate
            (12, 12, 6, 0.97), // near-empty rows
            (8, 8, 8, 0.0),    // fully-dense input
            (64, 48, 17, 0.75),
        ];
        for (m, k, n, s) in shapes {
            let csr = Csr::random(m, k, s, &mut rng);
            let dense = csr.to_dense();
            let b: Vec<f32> = (0..k * n)
                .map(|_| rng.normal_f32(0.0, 1.0))
                .collect();
            assert!(
                bitwise(&csr.spmm(&b, n),
                        &dense_matmul(&dense, &b, m, k, n)),
                "bitwise divergence at {m}x{k}x{n} s={s}"
            );
        }
    }

    #[test]
    fn spmm_matches_dense_matmul_bitwise_with_empty_rows() {
        // rows 1 and 3 are structurally empty: spmm never touches
        // them, dense_matmul skips every (zero) operand — both must
        // leave exact +0.0 outputs
        let dense = vec![
            1.5, 0.0, -2.0, 0.0, //
            0.0, 0.0, 0.0, 0.0, //
            0.0, 3.0, 0.0, -0.5, //
            0.0, 0.0, 0.0, 0.0,
        ];
        let csr = Csr::from_dense(&dense, 4, 4);
        assert_eq!(csr.row_ptr, vec![0, 2, 2, 4, 4]);
        let b: Vec<f32> = (0..4 * 3)
            .map(|i| (i as f32) * 0.37 - 1.1)
            .collect();
        let got = csr.spmm(&b, 3);
        assert!(bitwise(&got, &dense_matmul(&dense, &b, 4, 4, 3)));
        for j in 0..3 {
            assert_eq!(got[3 + j].to_bits(), 0.0f32.to_bits());
            assert_eq!(got[9 + j].to_bits(), 0.0f32.to_bits());
        }
    }

    #[test]
    fn spmm_nan_input_regression() {
        // NaN in the dense activations: both kernels skip it where
        // A's entry is (structurally) zero and propagate it where A
        // is nonzero — identically. Before the dense kernel mirrored
        // the zero-skip, 0·NaN would have poisoned the dense baseline
        // while spmm stayed finite.
        let dense = vec![
            2.0, 0.0, //
            0.0, 1.0,
        ];
        let csr = Csr::from_dense(&dense, 2, 2);
        // B row 1 is all-NaN: row 0 of A never reads it
        let b = vec![3.0, 4.0, f32::NAN, f32::NAN];
        let sp = csr.spmm(&b, 2);
        let dn = dense_matmul(&dense, &b, 2, 2, 2);
        assert!(bitwise(&sp, &dn));
        assert_eq!(&sp[..2], &[6.0, 8.0]); // NaN skipped, not spread
        assert!(sp[2].is_nan() && sp[3].is_nan());
    }

    #[test]
    fn property_spmm_equals_dense_matmul_bitwise() {
        crate::util::proptest::check(
            29, 10, 40,
            |rng: &mut Rng, size: usize| {
                let m = 1 + rng.below(size.max(2));
                let k = 1 + rng.below(size.max(2));
                let n = 1 + rng.below(12);
                let s = [0.0, 0.5, 0.75, 0.95][rng.below(4)];
                (m, k, n, s, rng.next_u64())
            },
            |&(m, k, n, s, seed)| {
                let mut rng = Rng::new(seed);
                let csr = Csr::random(m, k, s, &mut rng);
                let dense = csr.to_dense();
                let b: Vec<f32> = (0..k * n)
                    .map(|_| rng.normal_f32(0.0, 1.0))
                    .collect();
                bitwise(&csr.spmm(&b, n),
                        &dense_matmul(&dense, &b, m, k, n))
            },
        );
    }

    #[test]
    fn property_spmm_equals_dense_on_random_inputs() {
        crate::util::proptest::check(
            3, 12, 48,
            |rng: &mut Rng, size: usize| {
                let m = 4 + rng.below(size.max(4));
                let k = 4 + rng.below(size.max(4));
                let n = 1 + rng.below(16);
                let s = [0.0, 0.5, 0.9][rng.below(3)];
                (m, k, n, s, rng.next_u64())
            },
            |&(m, k, n, s, seed)| {
                let mut rng = Rng::new(seed);
                let csr = Csr::random(m, k, s, &mut rng);
                let dense = csr.to_dense();
                let b: Vec<f32> = (0..k * n)
                    .map(|_| rng.normal_f32(0.0, 1.0))
                    .collect();
                let want = dense_ref(&dense, &b, m, k, n);
                close(&csr.spmm(&b, n), &want)
            },
        );
    }
}
