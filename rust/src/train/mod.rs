//! Training: state management, LR schedules, the step driver, and
//! checkpointing.

pub mod checkpoint;
pub mod schedule;
pub mod session;
pub mod state;

pub use schedule::Schedule;
pub use session::{evaluate_loss, perplexity, StepLog, Trainer};
pub use state::{ParamMap, TrainState};
