//! Checkpoint store: params + optimizer moments + masks in a simple
//! self-describing binary format (JSON header + raw f32 LE blob).
//!
//! Format:
//!   8 bytes magic  "SPDFCKP1"
//!   8 bytes u64 LE header length H
//!   H bytes JSON header { step, sparsity, tensors: [{name, kind,
//!                         shape, offset, len}] }
//!   raw little-endian f32 data
//!
//! Small enough to fully load, explicit enough to survive refactors.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::sparsity::{MaskScheme, MaskSet};
use crate::train::state::TrainState;
use crate::util::json::Json;

const MAGIC: &[u8; 8] = b"SPDFCKP1";

pub fn save(state: &TrainState, path: &Path) -> anyhow::Result<()> {
    let mut tensors = Vec::new(); // (name, kind, shape-less len, data ref)
    let mut blob: Vec<f32> = Vec::new();
    let entry = |name: &str, kind: &str, data: &[f32],
                     tensors: &mut Vec<Json>, blob: &mut Vec<f32>| {
        let mut o = Json::obj();
        o.push("name", Json::Str(name.to_string()))
            .push("kind", Json::Str(kind.to_string()))
            .push("offset", Json::Num(blob.len() as f64))
            .push("len", Json::Num(data.len() as f64));
        tensors.push(o);
        blob.extend_from_slice(data);
    };
    for (name, data) in &state.params {
        entry(name, "param", data, &mut tensors, &mut blob);
    }
    for (name, data) in &state.opt_m {
        entry(name, "m", data, &mut tensors, &mut blob);
    }
    for (name, data) in &state.opt_v {
        entry(name, "v", data, &mut tensors, &mut blob);
    }
    for (name, data) in &state.masks.masks {
        entry(name, "mask", data, &mut tensors, &mut blob);
    }

    let mut header = Json::obj();
    header.push("step", Json::Num(state.step as f64))
        .push("target_sparsity",
              Json::Num(state.masks.target_sparsity))
        .push("tensors", Json::Arr(tensors));
    let header_bytes = header.to_string().into_bytes();

    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(header_bytes.len() as u64).to_le_bytes())?;
    f.write_all(&header_bytes)?;
    let bytes = unsafe {
        std::slice::from_raw_parts(blob.as_ptr() as *const u8,
                                   blob.len() * 4)
    };
    f.write_all(bytes)?;
    Ok(())
}

pub fn load(path: &Path) -> anyhow::Result<TrainState> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not a SPDF checkpoint: {path:?}");
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8)?;
    let hlen = u64::from_le_bytes(len8) as usize;
    let mut hbytes = vec![0u8; hlen];
    f.read_exact(&mut hbytes)?;
    let header = Json::parse(std::str::from_utf8(&hbytes)?)
        .map_err(|e| anyhow::anyhow!("checkpoint header: {e}"))?;
    let mut raw = Vec::new();
    f.read_to_end(&mut raw)?;
    anyhow::ensure!(raw.len() % 4 == 0, "truncated checkpoint blob");
    let blob: Vec<f32> = raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();

    let mut params = BTreeMap::new();
    let mut opt_m = BTreeMap::new();
    let mut opt_v = BTreeMap::new();
    let mut masks = BTreeMap::new();
    for t in header.req("tensors")?.as_arr().unwrap() {
        let name = t.req("name")?.as_str().unwrap().to_string();
        let kind = t.req("kind")?.as_str().unwrap();
        let off = t.req("offset")?.as_usize().unwrap();
        let len = t.req("len")?.as_usize().unwrap();
        anyhow::ensure!(off + len <= blob.len(),
                        "tensor {name} out of bounds");
        let data = blob[off..off + len].to_vec();
        match kind {
            "param" => params.insert(name, data),
            "m" => opt_m.insert(name, data),
            "v" => opt_v.insert(name, data),
            "mask" => masks.insert(name, data),
            other => anyhow::bail!("unknown tensor kind {other}"),
        };
    }
    let target = header.req("target_sparsity")?.as_f64().unwrap_or(0.0);
    let step = header.req("step")?.as_usize().unwrap_or(0) as u64;
    Ok(TrainState {
        params,
        opt_m,
        opt_v,
        masks: MaskSet {
            scheme: MaskScheme::Uniform,
            target_sparsity: target,
            masks,
        },
        step,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{InitKind, ModelManifest, ParamSpec};
    use crate::sparsity::MaskScheme;
    use crate::util::rng::Rng;
    use crate::config;

    fn tiny_manifest() -> ModelManifest {
        ModelManifest {
            config: config::sim_nano(),
            train_batch: 2,
            eval_batch: 2,
            decode_batch: 2,
            params: vec![
                ParamSpec { name: "wte".into(), shape: vec![8, 4],
                            init: InitKind::Normal },
                ParamSpec { name: "h0.attn.wq".into(), shape: vec![4, 4],
                            init: InitKind::Normal },
            ],
            masked_params: vec!["h0.attn.wq".into()],
            decay_params: vec![],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn round_trip_preserves_everything() {
        let m = tiny_manifest();
        let mut st = TrainState::init(&m, &mut Rng::new(0));
        st.sparsify(MaskSet::random(&m, 0.5, MaskScheme::Uniform,
                                    &mut Rng::new(1)));
        st.step = 42;
        st.opt_m.get_mut("wte").unwrap()[0] = 3.25;

        let dir = std::env::temp_dir().join("spdf-ckpt-test");
        let path = dir.join("test.ckpt");
        save(&st, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.step, 42);
        assert_eq!(loaded.params, st.params);
        assert_eq!(loaded.opt_m, st.opt_m);
        assert_eq!(loaded.opt_v, st.opt_v);
        assert_eq!(loaded.masks.masks, st.masks.masks);
        assert_eq!(loaded.masks.target_sparsity, 0.5);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let dir = std::env::temp_dir().join("spdf-ckpt-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
