//! The training driver: feeds batches through the `train_step` artifact,
//! tracks loss, runs evaluation through `eval_loss`.

use crate::data::Batch;
use crate::runtime::{HostTensor, ModelRuntime};
use crate::train::schedule::Schedule;
use crate::train::state::TrainState;

/// Per-step record for loss-curve logging.
#[derive(Debug, Clone, Copy)]
pub struct StepLog {
    pub step: u64,
    pub lr: f32,
    pub loss: f32,
    pub wall_ms: f64,
}

/// Literal-resident training state (§Perf L3): between steps the
/// params/moments live as the XLA literals returned by the previous
/// step, so the hot loop never copies them through `Vec<f32>`. Masks
/// are uploaded once per phase. Host materialization happens only on
/// `sync()` (evaluate / checkpoint / end of phase).
struct LitCache {
    /// 3P literals: params, then m, then v (flatten order each)
    state: Vec<xla::Literal>,
    /// mask literals (sorted name order), fixed for the phase
    masks: Vec<xla::Literal>,
}

pub struct Trainer<'a> {
    pub runtime: &'a ModelRuntime,
    pub state: TrainState,
    pub schedule: Schedule,
    pub history: Vec<StepLog>,
    lits: Option<LitCache>,
}

impl<'a> Trainer<'a> {
    pub fn new(runtime: &'a ModelRuntime, state: TrainState,
               schedule: Schedule) -> Trainer<'a> {
        Trainer { runtime, state, schedule, history: Vec::new(),
                  lits: None }
    }

    fn ensure_lits(&mut self) -> anyhow::Result<()> {
        if self.lits.is_some() {
            return Ok(());
        }
        let mm = &self.runtime.manifest;
        let mut state = Vec::new();
        for t in self.state.param_tensors(mm) {
            state.push(t.to_literal()?);
        }
        let (m, v) = self.state.opt_tensors(mm);
        for t in m.into_iter().chain(v) {
            state.push(t.to_literal()?);
        }
        let masks = self.state.mask_tensors(mm)
            .into_iter()
            .map(|t| t.to_literal())
            .collect::<anyhow::Result<Vec<_>>>()?;
        self.lits = Some(LitCache { state, masks });
        Ok(())
    }

    /// Materialize the literal-resident state back into `self.state`
    /// (no-op when the fast path hasn't run).
    pub fn sync(&mut self) -> anyhow::Result<()> {
        let Some(lits) = &self.lits else { return Ok(()) };
        let mm = &self.runtime.manifest;
        let order = mm.param_flatten_order();
        let p = order.len();
        for (i, name) in order.iter().enumerate() {
            self.state.params.insert(
                name.clone(), lits.state[i].to_vec::<f32>()?);
            self.state.opt_m.insert(
                name.clone(), lits.state[p + i].to_vec::<f32>()?);
            self.state.opt_v.insert(
                name.clone(), lits.state[2 * p + i].to_vec::<f32>()?);
        }
        Ok(())
    }

    /// Consume the trainer, returning the fully materialized state.
    pub fn into_state(mut self) -> anyhow::Result<TrainState> {
        self.sync()?;
        Ok(self.state)
    }

    /// One optimizer step on a batch; returns the batch loss.
    ///
    /// Hot path: inputs are the cached state literals + fresh batch
    /// literals; outputs replace the cached literals wholesale.
    pub fn step(&mut self, batch: &Batch) -> anyhow::Result<f32> {
        let t0 = std::time::Instant::now();
        self.ensure_lits()?;
        let exe = self.runtime.artifact("train_step")?;
        let step_num = self.state.step + 1;
        let lr = self.schedule.lr(step_num);

        let [tok, tgt, lmask] = batch.tensors();
        let tok_l = tok.to_literal()?;
        let tgt_l = tgt.to_literal()?;
        let lmask_l = lmask.to_literal()?;
        let step_l = HostTensor::scalar_f32(step_num as f32)
            .to_literal()?;
        let lr_l = HostTensor::scalar_f32(lr).to_literal()?;

        let lits = self.lits.as_ref().unwrap();
        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(lits.state.len() + lits.masks.len() + 5);
        inputs.extend(lits.state.iter());
        inputs.extend(lits.masks.iter());
        inputs.push(&tok_l);
        inputs.push(&tgt_l);
        inputs.push(&lmask_l);
        inputs.push(&step_l);
        inputs.push(&lr_l);

        let mut outputs = exe.run_raw(&inputs)?;
        let p3 = lits.state.len();
        anyhow::ensure!(outputs.len() == p3 + 1,
                        "train_step returned {} outputs, want {}",
                        outputs.len(), p3 + 1);
        let loss_lit = outputs.pop().unwrap();
        let loss: f32 = loss_lit.get_first_element()?;
        self.lits.as_mut().unwrap().state = outputs;
        self.state.step += 1;

        self.history.push(StepLog {
            step: step_num,
            lr,
            loss,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        });
        Ok(loss)
    }

    /// Mean loss-per-token over batches via the eval_loss artifact
    /// (exact: sum of CE / sum of mask). Syncs the literal state first.
    pub fn evaluate(&mut self, batches: &[Batch]) -> anyhow::Result<f64> {
        self.sync()?;
        evaluate_loss(self.runtime, &self.state, batches)
    }

    /// Trailing mean train loss over the last `n` steps.
    pub fn recent_loss(&self, n: usize) -> f64 {
        let tail = &self.history[self.history.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f64::NAN;
        }
        tail.iter().map(|s| s.loss as f64).sum::<f64>()
            / tail.len() as f64
    }
}

/// Standalone eval (used by the coordinator after training too).
pub fn evaluate_loss(
    runtime: &ModelRuntime,
    state: &TrainState,
    batches: &[Batch],
) -> anyhow::Result<f64> {
    let mm = &runtime.manifest;
    let exe = runtime.artifact("eval_loss")?;
    let params = state.param_tensors(mm);
    let mut total = 0.0f64;
    let mut count = 0.0f64;
    for batch in batches {
        let mut inputs = params.clone();
        let [tok, tgt, lmask] = batch.tensors();
        inputs.push(tok);
        inputs.push(tgt);
        inputs.push(lmask);
        let out = exe.run(&inputs)?;
        total += out[0].scalar()? as f64;
        count += out[1].scalar()? as f64;
    }
    anyhow::ensure!(count > 0.0, "eval batches carried no loss tokens");
    Ok(total / count)
}

/// Perplexity from a mean CE loss.
pub fn perplexity(mean_loss: f64) -> f64 {
    mean_loss.exp()
}

#[cfg(test)]
mod tests {
    #[test]
    fn perplexity_of_zero_loss_is_one() {
        assert_eq!(super::perplexity(0.0), 1.0);
        assert!((super::perplexity(2.0) - 7.389).abs() < 0.01);
    }
}
