//! Learning-rate schedules (paper App. A.1/A.2): linear warmup + cosine
//! decay to 10% of peak for pre-training; linear decay for fine-tuning.

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    /// warmup over `warmup` steps then cosine decay to `floor_frac *
    /// peak` at `total` steps (pre-training; paper: warmup over the
    /// first 375M tokens, decay to 10%).
    WarmupCosine { peak: f32, warmup: u64, total: u64, floor_frac: f32 },
    /// Linear from `peak` to 0 over `total` steps (fine-tuning, follows
    /// Hu et al. 2022).
    Linear { peak: f32, total: u64 },
    /// Constant (ablations / debugging).
    Constant { peak: f32 },
}

impl Schedule {
    /// LR at a 1-based step.
    pub fn lr(&self, step: u64) -> f32 {
        match *self {
            Schedule::Constant { peak } => peak,
            Schedule::Linear { peak, total } => {
                let t = (step.min(total)) as f32 / total.max(1) as f32;
                peak * (1.0 - t).max(0.0)
            }
            Schedule::WarmupCosine { peak, warmup, total, floor_frac } => {
                if step <= warmup && warmup > 0 {
                    return peak * step as f32 / warmup as f32;
                }
                let floor = floor_frac * peak;
                if step >= total {
                    return floor;
                }
                let t = (step - warmup) as f32
                    / (total - warmup).max(1) as f32;
                let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
                floor + (peak - floor) * cos
            }
        }
    }

    /// The paper's pre-training schedule for a given step budget:
    /// warmup over the leading ~15% (stand-in for 375M tokens at this
    /// scale), cosine to 10% of peak.
    pub fn pretrain(peak: f32, total: u64) -> Schedule {
        Schedule::WarmupCosine {
            peak,
            warmup: (total / 7).max(1),
            total,
            floor_frac: 0.1,
        }
    }

    pub fn finetune(peak: f32, total: u64) -> Schedule {
        Schedule::Linear { peak, total }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = Schedule::WarmupCosine {
            peak: 1.0, warmup: 10, total: 100, floor_frac: 0.1,
        };
        assert!((s.lr(5) - 0.5).abs() < 1e-6);
        assert!((s.lr(10) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_decays_to_floor() {
        let s = Schedule::WarmupCosine {
            peak: 2.0, warmup: 10, total: 100, floor_frac: 0.1,
        };
        assert!((s.lr(100) - 0.2).abs() < 1e-6);
        assert!((s.lr(1000) - 0.2).abs() < 1e-6);
        // midpoint between peak and floor at half decay
        let mid = s.lr(55);
        assert!((mid - 1.1).abs() < 0.02, "mid={mid}");
    }

    #[test]
    fn monotone_decay_after_warmup() {
        let s = Schedule::pretrain(6e-4, 1000);
        let mut prev = f32::MAX;
        for step in (150..1000).step_by(50) {
            let lr = s.lr(step);
            assert!(lr <= prev + 1e-9);
            prev = lr;
        }
    }

    #[test]
    fn linear_hits_zero() {
        let s = Schedule::finetune(1e-4, 200);
        assert!(s.lr(200) == 0.0);
        assert!((s.lr(100) - 0.5e-4).abs() < 1e-9);
        assert!(s.lr(1) > 0.0);
    }
}
