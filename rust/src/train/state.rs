//! Training state: parameters + AdamW moments + the active mask set.
//!
//! Parameters are initialized in rust from the manifest's init spec
//! (matching the python reference initializer's distributions), so the
//! full SPDF pipeline — init → sparsify → pre-train → densify →
//! fine-tune — runs without python.

use std::collections::BTreeMap;

use crate::runtime::{HostTensor, InitKind, ModelManifest};
use crate::sparsity::MaskSet;
use crate::util::rng::Rng;

pub type ParamMap = BTreeMap<String, Vec<f32>>;

#[derive(Debug, Clone)]
pub struct TrainState {
    pub params: ParamMap,
    pub opt_m: ParamMap,
    pub opt_v: ParamMap,
    pub masks: MaskSet,
    /// 1-based AdamW timestep (bias correction).
    pub step: u64,
}

impl TrainState {
    /// Fresh init (GPT-2 style: normal(0, 0.02), residual projections
    /// scaled by 1/sqrt(2L), zeros/ones for biases/LayerNorm).
    pub fn init(manifest: &ModelManifest, rng: &mut Rng) -> TrainState {
        let n_layers = manifest.config.n_layers as f32;
        let mut params = ParamMap::new();
        for spec in &manifest.params {
            let n = spec.elems();
            let data = match spec.init {
                InitKind::Zeros => vec![0.0; n],
                InitKind::Ones => vec![1.0; n],
                InitKind::Normal => {
                    (0..n).map(|_| rng.normal_f32(0.0, 0.02)).collect()
                }
                InitKind::NormalResid => {
                    let std = 0.02 / (2.0 * n_layers).sqrt();
                    (0..n).map(|_| rng.normal_f32(0.0, std)).collect()
                }
            };
            params.insert(spec.name.clone(), data);
        }
        let zeros: ParamMap = manifest
            .params
            .iter()
            .map(|s| (s.name.clone(), vec![0.0; s.elems()]))
            .collect();
        TrainState {
            params,
            opt_m: zeros.clone(),
            opt_v: zeros,
            masks: MaskSet::dense(manifest),
            step: 0,
        }
    }

    /// Install a mask set and apply it to the weights (sparsify step).
    pub fn sparsify(&mut self, masks: MaskSet) {
        masks.apply(&mut self.params);
        masks.apply(&mut self.opt_m);
        masks.apply(&mut self.opt_v);
        self.masks = masks;
    }

    /// The densify transition (the "D" in SPDF): drop the mask, keep the
    /// weights — revived weights start at exactly 0 (paper §2.2) because
    /// sparse pre-training kept them zero. Optimizer moments reset for
    /// the new task, matching a fresh fine-tuning optimizer.
    pub fn densify(&mut self, manifest: &ModelManifest) {
        self.masks = MaskSet::dense(manifest);
        for v in self.opt_m.values_mut() {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
        for v in self.opt_v.values_mut() {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
        self.step = 0;
    }

    /// Reset the optimizer for a new phase but keep the current masks
    /// (the sparse fine-tuning baseline of Figure 2).
    pub fn reset_optimizer(&mut self) {
        for v in self.opt_m.values_mut() {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
        for v in self.opt_v.values_mut() {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
        self.step = 0;
    }

    /// Flat tensors for the leading inputs of an artifact: params (then
    /// m, v, masks as requested) in jax flatten (sorted-name) order.
    pub fn param_tensors(&self, manifest: &ModelManifest)
                         -> Vec<HostTensor> {
        self.map_tensors(manifest, &self.params)
    }

    pub fn opt_tensors(&self, manifest: &ModelManifest)
                       -> (Vec<HostTensor>, Vec<HostTensor>) {
        (self.map_tensors(manifest, &self.opt_m),
         self.map_tensors(manifest, &self.opt_v))
    }

    pub fn mask_tensors(&self, manifest: &ModelManifest)
                        -> Vec<HostTensor> {
        let mut names: Vec<&String> =
            self.masks.masks.keys().collect();
        names.sort();
        names
            .into_iter()
            .map(|n| {
                let spec = manifest.param(n).expect("mask param");
                HostTensor::from_f32(&spec.shape,
                                     self.masks.masks[n].clone())
            })
            .collect()
    }

    fn map_tensors(&self, manifest: &ModelManifest, map: &ParamMap)
                   -> Vec<HostTensor> {
        manifest
            .param_flatten_order()
            .iter()
            .map(|n| {
                let spec = manifest.param(n).expect("param spec");
                HostTensor::from_f32(&spec.shape, map[n].clone())
            })
            .collect()
    }

    /// Write back updated params/moments from train_step outputs.
    pub fn absorb_step_outputs(
        &mut self,
        manifest: &ModelManifest,
        outputs: &[HostTensor],
    ) -> anyhow::Result<f32> {
        let order = manifest.param_flatten_order();
        let p = order.len();
        anyhow::ensure!(outputs.len() == 3 * p + 1,
                        "train_step returned {} outputs, want {}",
                        outputs.len(), 3 * p + 1);
        for (i, name) in order.iter().enumerate() {
            self.params.insert(name.clone(),
                               outputs[i].as_f32()?.to_vec());
            self.opt_m.insert(name.clone(),
                              outputs[p + i].as_f32()?.to_vec());
            self.opt_v.insert(name.clone(),
                              outputs[2 * p + i].as_f32()?.to_vec());
        }
        self.step += 1;
        outputs[3 * p].scalar()
    }

    /// L2 norm of all parameters (training health metric).
    pub fn param_norm(&self) -> f64 {
        self.params
            .values()
            .flat_map(|v| v.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpec;
    use crate::sparsity::MaskScheme;
    use crate::config;

    fn tiny_manifest() -> ModelManifest {
        ModelManifest {
            config: config::sim_nano(),
            train_batch: 2,
            eval_batch: 2,
            decode_batch: 2,
            params: vec![
                ParamSpec { name: "wte".into(), shape: vec![8, 4],
                            init: InitKind::Normal },
                ParamSpec { name: "h0.attn.wq".into(), shape: vec![4, 4],
                            init: InitKind::Normal },
                ParamSpec { name: "h0.ln1.g".into(), shape: vec![4],
                            init: InitKind::Ones },
                ParamSpec { name: "h0.ln1.b".into(), shape: vec![4],
                            init: InitKind::Zeros },
            ],
            masked_params: vec!["h0.attn.wq".into()],
            decay_params: vec![],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn init_respects_kinds() {
        let m = tiny_manifest();
        let st = TrainState::init(&m, &mut Rng::new(0));
        assert!(st.params["h0.ln1.g"].iter().all(|&x| x == 1.0));
        assert!(st.params["h0.ln1.b"].iter().all(|&x| x == 0.0));
        assert!(st.params["wte"].iter().any(|&x| x != 0.0));
        // std roughly 0.02
        let wte = &st.params["wte"];
        let var: f32 = wte.iter().map(|x| x * x).sum::<f32>()
            / wte.len() as f32;
        assert!(var.sqrt() < 0.08);
    }

    #[test]
    fn sparsify_then_densify_keeps_surviving_weights() {
        let m = tiny_manifest();
        let mut st = TrainState::init(&m, &mut Rng::new(1));
        let masks = MaskSet::random(&m, 0.5, MaskScheme::Uniform,
                                    &mut Rng::new(2));
        st.sparsify(masks.clone());
        masks.check_holes_zero(&st.params).unwrap();
        let frozen = st.params["h0.attn.wq"].clone();
        st.densify(&m);
        assert_eq!(st.params["h0.attn.wq"], frozen);
        assert_eq!(st.masks.realized_sparsity(), 0.0);
        assert_eq!(st.step, 0);
    }

    #[test]
    fn tensor_order_is_sorted_names() {
        let m = tiny_manifest();
        let st = TrainState::init(&m, &mut Rng::new(0));
        let ts = st.param_tensors(&m);
        assert_eq!(ts.len(), 4);
        // sorted: h0.attn.wq, h0.ln1.b, h0.ln1.g, wte
        assert_eq!(ts[0].shape(), &[4, 4]);
        assert_eq!(ts[3].shape(), &[8, 4]);
    }

    #[test]
    fn absorb_outputs_round_trip() {
        let m = tiny_manifest();
        let mut st = TrainState::init(&m, &mut Rng::new(0));
        let order = m.param_flatten_order();
        let mut outs = Vec::new();
        for mult in [2.0f32, 3.0, 4.0] {
            for n in &order {
                let spec = m.param(n).unwrap();
                outs.push(HostTensor::from_f32(
                    &spec.shape, vec![mult; spec.elems()]));
            }
        }
        outs.push(HostTensor::scalar_f32(1.25));
        let loss = st.absorb_step_outputs(&m, &outs).unwrap();
        assert_eq!(loss, 1.25);
        assert!(st.params["wte"].iter().all(|&x| x == 2.0));
        assert!(st.opt_m["wte"].iter().all(|&x| x == 3.0));
        assert!(st.opt_v["wte"].iter().all(|&x| x == 4.0));
        assert_eq!(st.step, 1);
    }
}
