//! The SPDF pipeline: sparsify → sparse pre-train → densify → dense
//! fine-tune → evaluate. This is the paper's §2.2 procedure as
//! executable orchestration.

use std::collections::BTreeMap;

use crate::data::{self, Batch, FinetuneBatches, PackedStream, Task,
                  TaskData};
use crate::generate::{DecodeEngine, DecodeParams, DecodeRequest};
use crate::runtime::{Engine, ModelRuntime};
use crate::sparsity::{MaskScheme, MaskSet};
use crate::tokenizer::{Tokenizer, BOS, SEP};
use crate::train::{self, Schedule, StepLog, TrainState, Trainer};
use crate::util::rng::Rng;
use crate::{eval, flops};

/// Everything data-side shared across a seed: tokenizer, pre-training
/// stream, downstream task datasets.
pub struct World {
    pub tokenizer: Tokenizer,
    pub stream: Vec<u32>,
    pub tasks: BTreeMap<Task, TaskData>,
}

#[derive(Debug, Clone)]
pub struct WorldConfig {
    pub seed: u64,
    pub corpus_words: usize,
    pub vocab_size: usize,
    /// dataset scale relative to paper/10 defaults
    pub task_scale: f64,
}

impl Default for WorldConfig {
    fn default() -> Self {
        WorldConfig {
            seed: 0,
            corpus_words: 400_000,
            vocab_size: 512,
            task_scale: 0.25,
        }
    }
}

/// Salt for the world-build RNG side-stream: corpus/task synthesis
/// draws stay decoupled from the run streams seeded directly with
/// `cfg.seed`. Same literal the seed used unnamed, so every pinned
/// world is unchanged.
pub const WORLD_SALT: u64 = 0x5bd1_e995;

impl World {
    pub fn build(cfg: &WorldConfig) -> World {
        let mut rng = Rng::new(cfg.seed ^ WORLD_SALT);
        let corpus = data::synthpile::corpus(&mut rng, cfg.corpus_words);
        // train the tokenizer on the corpus + downstream lexicon so
        // fine-tuning text stays in-vocabulary
        let mut tasks = BTreeMap::new();
        for task in Task::all() {
            let mut trng = rng.fork(task.name().len() as u64);
            tasks.insert(task, task.generate(&mut trng, cfg.task_scale));
        }
        let mut tok_corpus = corpus.clone();
        tok_corpus.push(' ');
        tok_corpus.push_str(&data::synthpile::lexicon());
        for td in tasks.values() {
            for ex in td.train.iter().take(200) {
                tok_corpus.push(' ');
                tok_corpus.push_str(&ex.input);
                tok_corpus.push(' ');
                tok_corpus.push_str(&ex.refs[0]);
            }
        }
        let tokenizer = Tokenizer::train(&tok_corpus, cfg.vocab_size);
        let stream = tokenizer.encode(&corpus);
        World { tokenizer, stream, tasks }
    }

    pub fn task(&self, task: Task) -> &TaskData {
        &self.tasks[&task]
    }
}

// ---------------------------------------------------------------------------
// Phase 1+2: sparsify + sparse pre-train
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct PretrainConfig {
    pub sparsity: f64,
    pub scheme: MaskScheme,
    pub steps: u64,
    pub peak_lr: f32,
    pub seed: u64,
    pub log_every: u64,
}

impl Default for PretrainConfig {
    fn default() -> Self {
        PretrainConfig {
            sparsity: 0.0,
            scheme: MaskScheme::Uniform,
            steps: 1200,
            peak_lr: 1e-3,
            seed: 0,
            log_every: 100,
        }
    }
}

pub struct PretrainResult {
    pub state: TrainState,
    pub history: Vec<StepLog>,
    pub final_eval_loss: f64,
    /// analytic train FLOPs actually spent at this scale
    pub train_flops: f64,
}

/// Steps 1+2 of SPDF: random-sparsify at init, pre-train on SynthPile.
pub fn pretrain(
    runtime: &ModelRuntime,
    world: &World,
    cfg: &PretrainConfig,
) -> anyhow::Result<PretrainResult> {
    let mm = &runtime.manifest;
    let mut rng = Rng::new(cfg.seed);
    let mut state = TrainState::init(mm, &mut rng);
    if cfg.sparsity > 0.0 {
        let masks = MaskSet::random(mm, cfg.sparsity, cfg.scheme,
                                    &mut rng.fork(1));
        state.sparsify(masks);
    }

    let (b, t) = (mm.train_batch, mm.config.ctx_len);
    // hold out a tail of the stream for eval
    let split = world.stream.len() - (world.stream.len() / 20)
        .max(t * b + 1);
    let mut train_stream =
        PackedStream::new(world.stream[..split].to_vec(), b, t);
    let eval_batches = eval_stream_batches(&world.stream[split..], b, t);

    let schedule = Schedule::pretrain(cfg.peak_lr, cfg.steps);
    let mut trainer = Trainer::new(runtime, state, schedule);
    for step in 1..=cfg.steps {
        let batch = train_stream.next_batch();
        let loss = trainer.step(&batch)?;
        if cfg.log_every > 0 && step % cfg.log_every == 0 {
            log(&format!(
                "pretrain[{} s={:.0}%] step {step}/{} loss {loss:.4} \
                 lr {:.2e}",
                mm.config.name, cfg.sparsity * 100.0, cfg.steps,
                trainer.schedule.lr(step)));
        }
    }
    let final_eval_loss = trainer.evaluate(&eval_batches)?; // syncs lits
    log(&format!(
        "pretrain[{} s={:.0}%] done: eval loss {final_eval_loss:.4} \
         (ppl {:.2})",
        mm.config.name, cfg.sparsity * 100.0,
        train::perplexity(final_eval_loss)));

    let tokens = cfg.steps as f64 * (b * t) as f64;
    let seqs = tokens / t as f64;
    let per_seq =
        flops::train_flops_per_seq(&mm.config, t as u64, cfg.sparsity);
    Ok(PretrainResult {
        state: trainer.state,
        history: trainer.history,
        final_eval_loss,
        train_flops: seqs * per_seq,
    })
}

fn eval_stream_batches(stream: &[u32], b: usize, t: usize) -> Vec<Batch> {
    let mut ps = PackedStream::new(stream.to_vec(), b, t);
    let n = ((stream.len() / (b * t)).max(1)).min(4);
    (0..n).map(|_| ps.next_batch()).collect()
}

// ---------------------------------------------------------------------------
// Phase 3: fine-tune (dense by default; sparse for the Fig. 2 baseline)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct FinetuneConfig {
    pub task: Task,
    pub epochs: usize,
    pub peak_lr: f32,
    /// true = SPDF dense fine-tuning; false = sparse FT (Fig. 2)
    pub dense: bool,
    pub seed: u64,
    /// early stopping patience in epochs (paper: stop on overfit)
    pub patience: usize,
    pub log_every: u64,
}

impl Default for FinetuneConfig {
    fn default() -> Self {
        FinetuneConfig {
            task: Task::E2e,
            epochs: 5,
            peak_lr: 3e-4,
            dense: true,
            seed: 0,
            patience: 2,
            log_every: 0,
        }
    }
}

pub struct FinetuneResult {
    pub state: TrainState,
    pub history: Vec<StepLog>,
    pub best_val_loss: f64,
    pub epochs_ran: usize,
    pub train_flops: f64,
}

/// Step 3 of SPDF: densify (mask → ones, revived weights start at 0)
/// and fine-tune with a linear schedule + per-epoch early stopping.
pub fn finetune(
    runtime: &ModelRuntime,
    world: &World,
    mut state: TrainState,
    cfg: &FinetuneConfig,
) -> anyhow::Result<FinetuneResult> {
    let mm = &runtime.manifest;
    if cfg.dense {
        state.densify(mm);
    } else {
        state.reset_optimizer();
    }

    let (b, t) = (mm.train_batch, mm.config.ctx_len);
    let td = world.task(cfg.task);
    let train_ex: Vec<(String, String)> = td
        .train
        .iter()
        .map(|ex| (ex.input.clone(), ex.refs[0].clone()))
        .collect();
    let mut batches = FinetuneBatches::new(
        &world.tokenizer, train_ex, b, t, cfg.seed ^ 0xf17e);
    let val_batches = finetune_eval_batches(
        &world.tokenizer, &td.valid, b, t);

    let steps_per_epoch = batches.batches_per_epoch() as u64;
    let total_steps = steps_per_epoch * cfg.epochs as u64;
    let schedule = Schedule::finetune(cfg.peak_lr, total_steps);
    let mut trainer = Trainer::new(runtime, state, schedule);

    let mut best_val = f64::INFINITY;
    let mut best_state: Option<TrainState> = None;
    let mut bad_epochs = 0;
    let mut epochs_ran = 0;
    'outer: for epoch in 0..cfg.epochs {
        for s in 0..steps_per_epoch {
            let batch = batches.next_batch();
            let loss = trainer.step(&batch)?;
            if cfg.log_every > 0
                && (epoch as u64 * steps_per_epoch + s + 1)
                    % cfg.log_every == 0
            {
                log(&format!(
                    "finetune[{}] epoch {epoch} step {s} loss {loss:.4}",
                    cfg.task.name()));
            }
        }
        epochs_ran = epoch + 1;
        let val = trainer.evaluate(&val_batches)?;
        log(&format!(
            "finetune[{} {}] epoch {epoch}: val loss {val:.4} \
             (ppl {:.2})",
            mm.config.name, cfg.task.name(), train::perplexity(val)));
        if val < best_val - 1e-4 {
            best_val = val;
            best_state = Some(trainer.state.clone());
            bad_epochs = 0;
        } else {
            bad_epochs += 1;
            if bad_epochs >= cfg.patience {
                log("finetune: early stop (overfitting)");
                break 'outer;
            }
        }
    }
    let history = trainer.history.clone();
    let state = match best_state {
        Some(s) => s,
        None => trainer.into_state()?,
    };

    let tokens = history.len() as f64 * (b * t) as f64;
    let sparsity = if cfg.dense { 0.0 } else {
        state.masks.target_sparsity
    };
    let per_seq =
        flops::train_flops_per_seq(&mm.config, t as u64, sparsity);
    Ok(FinetuneResult {
        state,
        history,
        best_val_loss: best_val,
        epochs_ran,
        train_flops: tokens / t as f64 * per_seq,
    })
}

fn finetune_eval_batches(
    tok: &Tokenizer,
    examples: &[data::TaskExample],
    b: usize,
    t: usize,
) -> Vec<Batch> {
    assert!(!examples.is_empty());
    let cap = examples.len().min(4 * b);
    let mut out = Vec::new();
    let mut cur_tokens = Vec::new();
    let mut cur_targets = Vec::new();
    let mut cur_mask = Vec::new();
    let mut rows = 0;
    // pad the tail batch by wrapping around (padded rows keep their
    // loss mask; slight double-weighting of the first examples is an
    // acceptable eval approximation over a fixed-geometry artifact)
    let padded = cap.div_ceil(b) * b;
    for i in 0..padded {
        let ex = &examples[i % cap];
        let (tk, tg, lm) =
            data::format_example(tok, &ex.input, &ex.refs[0], t);
        cur_tokens.extend(tk);
        cur_targets.extend(tg);
        cur_mask.extend(lm);
        rows += 1;
        if rows == b {
            out.push(Batch {
                b, t,
                tokens: std::mem::take(&mut cur_tokens),
                targets: std::mem::take(&mut cur_targets),
                loss_mask: std::mem::take(&mut cur_mask),
            });
            rows = 0;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Phase 4: downstream evaluation
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Default)]
pub struct TaskMetrics {
    pub bleu: f64,
    pub nist: f64,
    pub meteor: f64,
    pub rouge_l: f64,
    pub cider: f64,
    pub ter: f64,
    pub ppl: f64,
    pub n_examples: usize,
    /// WebNLG only (paper §3.1): BLEU on the seen-category and
    /// unseen-category halves of the test set.
    pub bleu_seen: Option<f64>,
    pub bleu_unseen: Option<f64>,
}

/// Generate on the test split and score with the official-metric suite;
/// PPL comes from teacher-forced eval_loss on the same split.
pub fn evaluate_task(
    runtime: &ModelRuntime,
    state: &TrainState,
    world: &World,
    task: Task,
    max_examples: usize,
    dp: &DecodeParams,
) -> anyhow::Result<TaskMetrics> {
    let mm = &runtime.manifest;
    let tok = &world.tokenizer;
    let td = world.task(task);
    let t = mm.config.ctx_len;
    let examples: Vec<&data::TaskExample> =
        td.test.iter().take(max_examples).collect();

    // ---- perplexity (teacher forced) ----------------------------------
    let owned: Vec<data::TaskExample> =
        examples.iter().map(|e| (*e).clone()).collect();
    let ppl_batches = finetune_eval_batches(
        tok, &owned, mm.eval_batch, t);
    let mean_loss =
        train::evaluate_loss(runtime, state, &ppl_batches)?;
    let ppl = train::perplexity(mean_loss);

    // ---- generation ----------------------------------------------------
    // one engine for the whole split: parameters upload to XLA
    // literals once, not once per chunk per step (§Perf serving path)
    let params = state.param_tensors(mm);
    let engine = DecodeEngine::new(runtime, &params)?;
    let mut pairs: Vec<(String, Vec<String>)> = Vec::new();
    if dp.beam_size <= 1 {
        // continuous slot-refill batching: every test prompt queues at
        // once; row independence keeps outputs identical to per-prompt
        // greedy decode
        let requests: Vec<DecodeRequest> = examples
            .iter()
            .enumerate()
            .map(|(i, ex)| DecodeRequest::new(
                i as u64,
                prompt_tokens(tok, &ex.input, t),
                dp.max_new_tokens))
            .collect();
        let report = engine.serve(&requests, dp)?;
        log(&format!(
            "decode[{}]: {} requests in {} steps, {:.0} tok/s, \
             occupancy {:.0}%",
            task.name(), report.stats.requests,
            report.stats.engine_steps, report.stats.tokens_per_sec,
            report.stats.occupancy * 100.0));
        for (ex, res) in examples.iter().zip(&report.results) {
            pairs.push((tok.decode(&res.tokens), ex.refs.clone()));
        }
    } else {
        for ex in &examples {
            let prompt = prompt_tokens(tok, &ex.input, t);
            let ids = engine.beam(&prompt, dp)?;
            pairs.push((tok.decode(&ids), ex.refs.clone()));
        }
    }

    if std::env::var("SPDF_DUMP_GEN").is_ok() {
        for (h, rs) in pairs.iter().take(6) {
            eprintln!("HYP: {h}\nREF: {}\n", rs[0]);
        }
    }

    // WebNLG's test set is half seen / half unseen categories (§3.1);
    // report BLEU per half like the official challenge script.
    let (mut bleu_seen, mut bleu_unseen) = (None, None);
    if task == Task::WebNlg {
        let split = |want: bool| -> Vec<(String, Vec<String>)> {
            pairs.iter()
                .zip(&examples)
                .filter(|(_, ex)| ex.seen_category == want)
                .map(|(p, _)| p.clone())
                .collect()
        };
        let seen = split(true);
        let unseen = split(false);
        if !seen.is_empty() {
            bleu_seen = Some(eval::bleu::corpus_bleu(&seen));
        }
        if !unseen.is_empty() {
            bleu_unseen = Some(eval::bleu::corpus_bleu(&unseen));
        }
    }

    Ok(TaskMetrics {
        bleu: eval::bleu::corpus_bleu(&pairs),
        nist: eval::nist::corpus_nist(&pairs),
        meteor: eval::meteor::corpus_meteor(&pairs),
        rouge_l: eval::rouge::corpus_rouge_l(&pairs),
        cider: eval::cider::corpus_cider(&pairs),
        ter: eval::ter::corpus_ter(&pairs),
        ppl,
        n_examples: pairs.len(),
        bleu_seen,
        bleu_unseen,
    })
}

/// Hyperparameter grid search over fine-tuning peak LRs (paper App.
/// A.2: select the best LR on the validation set). Returns the best
/// (lr, val_loss, result).
pub fn lr_grid_search(
    runtime: &ModelRuntime,
    world: &World,
    state: &TrainState,
    base: &FinetuneConfig,
    lrs: &[f32],
) -> anyhow::Result<(f32, FinetuneResult)> {
    anyhow::ensure!(!lrs.is_empty(), "empty lr grid");
    let mut best: Option<(f32, FinetuneResult)> = None;
    for &lr in lrs {
        let mut cfg = base.clone();
        cfg.peak_lr = lr;
        let res = finetune(runtime, world, state.clone(), &cfg)?;
        log(&format!("grid[{}] lr {lr:.1e}: val loss {:.4}",
                     base.task.name(), res.best_val_loss));
        let better = best.as_ref()
            .map_or(true, |(_, b)| res.best_val_loss < b.best_val_loss);
        if better {
            best = Some((lr, res));
        }
    }
    Ok(best.unwrap())
}

/// `BOS input SEP` — the decode-time prompt (matches format_example).
/// Public so `spdf serve` builds request streams the same way.
pub fn prompt_tokens(tok: &Tokenizer, input: &str, t: usize) -> Vec<u32> {
    let mut inp = tok.encode(input);
    let budget = t.saturating_sub(16); // leave room to generate
    if inp.len() + 2 > budget {
        let start = inp.len() - (budget - 2).min(inp.len());
        inp = inp[start..].to_vec();
    }
    let mut p = vec![BOS];
    p.extend(inp);
    p.push(SEP);
    p
}

fn log(msg: &str) {
    if std::env::var("SPDF_QUIET").is_err() {
        eprintln!("[spdf] {msg}");
    }
}

/// Convenience: compile + load a model's runtime from the default
/// artifact dir.
pub fn load_runtime(model: &str) -> anyhow::Result<(Engine, ModelRuntime)> {
    let engine = Engine::cpu(crate::runtime::default_artifact_dir())?;
    let runtime = engine.load_model(model)?;
    Ok((engine, runtime))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn world_builds_and_is_deterministic() {
        let cfg = WorldConfig {
            seed: 7, corpus_words: 3000, vocab_size: 512,
            task_scale: 0.01,
        };
        let w1 = World::build(&cfg);
        let w2 = World::build(&cfg);
        assert_eq!(w1.stream.len(), w2.stream.len());
        assert_eq!(w1.stream[..50], w2.stream[..50]);
        assert!(w1.stream.len() > 2000);
        assert_eq!(w1.tasks.len(), 4);
    }

    #[test]
    fn world_tokenizer_covers_task_text() {
        let cfg = WorldConfig {
            seed: 1, corpus_words: 3000, vocab_size: 512,
            task_scale: 0.01,
        };
        let w = World::build(&cfg);
        let ex = &w.task(Task::E2e).train[0];
        let ids = w.tokenizer.encode(&ex.input);
        assert_eq!(w.tokenizer.decode(&ids), ex.input);
    }

    #[test]
    fn prompt_tokens_truncates_from_left() {
        let tok = Tokenizer::train("a b c d e f g", 300);
        let long = "a b c d e f g ".repeat(50);
        let p = prompt_tokens(&tok, &long, 64);
        assert!(p.len() <= 64 - 14);
        assert_eq!(p[0], BOS);
        assert_eq!(*p.last().unwrap(), SEP);
    }
}
