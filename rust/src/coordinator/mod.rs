//! The SPDF coordinator: pipeline orchestration (pipeline.rs), the
//! experiment matrix runner (experiments.rs) and report formatting
//! (report.rs).

pub mod experiments;
pub mod pipeline;
pub mod report;

pub use pipeline::{
    evaluate_task, finetune, load_runtime, pretrain, prompt_tokens,
    FinetuneConfig, FinetuneResult, PretrainConfig, PretrainResult,
    TaskMetrics, World, WorldConfig,
};
