//! Experiment matrix runner: the loops behind Table 1, Figure 2 and
//! Figures 3–4, with checkpoint reuse so a pre-trained model is trained
//! once per (model, sparsity, seed) and fine-tuned many times.

use std::path::{Path, PathBuf};

use crate::coordinator::pipeline::{
    self, FinetuneConfig, PretrainConfig, TaskMetrics, World, WorldConfig,
};
use crate::data::Task;
use crate::generate::DecodeParams;
use crate::runtime::ModelRuntime;
use crate::sparsity::MaskScheme;
use crate::train::{checkpoint, TrainState};
use crate::util::json::Json;

/// One cell of the experiment matrix.
#[derive(Debug, Clone)]
pub struct RunSpec {
    pub model: String,
    pub sparsity: f64,
    pub scheme: MaskScheme,
    pub seed: u64,
    pub task: Task,
    /// dense fine-tuning (SPDF) vs sparse fine-tuning (Fig. 2 baseline)
    pub dense_ft: bool,
}

#[derive(Debug, Clone)]
pub struct RunKnobs {
    pub pretrain_steps: u64,
    pub pretrain_lr: f32,
    pub ft_epochs: usize,
    pub ft_lr: f32,
    pub eval_examples: usize,
    pub world: WorldConfig,
    pub decode: DecodeParams,
    pub run_dir: PathBuf,
}

impl Default for RunKnobs {
    fn default() -> Self {
        RunKnobs {
            pretrain_steps: 1200,
            pretrain_lr: 1e-3,
            ft_epochs: 4,
            ft_lr: 3e-4,
            eval_examples: 64,
            world: WorldConfig::default(),
            decode: DecodeParams::default(),
            run_dir: PathBuf::from("runs"),
        }
    }
}

impl RunKnobs {
    /// Per-model knob adjustments: the larger model takes a lower peak
    /// LR (paper App. Table 1: 6e-4 for Small vs 2e-4 for XL). Step
    /// budgets are set per invocation — the Chinchilla tokens/param
    /// rule and its single-core cap are documented in DESIGN.md §7 and
    /// EXPERIMENTS.md.
    pub fn for_model(&self, model: &str) -> RunKnobs {
        let mut k = self.clone();
        if model == "gpt-micro" {
            k.pretrain_lr = self.pretrain_lr * 0.6;
        }
        k
    }
}

/// Result of one matrix cell.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub spec_model: String,
    pub sparsity: f64,
    pub seed: u64,
    pub task: &'static str,
    pub dense_ft: bool,
    pub pretrain_eval_loss: f64,
    pub ft_val_loss: f64,
    pub metrics: TaskMetrics,
    pub pretrain_flops: f64,
    pub finetune_flops: f64,
}

impl RunResult {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("model", Json::Str(self.spec_model.clone()))
            .push("sparsity", Json::Num(self.sparsity))
            .push("seed", Json::Num(self.seed as f64))
            .push("task", Json::Str(self.task.to_string()))
            .push("dense_ft", Json::Bool(self.dense_ft))
            .push("pretrain_eval_loss",
                  Json::Num(self.pretrain_eval_loss))
            .push("ft_val_loss", Json::Num(self.ft_val_loss))
            .push("bleu", Json::Num(self.metrics.bleu))
            .push("nist", Json::Num(self.metrics.nist))
            .push("meteor", Json::Num(self.metrics.meteor))
            .push("rouge_l", Json::Num(self.metrics.rouge_l))
            .push("cider", Json::Num(self.metrics.cider))
            .push("ter", Json::Num(self.metrics.ter))
            .push("ppl", Json::Num(self.metrics.ppl))
            .push("n_eval", Json::Num(self.metrics.n_examples as f64))
            .push("bleu_seen",
                  self.metrics.bleu_seen.map(Json::Num)
                      .unwrap_or(Json::Null))
            .push("bleu_unseen",
                  self.metrics.bleu_unseen.map(Json::Num)
                      .unwrap_or(Json::Null))
            .push("pretrain_flops", Json::Num(self.pretrain_flops))
            .push("finetune_flops", Json::Num(self.finetune_flops));
        o
    }

    pub fn from_json(j: &Json) -> anyhow::Result<RunResult> {
        let num = |k: &str| -> f64 {
            j.get(k).and_then(|v| v.as_f64()).unwrap_or(f64::NAN)
        };
        Ok(RunResult {
            spec_model: j.req("model")?.as_str().unwrap_or("").into(),
            sparsity: num("sparsity"),
            seed: num("seed") as u64,
            task: Task::parse(j.req("task")?.as_str().unwrap_or(""))?
                .name(),
            dense_ft: j.get("dense_ft").and_then(|v| v.as_bool())
                .unwrap_or(true),
            pretrain_eval_loss: num("pretrain_eval_loss"),
            ft_val_loss: num("ft_val_loss"),
            metrics: TaskMetrics {
                bleu: num("bleu"),
                nist: num("nist"),
                meteor: num("meteor"),
                rouge_l: num("rouge_l"),
                cider: num("cider"),
                ter: num("ter"),
                ppl: num("ppl"),
                n_examples: num("n_eval") as usize,
                bleu_seen: j.get("bleu_seen").and_then(|v| v.as_f64()),
                bleu_unseen: j.get("bleu_unseen")
                    .and_then(|v| v.as_f64()),
            },
            pretrain_flops: num("pretrain_flops"),
            finetune_flops: num("finetune_flops"),
        })
    }
}

/// Checkpoint path for a pre-trained (model, sparsity, seed) cell.
pub fn pretrain_ckpt_path(dir: &Path, model: &str, sparsity: f64,
                          seed: u64) -> PathBuf {
    dir.join(format!("pretrain-{model}-s{:02.0}-seed{seed}.ckpt",
                     sparsity * 100.0))
}

/// Pre-train (or load a cached checkpoint) for one matrix cell.
pub fn pretrain_cached(
    runtime: &ModelRuntime,
    world: &World,
    knobs: &RunKnobs,
    model: &str,
    sparsity: f64,
    scheme: MaskScheme,
    seed: u64,
) -> anyhow::Result<(TrainState, f64, f64)> {
    let path = pretrain_ckpt_path(&knobs.run_dir, model, sparsity, seed);
    let loss_path = path.with_extension("loss.json");
    if path.exists() && loss_path.exists() {
        let state = checkpoint::load(&path)?;
        let j = Json::parse(&std::fs::read_to_string(&loss_path)?)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let loss = j.req("eval_loss")?.as_f64().unwrap_or(f64::NAN);
        let fl = j.req("train_flops")?.as_f64().unwrap_or(0.0);
        eprintln!("[spdf] reusing checkpoint {}", path.display());
        return Ok((state, loss, fl));
    }
    let cfg = PretrainConfig {
        sparsity,
        scheme,
        steps: knobs.pretrain_steps,
        peak_lr: knobs.pretrain_lr,
        seed,
        log_every: 200,
    };
    let res = pipeline::pretrain(runtime, world, &cfg)?;
    checkpoint::save(&res.state, &path)?;
    let mut j = Json::obj();
    j.push("eval_loss", Json::Num(res.final_eval_loss))
        .push("train_flops", Json::Num(res.train_flops));
    std::fs::write(&loss_path, j.to_string_pretty())?;
    Ok((res.state, res.final_eval_loss, res.train_flops))
}

/// Run one full matrix cell: (cached) pre-train → fine-tune → evaluate.
/// The caller owns the compiled `runtime` so artifact compilation is
/// paid once per model, not once per cell.
pub fn run_cell(
    runtime: &ModelRuntime,
    world: &World,
    knobs: &RunKnobs,
    spec: &RunSpec,
) -> anyhow::Result<RunResult> {
    anyhow::ensure!(runtime.manifest.config.name == spec.model,
                    "runtime/spec model mismatch");
    let knobs = knobs.for_model(&spec.model);
    let (state, pt_loss, pt_flops) = pretrain_cached(
        runtime, world, &knobs, &spec.model, spec.sparsity,
        spec.scheme, spec.seed)?;

    let ft_cfg = FinetuneConfig {
        task: spec.task,
        epochs: knobs.ft_epochs,
        peak_lr: knobs.ft_lr,
        dense: spec.dense_ft,
        seed: spec.seed,
        patience: 2,
        log_every: 0,
    };
    let ft = pipeline::finetune(runtime, world, state, &ft_cfg)?;
    let metrics = pipeline::evaluate_task(
        runtime, &ft.state, world, spec.task, knobs.eval_examples,
        &knobs.decode)?;
    eprintln!(
        "[spdf] cell {} s={:.0}% {} seed{} dense_ft={}: BLEU {:.2} \
         PPL {:.2}",
        spec.model, spec.sparsity * 100.0, spec.task.name(), spec.seed,
        spec.dense_ft, metrics.bleu, metrics.ppl);
    Ok(RunResult {
        spec_model: spec.model.clone(),
        sparsity: spec.sparsity,
        seed: spec.seed,
        task: spec.task.name(),
        dense_ft: spec.dense_ft,
        pretrain_eval_loss: pt_loss,
        ft_val_loss: ft.best_val_loss,
        metrics,
        pretrain_flops: pt_flops,
        finetune_flops: ft.train_flops,
    })
}

/// Append a result to the results ledger (JSON lines).
pub fn append_result(dir: &Path, r: &RunResult) -> anyhow::Result<()> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join("results.jsonl");
    let mut line = r.to_json().to_string();
    line.push('\n');
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)?;
    f.write_all(line.as_bytes())?;
    Ok(())
}

/// Load all results from the ledger.
pub fn load_results(dir: &Path) -> anyhow::Result<Vec<RunResult>> {
    let path = dir.join("results.jsonl");
    if !path.exists() {
        return Ok(Vec::new());
    }
    let text = std::fs::read_to_string(path)?;
    let mut out = Vec::new();
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("ledger line: {e}"))?;
        out.push(RunResult::from_json(&j)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_result() -> RunResult {
        RunResult {
            spec_model: "gpt-nano".into(),
            sparsity: 0.75,
            seed: 3,
            task: "e2e",
            dense_ft: true,
            pretrain_eval_loss: 2.5,
            ft_val_loss: 1.2,
            metrics: TaskMetrics {
                bleu: 42.0, nist: 5.0, meteor: 0.4, rouge_l: 60.0,
                cider: 3.1, ter: 0.5, ppl: 3.3, n_examples: 64,
                bleu_seen: None, bleu_unseen: None,
            },
            pretrain_flops: 1e15,
            finetune_flops: 2e13,
        }
    }

    #[test]
    fn result_json_round_trip() {
        let r = sample_result();
        let r2 = RunResult::from_json(&r.to_json()).unwrap();
        assert_eq!(r2.spec_model, "gpt-nano");
        assert_eq!(r2.sparsity, 0.75);
        assert_eq!(r2.metrics.bleu, 42.0);
        assert_eq!(r2.dense_ft, true);
        assert_eq!(r2.task, "e2e");
    }

    #[test]
    fn ledger_append_and_load() {
        let dir = std::env::temp_dir().join(format!(
            "spdf-ledger-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::remove_file(dir.join("results.jsonl")).ok();
        append_result(&dir, &sample_result()).unwrap();
        append_result(&dir, &sample_result()).unwrap();
        let rs = load_results(&dir).unwrap();
        assert_eq!(rs.len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ckpt_path_encodes_cell() {
        let p = pretrain_ckpt_path(Path::new("runs"), "gpt-nano",
                                   0.75, 2);
        assert_eq!(p.to_str().unwrap(),
                   "runs/pretrain-gpt-nano-s75-seed2.ckpt");
    }

    #[test]
    fn knobs_scale_for_micro() {
        let k = RunKnobs::default();
        let km = k.for_model("gpt-micro");
        assert!(km.pretrain_lr < k.pretrain_lr);
        assert_eq!(km.pretrain_steps, k.pretrain_steps);
        let kn = k.for_model("gpt-nano");
        assert_eq!(kn.pretrain_lr, k.pretrain_lr);
    }
}
